"""Continuous-batching scheduler: dynamic join/leave over one shared
device, KV-capacity-aware admission, retirement teardown, and the
differential guarantee (per-request tokens bit-identical to solo runs)."""

import numpy as np
import pytest

from repro.core import synth
from repro.core.tier import KV, ReadReq, WriteReq, make_device
from repro.runtime import (
    ServeEngine, ServeRequest, ServeScheduler, projected_kv_bytes,
)
from repro.runtime.paging import LOSSLESS_POLICY


# ---------------------------------------------------------------------------
# fast (no model): arrival traces + tier namespace teardown
# ---------------------------------------------------------------------------

def test_poisson_arrivals_shape_and_rate():
    t = synth.poisson_arrivals(2000, rate=0.5, seed=1)
    assert t.shape == (2000,) and np.all(np.diff(t) >= 0)
    # mean inter-arrival ~ 1/rate
    assert abs(np.diff(t).mean() - 2.0) < 0.2
    with pytest.raises(ValueError):
        synth.poisson_arrivals(4, rate=0.0)


def test_bursty_arrivals_clump_and_match_rate():
    t = synth.bursty_arrivals(2000, rate=0.5, burst=4, seed=2)
    assert t.shape == (2000,) and np.all(np.diff(t) >= 0)
    # members of a burst share an arrival time: 3 of every 4 gaps are zero
    assert (np.diff(t) == 0).mean() > 0.6
    # mean offered load still ~ rate
    assert abs(t[-1] / 2000 - 2.0) < 0.3


def test_request_trace_fields():
    tr = synth.request_trace(6, vocab=128, rate=1.0, kind="bursty",
                             prompt_len=16, new_tokens=4, seed=3)
    assert len(tr) == 6
    for r in tr:
        assert r["prompt"].shape == (1, 16)
        assert r["prompt"].dtype == np.int32
        assert 0 <= r["prompt"].min() and r["prompt"].max() < 128
        assert r["max_new_tokens"] == 4
    assert [r["arrival"] for r in tr] == sorted(r["arrival"] for r in tr)
    with pytest.raises(ValueError):
        synth.request_trace(2, 128, kind="uniform")


def test_delete_prefix_frees_namespace_only():
    # shards=1: asserts against one device's _index LRU
    dev = make_device("trace", kv_window=16, shards=1)
    dev.submit([
        WriteReq(f"r0.p{i}", synth.kv_cache(16, 64, seed=i), kind=KV)
        for i in range(3)
    ] + [WriteReq("r1.p0", synth.kv_cache(16, 64, seed=9), kind=KV)])
    survivor = dev.submit([ReadReq("r1.p0", kind=KV)])[0].data
    assert dev.delete_prefix("r0.") == 3
    # r0 namespace gone: keys, staging, index entries
    for i in range(3):
        with pytest.raises(KeyError):
            dev.submit([ReadReq(f"r0.p{i}", kind=KV)])
        assert dev.n_blocks(f"r0.p{i}") == 0
    assert not any(k[0].startswith("r0.") for k in dev._index._lru)
    # survivor is untouched, stored capacity now equals its footprint
    np.testing.assert_array_equal(
        dev.submit([ReadReq("r1.p0", kind=KV)])[0].data, survivor)
    assert dev.stats.dram_bytes_stored == dev.footprint("r1.p0")
    assert dev.delete_prefix("r1.") == 1
    assert dev.stats.dram_bytes_stored == 0 and dev.stats.blocks == 0


def test_delete_prefix_flushes_queued_reads_first():
    dev = make_device("trace", kv_window=16, window=64)
    dev.submit([WriteReq("r0.p", synth.kv_cache(16, 64, seed=0), kind=KV),
                WriteReq("r1.p", synth.kv_cache(16, 64, seed=1), kind=KV)])
    ticket = dev.submit_async([ReadReq("r1.p", kind=KV)])[0]
    assert not ticket.done
    dev.delete_prefix("r0.")          # must not orphan r1's queued read
    assert ticket.done
    assert ticket.wait().data is not None


# ---------------------------------------------------------------------------
# model-backed scheduler behavior
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_pair(smoke_model):
    return smoke_model("qwen2-0.5b")


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("device_kind", "trace")
    kw.setdefault("policy", LOSSLESS_POLICY)
    kw.setdefault("page_tokens", 16)
    kw.setdefault("hbm_kv_budget", 1 << 12)
    return ServeScheduler(cfg, params, **kw)


def _reqs(cfg, n, arrivals, prompt_len=32, new=5):
    rng = np.random.default_rng(11)
    return [
        ServeRequest(
            req_id=i, arrival=float(arrivals[i]),
            prompt=rng.integers(0, cfg.vocab, (1, prompt_len)).astype(
                np.int32),
            max_new_tokens=new, seed=100 + i,
        )
        for i in range(n)
    ]


@pytest.mark.slow
def test_continuous_batching_differential(engine_pair):
    """The acceptance invariant: dynamic join/leave + capacity-limited
    admission must not change one token vs solo runs of the same
    requests (same seed, same max_seq)."""
    cfg, params = engine_pair
    proj = projected_kv_bytes(cfg, 1, 32 + 5, 16)
    sched = _sched(cfg, params, max_batch=2,
                   kv_capacity_bytes=2 * proj)   # both slots usable, barely
    reqs = _reqs(cfg, 5, arrivals=[0.0, 0.5, 1.0, 6.0, 6.0])
    rep = sched.run(reqs)
    assert len(rep.records) == 5
    # dynamic membership actually happened: some request waited
    assert any(r.admit_step > int(np.ceil(r.arrival)) for r in rep.records)
    for req, rec in zip(reqs, rep.records):
        solo = ServeEngine(
            cfg, params, max_seq=sched._max_seq, batch=1, page_tokens=16,
            hbm_kv_budget=1 << 12, device_kind="trace",
            policy=LOSSLESS_POLICY,
        ).generate(req.prompt, req.max_new_tokens, seed=req.seed)
        np.testing.assert_array_equal(solo, rec.tokens)


@pytest.mark.slow
def test_admission_blocked_by_kv_capacity(engine_pair):
    """Capacity for ~1 request: admission must serialize even though a
    second batch slot is free the whole time."""
    cfg, params = engine_pair
    proj = projected_kv_bytes(cfg, 1, 32 + 5, 16)
    assert proj > 0
    sched = _sched(cfg, params, max_batch=2,
                   kv_capacity_bytes=int(1.5 * proj))
    reqs = _reqs(cfg, 3, arrivals=[0.0, 0.0, 0.0])
    max_active = 0
    sched.submit(reqs)
    while sched.step():
        max_active = max(max_active, sched.n_active)
        assert sched.kv_committed_bytes <= int(1.5 * proj)
    assert max_active == 1
    rep = sched.report()
    # each admission waited for the previous retirement
    admits = [r.admit_step for r in rep.records]
    finishes = [r.finish_step for r in rep.records]
    assert admits[1] > finishes[0] and admits[2] > finishes[1]
    assert rep.records[1].queue_delay_s > 0


@pytest.mark.slow
def test_oversized_request_admits_into_empty_batch(engine_pair):
    """A request larger than the whole capacity must still run (alone)
    rather than deadlock the FIFO."""
    cfg, params = engine_pair
    sched = _sched(cfg, params, kv_capacity_bytes=1)   # < any projection
    rep = sched.run(_reqs(cfg, 2, arrivals=[0.0, 0.0]))
    assert len(rep.records) == 2
    assert rep.records[1].admit_step > rep.records[0].finish_step


@pytest.mark.slow
def test_retirement_frees_pages_and_tier_keys(engine_pair):
    """No key leaks: after the run the shared device holds zero blocks,
    zero stored bytes, no staging, no index entries, and the scheduler's
    committed-capacity counter is back to zero."""
    cfg, params = engine_pair
    sched = _sched(cfg, params)
    sched.run(_reqs(cfg, 3, arrivals=[0.0, 0.0, 1.0]))
    d = sched.device_stats()
    assert d.dram_bytes_stored == 0
    assert d.raw_bytes_stored == 0
    assert d.blocks == 0
    dev = sched.device
    assert not dev._tensors and not dev._kv_staging and not dev._kv_channels
    assert not dev._index._lru
    assert sched.kv_committed_bytes == 0
    assert all(s is None for s in sched.active) and not sched.pending


@pytest.mark.slow
def test_empty_batch_idle_steps(engine_pair):
    """A late-arriving trace forces idle ticks: the clock and modeled
    time advance with zero active sequences, then the request runs."""
    cfg, params = engine_pair
    sched = _sched(cfg, params)
    sched.submit(_reqs(cfg, 1, arrivals=[4.7]))
    for _ in range(4):          # steps 0..3: nothing has arrived
        assert sched.step()
        assert sched.n_active == 0
    t_idle = sched.model_time_s
    assert sched.clock == 4 and t_idle > 0
    while sched.step():
        pass
    rep = sched.report()
    assert rep.records[0].admit_step == 5   # first tick with clock >= 4.7
    assert rep.records[0].queue_delay_s == 0.0
    assert rep.model_time_s > t_idle


@pytest.mark.slow
def test_single_request_degenerate(engine_pair):
    """One request == a solo engine run, and the report is coherent."""
    cfg, params = engine_pair
    sched = _sched(cfg, params, max_batch=4)
    req = _reqs(cfg, 1, arrivals=[0.0], new=6)[0]
    rep = sched.run([req])
    solo = ServeEngine(
        cfg, params, max_seq=sched._max_seq, batch=1, page_tokens=16,
        hbm_kv_budget=1 << 12, device_kind="trace", policy=LOSSLESS_POLICY,
    ).generate(req.prompt, req.max_new_tokens, seed=req.seed)
    np.testing.assert_array_equal(solo, rep.records[0].tokens)
    assert rep.decode_tokens == 6
    assert rep.p50_latency_s == rep.p99_latency_s == rep.records[0].latency_s
    assert rep.mean_queue_delay_s == 0.0
    assert rep.tok_s > 0 and rep.model_time_s > 0


def test_scheduler_report_empty():
    """Report before any work: no records, no NaN crashes."""
    from repro.runtime.serving import SchedulerReport

    rep = SchedulerReport(records=[], steps=0, model_time_s=0.0,
                          decode_tokens=0, prefill_tokens=0)
    assert rep.tok_s == 0.0
    assert np.isnan(rep.p50_latency_s) and np.isnan(rep.mean_queue_delay_s)


def test_capacity_model_validated():
    with pytest.raises(ValueError):
        ServeScheduler(None, None, capacity_model="psychic")


# ---------------------------------------------------------------------------
# ratio-aware admission + precision-elastic reclamation + TTFT/TPOT
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_physical_model_admits_larger_batch(engine_pair):
    """The tentpole claim: at a fixed kv_capacity_bytes on the trace
    device, ledger/ratio-aware admission overlaps requests the logical
    projection would serialize — larger peak batch, more tok/s — and
    with the degrade ladder disabled every request's tokens stay
    bit-identical to a solo run."""
    cfg, params = engine_pair
    proj = projected_kv_bytes(cfg, 1, 32 + 5, 16)
    cap = int(1.7 * proj)        # logical: only 1 fits; physical: ≥ 2
    reps = {}
    for model in ("logical", "physical"):
        sched = _sched(cfg, params, max_batch=3, kv_capacity_bytes=cap,
                       capacity_model=model)
        reps[model] = sched.run(_reqs(cfg, 3, arrivals=[0.0, 0.0, 0.0]))
    assert reps["logical"].peak_active == 1
    assert reps["physical"].peak_active > reps["logical"].peak_active
    assert reps["physical"].tok_s > reps["logical"].tok_s
    assert reps["physical"].kv_ratio_estimate > 1.0
    assert reps["physical"].reclaimed_bytes == 0      # no ladder configured
    # differential holds under the more aggressive membership
    for req, rec in zip(_reqs(cfg, 3, arrivals=[0.0, 0.0, 0.0]),
                        reps["physical"].records):
        solo = ServeEngine(
            cfg, params, max_seq=sched._max_seq, batch=1, page_tokens=16,
            hbm_kv_budget=1 << 12, device_kind="trace",
            policy=LOSSLESS_POLICY,
        ).generate(req.prompt, req.max_new_tokens, seed=req.seed)
        np.testing.assert_array_equal(solo, rec.tokens)


@pytest.mark.slow
def test_degrade_ladder_reclaims_before_stalling(engine_pair):
    """With the ladder on, a blocked head-of-line request sheds cold
    stored planes (TierStore.truncate_planes) instead of waiting for a
    retirement; the reclaimed bytes show up in the report and the run
    still drains cleanly."""
    from repro.runtime.paging import DEFAULT_DEGRADE_LADDER

    cfg, params = engine_pair
    proj = projected_kv_bytes(cfg, 1, 32 + 5, 16)
    tight = _sched(cfg, params, max_batch=3,
                   kv_capacity_bytes=int(1.5 * proj),
                   capacity_model="physical")
    rep_tight = tight.run(_reqs(cfg, 3, arrivals=[0.0, 0.0, 0.0]))
    ladder = _sched(cfg, params, max_batch=3,
                    kv_capacity_bytes=int(1.5 * proj),
                    capacity_model="physical",
                    degrade_ladder=DEFAULT_DEGRADE_LADDER)
    rep = ladder.run(_reqs(cfg, 3, arrivals=[0.0, 0.0, 0.0]))
    assert rep.reclaimed_bytes > 0
    assert rep.peak_active >= rep_tight.peak_active
    assert len(rep.records) == 3 and all(r.finished for r in rep.records)
    d = ladder.device_stats()
    assert d.dram_bytes_stored == 0 and d.blocks == 0
    assert ladder.device.resident_bytes() == 0


@pytest.mark.slow
def test_ttft_tpot_split(engine_pair):
    """Latency decomposes: queue wait ≤ TTFT ≤ total latency, and
    TTFT + (n-1)·TPOT reconstructs the finish stamp exactly."""
    cfg, params = engine_pair
    sched = _sched(cfg, params, max_batch=1)   # forced queueing
    rep = sched.run(_reqs(cfg, 3, arrivals=[0.0, 0.0, 0.0], new=4))
    assert np.isfinite(rep.p50_ttft_s) and np.isfinite(rep.p99_ttft_s)
    assert rep.mean_tpot_s > 0
    for r in rep.records:
        assert 0 <= r.queue_delay_s <= r.ttft_s <= r.latency_s
        assert r.first_token_step >= r.admit_step
        n = r.tokens.shape[1]
        assert r.ttft_s + (n - 1) * r.tpot_s == pytest.approx(r.latency_s)
    # queued requests pay their wait in TTFT, not TPOT
    assert rep.records[2].ttft_s > rep.records[0].ttft_s
    assert rep.p99_ttft_s >= rep.p50_ttft_s


@pytest.mark.slow
def test_single_token_request_tpot_nan(engine_pair):
    """One generated token has no inter-token gap: tpot_s is the
    explicit NaN, TTFT equals total latency, and the report's mean
    excludes it rather than crashing."""
    cfg, params = engine_pair
    sched = _sched(cfg, params)
    rep = sched.run(_reqs(cfg, 1, arrivals=[0.0], new=1))
    r = rep.records[0]
    assert np.isnan(r.tpot_s)
    assert r.ttft_s == pytest.approx(r.latency_s)
    assert np.isnan(rep.mean_tpot_s)


# ---------------------------------------------------------------------------
# SLO attainment (fast, no model): NaN-safe report arithmetic
# ---------------------------------------------------------------------------

def _slo_rec(req_id, ttft, tpot, ntok=4, finished=True):
    from repro.runtime import RequestRecord

    r = RequestRecord(req_id=req_id, arrival=0.0)
    r.t_arrive_s = 0.0
    r.t_first_token_s = ttft
    r.t_finish_s = ttft + tpot * (ntok - 1)
    if finished:
        r.tokens = np.zeros((1, ntok), np.int32)
    return r


def _slo_report(records, **slo):
    from repro.runtime import SchedulerReport

    return SchedulerReport(records=records, steps=1, model_time_s=1.0,
                           decode_tokens=1, prefill_tokens=1, **slo)


def test_slo_attainment_nan_when_unconfigured_or_empty():
    recs = [_slo_rec(0, ttft=0.1, tpot=0.01)]
    assert np.isnan(_slo_report(recs).slo_attainment)
    assert np.isnan(_slo_report([], slo_ttft_s=1.0).slo_attainment)
    unfinished = [_slo_rec(0, ttft=0.1, tpot=0.01, finished=False)]
    assert np.isnan(_slo_report(unfinished, slo_ttft_s=1.0).slo_attainment)


def test_slo_attainment_fraction_meeting_both():
    recs = [
        _slo_rec(0, ttft=0.10, tpot=0.01),   # meets both
        _slo_rec(1, ttft=0.90, tpot=0.01),   # misses TTFT
        _slo_rec(2, ttft=0.10, tpot=0.20),   # misses TPOT
    ]
    rep = _slo_report(recs, slo_ttft_s=0.5, slo_tpot_s=0.05)
    assert rep.slo_attainment == pytest.approx(1 / 3)
    # an unset target is vacuously met: TTFT-only counts record 2 back in
    assert _slo_report(recs, slo_ttft_s=0.5).slo_attainment == \
        pytest.approx(2 / 3)


def test_slo_attainment_single_token_tpot_nan_never_violates():
    """A single-token request has tpot_s == NaN: under a TPOT SLO it can
    only miss on TTFT (NaN is not a violation), mirroring mean_tpot_s's
    exclusion semantics."""
    solo = _slo_rec(0, ttft=0.1, tpot=0.0, ntok=1)
    assert np.isnan(solo.tpot_s)
    rep = _slo_report([solo], slo_ttft_s=0.5, slo_tpot_s=1e-9)
    assert rep.slo_attainment == 1.0
    assert _slo_report([solo], slo_ttft_s=0.01,
                       slo_tpot_s=1e-9).slo_attainment == 0.0


@pytest.mark.slow
def test_slo_attainment_end_to_end(engine_pair):
    """Scheduler plumbs the targets through to the report: generous
    SLOs attain 1.0, impossible ones attain 0.0, same workload."""
    cfg, params = engine_pair
    reqs = _reqs(cfg, 2, arrivals=[0.0, 0.0], new=3)
    rep = _sched(cfg, params, slo_ttft_s=1e6, slo_tpot_s=1e6).run(reqs)
    assert rep.slo_attainment == 1.0
    rep = _sched(cfg, params, slo_ttft_s=0.0).run(
        _reqs(cfg, 2, arrivals=[0.0, 0.0], new=3))
    assert rep.slo_attainment == 0.0
