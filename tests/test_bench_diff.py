"""tools/bench_diff: the benchmark regression gate's own behavior.

The gate replaced inline CI thresholds, so it needs its own negative
tests: absolute floors fire regardless of baseline, timing rows get the
wide band with unit-inferred direction, structural rows the tight band,
row-set drift (vanished/unbaselined) fails, track-only rows never gate,
and ``--update`` seeds baselines but still refuses floor-violating runs.
"""

import json
import os

from tools.bench_diff import main


def _write(dirpath, module, rows):
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, f"BENCH_{module}.json")
    with open(path, "w") as f:
        json.dump({"rows": [
            {"table": module, "name": n, "value": v, "unit": u, "note": ""}
            for n, v, u in rows
        ]}, f)
    return path


def _dirs(tmp_path):
    return str(tmp_path / "fresh"), str(tmp_path / "base")


def _run(fresh, base, *extra):
    return main(["--fresh", fresh, "--baseline", base, *extra])


def test_identical_rows_pass(tmp_path):
    fresh, base = _dirs(tmp_path)
    rows = [("encode_ms", 12.0, "ms"), ("stored_bytes", 4096, "bytes")]
    _write(fresh, "m", rows)
    _write(base, "m", rows)
    assert _run(fresh, base) == 0


def test_floor_fires_even_when_baseline_agrees(tmp_path):
    """lz4_kernel_speedup < 2.0 fails even if the committed baseline is
    just as bad — floors are PR acceptance, not drift detection."""
    fresh, base = _dirs(tmp_path)
    rows = [("lz4_kernel_speedup", 1.5, "x")]
    _write(fresh, "m", rows)
    _write(base, "m", rows)
    assert _run(fresh, base) == 1


def test_timing_band_direction_follows_unit(tmp_path):
    fresh, base = _dirs(tmp_path)
    _write(base, "m", [("step_ms", 10.0, "ms"), ("rate", 100.0, "tok/s")])
    # 4x slower time fails; 4x lower rate fails
    _write(fresh, "m", [("step_ms", 40.0, "ms"), ("rate", 100.0, "tok/s")])
    assert _run(fresh, base) == 1
    _write(fresh, "m", [("step_ms", 10.0, "ms"), ("rate", 20.0, "tok/s")])
    assert _run(fresh, base) == 1
    # within the 3x band (even 2x worse) passes; improvement passes too
    _write(fresh, "m", [("step_ms", 20.0, "ms"), ("rate", 900.0, "tok/s")])
    assert _run(fresh, base) == 0


def test_structural_rows_get_tight_band(tmp_path):
    fresh, base = _dirs(tmp_path)
    _write(base, "m", [("stored_bytes", 1000, "bytes")])
    _write(fresh, "m", [("stored_bytes", 1050, "bytes")])   # 5% drift
    assert _run(fresh, base) == 1
    _write(fresh, "m", [("stored_bytes", 1010, "bytes")])   # within 2%
    assert _run(fresh, base) == 0


def test_row_set_drift_fails_both_ways(tmp_path):
    fresh, base = _dirs(tmp_path)
    _write(base, "m", [("a", 1.0, "ms"), ("b", 2.0, "ms")])
    _write(fresh, "m", [("a", 1.0, "ms"), ("c", 3.0, "ms")])
    assert _run(fresh, base) == 1   # b vanished AND c unbaselined


def test_track_only_suffix_never_gates(tmp_path):
    fresh, base = _dirs(tmp_path)
    _write(base, "m", [("prefill_wall_ms", 5.0, "ms")])
    _write(fresh, "m", [("prefill_wall_ms", 500.0, "ms")])
    assert _run(fresh, base) == 0


def test_update_seeds_baseline_but_enforces_floors(tmp_path):
    fresh, base = _dirs(tmp_path)
    _write(fresh, "m", [("encode_ms", 12.0, "ms"),
                        ("lz4_kernel_speedup", 2.3, "x")])
    assert _run(fresh, base, "--update") == 0
    assert _run(fresh, base) == 0       # seeded baseline now gates cleanly
    # a floor-violating run must not become the new baseline
    _write(fresh, "bad", [("lz4_kernel_speedup", 1.2, "x")])
    assert _run(fresh, base, "--update") == 1
    assert not os.path.exists(os.path.join(base, "BENCH_bad.json"))


def test_missing_baseline_file_fails(tmp_path):
    fresh, base = _dirs(tmp_path)
    _write(fresh, "m", [("a", 1.0, "ms")])
    assert _run(fresh, base) == 1
