"""tracecheck: every rule fires on a violating fixture (negative tests),
pragmas suppress, toggles work, and the repo's own tree is clean.

Fixtures are written to ``tmp_path`` with ``tmp_path`` as the repo root,
so relative-path logic (R1 ownership, R2/R3 sanctioned-file exemption)
is exercised without depending on the live tree's layout.
"""

from pathlib import Path

from tools.tracecheck import ALL_RULES, ProjectIndex, run_paths
from tools.tracecheck.rules_flow import (
    R4AsyncDiscipline, R5BroadExcept, R6JitPurity,
)
from tools.tracecheck.rules_privacy import (
    R1PrivateAccess, R2IsinstanceDispatch, R3AccountingMutation,
)

REPO_ROOT = Path(__file__).resolve().parents[1]


def _index():
    """A small hand-built cross-file index (no live-tree scan)."""
    idx = ProjectIndex()
    idx.private_attrs = {
        "_ledger": {"src/repro/core/tier.py"},
        "_max_seq": {"src/repro/runtime/serving.py"},
    }
    idx.accounting_fields = {"dram_bytes_stored", "dram_bytes_read",
                             "blocks", "stored_bytes"}
    return idx


def _lint(tmp_path, source, rules, name="mod.py", index=None):
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(source)
    return run_paths([str(f)], rules, index=index or _index(),
                     repo_root=tmp_path)


# ---------------------------------------------------------------------------
# R1 — private attribute access
# ---------------------------------------------------------------------------

def test_r1_fires_on_foreign_private_access(tmp_path):
    diags = _lint(tmp_path, "x = sched._max_seq\n", [R1PrivateAccess()])
    assert [d.rule for d in diags] == ["R1"]
    assert "_max_seq" in diags[0].message
    assert diags[0].line == 1


def test_r1_allows_self_and_own_module(tmp_path):
    src = (
        "class Pool:\n"
        "    def __init__(self):\n"
        "        self._ledger = {}\n"
        "    def peek(self, other):\n"
        "        return other._ledger\n"   # same class → own private attr
    )
    assert _lint(tmp_path, src, [R1PrivateAccess()]) == []


def test_r1_allows_defining_module(tmp_path):
    diags = _lint(tmp_path, "x = store._ledger\n", [R1PrivateAccess()],
                  name="src/repro/core/tier.py")
    assert diags == []


def test_r1_pragma_suppresses(tmp_path):
    src = "x = sched._max_seq  # tracecheck: disable=R1\n"
    assert _lint(tmp_path, src, [R1PrivateAccess()]) == []


# ---------------------------------------------------------------------------
# R2 — isinstance dispatch on tier subtypes
# ---------------------------------------------------------------------------

def test_r2_fires_outside_tier(tmp_path):
    src = (
        "def f(dev):\n"
        "    if isinstance(dev, TraceDevice):\n"
        "        return 1\n"
    )
    diags = _lint(tmp_path, src, [R2IsinstanceDispatch()])
    assert [d.rule for d in diags] == ["R2"]
    assert "TraceDevice" in diags[0].message


def test_r2_tuple_and_attribute_forms(tmp_path):
    src = "ok = isinstance(x, (tier.WordLayout, int))\n"
    diags = _lint(tmp_path, src, [R2IsinstanceDispatch()])
    assert len(diags) == 1 and "WordLayout" in diags[0].message


def test_r2_sanctioned_in_tier(tmp_path):
    src = "y = isinstance(x, BitplaneLayout)\n"
    assert _lint(tmp_path, src, [R2IsinstanceDispatch()],
                 name="src/repro/core/tier.py") == []


def test_r2_unrelated_isinstance_clean(tmp_path):
    assert _lint(tmp_path, "y = isinstance(x, dict)\n",
                 [R2IsinstanceDispatch()]) == []


# ---------------------------------------------------------------------------
# R3 — accounting-field mutation outside the sanctioned helpers
# ---------------------------------------------------------------------------

def test_r3_fires_on_direct_mutation(tmp_path):
    src = "dev.stats.dram_bytes_stored += 100\n"
    diags = _lint(tmp_path, src, [R3AccountingMutation()])
    assert [d.rule for d in diags] == ["R3"]
    assert "dram_bytes_stored" in diags[0].message


def test_r3_plain_assign_also_fires(tmp_path):
    src = "rec.blocks = 0\n"
    diags = _lint(tmp_path, src, [R3AccountingMutation()])
    assert [d.rule for d in diags] == ["R3"]


def test_r3_exempt_in_tier_and_reads_clean(tmp_path):
    assert _lint(tmp_path, "self.stats.blocks += n\n",
                 [R3AccountingMutation()],
                 name="src/repro/core/tier.py") == []
    assert _lint(tmp_path, "total = dev.stats.blocks\n",
                 [R3AccountingMutation()]) == []


# ---------------------------------------------------------------------------
# R4 — submit_async must reach a wait on all paths
# ---------------------------------------------------------------------------

def test_r4_fires_on_dropped_tickets(tmp_path):
    src = (
        "def leak(dev, reqs):\n"
        "    tickets = dev.submit_async(reqs)\n"
        "    return None\n"
    )
    diags = _lint(tmp_path, src, [R4AsyncDiscipline()])
    assert [d.rule for d in diags] == ["R4"]
    assert "leak" in diags[0].message


def test_r4_fires_on_one_unwaited_branch(tmp_path):
    src = (
        "def maybe(dev, reqs, flag):\n"
        "    tickets = dev.submit_async(reqs)\n"
        "    if flag:\n"
        "        return [t.wait() for t in tickets]\n"
        "    return None\n"                       # tickets dropped here
    )
    diags = _lint(tmp_path, src, [R4AsyncDiscipline()])
    assert [d.rule for d in diags] == ["R4"]


def test_r4_clean_when_waited(tmp_path):
    src = (
        "def ok(dev, reqs):\n"
        "    tickets = dev.submit_async(reqs)\n"
        "    return [t.wait() for t in tickets]\n"
    )
    assert _lint(tmp_path, src, [R4AsyncDiscipline()]) == []


def test_r4_clean_when_escaping(tmp_path):
    # returned, stored on self, or handed to another call: the receiver
    # owns the wait now (the paging-pool idioms)
    src = (
        "def hand_back(dev, reqs):\n"
        "    return dev.submit_async(reqs)\n"
        "def stash(self, dev, reqs):\n"
        "    self._prefetched['k'] = dev.submit_async(reqs)\n"
        "def pass_on(self, dev, reqs):\n"
        "    ts = dev.submit_async(reqs)\n"
        "    self._account(ts)\n"
    )
    assert _lint(tmp_path, src, [R4AsyncDiscipline()]) == []


def test_r4_clean_on_quiesce(tmp_path):
    src = (
        "def drain_all(dev, reqs):\n"
        "    dev.submit_async(reqs)\n"
        "    dev.quiesce()\n"
    )
    assert _lint(tmp_path, src, [R4AsyncDiscipline()]) == []


# ---------------------------------------------------------------------------
# R5 — broad excepts need a reasoned pragma
# ---------------------------------------------------------------------------

def test_r5_fires_on_broad_except(tmp_path):
    src = (
        "try:\n"
        "    risky()\n"
        "except Exception:\n"
        "    pass\n"
    )
    diags = _lint(tmp_path, src, [R5BroadExcept()])
    assert [d.rule for d in diags] == ["R5"]


def test_r5_fires_on_bare_except(tmp_path):
    src = "try:\n    risky()\nexcept:\n    pass\n"
    diags = _lint(tmp_path, src, [R5BroadExcept()])
    assert [d.rule for d in diags] == ["R5"]


def test_r5_pragma_with_reason_allows(tmp_path):
    src = (
        "try:\n"
        "    risky()\n"
        "# tracecheck: allow-broad-except(third-party raises anything)\n"
        "except Exception:\n"
        "    pass\n"
    )
    assert _lint(tmp_path, src, [R5BroadExcept()]) == []


def test_r5_empty_reason_still_fires(tmp_path):
    src = (
        "try:\n"
        "    risky()\n"
        "# tracecheck: allow-broad-except()\n"
        "except Exception:\n"
        "    pass\n"
    )
    assert len(_lint(tmp_path, src, [R5BroadExcept()])) == 1


def test_r5_reraise_exempt_and_narrow_clean(tmp_path):
    src = (
        "try:\n"
        "    risky()\n"
        "except Exception:\n"
        "    cleanup()\n"
        "    raise\n"
        "try:\n"
        "    risky()\n"
        "except ValueError:\n"
        "    pass\n"
    )
    assert _lint(tmp_path, src, [R5BroadExcept()]) == []


# ---------------------------------------------------------------------------
# R6 — host-sync / RNG inside traced bodies
# ---------------------------------------------------------------------------

def test_r6_fires_on_host_sync_in_jit(tmp_path):
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return x.item()\n"
    )
    diags = _lint(tmp_path, src, [R6JitPurity()])
    assert [d.rule for d in diags] == ["R6"]
    assert ".item()" in diags[0].message


def test_r6_fires_on_np_random_in_pallas_kernel(tmp_path):
    src = (
        "import numpy as np\n"
        "from jax.experimental import pallas as pl\n"
        "def _kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...] + np.random.rand()\n"
        "def launch(x):\n"
        "    return pl.pallas_call(_kern, out_shape=None)(x)\n"
    )
    diags = _lint(tmp_path, src, [R6JitPurity()])
    assert [d.rule for d in diags] == ["R6"]
    assert "np.random" in diags[0].message


def test_r6_fires_on_module_level_jit_wrap(tmp_path):
    src = (
        "import jax, numpy as np\n"
        "def step(x):\n"
        "    return np.asarray(x)\n"
        "fast_step = jax.jit(step)\n"
    )
    diags = _lint(tmp_path, src, [R6JitPurity()])
    assert [d.rule for d in diags] == ["R6"]


def test_r6_untraced_function_clean(tmp_path):
    src = (
        "import numpy as np\n"
        "def host_side(x):\n"
        "    return np.asarray(x).item()\n"
    )
    assert _lint(tmp_path, src, [R6JitPurity()]) == []


# ---------------------------------------------------------------------------
# runner plumbing
# ---------------------------------------------------------------------------

def test_rules_are_individually_toggleable(tmp_path):
    src = (
        "x = sched._max_seq\n"
        "ok = isinstance(d, TierStore)\n"
    )
    both = _lint(tmp_path, src, [R1PrivateAccess(), R2IsinstanceDispatch()])
    assert sorted(d.rule for d in both) == ["R1", "R2"]
    only_r2 = _lint(tmp_path, src, [R2IsinstanceDispatch()])
    assert [d.rule for d in only_r2] == ["R2"]


def test_diagnostic_format_is_file_line_col_rule(tmp_path):
    diags = _lint(tmp_path, "x = sched._max_seq\n", [R1PrivateAccess()])
    text = diags[0].format()
    assert text.startswith("mod.py:1:") and " R1 " in text


def test_syntax_error_reported_not_crashed(tmp_path):
    diags = _lint(tmp_path, "def broken(:\n", [R1PrivateAccess()])
    assert [d.rule for d in diags] == ["E0"]


def test_cli_exit_codes(tmp_path, capsys):
    from tools.tracecheck.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    f()\nexcept Exception:\n    pass\n")
    assert main([str(bad), "--select", "R5"]) == 1
    assert main([str(bad), "--select", "R5", "--disable", "R5"]) == 0
    out = capsys.readouterr().out
    assert "R5" in out and "[tracecheck] OK" in out


def test_repo_tree_is_clean():
    """The acceptance gate, in-process: the live tree lints clean."""
    diags = run_paths(
        [str(REPO_ROOT / p) for p in ("src", "benchmarks", "examples")],
        [cls() for cls in ALL_RULES],
    )
    assert diags == [], "\n".join(d.format() for d in diags)
