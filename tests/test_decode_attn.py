"""fp8-KV decode attention kernel vs jnp oracle (shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention
from repro.kernels.ref import decode_attention_ref


@pytest.mark.parametrize("B,H,KV,hd,S,valid", [
    (1, 8, 2, 64, 256, 200),
    (2, 4, 4, 128, 512, 512),
    (2, 16, 2, 64, 1024, 700),
])
@pytest.mark.parametrize("kv_dtype", [jnp.bfloat16, jnp.float8_e4m3fn])
def test_decode_attention_matches_oracle(B, H, KV, hd, S, valid, kv_dtype):
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(kk, (B, S, KV, hd), jnp.bfloat16).astype(kv_dtype)
    v = jax.random.normal(kv_, (B, S, KV, hd), jnp.bfloat16).astype(kv_dtype)
    out = decode_attention(q, k, v, valid_len=valid, interpret=True)
    ref = decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3
    )


def test_decode_attention_blocks_dont_matter():
    """Result must be independent of the key-block tiling."""
    from repro.kernels.decode_attn import decode_attention_pallas

    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (1, 8, 64), jnp.bfloat16)
    k = jax.random.normal(kk, (1, 512, 2, 64), jnp.bfloat16)
    v = jax.random.normal(kv_, (1, 512, 2, 64), jnp.bfloat16)
    a = decode_attention_pallas(q, k, v, 400, block_s=512)
    b = decode_attention_pallas(q, k, v, 400, block_s=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_fp8_cache_bytes_halve():
    """The kernel input itself carries the traffic claim."""
    k8 = jnp.zeros((1, 512, 2, 64), jnp.float8_e4m3fn)
    k16 = jnp.zeros((1, 512, 2, 64), jnp.bfloat16)
    assert k8.nbytes * 2 == k16.nbytes
