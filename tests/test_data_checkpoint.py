"""Data pipeline determinism/sharding + checkpoint atomicity/resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data import DataConfig, ShardedTokenStream


CFG = DataConfig(vocab=512, seq_len=64, global_batch=8, seed=7)


def test_stream_deterministic():
    a = ShardedTokenStream(CFG).batch_at(3)
    b = ShardedTokenStream(CFG).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_stream_steps_differ():
    s = ShardedTokenStream(CFG)
    assert not np.array_equal(s.batch_at(0)["tokens"], s.batch_at(1)["tokens"])


def test_sharded_ranks_partition_global_batch():
    """world=4 rank slices concatenate to the world=1 batch (elastic
    restart re-slices the same global stream)."""
    s = ShardedTokenStream(CFG)
    whole = s.batch_at(5)["tokens"]
    parts = [s.batch_at(5, rank=r, world=4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_labels_shift():
    b = ShardedTokenStream(CFG).batch_at(0)
    assert b["tokens"].shape == (8, 64) and b["labels"].shape == (8, 64)


def test_pytree_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "step": jnp.int32(3)},
    }
    d = str(tmp_path / "ck")
    save_pytree(tree, d)
    out = restore_pytree(tree, d)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_manager_save_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((3, 3))}
    for step in (2, 4, 6):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree),
                 extra={"loss": 1.0 / step})
    assert mgr.steps() == [4, 6]     # retention
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 6
    np.testing.assert_array_equal(np.asarray(restored["w"]), 6.0)


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2,))}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_no_partial_checkpoint_visible(tmp_path):
    """Temp dirs (crash residue) are never listed as restorable steps."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / ".tmp_crashed")
    (tmp_path / ".tmp_crashed" / "arrays.npz").write_bytes(b"junk")
    os.makedirs(tmp_path / "step_00000009")  # dir without manifest
    assert mgr.steps() == []


@pytest.mark.slow   # full train loop (model forward + backward)
def test_train_restart_bitexact(tmp_path):
    """9 steps straight == 6 steps + restart + 3 steps (fault tolerance)."""
    from repro.launch.train import train

    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    kw = dict(arch="qwen2-0.5b", smoke=True, seq_len=32, global_batch=2,
              ckpt_every=3, log_every=100)
    out_straight = train(steps=9, ckpt_dir=d1, **kw)
    train(steps=6, ckpt_dir=d2, **kw)
    out_resumed = train(steps=9, ckpt_dir=d2, **kw)
    np.testing.assert_allclose(
        out_straight["losses"][-3:], out_resumed["losses"], rtol=1e-5
    )
