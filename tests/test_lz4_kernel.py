"""kernels/lz4: the device-resident LZ4 match kernel's correctness story.

Four pillars:

1. **byte identity** — the kernel path (``lz4_compress_batch`` →
   ``kernels.lz4.match_events_slab`` + ``lz4_emit_events``) produces the
   same bytes as the scalar per-block reference (``lz4_compress``) AND
   the PR 3 fused slab oracle (``TRACE_SCALAR_LZ4=1``), on an
   adversarial corpus and on hypothesis-generated batches;
2. **device parity** — the pallas+jnp path (``force="device"``,
   interpret mode on CPU) selects the exact events of the numpy path;
3. **decode hardening** — truncated and bit-flipped frames raise the
   structured :class:`codec.CorruptPayloadError`, never IndexError or a
   silently-wrong payload accepted as valid;
4. **R6 purity** — the pallas kernel body is recognized by tracecheck's
   jit-purity rule and lints host-sync-free (the check is asserted
   non-vacuous: ``_prep_kernel`` must be in the traced-function set).
"""

import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import codec
from repro.kernels import lz4 as klz4

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a pinned CI dep
    HAVE_HYPOTHESIS = False

REPO_ROOT = Path(__file__).resolve().parents[1]
KERNEL_FILE = REPO_ROOT / "src" / "repro" / "kernels" / "lz4.py"


def _adversarial_corpus():
    """The ISSUE's named adversaries plus the boundary cases the match
    rules care about (MFLIMIT edge, run-first anchoring, hash floods)."""
    rng = np.random.default_rng(7)
    return [
        b"\x00" * 4096,                                     # all-zero
        bytes(rng.integers(0, 256, 4096, dtype=np.uint8)),  # incompressible
        b"a" * 500,                                         # offset-1 run
        b"ab" + b"c" * 300 + b"de",                         # run + tails
        b"abcd" * 1024,                                     # stride-4 periodic
        b"",                                                # empty
        b"x",                                               # 1 byte
        b"\x00" * (klz4.MFLIMIT + 1),                       # smallest matchable
        b"\x00" * klz4.MFLIMIT,                             # all-literal edge
        bytes(rng.integers(0, 2, 2048, dtype=np.uint8)),    # low-entropy
        bytes(np.tile(rng.integers(0, 256, 97).astype(np.uint8), 40)),
        (b"\x00" * 64
         + bytes(rng.integers(0, 256, 64, dtype=np.uint8))) * 16,
    ]


def _scalar_oracle(chunks):
    return [codec.lz4_compress(c) for c in chunks]


# ---------------------------------------------------------------------------
# 1. kernel path vs scalar oracle — byte identity
# ---------------------------------------------------------------------------

def test_kernel_batch_identical_to_scalar_on_adversarial_corpus():
    chunks = _adversarial_corpus()
    scalar = _scalar_oracle(chunks)
    assert codec.lz4_compress_batch(chunks) == scalar
    # every frame round-trips under the hardened decoder
    for data, comp in zip(chunks, scalar):
        if data:
            assert codec.lz4_decompress(comp, max_out=len(data)) == data


def test_scalar_lz4_env_pins_oracle_with_identical_bytes(monkeypatch):
    """``TRACE_SCALAR_LZ4=1`` swaps in the PR 3 fused slab encoder; the
    bytes must not change — that is what makes it usable as a CI parity
    oracle (kernels_bench asserts the same identity per run)."""
    chunks = _adversarial_corpus()
    kernel = codec.lz4_compress_batch(chunks)
    monkeypatch.setenv("TRACE_SCALAR_LZ4", "1")
    assert codec._scalar_lz4_forced()
    assert codec.lz4_compress_batch(chunks) == kernel
    monkeypatch.setenv("TRACE_SCALAR_LZ4", "0")
    assert not codec._scalar_lz4_forced()


if HAVE_HYPOTHESIS:
    @given(st.lists(
        st.one_of(
            st.binary(min_size=0, max_size=1024),
            # byte runs and short-period tiles: the offset-1/stride rules
            st.builds(lambda b, n: b * n, st.binary(min_size=1, max_size=4),
                      st.integers(0, 400)),
        ),
        min_size=1, max_size=8))
    @settings(max_examples=40, deadline=None)
    def test_kernel_batch_identical_to_scalar_any_chunks(chunks):
        assert codec.lz4_compress_batch(chunks) == _scalar_oracle(chunks)
else:  # pragma: no cover - hypothesis is a pinned CI dep
    @pytest.mark.skip(reason="property test needs hypothesis")
    def test_kernel_batch_identical_to_scalar_any_chunks():
        pass


def test_match_events_slab_gapped_streams_untouched():
    """Bypassed (gapped) slab ranges must never influence match events:
    compressing streams sliced out of a gapped slab equals compressing
    the same streams from a dense one."""
    rng = np.random.default_rng(3)
    a = b"\x00" * 256
    b = bytes(rng.integers(0, 4, 256, dtype=np.uint8))
    gap = bytes(rng.integers(0, 256, 64, dtype=np.uint8))
    slab = np.frombuffer(a + gap + b, dtype=np.uint8)
    starts, ends = [0, 256 + 64], [256, 256 + 64 + 256]
    pos, dist, mlen = klz4.match_events_slab(slab, starts, ends)
    dense = np.frombuffer(a + b, dtype=np.uint8)
    dpos, ddist, dmlen = klz4.match_events_slab(dense, [0, 256], [256, 512])
    # same events modulo the gap's offset shift on the second stream
    shift = np.where(dpos >= 256, 64, 0)
    np.testing.assert_array_equal(pos, dpos + shift)
    np.testing.assert_array_equal(dist, ddist)
    np.testing.assert_array_equal(mlen, dmlen)


# ---------------------------------------------------------------------------
# 2. device (pallas+jnp) path parity — interpret mode on CPU
# ---------------------------------------------------------------------------

def test_device_path_matches_numpy_path():
    pytest.importorskip("jax", reason="device path needs jax")
    rng = np.random.default_rng(5)
    parts = [
        np.zeros(300, np.uint8),
        rng.integers(0, 256, 300, dtype=np.uint8),
        np.tile(np.arange(4, dtype=np.uint8), 100),
        rng.integers(0, 3, 300, dtype=np.uint8),
    ]
    buf = np.concatenate(parts)
    ends = np.cumsum([p.size for p in parts])
    starts = ends - [p.size for p in parts]
    ref = klz4.match_events_slab(buf, starts, ends, force="numpy")
    dev = klz4.match_events_slab(buf, starts, ends, force="device")
    for r, d in zip(ref, dev):
        np.testing.assert_array_equal(r, d)
    # ... and the full encode built on those events stays byte-identical
    chunks = [p.tobytes() for p in parts]
    assert codec._lz4_slab_streams(buf, buf, starts, ends,
                                   force="device") == _scalar_oracle(chunks)


# ---------------------------------------------------------------------------
# 3. decode hardening — corrupt frames raise structured errors
# ---------------------------------------------------------------------------

def _fuzz_corpus():
    rng = np.random.default_rng(17)
    return [
        b"\x00" * 600,
        b"the quick brown fox " * 40,
        bytes(rng.integers(0, 8, 700, dtype=np.uint8)),
        bytes(rng.integers(0, 256, 300, dtype=np.uint8)),
    ]


def test_decompress_truncated_frames_raise_structured_error():
    """Every proper prefix of a valid frame either raises
    CorruptPayloadError or decodes to a prefix-consistent payload —
    never IndexError, never bytes past the original."""
    for data in _fuzz_corpus():
        comp = codec.lz4_compress(data)
        for cut in range(len(comp)):
            try:
                out = codec.lz4_decompress(comp[:cut], max_out=len(data))
            except codec.CorruptPayloadError:
                continue
            assert data.startswith(out)


def test_decompress_bitflipped_frames_never_crash():
    """Single-bit flips at every byte: each either raises the structured
    error or decodes within the caller's bound — IndexError/OverflowError
    (the pre-hardening failure modes) are regressions."""
    for data in _fuzz_corpus():
        comp = codec.lz4_compress(data)
        stride = max(1, len(comp) // 128)   # cap work on long frames
        for i in range(0, len(comp), stride):
            for bit in (0x01, 0x80):
                bad = bytearray(comp)
                bad[i] ^= bit
                try:
                    out = codec.lz4_decompress(bytes(bad), max_out=len(data))
                except codec.CorruptPayloadError:
                    continue
                assert len(out) <= len(data)


def test_decompress_rejects_zero_and_early_offsets():
    # offset 0: token 0x04 (0 literals, 4-byte match), offset bytes 00 00
    with pytest.raises(codec.CorruptPayloadError):
        codec.lz4_decompress(b"\x04\x00\x00")
    # offset beyond the produced frontier (1 literal, offset 5)
    with pytest.raises(codec.CorruptPayloadError):
        codec.lz4_decompress(b"\x14A\x05\x00")
    assert issubclass(codec.CorruptPayloadError, ValueError)


# ---------------------------------------------------------------------------
# 4. tracecheck R6 — the kernel body stays host-sync-free
# ---------------------------------------------------------------------------

def test_r6_covers_and_passes_on_lz4_kernel():
    """``_prep_kernel`` must be in R6's traced-function set (the lint is
    not vacuous for this file) and the file must lint clean — a host
    sync or numpy materialization added to the kernel body fails here
    before it fails in CI's tracecheck job."""
    import ast

    from tools.tracecheck import run_paths
    from tools.tracecheck.rules_flow import R6JitPurity, _traced_functions

    tree = ast.parse(KERNEL_FILE.read_text())
    traced = _traced_functions(tree)
    assert "_prep_kernel" in traced
    assert traced["_prep_kernel"][1] == "pallas_call"
    diags = run_paths([str(KERNEL_FILE)], [R6JitPurity()],
                      repo_root=REPO_ROOT)
    assert diags == [], "\n".join(d.format() for d in diags)
