"""Serving runtime: paged KV spill → tier round-trip → decode integrity,
sync and async I/O, single- and multi-stream."""

import numpy as np
import pytest

from repro.core.precision import FULL
from repro.runtime import (
    MultiStreamEngine, PAPER_POLICY, KVPagePool, ServeEngine,
)
from repro.runtime.paging import LOSSLESS_POLICY, PagePolicy

pytestmark = pytest.mark.slow   # model-forward module


@pytest.fixture(scope="module")
def engine_pair(smoke_model):
    """Smoke cfg + params shared across the serving tests."""
    return smoke_model("qwen2-0.5b")


def _run(cfg, params, device, policy, n=12, budget=1 << 12, **kw):
    eng = ServeEngine(
        cfg, params, max_seq=96, batch=1, page_tokens=16,
        hbm_kv_budget=budget, device_kind=device, policy=policy, **kw,
    )
    prompt = np.arange(48, dtype=np.int32).reshape(1, 48) % cfg.vocab
    toks = eng.generate(prompt, n)
    return eng, toks


def test_lossless_trace_matches_plain_generation(engine_pair):
    """Byte-exact KV round-trip ⇒ identical greedy generations (the paper's
    §III-D correctness invariant, end to end)."""
    cfg, params = engine_pair
    _, t_plain = _run(cfg, params, "plain", LOSSLESS_POLICY)
    _, t_trace = _run(cfg, params, "trace", LOSSLESS_POLICY)
    np.testing.assert_array_equal(t_plain, t_trace)


def test_spill_and_compression_happen(engine_pair):
    cfg, params = engine_pair
    eng, _ = _run(cfg, params, "trace", LOSSLESS_POLICY)
    s = eng.stats()
    assert s.spilled_pages > 0
    assert s.tier_dram_read > 0
    assert s.kv_compression_ratio > 1.05  # bit-plane + lz4 on real KV


def test_policy_views_reduce_dram_reads(engine_pair):
    cfg, params = engine_pair
    e_full, _ = _run(cfg, params, "trace", LOSSLESS_POLICY)
    e_pol, _ = _run(cfg, params, "trace", PAPER_POLICY)
    # elastic policy fetches fewer planes for cold pages
    assert e_pol.stats().tier_dram_read < e_full.stats().tier_dram_read


def test_policy_generation_stays_sane(engine_pair):
    """Reduced-precision cold pages must not derail generation (tokens in
    vocab, no crash); quality deltas are measured in benchmarks."""
    cfg, params = engine_pair
    _, toks = _run(cfg, params, "trace", PAPER_POLICY)
    assert toks.min() >= 0 and toks.max() < cfg.vocab


def test_kv_through_tier_roundtrip(engine_pair):
    cfg, params = engine_pair
    eng, _ = _run(cfg, params, "trace", LOSSLESS_POLICY)
    kv = eng.kv_through_tier(0, "k")
    assert kv.size > 0 and kv.dtype == np.uint16


def test_page_pool_importance_eviction():
    pool = KVPagePool("trace", page_tokens=8, hbm_budget_bytes=8 * 64 * 2 * 2)
    rng = np.random.default_rng(0)
    for i in range(6):
        page = rng.normal(size=(8, 64)).astype(np.float32)
        import ml_dtypes

        u16 = page.astype(ml_dtypes.bfloat16).view(np.uint16)
        pool.append_page(0, "k", i * 8, u16, importance=float(i))
    # low-importance pages must have spilled first
    resident = [p.start for p in pool._pages if p.resident is not None]
    spilled = [p.start for p in pool._pages if p.resident is None]
    assert len(spilled) == 4 and max(spilled) < min(resident)
    out = pool.read_layer(0, "k")
    assert out.shape == (48, 64)


def test_policy_rank_views():
    pol = PagePolicy()
    views = [pol.view_for_rank(r).name for r in range(12)]
    assert views[:5] == ["bf16"] * 5
    assert views[5:8] == ["man4"] * 3
    assert views[8:] == ["man0"] * 4
    assert pol.avg_bits(10) == (5 * 16 + 3 * 13 + 2 * 9) / 10


# ---------------------------------------------------------------------------
# async I/O overlap + multi-stream serving
# ---------------------------------------------------------------------------

def test_async_io_matches_sync_engine_lossless(engine_pair):
    """With lossless readback, overlapping spill I/O with decode must not
    change a single token, and total tier traffic must match the
    serialized engine exactly (only latency accounting differs)."""
    cfg, params = engine_pair
    e_sync, t_sync = _run(cfg, params, "trace", LOSSLESS_POLICY,
                          async_io=False)
    e_async, t_async = _run(cfg, params, "trace", LOSSLESS_POLICY,
                            async_io=True)
    np.testing.assert_array_equal(t_sync, t_async)
    ss, sa = e_sync.stats(), e_async.stats()
    assert (ss.tier_dram_read, ss.tier_link_out, ss.tier_dram_stored) == \
        (sa.tier_dram_read, sa.tier_link_out, sa.tier_dram_stored)
    assert sa.tier_io_service_s > 0
    assert sa.tier_io_queue_delay_s >= 0


def test_many_streams_match_sequential_engines(engine_pair):
    """N streams sharing ONE device queue generate the same logits/tokens
    as N engines run one after another, and the summed per-stream receipt
    traffic equals the shared device totals field-for-field."""
    cfg, params = engine_pair
    n_streams, n_tok = 3, 6
    prompts = [
        ((np.arange(48) * (i + 1) + i) % cfg.vocab)
        .astype(np.int32).reshape(1, 48)
        for i in range(n_streams)
    ]

    multi = MultiStreamEngine(
        cfg, params, n_streams, device_kind="trace", max_seq=96, batch=1,
        page_tokens=16, hbm_kv_budget=1 << 12, policy=PAPER_POLICY,
    )
    toks_multi = multi.generate(prompts, n_tok)

    for i in range(n_streams):
        eng = ServeEngine(
            cfg, params, max_seq=96, batch=1, page_tokens=16,
            hbm_kv_budget=1 << 12, device_kind="trace",
            policy=PAPER_POLICY, key_prefix=f"s{i}.",
        )
        toks_solo = eng.generate(prompts[i], n_tok)
        np.testing.assert_array_equal(toks_multi[i], toks_solo)

    # per-stream receipts conserve the shared device's aggregate traffic
    d = multi.device_stats()
    summed = {
        f: sum(getattr(t, f)
               for eng in multi.streams
               for t in eng.pool.page_traffic.values())
        for f in ("dram_bytes_read", "dram_bytes_written",
                  "link_bytes_in", "link_bytes_out", "index_bytes")
    }
    assert summed == {f: getattr(d, f) for f in summed}
    # streams are namespaced: no key collisions on the shared device
    keys = [k for eng in multi.streams for k in eng.pool.page_traffic]
    assert len(keys) == len(set(keys))
    assert multi.throughput_ceiling() > 0
    # sharing one window actually coalesces: some receipt waited behind
    # another stream's request on the shared pipes
    assert sum(s.tier_io_queue_delay_s for s in multi.stats()) > 0
