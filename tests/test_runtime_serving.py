"""Serving runtime: paged KV spill → tier round-trip → decode integrity."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.core.precision import FULL
from repro.models.model import init_params
from repro.runtime import PAPER_POLICY, KVPagePool, ServeEngine
from repro.runtime.paging import LOSSLESS_POLICY, PagePolicy


@pytest.fixture(scope="module")
def engine_pair():
    """Two engines, lossless-TRACE vs plain, same params/prompt."""
    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, device, policy, n=12, budget=1 << 12):
    eng = ServeEngine(
        cfg, params, max_seq=96, batch=1, page_tokens=16,
        hbm_kv_budget=budget, device_kind=device, policy=policy,
    )
    prompt = np.arange(48, dtype=np.int32).reshape(1, 48) % cfg.vocab
    toks = eng.generate(prompt, n)
    return eng, toks


def test_lossless_trace_matches_plain_generation(engine_pair):
    """Byte-exact KV round-trip ⇒ identical greedy generations (the paper's
    §III-D correctness invariant, end to end)."""
    cfg, params = engine_pair
    _, t_plain = _run(cfg, params, "plain", LOSSLESS_POLICY)
    _, t_trace = _run(cfg, params, "trace", LOSSLESS_POLICY)
    np.testing.assert_array_equal(t_plain, t_trace)


def test_spill_and_compression_happen(engine_pair):
    cfg, params = engine_pair
    eng, _ = _run(cfg, params, "trace", LOSSLESS_POLICY)
    s = eng.stats()
    assert s.spilled_pages > 0
    assert s.tier_dram_read > 0
    assert s.kv_compression_ratio > 1.05  # bit-plane + lz4 on real KV


def test_policy_views_reduce_dram_reads(engine_pair):
    cfg, params = engine_pair
    e_full, _ = _run(cfg, params, "trace", LOSSLESS_POLICY)
    e_pol, _ = _run(cfg, params, "trace", PAPER_POLICY)
    # elastic policy fetches fewer planes for cold pages
    assert e_pol.stats().tier_dram_read < e_full.stats().tier_dram_read


def test_policy_generation_stays_sane(engine_pair):
    """Reduced-precision cold pages must not derail generation (tokens in
    vocab, no crash); quality deltas are measured in benchmarks."""
    cfg, params = engine_pair
    _, toks = _run(cfg, params, "trace", PAPER_POLICY)
    assert toks.min() >= 0 and toks.max() < cfg.vocab


def test_kv_through_tier_roundtrip(engine_pair):
    cfg, params = engine_pair
    eng, _ = _run(cfg, params, "trace", LOSSLESS_POLICY)
    kv = eng.kv_through_tier(0, "k")
    assert kv.size > 0 and kv.dtype == np.uint16


def test_page_pool_importance_eviction():
    pool = KVPagePool("trace", page_tokens=8, hbm_budget_bytes=8 * 64 * 2 * 2)
    rng = np.random.default_rng(0)
    for i in range(6):
        page = rng.normal(size=(8, 64)).astype(np.float32)
        import ml_dtypes

        u16 = page.astype(ml_dtypes.bfloat16).view(np.uint16)
        pool.append_page(0, "k", i * 8, u16, importance=float(i))
    # low-importance pages must have spilled first
    resident = [p.start for p in pool._pages if p.resident is not None]
    spilled = [p.start for p in pool._pages if p.resident is None]
    assert len(spilled) == 4 and max(spilled) < min(resident)
    out = pool.read_layer(0, "k")
    assert out.shape == (48, 64)


def test_policy_rank_views():
    pol = PagePolicy()
    views = [pol.view_for_rank(r).name for r in range(12)]
    assert views[:5] == ["bf16"] * 5
    assert views[5:8] == ["man4"] * 3
    assert views[8:] == ["man0"] * 4
    assert pol.avg_bits(10) == (5 * 16 + 3 * 13 + 2 * 9) / 10
