"""Throughput / controller / DRAM model unit tests vs published anchors."""

import numpy as np
import pytest

from repro.core.controller import load_to_use_cycles
from repro.core.dram_model import (
    EXPERT, HEAD, NEURON, energy_per_weight_pj, mixture_for_target,
)
from repro.core.system_model import (
    PAPER_ANCHORS_FIG12, gpt_oss_120b, sweep_alpha, throughput,
)


def test_fig12_anchors_within_tolerance():
    """Mean relative error over the 8 published Fig-12 points < 15 %.
    The loosest single point is TRACE@256k (our constant-elastic model
    over-credits the deep-spill tail; see EXPERIMENTS.md §Validation)."""
    m = gpt_oss_120b("mxfp4")
    errs = []
    for design, anchors in PAPER_ANCHORS_FIG12.items():
        for ctx, want in anchors.items():
            got = throughput(m, ctx, design).tok_s
            errs.append(abs(got - want) / want)
    assert float(np.mean(errs)) < 0.15, errs


def test_gcomp_useless_on_kv_bound_regime():
    m = gpt_oss_120b("mxfp4")
    p = throughput(m, 131072, "plain").tok_s
    g = throughput(m, 131072, "gcomp").tok_s
    assert g / p < 1.1  # paper: curves overlap


def test_trace_dominates_all_contexts():
    m = gpt_oss_120b("bf16")
    for ctx in (4096, 65536, 131072, 262144):
        t = throughput(m, ctx, "trace", alpha=0.8).tok_s
        p = throughput(m, ctx, "plain", alpha=0.8).tok_s
        assert t >= p


def test_alpha_sweep_unimodal_and_trace_peak_higher():
    m = gpt_oss_120b("bf16")
    alphas = list(np.linspace(0.1, 0.95, 18))
    sw = sweep_alpha(m, 131072, alphas)
    for design, ys in sw.items():
        arr = np.round(np.array(ys), 9)
        d = np.sign(np.diff(arr))
        d = d[d != 0]
        assert np.sum(np.abs(np.diff(d))) <= 2, design  # ≤1 direction change
    assert max(sw["trace"]) > max(sw["gcomp"]) > max(sw["plain"])


def test_controller_anchor_cycles():
    assert load_to_use_cycles("plain") == 71
    assert load_to_use_cycles("gcomp") == 84
    assert load_to_use_cycles("trace") == 89
    assert load_to_use_cycles("trace", comp_ratio=3.0) == 85
    assert load_to_use_cycles("trace", bypass=True) == 76
    assert load_to_use_cycles("trace", meta_hit=False) > 89


def test_mixture_hits_target_mean():
    for target in (1.6, 4.8, 8.0, 12.0):
        mix = mixture_for_target(target)
        mean = sum(b * f for b, f in mix.items())
        assert mean == pytest.approx(target, rel=0.02)


def test_plane_fetch_beats_word_fetch_everywhere():
    for unit in (EXPERT, HEAD, NEURON):
        for bits in (1.6, 4.8, 8.0):
            e_p = energy_per_weight_pj(unit, bits, "plain")
            e_t = energy_per_weight_pj(unit, bits, "trace")
            assert e_t < e_p, (unit.name, bits)


def test_neuron_savings_below_head_savings():
    """Paper: fine-grained units pay stripe-gap activations."""
    for bits in (4.8, 8.0):
        s_head = 1 - (energy_per_weight_pj(HEAD, bits, "trace")
                      / energy_per_weight_pj(HEAD, bits, "plain"))
        s_neu = 1 - (energy_per_weight_pj(NEURON, bits, "trace")
                     / energy_per_weight_pj(NEURON, bits, "plain"))
        assert s_neu < s_head + 1e-9
