"""Shared-prefix KV reuse: content-addressed chain hashing, the
refcounted residency ledger (acquire/release, stored bytes counted
once, freed at last retirement), copy-on-write divergence, the
scheduler's novel-KV admission discount, and the regression sweep —
stale prefetch after reclaim, namespace-prefix collisions, the bounded
per-token projection cache, and refcount fault injection under the
sanitizer."""

import numpy as np
import pytest

from repro.core import synth
from repro.core.precision import MAN0, MAN4
from repro.core.sharding import ShardedTierStore
from repro.core.tier import (
    KV, ReadReq, SanitizerViolation, WriteReq, make_device,
)
from repro.runtime import (
    ServeEngine, ServeRequest, ServeScheduler, projected_kv_bytes,
)
from repro.runtime.paging import (
    DEFAULT_DEGRADE_LADDER, KVPagePool, LOSSLESS_POLICY, PrefixShareIndex,
    prefix_chain_hashes, shared_page_key,
)


def _payload(seed=0, shape=(64, 256)):
    return np.random.default_rng(seed).integers(
        0, 1 << 16, size=shape, dtype=np.uint16)


# ---------------------------------------------------------------------------
# chain hashing: the copy-on-write divergence rule
# ---------------------------------------------------------------------------

def test_chain_hashes_window_count_and_determinism():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, (1, 50)).astype(np.int32)
    hs = prefix_chain_hashes(toks, 16)
    assert len(hs) == 3                       # 50 // 16 full windows
    assert hs == prefix_chain_hashes(toks.copy(), 16)
    assert len(set(hs)) == 3                  # chained, not repeated
    # the page size seeds the chain: same tokens, different paging,
    # disjoint hash namespaces
    assert prefix_chain_hashes(toks, 25)[0] not in hs


def test_chain_hashes_diverge_after_first_differing_token():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 1000, (1, 64)).astype(np.int32)
    b = a.copy()
    b[0, 20] += 1                             # differs inside window 1
    ha, hb = prefix_chain_hashes(a, 16), prefix_chain_hashes(b, 16)
    assert ha[0] == hb[0]                     # window 0 identical
    assert all(x != y for x, y in zip(ha[1:], hb[1:]))  # chained divergence


def test_shared_page_key_namespace():
    k = shared_page_key("abcd", 3, "v")
    assert k == "shared.abcd.L3.v"
    assert k.startswith("shared.")


# ---------------------------------------------------------------------------
# tier-level refcounting: one stored copy, exact bytes at any interleaving
# ---------------------------------------------------------------------------

def test_acquire_release_counts_stored_bytes_once():
    dev = make_device("trace", sanitize=True, kv_window=16)
    dev.submit([WriteReq("shared.h0.L0.k", synth.kv_cache(16, 64, seed=0),
                         kind=KV)])
    one_copy = dev.resident_bytes()
    assert dev.refcount("shared.h0.L0.k") == 1
    assert dev.acquire("shared.h0.L0.k") == 2
    assert dev.acquire("shared.h0.L0.k") == 3
    # co-owners do not multiply the footprint
    assert dev.resident_bytes() == one_copy
    # early releases keep the bytes; the last one frees them
    assert dev.release("shared.h0.L0.k") == 2
    assert dev.release("shared.h0.L0.k") == 1
    assert dev.resident_bytes() == one_copy
    assert dev.release("shared.h0.L0.k") == 0
    assert dev.resident_bytes() == 0 and dev.stats.blocks == 0
    assert dev.refcount("shared.h0.L0.k") == 0


def test_acquire_unknown_and_double_release_raise():
    dev = make_device("trace", sanitize=True)
    with pytest.raises(KeyError):
        dev.acquire("ghost")
    dev.submit([WriteReq("k", _payload())])
    dev.release("k")
    with pytest.raises(KeyError):
        dev.release("k")                      # double release is a bug


def test_delete_on_shared_key_only_drops_one_reference():
    dev = make_device("trace", sanitize=True)
    dev.submit([WriteReq("s", _payload(1))])
    dev.acquire("s")
    dev.delete("s")                           # one referer's claim, not the bytes
    assert dev.refcount("s") == 1
    np.testing.assert_array_equal(
        dev.submit([ReadReq("s")])[0].data, _payload(1))
    dev.delete("s")
    assert dev.resident_bytes() == 0


def test_delete_prefix_spares_shared_survivors():
    dev = make_device("trace", sanitize=True, kv_window=16)
    dev.submit([WriteReq("shared.h.L0.k", synth.kv_cache(16, 64, seed=2),
                         kind=KV),
                WriteReq("shared.h.L1.k", synth.kv_cache(16, 64, seed=3),
                         kind=KV)])
    dev.acquire("shared.h.L0.k")              # co-owned; L1 is sole-owned
    assert dev.delete_prefix("shared.") == 2
    assert dev.refcount("shared.h.L0.k") == 1    # survived, one ref dropped
    assert dev.refcount("shared.h.L1.k") == 0    # freed outright
    assert dev.resident_bytes() == dev.resident_bytes("shared.h.L0.k") > 0
    assert dev.delete_prefix("shared.") == 1
    assert dev.resident_bytes() == 0


def test_truncate_refused_on_coowned_and_acquire_refused_on_truncated():
    dev = make_device("trace", kv_window=16)
    dev.submit([WriteReq("s.p", synth.kv_cache(16, 64, seed=4), kind=KV)])
    dev.acquire("s.p")
    with pytest.raises(ValueError):
        dev.truncate_planes(["s.p"], MAN4)    # would degrade every referer
    dev.release("s.p")
    assert dev.truncate_planes(["s.p"], MAN4) > 0
    with pytest.raises(ValueError):
        dev.acquire("s.p")                    # new referer must not decode
    dev.delete("s.p")                         # degraded data


def test_refcount_conservation_random_interleavings():
    """Property: any interleaving of writes, acquires, releases and
    deletes keeps the ledger refcounts equal to a host-side model, the
    resident bytes equal to the stored-block walk (shared keys counted
    once), and runs clean under the sanitizer's shadow map."""
    rng = np.random.default_rng(13)
    # shards=1: the stored-block walk below reads one device's _tensors
    dev = make_device("trace", sanitize=True, kv_window=16, shards=1)
    refs = {}                                 # host model: key -> count
    for _ in range(200):
        op = rng.integers(0, 8)
        key = f"shared.h{rng.integers(0, 5)}.L0.k"
        if op < 3:                            # write (idempotent refresh)
            if key not in refs:
                dev.submit([WriteReq(key, synth.kv_cache(
                    16, 64, seed=int(rng.integers(1 << 16))), kind=KV)])
                refs[key] = 1
        elif op < 5 and key in refs:          # acquire
            assert dev.acquire(key) == refs[key] + 1
            refs[key] += 1
        elif op < 7 and key in refs:          # release
            assert dev.release(key) == refs[key] - 1
            refs[key] -= 1
            if refs[key] == 0:
                del refs[key]
        elif refs:                            # namespace delete
            dev.delete_prefix("shared.")
            refs = {k: n - 1 for k, n in refs.items() if n > 1}
        for k, n in refs.items():
            assert dev.refcount(k) == n
        walk = sum(b.stored_bytes + 64 for k in refs
                   for b in dev._tensors.get(k, ()))
        assert dev.resident_bytes() == walk
    for k in sorted(refs):
        while dev.refcount(k):
            dev.release(k)
    assert dev.resident_bytes() == 0 and dev.stats.blocks == 0


# ---------------------------------------------------------------------------
# namespace-prefix matching (the "r1" vs "r10." collision fix)
# ---------------------------------------------------------------------------

def test_prefix_match_is_namespace_delimited():
    """12 concurrent request namespaces: an undotted prefix must bind to
    exactly its own namespace, never to the lexical superstrings that a
    raw startswith would also match (r1 -> r10, r11, r12)."""
    dev = make_device("trace", sanitize=True)
    for i in range(1, 13):
        dev.submit([WriteReq(f"r{i}.p0", _payload(i))])
    per_ns = {i: dev.resident_bytes(f"r{i}.") for i in range(1, 13)}
    assert sum(per_ns.values()) == dev.resident_bytes()
    # the undotted form means the same namespace, not a lexical prefix
    assert dev.resident_bytes("r1") == per_ns[1]
    assert dev.compression_ratio("r1") == dev.compression_ratio("r1.")
    assert dev.delete_prefix("r1") == 1
    for i in (10, 11, 12):                    # superstring namespaces intact
        np.testing.assert_array_equal(
            dev.submit([ReadReq(f"r{i}.p0")])[0].data, _payload(i))
    assert dev.delete_prefix("") == 11
    assert dev.resident_bytes() == 0


def test_exact_key_still_matches_itself():
    dev = make_device("trace")
    dev.submit([WriteReq("solo", _payload(7))])
    assert dev.resident_bytes("solo") > 0     # exact key, no namespace dot
    assert dev.delete_prefix("solo") == 1


# ---------------------------------------------------------------------------
# sharding: shared. pages stay device-local (refcounts live on one shard)
# ---------------------------------------------------------------------------

def test_sharded_shared_pages_colocate_by_content_hash():
    """Every (layer, kind) page of one content hash routes to the SAME
    shard — the invariant that keeps a shared chain's refcounts local to
    one device — while distinct hashes still spread over the fleet."""
    fleet = ShardedTierStore(4, kind="trace", kv_window=16)
    chain = [shared_page_key("abcd", layer, kind)
             for layer in range(4) for kind in ("k", "v")]
    assert len({fleet.owner(k) for k in chain}) == 1
    spread = {fleet.owner(shared_page_key(f"h{i:04x}", 0, "k"))
              for i in range(32)}
    assert len(spread) > 1


def test_sharded_namespace_delete_decrements_owner_shard_only():
    """Fleet delete_prefix broadcasts to every shard, but a co-owned
    shared. page must lose exactly ONE reference — on its owning shard —
    never one per shard, and no ghost entries may appear elsewhere."""
    fleet = ShardedTierStore(4, kind="trace", kv_window=16, sanitize=True)
    key = shared_page_key("feed", 0, "k")
    fleet.submit([WriteReq(key, synth.kv_cache(16, 64, seed=8), kind=KV)])
    owner = fleet.owner(key)
    fleet.acquire(key)
    fleet.acquire(key)                        # 3 references, one copy
    one_copy = fleet.resident_bytes("")
    assert fleet.delete_prefix("shared") == 1
    assert fleet.refcount(key) == 2           # exactly one ref dropped
    assert fleet.shards[owner].refcount(key) == 2
    for i, s in enumerate(fleet.shards):
        if i != owner:
            assert s.refcount(key) == 0
            assert s.resident_bytes("shared") == 0
    assert fleet.resident_bytes("") == one_copy   # bytes still counted once
    assert fleet.delete_prefix("shared") == 1
    assert fleet.delete_prefix("shared") == 1     # last referer frees
    assert fleet.resident_bytes("") == 0


def test_sharded_prefix_collision_regression():
    """The r1-vs-r10 namespace collision, now with the namespaces spread
    over a fleet: an undotted prefix must bind to its own namespace on
    every shard it touches, never to lexical superstrings."""
    fleet = ShardedTierStore(3, kind="trace", sanitize=True)
    for i in range(1, 13):
        fleet.submit([WriteReq(f"r{i}.p0", _payload(i))])
    per_ns = {i: fleet.resident_bytes(f"r{i}.") for i in range(1, 13)}
    assert sum(per_ns.values()) == fleet.resident_bytes("")
    assert fleet.resident_bytes("r1") == per_ns[1]
    assert fleet.delete_prefix("r1") == 1
    for i in (10, 11, 12):                    # superstring namespaces intact
        np.testing.assert_array_equal(
            fleet.submit([ReadReq(f"r{i}.p0")])[0].data, _payload(i))
    assert fleet.delete_prefix("") == 11
    assert fleet.resident_bytes("") == 0


def test_prefix_share_index_routes_refs_to_owning_shard():
    """PrefixShareIndex over a sharded device: acquire/release flow
    through the fleet front-end to the owning shard's ledger, and the
    last release frees the one stored copy."""
    fleet = ShardedTierStore(3, kind="trace", kv_window=16, sanitize=True)
    idx = PrefixShareIndex(fleet)
    key = shared_page_key("cafe", 2, "v")
    fleet.submit([WriteReq(key, synth.kv_cache(16, 64, seed=9), kind=KV)])
    idx.register("cafe", 2, "v", key)
    owner = fleet.owner(key)
    assert idx.acquire("cafe", 2, "v") == key
    assert fleet.shards[owner].refcount(key) == 2
    assert idx.acquire("missing", 0, "k") is None
    assert idx.release(key) == 1
    assert idx.release(key) == 0              # unindexed + freed
    assert idx.acquire("cafe", 2, "v") is None
    assert fleet.resident_bytes("") == 0
    assert all(s.stats.blocks == 0 for s in fleet.shards)


# ---------------------------------------------------------------------------
# sanitizer: refcount-conservation fault injection
# ---------------------------------------------------------------------------

def test_corrupt_refcount_trips_sanitizer():
    dev = make_device("trace", sanitize=True, shards=1)  # pokes _ledger
    dev.submit([WriteReq("k0", _payload(0))])
    dev.acquire("k0")
    dev._ledger["k0"].refs = 5                # drifts from the shadow (2)
    with pytest.raises(SanitizerViolation) as ei:
        dev.submit([ReadReq("k0")])
    assert ei.value.invariant == "refcount-conservation"
    assert ei.value.key == "k0"
    assert ei.value.expected == 2 and ei.value.actual == 5


def test_nonpositive_refcount_trips_sanitizer():
    dev = make_device("trace", sanitize=True, shards=1)  # pokes _ledger
    dev.submit([WriteReq("k0", _payload(0))])
    dev._ledger["k0"].refs = 0                # a live entry must be referenced
    with pytest.raises(SanitizerViolation) as ei:
        dev.submit([ReadReq("k0")])
    assert ei.value.invariant == "refcount-conservation"


# ---------------------------------------------------------------------------
# pool-level sharing: spill-time dedup through the index
# ---------------------------------------------------------------------------

def _kv_pages(n, seed0=40):
    return [(0, "k", 16 * i, synth.kv_cache(16, 64, seed=seed0 + i),
             float(i), f"h{i}") for i in range(n)]


def test_pools_share_spilled_pages_one_stored_copy():
    dev = make_device("trace", sanitize=True, kv_window=16)
    idx = PrefixShareIndex(dev)
    pools = [KVPagePool(dev, page_tokens=16, hbm_budget_bytes=0,
                        policy=LOSSLESS_POLICY, key_prefix=f"r{i}.",
                        prefix_index=idx) for i in range(3)]
    for pool in pools:
        pool.append_pages(_kv_pages(2))
    one_copy = dev.resident_bytes("shared.")
    assert one_copy > 0 and dev.resident_bytes() == one_copy
    for i in range(2):
        assert dev.refcount(shared_page_key(f"h{i}", 0, "k")) == 3
    # every pool reads back the same bytes as a solo (unshared) pool
    solo = KVPagePool("trace", page_tokens=16, hbm_budget_bytes=0,
                      policy=LOSSLESS_POLICY, key_prefix="r0.")
    solo.append_pages([e[:5] for e in _kv_pages(2)])
    want = solo.read_layer(0, "k")
    for pool in pools:
        np.testing.assert_array_equal(pool.read_layer(0, "k"), want)
    # releases retire references; the last one frees the bytes
    pools[0].release()
    pools[1].release()
    assert dev.resident_bytes("shared.") == one_copy
    pools[2].release()
    assert dev.resident_bytes() == 0 and dev.stats.blocks == 0


def test_index_device_mismatch_rejected():
    idx = PrefixShareIndex(make_device("trace"))
    with pytest.raises(ValueError):
        KVPagePool(make_device("trace"), prefix_index=idx)


def test_reclaim_never_degrades_shared_pages():
    """The ladder walks private pages only: a shared page keeps its
    content-addressed key even with one referer left, so degrading it in
    place would poison the stream a later identical-prefix request
    re-writes (and every co-owner's decode).  Shared bytes free whole at
    the last retirement instead."""
    dev = make_device("trace", sanitize=True, kv_window=16)
    idx = PrefixShareIndex(dev)
    mk = lambda i: KVPagePool(dev, page_tokens=16, hbm_budget_bytes=0,
                              policy=LOSSLESS_POLICY, key_prefix=f"r{i}.",
                              degrade_ladder=DEFAULT_DEGRADE_LADDER,
                              prefix_index=idx)
    a, b = mk(0), mk(1)
    a.append_pages(_kv_pages(2))              # shared head windows
    a.append_pages([(0, "k", 32 + 16 * i,
                     synth.kv_cache(16, 64, seed=60 + i), 10.0 + i)
                    for i in range(2)])       # private tail (no hash)
    b.append_pages(_kv_pages(2))
    shared_before = dev.resident_bytes("shared.")
    assert a.reclaim(1 << 30) > 0             # private pages shed planes
    assert dev.resident_bytes("shared.") == shared_before
    assert idx.resident_chain(["h0", "h1"]) == 2   # still acquirable
    b.release()
    # even as sole referer the shared pages stay pristine
    assert a.reclaim(1 << 30) == 0            # ladder already exhausted on
    assert dev.resident_bytes("shared.") == shared_before   # private pages
    solo = KVPagePool("trace", page_tokens=16, hbm_budget_bytes=0,
                      policy=LOSSLESS_POLICY, key_prefix="r0.")
    solo.append_pages([e[:5] for e in _kv_pages(2)])
    want = solo.read_layer(0, "k")
    c = mk(2)
    c.append_pages(_kv_pages(2))              # acquires, does not re-write
    assert dev.refcount(shared_page_key("h0", 0, "k")) == 2
    np.testing.assert_array_equal(c.read_layer(0, "k")[:32], want)
    a.release()
    c.release()
    assert dev.resident_bytes() == 0


# ---------------------------------------------------------------------------
# regression: reclaim must not serve pre-truncation prefetch data
# ---------------------------------------------------------------------------

def test_read_after_reclaim_reflects_truncation_despite_prefetch():
    """prefetch_layer -> reclaim -> read_layer: the prefetch executed
    against full-precision planes; after the coldest page is truncated
    in place, read_layer must serve the degraded state (what a fresh
    read returns), not the stale prefetched bytes."""
    pool = KVPagePool("trace", page_tokens=16, hbm_budget_bytes=0,
                      policy=LOSSLESS_POLICY, key_prefix="r0.",
                      degrade_ladder=(MAN0,), sanitize=True)
    pool.append_pages([e[:5] for e in _kv_pages(3)])
    full = pool.read_layer(0, "k")
    assert pool.prefetch_layer(0, "k") == 3
    freed = pool.reclaim(1)                   # truncates the coldest page
    assert freed > 0
    got = pool.read_layer(0, "k")
    want = np.concatenate([
        pool.device.submit([ReadReq(p.key, kind=KV)])[0].data
        for p in sorted(pool._pages, key=lambda p: p.start)
    ], axis=0)
    np.testing.assert_array_equal(got, want)
    assert not np.array_equal(got, full)      # the degrade is visible
    # surviving prefetches (untruncated pages) were consumed, not leaked
    assert not pool._prefetched
    pool.release()
    assert pool.device.resident_bytes() == 0


# ---------------------------------------------------------------------------
# projection-slope cache: keyed on cfg only, bounded, linear in batch
# ---------------------------------------------------------------------------

def test_kv_per_token_linear_in_batch_and_bounded_cache():
    from repro.runtime.serving import (
        _kv_bytes_per_token, _kv_bytes_per_token_b1,
    )
    from repro.configs import ARCHS, smoke_config

    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    base = _kv_bytes_per_token(cfg, 1)
    assert base > 0
    for b in (2, 3, 8, 1024):                 # exact linearity, no per-batch
        assert _kv_bytes_per_token(cfg, b) == base * b   # cache entries
    info = _kv_bytes_per_token_b1.cache_info()
    assert info.maxsize == 32                 # bounded, not lru_cache(None)
    assert info.currsize <= 1 + len(ARCHS)


# ---------------------------------------------------------------------------
# model-backed: admission discount + copy-on-write differential
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_pair(smoke_model):
    return smoke_model("qwen2-0.5b")


def _solo(cfg, params, sched, req):
    return ServeEngine(
        cfg, params, max_seq=sched._max_seq, batch=1, page_tokens=16,
        hbm_kv_budget=1 << 12, device_kind="trace", policy=LOSSLESS_POLICY,
    ).generate(req.prompt, req.max_new_tokens, seed=req.seed)


@pytest.mark.slow
def test_shared_prefix_unblocks_admission(engine_pair):
    """The tentpole claim at scheduler level: capacity for ~1.5 logical
    projections serializes identical prompts without sharing, but admits
    them together when followers are charged only their novel KV — and
    every request's tokens stay bit-identical to a solo run."""
    cfg, params = engine_pair
    proj = projected_kv_bytes(cfg, 1, 32 + 5, 16)
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab, (1, 32)).astype(np.int32)
    mk = lambda share: ServeScheduler(
        cfg, params, max_batch=3, device_kind="trace",
        policy=LOSSLESS_POLICY, page_tokens=16, hbm_kv_budget=1 << 12,
        kv_capacity_bytes=int(1.5 * proj), prefix_share=share)
    reqs = lambda: [ServeRequest(req_id=i, arrival=0.0,
                                 prompt=prompt.copy(), max_new_tokens=5,
                                 seed=100 + i) for i in range(3)]
    base = mk(False).run(reqs())
    assert base.peak_active == 1              # capacity serializes
    sched = mk(True)
    rep = sched.run(reqs())
    assert rep.peak_active >= 2               # followers charged novel only
    recs = sorted(rep.records, key=lambda r: r.admit_step)
    assert recs[0].kv_novel_bytes == recs[0].kv_projected_bytes
    assert any(r.kv_novel_bytes < r.kv_projected_bytes for r in recs[1:])
    for req, rec in zip(reqs(), rep.records):
        np.testing.assert_array_equal(_solo(cfg, params, sched, req),
                                      rec.tokens)
    assert sched.device.resident_bytes("") == 0
    assert sched.kv_committed_bytes == 0


@pytest.mark.slow
def test_cow_divergence_bit_identical(engine_pair):
    """Copy-on-write: prompts share two page windows then diverge; the
    shared windows are stored once, the divergent tails stay private,
    and every request decodes bit-identically to its solo run."""
    cfg, params = engine_pair
    rng = np.random.default_rng(23)
    head = rng.integers(0, cfg.vocab, (1, 32)).astype(np.int32)
    prompts = [np.concatenate(
        [head, rng.integers(0, cfg.vocab, (1, 16)).astype(np.int32)],
        axis=1) for _ in range(3)]
    reqs = [ServeRequest(req_id=i, arrival=0.0, prompt=p, max_new_tokens=4,
                         seed=300 + i) for i, p in enumerate(prompts)]
    sched = ServeScheduler(
        cfg, params, max_batch=3, device_kind="trace",
        policy=LOSSLESS_POLICY, page_tokens=16, hbm_kv_budget=1 << 12,
        prefix_share=True)
    sched.submit(reqs)
    peak_refs = 0
    while sched.step():
        for k, e in sched.device._ledger.items():
            if k.startswith("shared."):
                peak_refs = max(peak_refs, e.refs)
    rep = sched.report()
    assert peak_refs == 3                     # head windows truly co-owned
    for req, rec in zip(reqs, rep.records):
        np.testing.assert_array_equal(_solo(cfg, params, sched, req),
                                      rec.tokens)
    assert sched.device.resident_bytes("") == 0
    assert sched.kv_committed_bytes == 0
