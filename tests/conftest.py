"""Shared test fixtures.

``smoke_model`` builds a smoke-config model ONCE per session and caches it
by (arch, seed): the model-forward modules (serving, kv-dtype) used to
re-init params and re-trace jit per module, which dominated the tier-1
wall clock.  Model-forward tests are also marked ``slow`` (registered in
pyproject.toml) so local iteration can run ``-m "not slow"``; the full
suite still runs everything by default.
"""

import jax
import pytest


@pytest.fixture(scope="session")
def smoke_model():
    """Factory: ``smoke_model(name, seed)`` → cached ``(cfg, params)``.

    ``cfg`` is the smoke-reduced arch config; callers that need variant
    configs (e.g. a different ``kv_dtype``) should ``dataclasses.replace``
    the returned cfg — params do not depend on cache dtype, so they can be
    shared across variants.
    """
    from repro.configs import ARCHS, smoke_config
    from repro.models.model import init_params

    cache = {}

    def get(name="qwen2-0.5b", seed=0):
        key = (name, seed)
        if key not in cache:
            cfg = smoke_config(ARCHS[name])
            cache[key] = (cfg, init_params(cfg, jax.random.PRNGKey(seed)))
        return cache[key]

    return get
