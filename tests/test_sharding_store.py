"""ShardedTierStore fleet invariants.

Three battery groups, one per PR satellite:

* a Hypothesis property test drives random write/delete/truncate/
  acquire/release/delete_prefix interleavings through a one-shard
  reference fleet and a wide fleet in lockstep — per-shard ledgers must
  sum to the fleet ledger at every step, refcounts must agree with the
  owning shard, surviving pages must read back byte-identical, and
  ``resident_bytes("")`` must drain to 0 after full retirement;
* fault injection: one deliberately slow shard (scaled LinkModel pipes)
  may only cost latency — bytes, receipts and accounting must be
  identical to a balanced fleet;
* the accounting sanitizer runs clean on a sharded fleet and still
  catches ledger corruption injected into a single shard.
"""

import numpy as np
import pytest

from repro.core import synth
from repro.core.precision import FULL, VIEWS
from repro.core.sharding import ShardedTierStore
from repro.core.tier import (
    KV,
    LinkModel,
    ReadReq,
    SanitizerViolation,
    WriteReq,
)

SUM_FIELDS = (
    "dram_bytes_read", "dram_bytes_written", "dram_bytes_stored",
    "raw_bytes_stored", "link_bytes_in", "link_bytes_out",
    "index_bytes", "index_hits", "index_misses", "blocks",
)

KEYS = [f"r{i}.p{j}" for i in range(3) for j in range(2)] + [
    "shared.h0.p0", "shared.h1.p0",
]


def _fleet_invariants(ref, fleet):
    """The per-step contract: the wide fleet is indistinguishable from
    the one-shard reference at the ledger, and the fleet view is exactly
    the sum of its shards' ledgers."""
    assert fleet.resident_bytes("") == ref.resident_bytes("")
    assert fleet.resident_bytes("") == sum(
        s.resident_bytes("") for s in fleet.shards)
    assert fleet.stats.blocks == ref.stats.blocks
    assert fleet.stats.blocks == sum(s.stats.blocks for s in fleet.shards)
    for key in KEYS:
        rc = fleet.refcount(key)
        assert rc == ref.refcount(key)
        assert rc == fleet.shards[fleet.owner(key)].refcount(key)


def _apply(ops, ref, fleet):
    """Interpret one op sequence on both stores; legality is judged on
    the reference store so both always take the same branch."""
    stores = (ref, fleet)
    for code, ki, seed in ops:
        key = KEYS[ki]
        rc = ref.refcount(key)
        if code == 0:                     # write / append a KV page
            if rc > 1:                    # never rewrite under co-owners
                continue
            data = synth.kv_cache(16, 32, seed=seed)
            for s in stores:
                s.submit([WriteReq(key, data, kind=KV)])
        elif code == 1:                   # acquire a co-owner reference
            if rc < 1:
                continue
            try:
                got = [s.acquire(key) for s in stores]
            except ValueError:            # truncated page: both refuse
                with pytest.raises(ValueError):
                    fleet.acquire(key)
                continue
            assert got[0] == got[1]
        elif code == 2:                   # release one reference
            if rc < 1:
                continue
            assert ref.release(key) == fleet.release(key)
        elif code == 3:                   # delete (co-owned → release)
            for s in stores:
                s.delete(key)
        elif code == 4:                   # shed mantissa planes in place
            if rc > 1:
                continue
            got = [s.truncate_planes([key], VIEWS["man4"]) for s in stores]
            assert got[0] == got[1], "reclaimed bytes must not depend on n"
        else:                             # retire a whole namespace
            prefix = key.split(".", 1)[0]
            assert ref.delete_prefix(prefix) == fleet.delete_prefix(prefix)
        _fleet_invariants(ref, fleet)


def test_sharded_ledger_property_random_interleavings():
    """Hypothesis sweep over random op interleavings (satellite 2)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(
        n=st.integers(min_value=2, max_value=4),
        ops=st.lists(
            st.tuples(st.integers(0, 5), st.integers(0, len(KEYS) - 1),
                      st.integers(0, 7)),
            max_size=30),
    )
    def run(n, ops):
        ref = ShardedTierStore(1, kind="trace", kv_window=16, sanitize=True)
        fleet = ShardedTierStore(n, kind="trace", kv_window=16,
                                 sanitize=True)
        _apply(ops, ref, fleet)
        # surviving pages read back byte-identical however wide the fleet
        live = [k for k in KEYS if ref.refcount(k) >= 1]
        if live:
            reqs = [ReadReq(k, kind=KV, view=FULL) for k in live]
            for a, b in zip(ref.submit(reqs), fleet.submit(reqs)):
                np.testing.assert_array_equal(a.data, b.data)
        # full retirement: one delete_prefix("") per outstanding reference
        for _ in range(len(ops) + 1):
            if ref.resident_bytes("") == 0:
                break
            for s in (ref, fleet):
                s.delete_prefix("")
            _fleet_invariants(ref, fleet)
        assert ref.resident_bytes("") == 0
        assert fleet.resident_bytes("") == 0
        assert all(s.resident_bytes("") == 0 and s.stats.blocks == 0
                   for s in fleet.shards)

    run()


@pytest.mark.parametrize("rng_seed", [0, 1, 2])
@pytest.mark.parametrize("n", [2, 4])
def test_sharded_ledger_fixed_random_interleavings(n, rng_seed):
    """Deterministic twin of the Hypothesis sweep: the same interpreter
    over seeded random op tapes, so the interleaving invariants run even
    where hypothesis is not installed."""
    rng = np.random.default_rng(rng_seed)
    ops = [(int(rng.integers(0, 6)), int(rng.integers(0, len(KEYS))),
            int(rng.integers(0, 8))) for _ in range(40)]
    ref = ShardedTierStore(1, kind="trace", kv_window=16, sanitize=True)
    fleet = ShardedTierStore(n, kind="trace", kv_window=16, sanitize=True)
    _apply(ops, ref, fleet)
    for _ in range(len(ops) + 1):
        if ref.resident_bytes("") == 0:
            break
        for s in (ref, fleet):
            s.delete_prefix("")
        _fleet_invariants(ref, fleet)
    assert fleet.resident_bytes("") == 0
    assert all(s.resident_bytes("") == 0 and s.stats.blocks == 0
               for s in fleet.shards)


# ---------------------------------------------------------------------------
# fault injection: a slow shard may cost time, never bits (satellite 3)
# ---------------------------------------------------------------------------

def _session(dev):
    pages = {f"r{i}.p{j}": synth.kv_cache(16, 32, seed=90 + 4 * i + j)
             for i in range(4) for j in range(3)}
    wrecs = dev.submit([WriteReq(k, v, kind=KV) for k, v in pages.items()])
    rrecs = dev.drain(dev.submit_async(
        [ReadReq(k, kind=KV) for k in pages]))
    return wrecs + rrecs


def test_slow_shard_changes_latency_never_bytes():
    fast = LinkModel()
    slow = LinkModel(ddr_bw=fast.ddr_bw / 64, link_bw=fast.link_bw / 64,
                     base_s=fast.base_s * 64)
    balanced = ShardedTierStore(4, kind="trace", kv_window=16,
                                link_models=[fast] * 4)
    degraded = ShardedTierStore(4, kind="trace", kv_window=16,
                                link_models=[slow] + [fast] * 3)
    ra, rb = _session(balanced), _session(degraded)
    slow_hit = False
    for a, b in zip(ra, rb):
        # every byte- and accounting-field identical; only time may move
        for f in SUM_FIELDS + ("key", "op", "kind", "device_id"):
            assert getattr(a, f) == getattr(b, f), f
        if a.data is None:
            assert b.data is None
        else:
            np.testing.assert_array_equal(a.data, b.data)
        assert b.latency_s >= a.latency_s
        if b.device_id == 0 and b.latency_s > a.latency_s:
            slow_hit = True
    assert slow_hit, "no request ever touched the slow shard"
    # receipt conservation holds on the degraded fleet, shard by shard
    for shard in degraded.shards:
        assert shard.stats.blocks >= 0
    for f in SUM_FIELDS:
        assert (sum(getattr(r, f) for r in rb)
                == getattr(degraded.stats, f)), f
    # and the fleet skew readout flags nothing (bytes stay balanced even
    # though time is not)
    assert degraded.fleet_skew() == balanced.fleet_skew()


def test_slow_shard_gates_async_completion():
    """The straggler's queue, not the fleet average, bounds drain time."""
    fast = LinkModel()
    slow = LinkModel(ddr_bw=fast.ddr_bw / 64, link_bw=fast.link_bw / 64,
                     base_s=fast.base_s * 64)
    done = {}
    for tag, models in (("balanced", [fast] * 4),
                        ("slow", [slow] + [fast] * 3)):
        dev = ShardedTierStore(4, kind="trace", kv_window=16,
                               link_models=models)
        dev.submit([
            WriteReq(f"p{i}", synth.kv_cache(16, 64, seed=110 + i), kind=KV)
            for i in range(16)
        ])
        dev.quiesce()
        recs = dev.drain(dev.submit_async(
            [ReadReq(f"p{i}", kind=KV) for i in range(16)]))
        done[tag] = max(r.latency_s for r in recs)
    assert done["slow"] > done["balanced"]
    assert dev.busy_backlog_s == 0.0      # drain leaves no residual work


# ---------------------------------------------------------------------------
# sanitizer on a fleet: clean runs stay silent, per-shard corruption trips
# ---------------------------------------------------------------------------

def test_sanitizer_env_reaches_every_shard(monkeypatch):
    monkeypatch.setenv("TRACE_SANITIZE", "1")
    fleet = ShardedTierStore(3, kind="trace", kv_window=16)
    assert fleet.sanitize
    assert all(s.sanitize for s in fleet.shards)


def test_sanitized_fleet_runs_clean():
    fleet = ShardedTierStore(3, kind="trace", kv_window=16, sanitize=True)
    _session(fleet)
    fleet.acquire("r0.p0")
    fleet.delete_prefix("r0")             # survives: one reference left
    assert fleet.refcount("r0.p0") == 1
    fleet.truncate_planes(["r1.p0"], VIEWS["man4"])
    fleet.delete_prefix("")
    assert fleet.resident_bytes("") == 0


def test_sanitizer_catches_single_shard_ledger_corruption():
    fleet = ShardedTierStore(3, kind="trace", kv_window=16, sanitize=True)
    pages = {f"r{i}.p{j}": synth.kv_cache(16, 32, seed=120 + 4 * i + j)
             for i in range(4) for j in range(2)}
    fleet.submit([WriteReq(k, v, kind=KV) for k, v in pages.items()])
    # corrupt ONE shard's residency ledger behind the fleet's back
    victim_key = next(k for k in pages if fleet.owner(k) == 1)
    fleet.shards[1]._ledger[victim_key].payload_bytes += 7
    with pytest.raises(SanitizerViolation) as ei:
        fleet.submit([ReadReq(victim_key, kind=KV)])
    assert ei.value.invariant == "ledger-stored-equality"
    assert ei.value.key == victim_key
    # the other shards are untouched and still serve reads
    clean_key = next(k for k in pages if fleet.owner(k) != 1)
    rec, = fleet.submit([ReadReq(clean_key, kind=KV)])
    np.testing.assert_array_equal(
        rec.data, ShardedTierStore(
            3, kind="trace", kv_window=16).submit(
            [WriteReq(clean_key, pages[clean_key], kind=KV),
             ReadReq(clean_key, kind=KV)])[1].data)
