"""Async queued submission: tickets, in-flight window, ordering hazards.

The contract under test: ``submit_async`` + ``drain`` is byte- and
receipt-identical to one sync ``submit`` of the same batch (for every
layout), receipts always sum exactly to the ``DeviceStats`` aggregate, and
the queue survives window overflow, out-of-order waits, double waits,
hazard fences, and mid-flush device failures without desyncing accounting.
"""

import numpy as np
import pytest

from repro.core import synth
from repro.core.precision import FULL, VIEWS
from repro.core.tier import (
    KV,
    LAYOUTS,
    LinkModel,
    ReadReq,
    TENSOR,
    TierStore,
    WriteReq,
    make_device,
)

SUM_FIELDS = (
    "dram_bytes_read", "dram_bytes_written", "dram_bytes_stored",
    "raw_bytes_stored", "link_bytes_in", "link_bytes_out",
    "index_bytes", "index_hits", "index_misses", "blocks",
)


def _sum_receipts(receipts):
    return {f: sum(getattr(r, f) for r in receipts) for f in SUM_FIELDS}


def _stats_dict(stats):
    return {f: getattr(stats, f) for f in SUM_FIELDS}


def _mixed_batch(kv_window):
    """Writes then reads over tensors + KV streams, several views."""
    batch = [
        WriteReq("w0", synth.weights(6_000, seed=0)),
        WriteReq("s0", synth.kv_cache(2 * kv_window, 64, seed=1), kind=KV),
        WriteReq("w1", synth.weights(2_048, seed=2)),
        WriteReq("s1", synth.kv_cache(kv_window, 32, seed=3), kind=KV),
        WriteReq("part", synth.kv_cache(kv_window // 2, 32, seed=4),
                 kind=KV, flush=False),          # stays staged → read flushes
    ]
    batch += [
        ReadReq("s0", kind=KV),
        ReadReq("w0", view=VIEWS["man4"]),
        ReadReq("s1", kind=KV, view=VIEWS["man0"]),
        ReadReq("w0", view=FULL),
        ReadReq("part", kind=KV),
        ReadReq("w1", block_range=(0, 1)),
    ]
    return batch


def _check_receipt_pair(sync_rec, async_rec):
    assert sync_rec.op == async_rec.op and sync_rec.key == async_rec.key
    if sync_rec.data is None:
        assert async_rec.data is None
    else:
        np.testing.assert_array_equal(sync_rec.data, async_rec.data)
    for f in SUM_FIELDS:
        assert getattr(sync_rec, f) == getattr(async_rec, f), f


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_async_drain_differential_vs_sync(layout):
    """submit_async + drain == submit: same bytes, same per-request traffic,
    same aggregate — for every layout, on a mixed tensor/KV batch."""
    kv_window = 16
    sync_dev = TierStore(layout=layout, kv_window=kv_window)
    async_dev = TierStore(layout=layout, kv_window=kv_window)
    batch = _mixed_batch(kv_window)
    # KV reduced views are only legal on kv-transform layouts
    if not sync_dev.layout.kv_transform:
        batch = [r if not (isinstance(r, ReadReq) and r.kind == KV)
                 else ReadReq(r.key, kind=KV, view=FULL, tag=r.tag)
                 for r in batch]

    sync_recs = sync_dev.submit(batch)
    tickets = async_dev.submit_async(batch)
    async_recs = async_dev.drain(tickets)

    assert len(sync_recs) == len(async_recs) == len(batch)
    for s, a in zip(sync_recs, async_recs):
        _check_receipt_pair(s, a)
    # receipt sums are conserved on both devices and agree with each other
    assert _sum_receipts(async_recs) == _stats_dict(async_dev.stats)
    assert _stats_dict(sync_dev.stats) == _stats_dict(async_dev.stats)


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_async_receipt_sums_conserved_across_flush_patterns(layout):
    """However the window slices the queue into flush groups, every receipt
    lands in the aggregate exactly once."""
    dev = TierStore(layout=layout, kv_window=8, window=3)
    streams = {f"s{i}": synth.kv_cache(8, 16, seed=20 + i) for i in range(7)}
    receipts = [
        t.wait()
        for t in dev.submit_async(
            [WriteReq(k, v, kind=KV) for k, v in streams.items()]
        )
    ]
    tickets = []
    for i, k in enumerate(streams):          # one call per request → window
        tickets += dev.submit_async([ReadReq(k, kind=KV)])  # overflow fires
        if i == 4:
            receipts.append(dev.submit(      # sync call drains the queue
                [ReadReq("s0", kind=KV)]
            )[0])
    receipts += dev.drain(tickets)
    assert _sum_receipts(receipts) == _stats_dict(dev.stats)


def test_window_limit_bounds_inflight_and_triggers_execution():
    # shards=1: pending counts assume one shared in-flight window
    dev = make_device("trace", kv_window=16, window=4, shards=1)
    dev.submit([WriteReq(f"p{i}", synth.kv_cache(16, 32, seed=i), kind=KV)
                for i in range(6)])
    base = _stats_dict(dev.stats)

    # up to `window` reads stay lazy: nothing executes, nothing is counted
    tickets = dev.submit_async([ReadReq(f"p{i}", kind=KV) for i in range(4)])
    assert dev.pending == 4
    assert not any(t.done for t in tickets)
    assert _stats_dict(dev.stats) == base

    # the (window+1)th read flushes the full group as one coalesced batch
    tickets += dev.submit_async([ReadReq("p4", kind=KV)])
    assert all(t.done for t in tickets[:4])
    assert not tickets[4].done and dev.pending == 1
    assert _stats_dict(dev.stats) != base

    dev.drain()
    assert dev.pending == 0 and tickets[4].done


def test_out_of_order_wait_and_double_wait():
    # shards=1: prefix-flush semantics are per-queue, not per-fleet
    dev = make_device("trace", kv_window=16, window=64, shards=1)
    data = {f"p{i}": synth.kv_cache(16, 32, seed=40 + i) for i in range(6)}
    dev.submit([WriteReq(k, v, kind=KV) for k, v in data.items()])
    tickets = dev.submit_async([ReadReq(k, kind=KV) for k in data])

    # waiting on a late ticket completes the queue prefix up to it...
    r4 = tickets[4].wait()
    assert all(t.done for t in tickets[:5])
    assert not tickets[5].done and dev.pending == 1
    # ...so earlier tickets answer out of wait order, without re-executing
    before = _stats_dict(dev.stats)
    r1 = tickets[1].wait()
    assert _stats_dict(dev.stats) == before
    np.testing.assert_array_equal(r1.data, data["p1"])
    np.testing.assert_array_equal(r4.data, data["p4"])
    # double-wait is idempotent: the very same receipt object
    assert tickets[4].wait() is r4 and tickets[1].wait() is r1
    np.testing.assert_array_equal(tickets[5].wait().data, data["p5"])


def test_validation_failure_leaves_device_and_queue_untouched():
    dev = make_device("trace", kv_window=16)
    dev.submit([WriteReq("w", synth.weights(2_048, seed=0))])
    ok = dev.submit_async([ReadReq("w")])
    before = _stats_dict(dev.stats)
    with pytest.raises(KeyError):
        dev.submit_async([WriteReq("x", synth.weights(2_048, seed=1)),
                          ReadReq("typo")])
    assert _stats_dict(dev.stats) == before   # nothing posted, nothing queued
    assert dev.pending == 1
    np.testing.assert_array_equal(
        dev.drain(ok)[0].data.ravel(), synth.weights(2_048, seed=0)
    )


def test_flush_failure_faults_all_group_tickets_then_device_recovers():
    """A device-side failure mid-flush (simulated decode fault) must fault
    every ticket of the group with the same error, keep wait() re-raising,
    and leave the device usable for subsequent requests."""
    # shards=1: the fault is injected into one device's layout object
    dev = make_device("trace", kv_window=16, window=64, shards=1)
    data = {f"p{i}": synth.kv_cache(16, 32, seed=60 + i) for i in range(3)}
    dev.submit([WriteReq(k, v, kind=KV) for k, v in data.items()])
    tickets = dev.submit_async([ReadReq(k, kind=KV) for k in data])

    real_decode = dev.layout.decode_batch
    boom = RuntimeError("simulated ECC fault")

    def faulty(*a, **kw):
        raise boom

    dev.layout.decode_batch = faulty
    try:
        with pytest.raises(RuntimeError, match="simulated ECC fault"):
            tickets[1].wait()
    finally:
        dev.layout.decode_batch = real_decode

    for t in tickets[:2]:                    # the failed flush group
        assert t.done
        with pytest.raises(RuntimeError, match="simulated ECC fault"):
            t.wait()                         # exception path is idempotent
    assert dev.pending == 1                  # ticket 2 was never flushed

    # the queue and device still work after the fault
    np.testing.assert_array_equal(tickets[2].wait().data, data["p2"])
    rec, = dev.submit([ReadReq("p0", kind=KV)])
    np.testing.assert_array_equal(rec.data, data["p0"])


def test_write_after_read_fence_preserves_program_order():
    """A write posted over a queued read of the same key must not be
    observed by that read: async results equal the sync program order."""
    dev = make_device("trace", kv_window=8, window=64)
    first = synth.kv_cache(8, 16, seed=0)
    more = synth.kv_cache(8, 16, seed=1)
    dev.submit([WriteReq("s", first, kind=KV)])
    t_read, = dev.submit_async([ReadReq("s", kind=KV)])
    dev.submit_async([WriteReq("s", more, kind=KV)])   # triggers the fence
    np.testing.assert_array_equal(t_read.wait().data, first)
    t2, = dev.submit_async([ReadReq("s", kind=KV)])
    np.testing.assert_array_equal(
        t2.wait().data, np.concatenate([first, more])
    )


def test_sync_submit_drains_queue_first():
    """Legacy sync callers always observe program order even with tickets
    outstanding (the drain-then-sync fallback of the protocol)."""
    dev = make_device("trace", kv_window=8, window=64)
    kv = synth.kv_cache(8, 16, seed=3)
    dev.submit([WriteReq("s", kv, kind=KV)])
    t, = dev.submit_async([ReadReq("s", kind=KV)])
    rec = dev.read_kv("s")                   # shim → submit → drains queue
    assert t.done
    np.testing.assert_array_equal(t.wait().data, kv)
    np.testing.assert_array_equal(rec, kv)


def test_delete_completes_inflight_reads_first():
    dev = make_device("trace", kv_window=8, window=64)
    kv = synth.kv_cache(8, 16, seed=4)
    dev.submit([WriteReq("s", kv, kind=KV)])
    t, = dev.submit_async([ReadReq("s", kind=KV)])
    dev.delete("s")
    np.testing.assert_array_equal(t.wait().data, kv)
    assert dev.n_blocks("s") == 0


def test_queue_delay_and_overlap_latency_model():
    """Receipts in one flush group share the pipes: completion times are
    monotone, each request's latency >= its serialized service, delay 0 on
    the group head (pipes quiesced), and the group completes faster than
    serial service."""
    # shards=1: the cumulative pipe math below models one device's clock
    dev = make_device("trace", kv_window=32, window=64, shards=1)
    dev.submit([WriteReq(f"p{i}", synth.kv_cache(32, 128, seed=80 + i),
                         kind=KV) for i in range(8)])
    dev.quiesce()     # writes are posted; idle the pipes so the read
    recs = dev.drain(dev.submit_async(   # group starts on a clean clock
        [ReadReq(f"p{i}", kind=KV) for i in range(8)]
    ))
    lats = [r.latency_s for r in recs]
    assert lats == sorted(lats)
    assert recs[0].queue_delay_s == 0.0
    for r in recs:
        assert r.service_s > 0
        assert r.latency_s >= r.service_s - 1e-18
        assert r.latency_s == pytest.approx(r.queue_delay_s + r.service_s)
    assert max(lats) < sum(r.service_s for r in recs)
    # the schedule helper agrees with an explicit cumulative computation
    # (the device's own model: named designs carry the calibrated
    # controller-anchor base_s, not the LinkModel() default constant)
    lm = dev.link_model
    traffic = [(r.dram_bytes_read, r.link_bytes_out) for r in recs]
    cum_d = cum_l = 0
    for (d, l), r in zip(traffic, recs):
        cum_d, cum_l = cum_d + d, cum_l + l
        want = lm.base_s + max(cum_d / lm.ddr_bw, cum_l / lm.link_bw)
        assert r.latency_s == pytest.approx(want)


def test_busy_clock_prices_cross_group_contention():
    """The device-global busy clock (ROADMAP open item): pipe occupancy
    left by groups the host never waited for — posted writes, window
    overflow flushes — delays LATER groups, while a host that waits (or
    quiesces) starts the next group on idle pipes.  Accounting stays
    exact: receipts-sum == DeviceStats regardless of latency pricing."""
    def fresh(window=2):
        # shards=1: backlog pricing assumes one device-global busy clock
        dev = make_device("trace", kv_window=16, window=window, shards=1)
        recs = dev.submit([WriteReq(f"p{i}", synth.kv_cache(16, 64,
                                                            seed=90 + i),
                                    kind=KV) for i in range(6)])
        return dev, recs

    # 1) posted writes leave backlog: an immediate read queues behind it,
    #    a quiesced read does not — same bytes, different delay
    dev_a, wrecs_a = fresh()
    busy, = dev_a.submit([ReadReq("p0", kind=KV)])
    dev_b, wrecs_b = fresh()
    dev_b.quiesce()
    idle, = dev_b.submit([ReadReq("p0", kind=KV)])
    assert busy.queue_delay_s > 0.0
    assert idle.queue_delay_s == 0.0
    assert busy.service_s == idle.service_s
    assert busy.latency_s == pytest.approx(
        busy.queue_delay_s + busy.service_s)
    # writes themselves price intra-group pipe sharing: later writes of
    # the posting group waited on earlier ones
    assert wrecs_a[0].queue_delay_s == 0.0
    assert all(r.queue_delay_s > 0 for r in wrecs_a[1:])

    # 2) window-overflow groups carry occupancy forward: with the host
    #    never waiting, the second flush group's head is delayed by the
    #    first group's residual
    dev_c, _ = fresh(window=2)
    dev_c.quiesce()
    tickets = []
    for i in range(5):   # window=2 → overflow flushes groups of 2
        tickets += dev_c.submit_async([ReadReq(f"p{i}", kind=KV)])
    heads = [t.wait() for t in tickets]
    assert heads[0].queue_delay_s == 0.0          # first group, idle pipes
    assert heads[2].queue_delay_s > 0.0           # second group head queued
    # 3) conservation is latency-independent
    recs = wrecs_a + [busy]
    assert _sum_receipts(recs) == _stats_dict(dev_a.stats)


# ---------------------------------------------------------------------------
# randomized interleaving differential (seeded mirror of the hypothesis
# property in test_property.py, so the invariant is exercised even where
# hypothesis is not installed)
# ---------------------------------------------------------------------------

def run_interleaving_differential(layout, ops, kv_window=8, window=3):
    """Replay ``ops`` on a sync-only device and on a device whose reads go
    through the async queue; assert byte-identical results and equal
    aggregate traffic.

    ``ops`` is a sequence of tuples:
      ("w",  key, seed, n_tokens)  — KV write (flush)
      ("wt", key, seed, n_elems)   — tensor write
      ("r",  key)                  — sync read
      ("ra", key)                  — async read (awaited at the end)
    Reads are only issued for keys already written.
    """
    sync_dev = TierStore(layout=layout, kv_window=kv_window, window=window)
    async_dev = TierStore(layout=layout, kv_window=kv_window, window=window)
    kinds = {}
    sync_out, async_tickets, async_expect = [], [], []

    for op in ops:
        if op[0] == "w":
            _, key, seed, n = op
            data = synth.kv_cache(n, 16, seed=seed)
            kinds[key] = KV
            sync_dev.submit([WriteReq(key, data, kind=KV)])
            async_dev.submit_async([WriteReq(key, data, kind=KV)])
        elif op[0] == "wt":
            _, key, seed, n = op
            data = synth.weights(n, seed=seed)
            kinds[key] = TENSOR
            sync_dev.submit([WriteReq(key, data)])
            async_dev.submit_async([WriteReq(key, data)])
        else:
            _, key = op[0], op[1]
            req = ReadReq(key, kind=kinds[key])
            want, = sync_dev.submit([req])
            if op[0] == "r":
                got, = async_dev.submit([req])
                np.testing.assert_array_equal(want.data, got.data)
            else:
                async_tickets += async_dev.submit_async([req])
                async_expect.append(want.data)
    for t, want in zip(async_tickets, async_expect):
        np.testing.assert_array_equal(t.wait().data, want)
    assert _stats_dict(sync_dev.stats) == _stats_dict(async_dev.stats)


def random_ops(rng, n_ops=24, n_keys=4):
    """A random program-order op sequence (shared with the property test)."""
    ops, written = [], []
    for _ in range(n_ops):
        roll = rng.random()
        key = f"k{rng.integers(n_keys)}"
        if roll < 0.4 or not written:
            if rng.random() < 0.5:
                ops.append(("w", key, int(rng.integers(1000)),
                            int(rng.integers(1, 4)) * 8))
            else:
                ops.append(("wt", key + "t", int(rng.integers(1000)),
                            int(rng.integers(1, 5)) * 512))
            written.append(ops[-1][1])
        elif roll < 0.65:
            ops.append(("r", written[int(rng.integers(len(written)))]))
        else:
            ops.append(("ra", written[int(rng.integers(len(written)))]))
    return ops


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_interleavings_differential(layout, seed):
    rng = np.random.default_rng(seed)
    run_interleaving_differential(layout, random_ops(rng))


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_write_heavy_async_interleaving_differential(layout):
    """Write-heavy async traffic (multi-write posting groups interleaved
    with queued reads — the prefill-spill / multi-stream-eviction shape):
    slab-batched write posting through ``submit_async`` must stay byte-
    and stats-identical to a sync-only device issuing one request at a
    time, across partial-window KV appends and a small read window."""
    rng = np.random.default_rng(7)
    sync_dev = TierStore(layout=layout, kv_window=8, window=3)
    async_dev = TierStore(layout=layout, kv_window=8, window=3)
    tickets, expected = [], []
    for round_ in range(6):
        # a burst of writes — several streams + a tensor — in ONE async
        # call (one encode slab), vs one-by-one sync submits
        writes = [
            WriteReq(f"s{round_}.{j}",
                     synth.kv_cache(4 + 4 * (j % 3), 16,
                                    seed=100 * round_ + j),
                     kind=KV, flush=(j % 2 == 0))
            for j in range(3)
        ] + [WriteReq(f"t{round_}", synth.weights(1024 * (1 + round_ % 3),
                                                  seed=round_))]
        for w in writes:
            sync_dev.submit([w])
        async_dev.submit_async(writes)
        # interleave async reads over earlier rounds' keys
        if round_ >= 1:
            key = f"s{round_ - 1}.0"
            want, = sync_dev.submit([ReadReq(key, kind=KV)])
            tickets += async_dev.submit_async([ReadReq(key, kind=KV)])
            expected.append(want.data)
    for t, want in zip(tickets, expected):
        np.testing.assert_array_equal(t.wait().data, want)
    assert _stats_dict(sync_dev.stats) == _stats_dict(async_dev.stats)


# ---------------------------------------------------------------------------
# KVPagePool over the async front-end (no model forward needed)
# ---------------------------------------------------------------------------

def _filled_pool(kind="trace", pages=6, layers=1, policy=None, shards=None):
    from repro.runtime.paging import KVPagePool

    kw = {"policy": policy} if policy is not None else {}
    pool = KVPagePool(make_device(kind, shards=shards), page_tokens=8,
                      hbm_budget_bytes=8 * 64 * 2 * 2, **kw)
    rng = np.random.default_rng(0)
    for i in range(pages):
        for layer in range(layers):
            page = (rng.normal(size=(8, 64)).astype(np.float32)
                    .view(np.uint32) >> 16).astype(np.uint16)
            pool.append_page(layer, "k", i * 8, page,
                             importance=float(i * layers + layer))
    return pool


@pytest.mark.parametrize("kind", ["plain", "gcomp", "trace"])
def test_pool_async_readback_matches_sync(kind):
    sync_pool, async_pool = _filled_pool(kind), _filled_pool(kind)
    spilled = [p for p in sync_pool._pages if p.resident is None]
    assert spilled
    want = sync_pool.read_pages(spilled)
    spilled_b = [p for p in async_pool._pages if p.resident is None]
    tickets = async_pool.read_pages_async(spilled_b)
    assert async_pool.device.pending == len(tickets)
    got = async_pool.drain_reads(tickets)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # identical traffic attribution and queue-delay accounting present
    a = {k: vars(t) for k, t in sync_pool.page_traffic.items()}
    b = {k: vars(t) for k, t in async_pool.page_traffic.items()}
    assert a == b
    assert async_pool.io_service_s > 0 and async_pool.io_queue_delay_s >= 0


def test_pool_prefetch_served_by_read_layer():
    plain, pre = _filled_pool(), _filled_pool()
    want = plain.read_layer(0, "k")
    n = pre.prefetch_layer(0, "k")
    assert n == pre.spilled_pages > 0
    assert pre.prefetch_layer(0, "k") == 0      # already in flight
    got = pre.read_layer(0, "k")
    np.testing.assert_array_equal(want, got)
    # every prefetch ticket was consumed and accounted exactly once
    assert not pre._prefetched
    assert _stats_dict(plain.device.stats) == _stats_dict(pre.device.stats)


def test_pool_prefetch_views_match_read_layer_multilayer_lossy():
    """Prefetch must rank views on the same (layer, kind)-subset basis as
    read_layer: under a lossy policy with several layers, a global-rank
    prefetch would issue mismatched views and every page would be
    discarded and re-read (regression test)."""
    from repro.runtime.paging import PAPER_POLICY

    plain = _filled_pool(layers=2, policy=PAPER_POLICY)
    pre = _filled_pool(layers=2, policy=PAPER_POLICY)
    want0, want1 = plain.read_layer(0, "k"), plain.read_layer(1, "k")
    assert pre.prefetch_layer(0, "k") > 0
    assert pre.prefetch_layer(1, "k") > 0
    np.testing.assert_array_equal(want0, pre.read_layer(0, "k"))
    np.testing.assert_array_equal(want1, pre.read_layer(1, "k"))
    assert not pre._prefetched                  # all consumed
    # consumed, not re-read: identical total traffic to the no-prefetch pool
    assert _stats_dict(plain.device.stats) == _stats_dict(pre.device.stats)


def _pool_traffic_sums(pool):
    fields = ("dram_bytes_read", "dram_bytes_written",
              "link_bytes_in", "link_bytes_out", "index_bytes")
    return {f: sum(getattr(t, f) for t in pool.page_traffic.values())
            for f in fields}


def test_abandoned_prefetch_stays_conserved():
    """A prefetch flushed by unrelated traffic but never consumed by
    read_layer must still be folded into the pool's receipts: the
    receipts-sum == device-stats invariant survives abandonment."""
    # shards=1: "unrelated traffic drains the queue" is a single-queue
    # coupling — on a fleet only the traffic's own shard flushes
    pool = _filled_pool(shards=1)
    assert pool.prefetch_layer(0, "k") > 0
    # unrelated sync traffic drains the device queue → prefetch executes
    spilled = [p for p in pool._pages if p.resident is None]
    pool.read_pages(spilled[:1])
    assert all(e[0].done for e in pool._prefetched.values())
    # stats() settles executed-but-unconsumed tickets before reporting
    d = pool.stats()
    want = {f: getattr(d, f) for f in
            ("dram_bytes_read", "dram_bytes_written",
             "link_bytes_in", "link_bytes_out", "index_bytes")}
    assert _pool_traffic_sums(pool) == want
    # the settled data is still served to a later read_layer, re-read-free
    before = pool.stats().dram_bytes_read
    pool.read_layer(0, "k")
    after = pool.stats().dram_bytes_read
    assert not pool._prefetched
    assert _pool_traffic_sums(pool)["dram_bytes_read"] == after
    assert after == before   # served from settled prefetch receipts


# ---------------------------------------------------------------------------
# Sharded async differential: the fleet front-end preserves the
# submit_async/drain contract for every layout and shard count
# ---------------------------------------------------------------------------

from repro.core.sharding import ShardedTierStore  # noqa: E402


def _legal_batch(dev, kv_window):
    batch = _mixed_batch(kv_window)
    if not dev.layout.kv_transform:
        batch = [r if not (isinstance(r, ReadReq) and r.kind == KV)
                 else ReadReq(r.key, kind=KV, view=FULL, tag=r.tag)
                 for r in batch]
    return batch


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("n", [1, 3])
def test_sharded_async_differential_vs_sync(layout, n):
    """Fleet submit_async + drain == fleet submit == bare-device submit:
    same bytes, same per-request traffic, for every layout, sync and
    async, at n=1 and n>1."""
    kv_window = 16
    bare = TierStore(layout=layout, kv_window=kv_window)
    sync_fleet = ShardedTierStore(n, layout=layout, kv_window=kv_window)
    async_fleet = ShardedTierStore(n, layout=layout, kv_window=kv_window)
    batch = _legal_batch(bare, kv_window)

    bare_recs = bare.submit(batch)
    sync_recs = sync_fleet.submit(batch)
    async_recs = async_fleet.drain(async_fleet.submit_async(batch))

    assert len(bare_recs) == len(sync_recs) == len(async_recs) == len(batch)
    for b, s, a in zip(bare_recs, sync_recs, async_recs):
        _check_receipt_pair(b, s)
        _check_receipt_pair(s, a)
    # fleet aggregate == receipt sums == bare-device totals
    assert _sum_receipts(async_recs) == _stats_dict(async_fleet.stats)
    assert _stats_dict(sync_fleet.stats) == _stats_dict(bare.stats)
    assert _stats_dict(async_fleet.stats) == _stats_dict(bare.stats)


@pytest.mark.parametrize("n", [2, 4])
def test_sharded_async_out_of_order_waits(n):
    """Waiting tickets in reverse order across shards still yields each
    request's own receipt, byte-identical to the in-order drain."""
    fleet = ShardedTierStore(n, kind="trace", kv_window=16)
    ref = ShardedTierStore(n, kind="trace", kv_window=16)
    pages = {f"p{i}": synth.kv_cache(16, 32, seed=70 + i) for i in range(9)}
    for dev in (fleet, ref):
        dev.submit([WriteReq(k, v, kind=KV) for k, v in pages.items()])
    reqs = [ReadReq(k, kind=KV) for k in pages]
    in_order = ref.drain(ref.submit_async(reqs))
    tickets = fleet.submit_async(reqs)
    reversed_recs = [t.wait() for t in reversed(tickets)][::-1]
    for a, b in zip(in_order, reversed_recs):
        assert a.key == b.key
        np.testing.assert_array_equal(a.data, b.data)
    assert fleet.pending == 0
