"""fp8 KV-cache storage (§Perf lever): decode must track the bf16-cache
decode closely — storage dtype only affects the cache, not the math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import decode_step, forward, init_cache, init_params

B, S = 2, 24


@pytest.mark.parametrize("name", ["qwen2-0.5b", "deepseek-v2-lite-16b"])
def test_fp8_cache_decode_tracks_bf16(name):
    cfg8 = dataclasses.replace(
        smoke_config(ARCHS[name]), kv_dtype="float8_e4m3fn"
    )
    cfg16 = dataclasses.replace(cfg8, kv_dtype="bfloat16")
    params = init_params(cfg16, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg16.vocab)

    outs = {}
    for cfg in (cfg16, cfg8):
        cache = init_cache(cfg, B, max_seq=S)
        assert cache["layers"][
            "c_kv" if cfg.mla else "k"
        ].dtype == jnp.dtype(cfg.kv_dtype)
        step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))
        seq = []
        for t in range(S):
            sb = {"tokens": toks[:, t : t + 1], "cache_pos": jnp.int32(t)}
            logits, cache = step(params, sb, cache)
            seq.append(np.asarray(logits[:, 0], np.float32))
        outs[cfg.kv_dtype] = np.stack(seq, 1)

    ref, got = outs["bfloat16"], outs["float8_e4m3fn"]
    # same top-1 for the overwhelming majority of positions
    agree = np.mean(ref.argmax(-1) == got.argmax(-1))
    assert agree > 0.9, agree
    corr = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert corr > 0.99
