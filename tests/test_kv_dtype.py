"""fp8 KV-cache storage (§Perf lever): decode must track the bf16-cache
decode closely — storage dtype only affects the cache, not the math.

"Tracks" is asserted with a margin-aware bound rather than a raw top-1
agreement rate: a random-init smoke model produces near-tied logits, so
fp8 rounding legitimately flips argmax at positions whose top-1 margin is
inside the fp8-induced perturbation band.  The invariants:

1. the perturbation itself is small on the *decision scale* — RMS logit
   error below half the median top-1 margin (this anchors the test: the
   band cannot silently widen itself, a ~2x fp8 tracking regression
   fails here);
2. every decisive position (bf16 margin above the band) agrees — flips
   only ever happen among near-ties;
3. the two logit trajectories stay globally correlated and flips stay
   rare overall.

This is deterministic — no seed retries, no blanket tolerance widening.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import decode_step, init_cache

pytestmark = pytest.mark.slow   # model-forward module

B, S = 2, 24


@pytest.mark.parametrize("name", ["qwen2-0.5b", "deepseek-v2-lite-16b"])
def test_fp8_cache_decode_tracks_bf16(name, smoke_model):
    cfg_base, params = smoke_model(name)
    cfg8 = dataclasses.replace(cfg_base, kv_dtype="float8_e4m3fn")
    cfg16 = dataclasses.replace(cfg8, kv_dtype="bfloat16")
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg16.vocab)

    outs = {}
    for cfg in (cfg16, cfg8):
        cache = init_cache(cfg, B, max_seq=S)
        assert cache["layers"][
            "c_kv" if cfg.mla else "k"
        ].dtype == jnp.dtype(cfg.kv_dtype)
        step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))
        seq = []
        for t in range(S):
            sb = {"tokens": toks[:, t : t + 1], "cache_pos": jnp.int32(t)}
            logits, cache = step(params, sb, cache)
            seq.append(np.asarray(logits[:, 0], np.float32))
        outs[cfg.kv_dtype] = np.stack(seq, 1)

    ref, got = outs["bfloat16"], outs["float8_e4m3fn"]
    agree = ref.argmax(-1) == got.argmax(-1)
    srt = np.sort(ref, axis=-1)
    margin = srt[..., -1] - srt[..., -2]            # bf16 top-1 margin
    rms = float(np.sqrt(np.mean((ref - got) ** 2)))

    # (1) anchored tracking bound: the fp8 perturbation must sit well
    # below the typical decision margin (measured headroom ~1.7-1.9x; a
    # ~2x error regression trips this even though the band below is
    # derived from the error itself)
    assert rms < 0.5 * float(np.median(margin)), (rms, np.median(margin))
    # (2) every decisive position must agree — fp8 may only flip near-ties
    band = 4.0 * rms
    decisive = margin > band
    assert agree[decisive].all(), (
        f"fp8 flipped a decisive position: margins "
        f"{margin[decisive & ~agree]}, band {band:.4f}"
    )
    # (3) flips stay rare even among near-ties, trajectories correlated
    assert agree.mean() > 0.8, agree.mean()
    corr = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert corr > 0.99
