"""decode_layout rules: batch_dp vs replicated (§Perf cell 4 lever)."""

import dataclasses

from repro.configs import ARCHS, SHAPES
from repro.launch import mesh as mesh_lib


def test_replicated_decode_layout_rules():
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    base = ARCHS["nemotron-4-340b"]
    assert base.decode_layout == "batch_dp"
    r_dp = mesh_lib.rules_for(base, SHAPES["decode_32k"], mesh)
    assert r_dp.rules["batch"] == ("data",)
    assert r_dp.rules["kv_seq"] == "model"

    repl = dataclasses.replace(base, decode_layout="replicated")
    r_re = mesh_lib.rules_for(repl, SHAPES["decode_32k"], mesh)
    assert r_re.rules["batch"] is None               # batch replicated
    assert r_re.rules["kv_seq"] == ("data", "model")  # cache over both axes
    assert r_re.rules["embed"] == "data"             # weights stay 2D

    # train cells are unaffected by the decode layout
    r_tr = mesh_lib.rules_for(repl, SHAPES["train_4k"], mesh)
    assert r_tr.rules["batch"] == ("data",)
