"""Property-based tests (hypothesis) on the system's core invariants:

1. bit-plane pack/unpack is a bijection for ANY uint16 payload;
2. the KV transform is lossless for ANY payload and ANY beta;
3. LZ4 compress/decompress round-trips ANY byte string;
4. every device kind returns byte-exact tensors at the full view
   (the paper's §III-D correctness invariant);
5. precision views: reconstruction only keeps kept-planes bits, guard
   rounding never moves a value by more than one ULP at the cut;
6. plane-aligned DRAM bytes are monotone in the view's plane count;
7. ANY interleaving of writes / sync reads / async reads, on any layout
   and any in-flight window size, returns the same bytes and the same
   total ``DeviceStats`` as a sync-only device (the async queue is
   semantically invisible).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import codec, synth
from repro.core.bitplane import pack_planes, plane_bytes, unpack_planes
from repro.core.kv_transform import (
    KVBlockMeta, kv_forward, kv_inverse, kv_pack, kv_unpack,
)
from repro.core.precision import (
    EXP_BITS, MAN_BITS, PrecisionView, truncate_reference, view_dram_bytes,
)
from repro.core.tier import LAYOUTS, make_device

u16s = st.integers(min_value=0, max_value=0xFFFF)


@st.composite
def u16_blocks(draw, min_elems=8, max_elems=512, multiple_of=8):
    n = draw(st.integers(min_elems // multiple_of, max_elems // multiple_of))
    data = draw(
        st.lists(u16s, min_size=n * multiple_of, max_size=n * multiple_of)
    )
    return np.array(data, dtype=np.uint16)


@given(u16_blocks())
@settings(max_examples=50, deadline=None)
def test_bitplane_bijection(block):
    planes = pack_planes(block)
    assert planes.shape == (16, plane_bytes(block.size))
    out = unpack_planes(planes, block.size)
    np.testing.assert_array_equal(out, block)


@given(u16_blocks(min_elems=32, max_elems=256, multiple_of=32),
       st.integers(0, 255))
@settings(max_examples=50, deadline=None)
def test_kv_transform_lossless_any_payload(block, beta_val):
    n = block.size // 8
    kv = block.reshape(n, 8)
    stream, meta = kv_forward(kv)
    np.testing.assert_array_equal(kv_inverse(stream, meta), kv)
    # arbitrary (non-modal) beta must also round-trip
    meta2 = KVBlockMeta(
        beta=np.full(8, beta_val, np.uint8), n_tokens=n, n_channels=8
    )
    # forward with forced beta: emulate by transposing manually
    stream2 = kv_inverse(stream, meta)  # original
    s3, m3 = kv_forward(stream2)
    np.testing.assert_array_equal(kv_inverse(s3, m3), kv)


@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=40, deadline=None)
def test_lz4_roundtrip_any_bytes(data):
    comp = codec.lz4_compress(data)
    out = codec.lz4_decompress(comp) if data else b""
    assert out == data


@given(st.binary(min_size=64, max_size=1024))
@settings(max_examples=20, deadline=None)
def test_compress_block_bypass_never_expands(data):
    payload, flag = codec.compress_block(data, "lz4")
    assert len(payload) <= len(data)
    assert codec.decompress_block(payload, flag, "lz4", len(data)) == data


@given(st.lists(st.binary(min_size=0, max_size=2048), min_size=1,
                max_size=12))
@settings(max_examples=30, deadline=None)
def test_compress_batch_identical_to_scalar_any_chunks(chunks):
    """The slab-vectorized batch encoder is byte-identical to per-block
    compression for ANY chunk mix — payloads, flags, and round-trip."""
    pays, flags = codec.compress_batch(chunks, "lz4")
    for chunk, pay, fl in zip(chunks, pays, flags):
        assert (pay, fl) == codec.compress_block(chunk, "lz4")
    assert codec.decompress_batch(pays, flags, "lz4",
                                  [len(c) for c in chunks]) == chunks


@st.composite
def encode_chunk_batches(draw, max_chunks=6):
    """Random uint16 chunk batches (sizes multiple of 8, mixed content
    classes) for layout-level encode parity."""
    chunks = []
    for _ in range(draw(st.integers(1, max_chunks))):
        n = draw(st.integers(1, 64)) * 8
        kind = draw(st.sampled_from(["random", "zero", "lowent", "smooth"]))
        if kind == "random":
            data = draw(st.lists(u16s, min_size=n, max_size=n))
            chunks.append(np.array(data, dtype=np.uint16))
        elif kind == "zero":
            chunks.append(np.zeros(n, dtype=np.uint16))
        elif kind == "lowent":
            val = draw(u16s)
            chunks.append(np.full(n, val, dtype=np.uint16)
                          ^ (np.arange(n, dtype=np.uint16) & 1))
        else:
            seed = draw(st.integers(0, 999))
            chunks.append(
                np.asarray(synth.weights(n, seed=seed), dtype=np.uint16))
    return chunks


@given(encode_chunk_batches(), st.sampled_from(sorted(LAYOUTS)))
@settings(max_examples=25, deadline=None)
def test_layout_encode_batch_identical_to_scalar(chunks, layout):
    """Layout-level parity over random chunk shapes/dtypes: the batched
    encoder (one pack + one compress_batch) equals the per-block scalar
    reference exactly, for every layout."""
    lay = LAYOUTS[layout]()
    assert lay.encode_batch(chunks, "lz4") == \
        lay.encode_batch_scalar(chunks, "lz4")


@given(u16_blocks(min_elems=64, max_elems=512, multiple_of=64))
@settings(max_examples=15, deadline=None)
def test_all_devices_full_view_byte_exact(block):
    kv = block.reshape(-1, 64)
    for kind in ("plain", "gcomp", "trace"):
        kw = {"kv_window": kv.shape[0]} if kind == "trace" else {}
        dev = make_device(kind, **kw)
        dev.write_kv("s", kv)
        if hasattr(dev, "flush_kv"):
            dev.flush_kv("s")
        np.testing.assert_array_equal(dev.read_kv("s"), kv)


@given(u16_blocks(), st.integers(0, MAN_BITS), st.integers(0, 1))
@settings(max_examples=50, deadline=None)
def test_view_reconstruction_invariants(block, r_m, d_m):
    if r_m + d_m > MAN_BITS:
        d_m = 0
    view = PrecisionView(r_e=EXP_BITS, r_m=r_m, d_m=d_m)
    out = truncate_reference(block, view)
    # only kept bits survive
    keep = np.uint16(0)
    for p in view.kept_planes():
        keep |= np.uint16(1 << p)
    assert np.all((out & ~keep) == 0)
    # rounding moves magnitude by at most one step at the cut
    cut = 7 - r_m
    step = np.uint16(1 << cut)
    mag_in = (block & np.uint16(0x7FFF)) & ~np.uint16((1 << cut) - 1)
    mag_out = out & np.uint16(0x7FFF)
    specials = (block & np.uint16(0x7F80)) == np.uint16(0x7F80)
    diff = np.abs(mag_out.astype(np.int32) - mag_in.astype(np.int32))
    assert np.all(diff[~specials] <= step)


@given(st.integers(0, MAN_BITS), st.integers(0, MAN_BITS))
@settings(max_examples=30, deadline=None)
def test_view_bytes_monotone(r1, r2):
    v1 = PrecisionView(r_m=min(r1, r2))
    v2 = PrecisionView(r_m=max(r1, r2))
    assert view_dram_bytes(4096, v1) <= view_dram_bytes(4096, v2)


# ---------------------------------------------------------------------------
# async queue: random interleavings are semantically invisible
# ---------------------------------------------------------------------------

@st.composite
def tier_programs(draw, n_keys=3, max_ops=14):
    """Program-order op sequences for ``run_interleaving_differential``:
    KV writes ("w"), tensor writes ("wt"), sync reads ("r") and async
    reads ("ra"), reads only over keys written earlier."""
    ops, written = [], []
    for _ in range(draw(st.integers(4, max_ops))):
        if not written or draw(st.booleans()):
            if draw(st.booleans()):
                key = f"k{draw(st.integers(0, n_keys - 1))}"
                ops.append(("w", key, draw(st.integers(0, 999)),
                            draw(st.integers(1, 3)) * 8))
            else:
                key = f"t{draw(st.integers(0, n_keys - 1))}"
                ops.append(("wt", key, draw(st.integers(0, 999)),
                            draw(st.integers(1, 4)) * 512))
            written.append(ops[-1][1])
        else:
            ops.append((draw(st.sampled_from(["r", "ra"])),
                        draw(st.sampled_from(written))))
    return ops


@given(tier_programs(), st.sampled_from(sorted(LAYOUTS)), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_async_interleavings_never_change_bytes_or_stats(ops, layout, window):
    """Replaying any write/read/async-read interleaving through the queued
    front-end returns byte-identical data and identical DeviceStats totals
    vs a sync-only device — for every layout and window size."""
    from test_tier_async import run_interleaving_differential

    run_interleaving_differential(ops=ops, layout=layout,
                                  kv_window=8, window=window)
