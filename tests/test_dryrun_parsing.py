"""Unit tests for dry-run HLO parsing + roofline math (no 512-device env —
dryrun.py itself is never imported by tests; the parsing helpers are
reimplemented import-safe here via importlib machinery)."""

import importlib.util
import sys
import types

import numpy as np


def _load_dryrun_parsers():
    """Load ONLY the parsing helpers from dryrun.py without triggering the
    XLA_FLAGS device-count side effect (we stub os.environ writes)."""
    import os

    spec = importlib.util.find_spec("repro.launch.dryrun")
    src = open(spec.origin).read()
    # strip the XLA_FLAGS preamble — tests must keep 1 device
    src = src.replace(
        'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"',
        "pass",
    )
    mod = types.ModuleType("dryrun_for_tests")
    mod.__package__ = "repro.launch"
    exec(compile(src, spec.origin, "exec"), mod.__dict__)
    return mod


DR = _load_dryrun_parsers()


HLO_SAMPLE = """
%all-gather.1 = bf16[8,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256]
%fusion.2 = f32[128]{0} fusion(%x), kind=kLoop
%all-reduce.3 = f32[2048]{0} all-reduce(%fusion.2), channel_id=2, replica_groups=[1,256]<=[256]
%tuple.ar = (bf16[64]{0}, bf16[32]{0}) all-reduce(%a, %b), channel_id=3
%reduce-scatter.4 = bf16[4,4]{1,0} reduce-scatter(%y), channel_id=4
%cp = f32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""


def test_collective_bytes_parses_ops():
    out = DR.collective_bytes(HLO_SAMPLE)
    assert out["n_all-gather"] == 1
    assert out["n_all-reduce"] == 2
    assert out["n_reduce-scatter"] == 1
    assert out["n_collective-permute"] == 1
    assert out["all-gather"] == 8 * 1024 * 2
    assert out["all-reduce"] == 2048 * 4 + 64 * 2 + 32 * 2
    assert out["reduce-scatter"] == 16 * 2
    assert out["total"] > 0


def test_collective_bytes_ignores_noncollectives():
    out = DR.collective_bytes("%dot = f32[4,4]{1,0} dot(%a, %b)\n")
    assert out["total"] == 0


def test_scan_trip_count():
    from repro.configs import ARCHS

    assert DR.scan_trip_count(ARCHS["qwen2-0.5b"]) == 24
    assert DR.scan_trip_count(ARCHS["deepseek-v2-lite-16b"]) == 26  # 27 - 1 dense
    assert DR.scan_trip_count(ARCHS["falcon-mamba-7b"]) == 64
    assert DR.scan_trip_count(ARCHS["zamba2-7b"]) == 81


def test_roofline_math():
    from benchmarks.roofline import analyse

    rec = {
        "arch": "qwen2-0.5b", "shape": "train_4k", "chips": 256,
        "kind": "train",
        "hlo_flops": 1e12, "hlo_flops_corrected": 1.6e13,
        "hlo_bytes": 1e11, "hlo_bytes_corrected": 8e11,
        "argument_size_in_bytes": int(2e10),
        "output_size_in_bytes": int(1e10),
        "temp_size_in_bytes": int(5e10),
        "collectives": {"total": 1e9},
        "collective_bytes_corrected": 2e10,
    }
    row = analyse(rec)
    assert row["t_compute_s"] == 1.6e13 / 197e12
    assert row["t_memory_s"] == 8e10 / 819e9        # mandatory bytes
    assert row["t_memory_hlo_s"] == 8e11 / 819e9    # fusion-waste signal
    assert row["t_collective_s"] == 2e10 / 100e9
    assert row["dominant"] == "collective"  # 0.2 s > mem 0.098 > comp 0.081
    assert 0 < row["useful_flops_ratio"] < 2


def test_multipod_group_decode():
    from repro.launch.verify_multipod import group_crosses_pods

    # consecutive groups of 16 inside one pod
    assert not group_crosses_pods("[32,16]<=[512]")
    # transposed: each group strides across both pods
    assert group_crosses_pods("[16,32]<=[32,16]T(1,0)")
    # explicit groups
    assert not group_crosses_pods("{{0,1,2},{3,4,5}}")
    assert group_crosses_pods("{{0,256}}")
