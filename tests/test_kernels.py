"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp/numpy oracles,
swept over shapes, views, and value distributions (incl. Inf/NaN)."""

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.core import bitplane as bp_np
from repro.core import synth
from repro.core.kv_transform import kv_forward
from repro.core.precision import PrecisionView, truncate_reference
from repro.kernels import (
    bitplane_pack,
    elastic_matmul,
    elastic_unpack,
    kv_transform,
    kv_transform_inv,
)
from repro.kernels import ref as kref


def _rand_u16(rng, shape, specials=False):
    u = rng.integers(0, 1 << 16, size=shape).astype(np.uint16)
    if specials:
        idx = rng.integers(0, u.size, size=max(u.size // 64, 1))
        flat = u.ravel()
        flat[idx[::2]] = 0x7FC0          # NaN
        flat[idx[1::2]] = 0xFF80         # -Inf
    return u


SHAPES = [(8, 128), (64, 256), (128, 1024), (32, 8)]


# ---------------------------------------------------------------------------
# bitplane pack / unpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
def test_pack_matches_oracle_and_numpy(shape):
    rng = np.random.default_rng(0)
    x = _rand_u16(rng, shape, specials=True)
    out = np.asarray(bitplane_pack(jnp.asarray(x)))
    ref = np.asarray(kref.pack_planes_2d(jnp.asarray(x)))
    np.testing.assert_array_equal(out, ref)
    # cross-check vs the device-side numpy path (flat layout)
    flat = np.asarray(bp_np.pack_planes(x.ravel()))
    np.testing.assert_array_equal(
        out.reshape(16, -1), flat
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_pack_unpack_roundtrip_bitexact(shape):
    rng = np.random.default_rng(1)
    x = _rand_u16(rng, shape, specials=True)
    planes = bitplane_pack(jnp.asarray(x))
    back = np.asarray(elastic_unpack(planes))  # full view
    np.testing.assert_array_equal(back, x)


@pytest.mark.parametrize("r_m,d_m", [(7, 0), (4, 1), (2, 1), (0, 1), (3, 0)])
@pytest.mark.parametrize("shape", [(64, 256), (8, 128)])
def test_elastic_unpack_views_match_reference(shape, r_m, d_m):
    """Kernel == jnp oracle == the numpy device-model reference, per view."""
    rng = np.random.default_rng(2)
    x = _rand_u16(rng, shape, specials=True)
    planes = bitplane_pack(jnp.asarray(x))
    out = np.asarray(elastic_unpack(planes, r_e=8, r_m=r_m, d_m=d_m))
    jref = np.asarray(kref.elastic_unpack_ref(planes, 8, r_m, d_m))
    np.testing.assert_array_equal(out, jref)
    view = PrecisionView(r_e=8, r_m=r_m, d_m=d_m)
    nref = truncate_reference(x.ravel(), view).reshape(shape)
    np.testing.assert_array_equal(out, nref)


def test_elastic_unpack_view_is_valid_bf16_truncation():
    """man4+guard view must be within 1 ulp(cut) of the full value."""
    rng = np.random.default_rng(3)
    f = rng.normal(size=(64, 256)).astype(ml_dtypes.bfloat16)
    x = f.view(np.uint16)
    planes = bitplane_pack(jnp.asarray(x))
    out = np.asarray(elastic_unpack(planes, r_m=4, d_m=1)).view(ml_dtypes.bfloat16)
    rel = np.abs(out.astype(np.float32) - f.astype(np.float32))
    scale = np.maximum(np.abs(f.astype(np.float32)), 1e-30)
    assert np.quantile(rel / scale, 0.99) < 2.0 ** (-4)  # 4 mantissa bits


# ---------------------------------------------------------------------------
# KV transform
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,C", [(64, 128), (256, 256), (16, 512)])
def test_kv_transform_matches_numpy_pipeline(n, C):
    kv = synth.kv_cache(n, C, seed=5)
    stream_np, meta = kv_forward(kv)            # numpy reference chain
    out = np.asarray(
        kv_transform(jnp.asarray(kv), jnp.asarray(meta.beta))
    )
    np.testing.assert_array_equal(out.ravel(), stream_np)


@pytest.mark.parametrize("n,C", [(64, 128), (256, 256)])
def test_kv_transform_roundtrip(n, C):
    kv = synth.kv_cache(n, C, seed=6)
    _, meta = kv_forward(kv)
    beta = jnp.asarray(meta.beta)
    cm = kv_transform(jnp.asarray(kv), beta)
    back = np.asarray(kv_transform_inv(cm, beta))
    np.testing.assert_array_equal(back, kv)
    # jnp oracle agreement
    jref = np.asarray(kref.kv_delta_ref(jnp.asarray(kv), beta))
    np.testing.assert_array_equal(np.asarray(cm), jref)


def test_kv_transform_arbitrary_beta_roundtrips():
    """Losslessness must not depend on beta being modal (mod-256 zigzag)."""
    rng = np.random.default_rng(7)
    kv = _rand_u16(rng, (64, 128), specials=True)
    beta = jnp.asarray(rng.integers(0, 256, 128).astype(np.int32))
    cm = kv_transform(jnp.asarray(kv), beta)
    back = np.asarray(kv_transform_inv(cm, beta))
    np.testing.assert_array_equal(back, kv)


# ---------------------------------------------------------------------------
# elastic matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(8, 64, 128), (16, 512, 256), (128, 128, 128)])
@pytest.mark.parametrize("r_m,d_m", [(7, 0), (4, 1), (0, 1)])
def test_elastic_matmul_matches_oracle(M, K, N, r_m, d_m):
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (M, K), jnp.bfloat16)
    w = jax.random.normal(kw, (K, N), jnp.bfloat16)
    planes = kref.pack_weights_kmajor(w)
    out = elastic_matmul(x, planes, r_m=r_m, d_m=d_m)
    ref = kref.elastic_matmul_ref(x, planes, r_m, d_m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_elastic_matmul_full_view_equals_dense():
    key = jax.random.PRNGKey(1)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (16, 256), jnp.bfloat16)
    w = jax.random.normal(kw, (256, 128), jnp.bfloat16)
    planes = kref.pack_weights_kmajor(w)
    out = np.asarray(elastic_matmul(x, planes, r_m=7, d_m=0))
    dense = np.asarray(jnp.dot(x, w, preferred_element_type=jnp.float32))
    np.testing.assert_allclose(out, dense, rtol=1e-6, atol=1e-6)


def test_elastic_matmul_precision_degrades_gracefully():
    """Error must grow monotonically-ish as mantissa planes drop, and the
    man0 view must still track the dense result to ~exponent precision."""
    key = jax.random.PRNGKey(2)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (32, 512), jnp.bfloat16)
    w = jax.random.normal(kw, (512, 256), jnp.bfloat16)
    planes = kref.pack_weights_kmajor(w)
    dense = np.asarray(jnp.dot(x, w, preferred_element_type=jnp.float32))
    errs = []
    for r_m in (7, 4, 2, 0):
        out = np.asarray(elastic_matmul(x, planes, r_m=r_m, d_m=1))
        errs.append(np.abs(out - dense).mean())
    assert errs[0] <= errs[1] <= errs[2] <= errs[3] + 1e-6
    # man0 = sign+exponent grid: per-weight rel. error ≤ 1/3 under
    # round-to-nearest → accumulated mean rel. error well under 0.35
    assert errs[3] / (np.abs(dense).mean() + 1e-9) < 0.35


def test_fetched_plane_bytes_scale():
    """The kernel input slice must shrink with the view (bytes ∝ planes)."""
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 128), jnp.bfloat16)
    planes = kref.pack_weights_kmajor(w)
    full = planes.size
    man0 = planes[jnp.array([15] + list(range(14, 6, -1)))].size
    assert man0 / full == pytest.approx(9 / 16)
