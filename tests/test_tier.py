"""Device-model correctness: host-visible values are byte-exact across all
three designs (paper §III-D invariant) while traffic differs."""

import numpy as np
import pytest

from repro.core import codec, precision as prec, synth
from repro.core.tier import GCompDevice, PlainDevice, TraceDevice
from repro.core import controller, dram_model, system_model as sm

# Prefer the real zstd when installed; otherwise exercise the same paths
# with the built-in lz4 (the registry would fall back anyway, but tests
# should say what they run).  zstd-only cases skip via ZSTD_ONLY.
CODEC = "zstd" if codec.HAVE_ZSTD else "lz4"
ZSTD_ONLY = pytest.mark.skipif(not codec.HAVE_ZSTD,
                               reason="zstandard not installed")
ALL_CODECS = [
    "lz4",
    pytest.param("zstd", marks=ZSTD_ONLY),
]


@pytest.fixture(params=["plain", "gcomp", "trace"])
def device(request):
    from repro.core.tier import make_device

    return make_device(request.param, codec=CODEC)


@pytest.mark.parametrize("codec_name", ALL_CODECS)
def test_weight_roundtrip_all_devices_codecs(device, codec_name):
    device.codec = codec.resolve_codec(codec_name)
    w = synth.weights(10_000, seed=1)
    device.write_tensor("w", w)
    out = device.read_tensor("w")
    np.testing.assert_array_equal(out.ravel(), w)


def test_kv_roundtrip_trace_matches_plain():
    kv = synth.kv_cache(256, 128, seed=2)
    tr, pl = TraceDevice(codec=CODEC, kv_window=64), PlainDevice()
    for t in range(0, 256, 16):
        tr.write_kv("kv", kv[t : t + 16])
    pl.write_kv("kv", kv)
    np.testing.assert_array_equal(tr.read_kv("kv"), kv)
    np.testing.assert_array_equal(pl.read_kv("kv").ravel(), kv.ravel())


def test_trace_compresses_kv_better_than_gcomp():
    kv = synth.kv_cache(512, 256, seed=3)
    tr = TraceDevice(codec=CODEC, kv_window=128)
    gc = GCompDevice(codec=CODEC)
    tr.write_kv("kv", kv)
    tr.flush_kv("kv")
    gc.write_kv("kv", kv)
    r_tr = tr.stats.compression_ratio
    r_gc = gc.stats.compression_ratio
    assert r_tr > r_gc * 1.2, (r_tr, r_gc)
    assert r_tr > 1.4


def test_precision_view_moves_fewer_dram_bytes():
    w = synth.weights(32_768, seed=4)
    dev = TraceDevice(codec=CODEC)
    dev.write_tensor("w", w)
    dev.stats.reset_traffic()
    dev.read_tensor("w", prec.FULL)
    full_bytes = dev.stats.dram_bytes_read
    dev.stats.reset_traffic()
    out = dev.read_tensor("w", prec.MAN0)
    reduced_bytes = dev.stats.dram_bytes_read
    assert reduced_bytes < 0.75 * full_bytes
    # host-visible values equal the truncation oracle
    want = prec.truncate_reference(w, prec.MAN0)
    np.testing.assert_array_equal(out.ravel(), want)


def test_kv_reduced_view_error_is_bounded():
    import ml_dtypes

    kv = synth.kv_cache(128, 64, seed=5)
    dev = TraceDevice(codec=CODEC, kv_window=64)
    dev.write_kv("kv", kv)
    out = dev.read_kv("kv", prec.MAN2)
    f0 = kv.view(ml_dtypes.bfloat16).astype(np.float64)
    f1 = out.view(ml_dtypes.bfloat16).astype(np.float64)
    denom = np.abs(f0).mean()
    # 2 kept mantissa bits + 1 guard bit RNE → mean |rel err| ≈ 6-7%
    assert np.abs(f0 - f1).mean() / denom < 0.08
    # exactness: device pipeline == plane-mask + rounding oracle
    want = prec.truncate_reference(kv, prec.MAN2)
    np.testing.assert_array_equal(out, want.reshape(out.shape))


def test_index_cache_hit_miss_accounting():
    dev = TraceDevice(codec=CODEC, index_cache_entries=2)
    w = synth.weights(2048 * 8, seed=6)
    dev.write_tensor("w", w)
    dev.stats.reset_traffic()
    dev.read_tensor("w")
    assert dev.stats.index_misses == 8          # 8 blocks, cold cache
    assert dev.stats.index_bytes == 8 * 64
    dev.stats.reset_traffic()
    dev.read_tensor("w")
    assert dev.stats.index_misses >= 6          # cache only holds 2 entries


def test_incompressible_blocks_bypass():
    rng = np.random.default_rng(7)
    noise = rng.integers(0, 2**16, size=4096, dtype=np.uint16)
    dev = GCompDevice(codec="lz4")
    dev.write_tensor("n", noise)
    assert dev.stats.dram_bytes_stored <= noise.size * 2  # never inflates
    np.testing.assert_array_equal(dev.read_tensor("n").ravel(), noise)


# ---------------------------------------------------------------------------
# analytic models reproduce the paper's anchor points
# ---------------------------------------------------------------------------

def test_controller_matches_table_v():
    assert controller.load_to_use_cycles("plain") == 71
    assert controller.load_to_use_cycles("gcomp", comp_ratio=1.5) == 84
    assert controller.load_to_use_cycles("trace", comp_ratio=1.5) == 89
    assert controller.load_to_use_cycles("trace", comp_ratio=3.0) == 85
    assert controller.load_to_use_cycles("trace", bypass=True) == 76
    miss = controller.load_to_use_cycles("trace", comp_ratio=1.5, meta_hit=False)
    assert miss > 89 + 30  # one extra DRAM window


def test_controller_ppa_table():
    t = controller.PPA_TABLE
    assert t["trace"].area_mm2 == pytest.approx(7.14)
    rel_area = t["trace"].area_mm2 / t["gcomp"].area_mm2 - 1
    rel_pwr = t["trace"].power_w / t["gcomp"].power_w - 1
    assert rel_area == pytest.approx(0.072, abs=0.002)
    assert rel_pwr == pytest.approx(0.047, abs=0.002)


def test_staging_buffer_eq4():
    assert controller.staging_sram_bytes(64, 128) == 64 * 128 * 2 + 64


def test_dram_plane_fetch_saves_energy_at_head_granularity():
    for t in (1.6, 4.8, 8.0):
        b = dram_model.energy_per_weight_pj(dram_model.HEAD, t, "plain")
        tr = dram_model.energy_per_weight_pj(dram_model.HEAD, t, "trace")
        sav = 1 - tr / b
        assert 0.15 < sav < 0.45, (t, sav)   # paper band: 30.5-40.9%
    # neuron granularity saves less (plane-stripe gap activations)
    sav_head = 1 - dram_model.energy_per_weight_pj(
        dram_model.HEAD, 4.8, "trace"
    ) / dram_model.energy_per_weight_pj(dram_model.HEAD, 4.8, "plain")
    sav_neuron = 1 - dram_model.energy_per_weight_pj(
        dram_model.NEURON, 4.8, "trace"
    ) / dram_model.energy_per_weight_pj(dram_model.NEURON, 4.8, "plain")
    assert sav_head > sav_neuron > 0
    # latency savings track byte savings (paper Fig. 19: 25-30% on BF16)
    lp = dram_model.load_latency_s(960, dram_model.HEAD, 4.8, "plain")
    lt = dram_model.load_latency_s(960, dram_model.HEAD, 4.8, "trace")
    assert 0.1 < 1 - lt / lp < 0.6


def test_system_model_fig12_anchors():
    """Reproduce the paper's Fig. 12 shape: overlap before spill, cliff for
    word devices after, TRACE ~4x at 128k and sustained at the cap."""
    m = sm.gpt_oss_120b("mxfp4")
    ctxs = [65536, 131072, 196608, 262144]
    res = sm.sweep_context(m, ctxs)
    # short context: all designs pinned at the compute cap (CXL off path)
    for d in ("plain", "gcomp", "trace"):
        assert res[d][0] == pytest.approx(68.99)
    # 128k: plain collapses (paper 16.28), gcomp ~= plain, trace ~= cap
    assert res["plain"][1] == pytest.approx(16.28, rel=0.2)
    assert res["gcomp"][1] == pytest.approx(res["plain"][1], rel=0.1)
    assert res["trace"][1] > 4.0 * res["plain"][1]
    # monotone decreasing once spilled
    assert res["trace"][3] < res["trace"][2] <= 68.99


def test_system_model_fig13_weight_spill():
    m = sm.gpt_oss_120b("bf16")
    res = {d: sm.throughput(m, 4096, d, alpha=0.8).tok_s
           for d in ("plain", "gcomp", "trace")}
    # paper: 33.61 / 36.97 / 42.02 at 4k (weights spill, KV hot)
    assert res["plain"] == pytest.approx(33.61, rel=0.05)
    assert res["gcomp"] == pytest.approx(36.97, rel=0.05)
    assert res["trace"] == pytest.approx(42.02, rel=0.05)


def test_system_model_alpha_unimodal():
    m = sm.gpt_oss_120b("bf16")
    alphas = np.linspace(0.1, 0.99, 45)
    res = sm.sweep_alpha(m, 65536, alphas)
    for d in ("plain", "gcomp", "trace"):
        ys = res[d]
        peak = int(np.argmax(ys))
        assert 0 < peak < len(ys) - 1  # interior peak → unimodal trade-off
    # TRACE peak ≥ others and at ≥ alpha (paper Fig. 14)
    assert max(res["trace"]) > max(res["gcomp"]) > max(res["plain"])
    assert np.argmax(res["trace"]) >= np.argmax(res["plain"])
