"""Lossless round-trip properties of the TRACE core transforms."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: only @given tests skip
    class _AnyStrategy:
        """Chainable stand-in so module-level strategy expressions parse."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*a, **k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = fn.__name__
            return skipped

        return deco

    def settings(*a, **k):
        return lambda fn: fn

from repro.core import bitplane as bp
from repro.core import codec
from repro.core import kv_transform as kvt
from repro.core import precision as prec


u16_arrays = st.integers(0, 2**16 - 1)


@given(st.lists(u16_arrays, min_size=8, max_size=512).filter(lambda l: len(l) % 8 == 0))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(vals):
    x = np.array(vals, dtype=np.uint16)
    planes = bp.pack_planes(x)
    assert planes.shape == (16, len(vals) // 8)
    y = bp.unpack_planes(planes, len(vals))
    np.testing.assert_array_equal(x, y)


def test_pack_unpack_jnp_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2**16, size=256, dtype=np.uint16)
    pn = bp.pack_planes(x)
    pj = np.asarray(bp.pack_planes_jnp(x))
    np.testing.assert_array_equal(pn, pj)
    yj = np.asarray(bp.unpack_planes_jnp(pj, 256))
    np.testing.assert_array_equal(x, yj)


def test_special_values_roundtrip():
    import ml_dtypes

    specials = np.array(
        [0x7F80, 0xFF80, 0x7FC0, 0x7FFF, 0x0001, 0x8000, 0x0000],  # inf,-inf,nan,nan,subnormal,-0,0
        dtype=np.uint16,
    )
    x = np.tile(specials, 8)[:56]
    x = np.pad(x, (0, 8 - x.size % 8))
    y = bp.unpack_planes(bp.pack_planes(x), x.size)
    np.testing.assert_array_equal(x, y)
    _ = x.view(ml_dtypes.bfloat16)  # merely checks the view is legal


@given(
    st.integers(1, 16),   # tokens (rows)
    st.integers(1, 32),   # channels
    st.integers(0, 2**32 - 1),
)
@settings(max_examples=40, deadline=None)
def test_kv_transform_roundtrip(n, c, seed):
    rng = np.random.default_rng(seed)
    block = rng.integers(0, 2**16, size=(n, c), dtype=np.uint16)
    stream, meta = kvt.kv_forward(block)
    back = kvt.kv_inverse(stream, meta)
    np.testing.assert_array_equal(block, back)


def test_kv_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    block = rng.integers(0, 2**16, size=(64, 32), dtype=np.uint16)
    planes, meta = kvt.kv_pack(block)
    back = kvt.kv_unpack(planes, meta)
    np.testing.assert_array_equal(block, back)


def test_kv_transform_reduces_exponent_entropy():
    """Smooth per-channel series must yield near-empty high delta planes."""
    import ml_dtypes

    rng = np.random.default_rng(2)
    base = rng.normal(0, 1, size=(1, 64))
    walk = base + 0.01 * np.cumsum(rng.normal(0, 1, size=(128, 64)), axis=0)
    block = walk.astype(ml_dtypes.bfloat16).view(np.uint16)
    stream, _ = kvt.kv_forward(block)
    planes = bp.pack_planes(stream)
    # top 4 delta-exponent planes (bits 14..11) should be mostly zero bytes
    top = planes[11:15]
    assert (top == 0).mean() > 0.9


def test_kv_forward_jnp_matches_numpy():
    rng = np.random.default_rng(3)
    block = rng.integers(0, 2**16, size=(32, 16), dtype=np.uint16)
    stream, meta = kvt.kv_forward(block)
    out_j = np.asarray(kvt.kv_forward_jnp(block, meta.beta)).ravel()
    np.testing.assert_array_equal(stream, out_j)


# ---------------------------------------------------------------------------
# precision views
# ---------------------------------------------------------------------------

def test_full_view_is_identity():
    rng = np.random.default_rng(4)
    x = rng.integers(0, 2**16, size=2048, dtype=np.uint16)
    planes = bp.pack_planes(x)
    y = prec.assemble_from_planes(planes, x.size, prec.FULL)
    np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("view", [prec.MAN4, prec.MAN2, prec.MAN0])
def test_view_matches_truncation_oracle(view):
    rng = np.random.default_rng(5)
    x = rng.integers(0, 2**16, size=2048, dtype=np.uint16)
    planes = bp.pack_planes(x)
    got = prec.assemble_from_planes(planes, x.size, view)
    want = prec.truncate_reference(x, view)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("view", [prec.MAN4, prec.MAN2])
def test_guard_rounding_beats_truncation(view):
    """RNE with guard planes must have ≤ error of plain truncation."""
    import ml_dtypes

    rng = np.random.default_rng(6)
    f = rng.normal(0, 1, size=4096).astype(ml_dtypes.bfloat16)
    x = f.view(np.uint16)
    planes = bp.pack_planes(x)
    rounded = prec.assemble_from_planes(planes, x.size, view)
    trunc_view = prec.PrecisionView(r_e=view.r_e, r_m=view.r_m, d_m=0)
    truncated = prec.assemble_from_planes(planes, x.size, trunc_view)
    err_r = np.abs(
        rounded.view(ml_dtypes.bfloat16).astype(np.float64) - f.astype(np.float64)
    ).mean()
    err_t = np.abs(
        truncated.view(ml_dtypes.bfloat16).astype(np.float64) - f.astype(np.float64)
    ).mean()
    assert err_r <= err_t * 1.0001
    assert np.isfinite(err_r)


def test_view_plane_counts():
    assert prec.FULL.bits == 16
    assert len(prec.FULL.fetched_planes()) == 16
    assert prec.MAN0.bits == 9
    assert len(prec.MAN0.fetched_planes()) == 10  # + 1 guard plane
    assert prec.MAN2.plane_mask() & (1 << 15)


def test_qnan_preserved_under_views():
    """Quiet NaNs (mantissa MSB set — all NaNs produced by IEEE hardware)
    survive any view with r_m >= 1.  A *signaling* NaN whose payload lives
    only in dropped planes is physically unreadable by plane-aligned fetch
    and collapses to Inf; documented semantics, not a bug."""
    x = np.full(64, 0x7FC1, dtype=np.uint16)  # qNaN + low payload bit
    planes = bp.pack_planes(x)
    y = prec.assemble_from_planes(planes, 64, prec.MAN2)
    exp_mask, man_mask = 0x7F80, 0x007F
    assert ((y & exp_mask) == exp_mask).all()
    assert ((y & man_mask) != 0).all()  # still NaN, not Inf
    inf = np.full(8, 0xFF80, dtype=np.uint16)  # -Inf survives exactly
    yi = prec.assemble_from_planes(bp.pack_planes(inf), 8, prec.MAN0)
    np.testing.assert_array_equal(yi, inf)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=60, deadline=None)
def test_lz4_roundtrip(data):
    comp = codec.lz4_compress(data)
    back = codec.lz4_decompress(comp)
    assert back == data


def test_lz4_compresses_runs():
    data = b"\x00" * 4096
    comp = codec.lz4_compress(data)
    assert len(comp) < 64
    assert codec.lz4_decompress(comp) == data


@pytest.mark.skipif(not codec.HAVE_ZSTD, reason="zstandard not installed")
def test_zstd_roundtrip():
    rng = np.random.default_rng(7)
    data = rng.integers(0, 4, size=4096, dtype=np.uint8).tobytes()
    comp = codec.zstd_compress(data)
    assert codec.zstd_decompress(comp, max_out=4096) == data


def test_bypass_on_incompressible():
    rng = np.random.default_rng(8)
    data = rng.integers(0, 256, size=4096, dtype=np.uint8).tobytes()
    payload, flag = codec.compress_block(data, "lz4")
    if flag == codec.RAW:
        assert payload == data
    assert codec.decompress_block(payload, flag, "lz4", len(data)) == data
