"""Optimizer + gradient-compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, init as opt_init, update as opt_update
from repro.optim.grad_compress import (
    compress_grads, compressed_bytes, init_error_feedback,
)


def _params():
    return {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.05, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt_init(cfg, params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(60):
        g = jax.grad(loss_fn)(params)
        params, state, _ = opt_update(cfg, g, state, params)
    assert float(loss_fn(params)) < 0.2


def test_adamw_low_precision_moments():
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    params = _params()
    state = opt_init(cfg, params)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    g = jax.tree.map(jnp.ones_like, params)
    _, state, _ = opt_update(cfg, g, state, params)
    assert state["nu"]["w"].dtype == jnp.bfloat16


def test_grad_clip_metric():
    cfg = AdamWConfig(clip_norm=1e-3)
    params = _params()
    state = opt_init(cfg, params)
    g = jax.tree.map(lambda p: 100.0 * jnp.ones_like(p), params)
    new_params, _, m = opt_update(cfg, g, state, params)
    assert float(m["grad_norm"]) > 100
    # clipped step must be tiny
    delta = np.abs(np.asarray(new_params["w"]) - np.asarray(params["w"]))
    assert delta.max() < 0.1


def test_error_feedback_preserves_signal():
    """Quantisation residual must be carried, not lost: the SUM of
    dequantised grads over steps converges to the sum of true grads."""
    params = {"w": jnp.zeros((64,))}
    err = init_error_feedback(params)
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    for step in range(50):
        g = {"w": jnp.asarray(rng.normal(0, 1e-4, 64), jnp.float32)}
        true_sum += np.asarray(g["w"])
        deq, err = compress_grads(g, err)
        deq_sum += np.asarray(deq["w"], dtype=np.float64)
    resid = np.abs(np.asarray(err["w"], dtype=np.float64))
    np.testing.assert_allclose(deq_sum, true_sum, atol=resid.max() + 1e-5)


def test_compressed_bytes_quarter_of_f32():
    params = _params()
    wire = compressed_bytes(params)
    f32 = sum(p.size * 4 for p in jax.tree.leaves(params))
    assert wire < f32 / 3  # int8 + per-tensor scale
