"""TierStore request/receipt protocol: batched semantics + accounting.

Receipts are the unit of traffic attribution; the legacy ``DeviceStats``
aggregate must be exactly the sum of all receipts, and batched submission
must be byte-identical to sequential single-request reads.
"""

import time

import numpy as np
import pytest

from repro.core import synth
from repro.core.precision import FULL, MAN0, MAN4, VIEWS
from repro.core.tier import (
    KV,
    TENSOR,
    BitplaneLayout,
    LAYOUTS,
    ReadReq,
    TierStore,
    WordLayout,
    WriteReq,
    make_device,
)

RECEIPT_FIELDS = (
    "dram_bytes_read", "dram_bytes_written", "dram_bytes_stored",
    "raw_bytes_stored", "link_bytes_in", "link_bytes_out",
    "index_bytes", "index_hits", "index_misses", "blocks",
)


def _sum_receipts(receipts):
    return {f: sum(getattr(r, f) for r in receipts) for f in RECEIPT_FIELDS}


def _stats_dict(stats):
    return {f: getattr(stats, f) for f in RECEIPT_FIELDS}


@pytest.mark.parametrize("kind", ["plain", "gcomp", "trace"])
def test_receipts_sum_to_device_totals_mixed_batch(kind):
    """Per-request receipts across a mixed tensor/KV session reproduce the
    DeviceStats aggregate field-for-field."""
    dev = make_device(kind, kv_window=32)
    w = synth.weights(5_000, seed=0)
    kv = synth.kv_cache(96, 64, seed=1)

    receipts = []
    receipts += dev.submit([
        WriteReq("w", w, kind=TENSOR),
        WriteReq("s0", kv[:48], kind=KV),
        WriteReq("s1", kv[48:], kind=KV),
    ])
    receipts += dev.submit([
        ReadReq("w", kind=TENSOR, view=MAN4),
        ReadReq("s0", kind=KV),
        ReadReq("w", kind=TENSOR),
        ReadReq("s1", kind=KV, view=MAN0 if kind == "trace" else FULL),
    ])
    assert _sum_receipts(receipts) == _stats_dict(dev.stats)
    for r in receipts:
        assert r.latency_s > 0


def test_write_receipts_carry_capacity_and_compression():
    dev = make_device("trace")
    kv = synth.kv_cache(256, 128, seed=2)
    rec, = dev.submit([WriteReq("kv", kv, kind=KV)])
    assert rec.op == "write" and rec.kind == KV
    assert rec.blocks == dev.stats.blocks > 0
    assert rec.raw_bytes_stored == kv.size * 2
    assert 0 < rec.dram_bytes_stored < rec.raw_bytes_stored  # compressed
    assert rec.link_bytes_in == kv.size * 2


@pytest.mark.parametrize("kind", ["plain", "gcomp", "trace"])
def test_batched_reads_byte_identical_to_sequential(kind):
    """One submit over many streams == the same reads one at a time."""
    dev_a = make_device(kind, kv_window=16)
    dev_b = make_device(kind, kv_window=16)
    views = [FULL, VIEWS["man4"], VIEWS["man0"], FULL]
    streams = {}
    for i in range(8):
        streams[f"p{i}"] = synth.kv_cache(16, 64, seed=10 + i)
    for dev in (dev_a, dev_b):
        dev.submit([WriteReq(k, v, kind=KV) for k, v in streams.items()])

    reqs = [ReadReq(k, kind=KV, view=views[i % len(views)])
            for i, k in enumerate(streams)]
    batched = dev_a.submit(reqs)
    for req, rec in zip(reqs, batched):
        seq, = dev_b.submit([req])
        np.testing.assert_array_equal(rec.data, seq.data)
        assert rec.dram_bytes_read == seq.dram_bytes_read
        assert rec.link_bytes_out == seq.link_bytes_out
    # both devices saw identical total traffic
    assert _stats_dict(dev_a.stats) == _stats_dict(dev_b.stats)


def test_batch_and_legacy_shims_agree():
    dev = make_device("trace")
    w = synth.weights(9_000, seed=3)
    dev.write_tensor("w", w)
    via_shim = dev.read_tensor("w", VIEWS["man4"])
    via_batch, = dev.submit([ReadReq("w", view=VIEWS["man4"])])
    np.testing.assert_array_equal(via_shim, via_batch.data)


def test_write_then_read_in_one_batch():
    dev = make_device("trace")
    w = synth.weights(4_096, seed=4)
    wrec, rrec = dev.submit([WriteReq("w", w), ReadReq("w")])
    assert wrec.op == "write" and rrec.op == "read"
    np.testing.assert_array_equal(rrec.data.ravel(), w)


def test_block_range_reads():
    dev = make_device("trace")
    w = synth.weights(2048 * 4, seed=5)
    dev.write_tensor("w", w)
    whole, = dev.submit([ReadReq("w")])
    part, = dev.submit([ReadReq("w", block_range=(1, 3))])
    np.testing.assert_array_equal(part.data, whole.data.ravel()[2048:2048 * 3])
    assert part.blocks == 0  # blocks counts commits, not reads
    assert part.dram_bytes_read < whole.dram_bytes_read


def test_kv_read_flushes_partial_window_with_accounting():
    dev = make_device("trace", kv_window=64)
    kv = synth.kv_cache(40, 32, seed=6)  # < one window
    dev.submit([WriteReq("s", kv, kind=KV, flush=False)])
    assert dev.stats.blocks == 0  # still staged
    rec, = dev.submit([ReadReq("s", kind=KV)])
    np.testing.assert_array_equal(rec.data, kv)
    # the implicit flush is accounted on the read's receipt
    assert rec.dram_bytes_written > 0
    assert _stats_dict(dev.stats)["dram_bytes_written"] == rec.dram_bytes_written


def test_word_layouts_cannot_scale_link_traffic():
    """Reduced views cut DRAM + link bytes only on plane-aligned layouts
    (paper Issue 2); word devices move full containers either way."""
    n = 2048 * 8
    w = synth.weights(n, seed=7)
    for kind, scales in (("plain", False), ("gcomp", False), ("trace", True)):
        dev = make_device(kind)
        dev.write_tensor("w", w)
        full, = dev.submit([ReadReq("w", view=FULL)])
        low, = dev.submit([ReadReq("w", view=VIEWS["man0"])])
        if scales:
            assert low.link_bytes_out < full.link_bytes_out
            assert low.dram_bytes_read < full.dram_bytes_read
        else:
            assert low.link_bytes_out == full.link_bytes_out


def test_trace_kv_view_requires_full_exponent():
    dev = make_device("trace", kv_window=16)
    dev.submit([WriteReq("s", synth.kv_cache(16, 16, seed=8), kind=KV)])
    from repro.core.precision import PrecisionView

    with pytest.raises(ValueError):
        dev.submit([ReadReq("s", kind=KV, view=PrecisionView(r_e=4))])


def test_layout_registry_and_device_configs():
    assert set(LAYOUTS) == {"word", "word-comp", "bitplane", "bitplane-kv"}
    assert isinstance(make_device("plain").layout, WordLayout)
    assert not make_device("plain").layout.compress
    assert make_device("gcomp").layout.compress
    tr = make_device("trace")
    assert isinstance(tr.layout, BitplaneLayout) and tr.layout.kv_transform
    # a custom composition: bit-plane substrate without the KV transform
    store = TierStore(layout="bitplane", codec="lz4", kv_window=32)
    kv = synth.kv_cache(64, 32, seed=9)
    store.submit([WriteReq("s", kv, kind=KV)])
    rec, = store.submit([ReadReq("s", kind=KV)])
    np.testing.assert_array_equal(rec.data, kv)


def test_pool_speaks_protocol_only_and_attributes_traffic():
    """KVPagePool works with every device kind (no isinstance special
    cases) and its per-page traffic sums to the device aggregate."""
    import ml_dtypes

    from repro.runtime.paging import KVPagePool

    for kind in ("plain", "gcomp", "trace"):
        pool = KVPagePool(kind, page_tokens=8, hbm_budget_bytes=8 * 64 * 2 * 2)
        rng = np.random.default_rng(0)
        for i in range(6):
            page = rng.normal(size=(8, 64)).astype(ml_dtypes.bfloat16)
            pool.append_page(0, "k", i * 8, page.view(np.uint16),
                             importance=float(i))
        assert pool.spilled_pages == 4
        out = pool.read_layer(0, "k")
        assert out.shape == (48, 64)
        got = {
            f: sum(getattr(t, f) for t in pool.page_traffic.values())
            for f in ("dram_bytes_read", "dram_bytes_written",
                      "link_bytes_in", "link_bytes_out", "index_bytes")
        }
        want = {f: getattr(pool.stats(), f) for f in got}
        assert got == want, kind
        assert pool.traffic_by_layer()[0].requests == sum(
            t.requests for t in pool.page_traffic.values()
        )


def test_missing_key_read_raises_before_any_mutation():
    dev = make_device("trace")
    dev.submit([WriteReq("w", synth.weights(2048, seed=0))])
    before = _stats_dict(dev.stats)
    with pytest.raises(KeyError):
        dev.submit([WriteReq("x", synth.weights(2048, seed=1)),
                    ReadReq("typo")])
    # the invalid batch committed nothing and counted nothing
    assert _stats_dict(dev.stats) == before
    with pytest.raises(KeyError):
        dev.read_tensor("typo")


def test_batched_kv_stream_read_faster_than_sequential():
    """A 64-page batched submit must beat 64 sequential read_kv calls —
    the batch path amortizes plane unpack + reconstruction across blocks.
    Serving-sized pages (16 tokens x 64 ch) keep the margin wide."""
    dev = make_device("trace", kv_window=16)
    keys = [f"p{i}" for i in range(64)]
    dev.submit([
        WriteReq(k, synth.kv_cache(16, 64, seed=100 + i), kind=KV)
        for i, k in enumerate(keys)
    ])
    reqs = [ReadReq(k, kind=KV) for k in keys]

    def batched():
        return [r.data for r in dev.submit(reqs)]

    def sequential():
        return [dev.read_kv(k) for k in keys]

    # warm up (index cache population is identical for both afterwards)
    b0, s0 = batched(), sequential()
    for b, s in zip(b0, s0):
        np.testing.assert_array_equal(b, s)

    def best_of(fn, reps=3):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_batch, t_seq = best_of(batched), best_of(sequential)
    # generous margin to keep CI stable; locally the gap is much larger
    assert t_batch < t_seq, (t_batch, t_seq)


# ---------------------------------------------------------------------------
# Sharding differential: the fleet front-end must be invisible at the
# request/receipt protocol (satellite of the ShardedTierStore PR)
# ---------------------------------------------------------------------------

from repro.core.sharding import ShardedTierStore  # noqa: E402

SHARD_RECEIPT_FIELDS = RECEIPT_FIELDS + (
    "latency_s", "queue_delay_s", "service_s", "device_id",
)


def _mixed_session(dev):
    """The same mixed tensor/KV write+read session against any store."""
    w = synth.weights(5_000, seed=20)
    kv = synth.kv_cache(96, 64, seed=21)
    recs = list(dev.submit([
        WriteReq("w", w, kind=TENSOR),
        WriteReq("a.s0", kv[:48], kind=KV),
        WriteReq("b.s1", kv[48:], kind=KV),
    ]))
    recs += dev.submit([
        ReadReq("w", kind=TENSOR, view=VIEWS["man4"]),
        ReadReq("a.s0", kind=KV),
        ReadReq("b.s1", kind=KV),
        ReadReq("w", kind=TENSOR),
    ])
    return recs


@pytest.mark.parametrize("kind", ["plain", "gcomp", "trace"])
def test_sharded_n1_receipt_identical_to_bare(kind):
    """A one-shard fleet is receipt-identical to the bare device: every
    accounting field, every modeled time, the stamped device_id, and the
    returned bytes — the wrapper adds routing, not semantics."""
    bare = make_device(kind, shards=1, kv_window=32)
    fleet = ShardedTierStore(1, kind=kind, kv_window=32)
    ra, rb = _mixed_session(bare), _mixed_session(fleet)
    assert len(ra) == len(rb)
    for a, b in zip(ra, rb):
        for f in SHARD_RECEIPT_FIELDS:
            assert getattr(a, f) == getattr(b, f), f
        if a.data is None:
            assert b.data is None
        else:
            np.testing.assert_array_equal(a.data, b.data)
    assert _stats_dict(bare.stats) == _stats_dict(fleet.stats)


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("n", [2, 3])
def test_sharded_reads_byte_identical_across_widths(layout, n):
    """n>1 read-back is byte-identical to the one-shard fleet for every
    layout: placement chooses where bytes live, never what they are."""
    solo = ShardedTierStore(1, layout=layout, kv_window=16)
    fleet = ShardedTierStore(n, layout=layout, kv_window=16)
    pages = {f"r{i}.p{j}": synth.kv_cache(16, 32, seed=30 + 4 * i + j)
             for i in range(3) for j in range(4)}
    w = synth.weights(2_048, seed=29)
    for dev in (solo, fleet):
        dev.submit([WriteReq("w", w, kind=TENSOR)] + [
            WriteReq(k, v, kind=KV) for k, v in pages.items()
        ])
    reqs = [ReadReq("w", kind=TENSOR)] + [
        ReadReq(k, kind=KV) for k in pages
    ]
    for a, b in zip(solo.submit(reqs), fleet.submit(reqs)):
        np.testing.assert_array_equal(a.data, b.data)
    # the fleet actually spread the pages: more than one device moved bytes
    if n > 1:
        touched = [i for i, s in enumerate(fleet.per_device_stats())
                   if s.dram_bytes_stored > 0]
        assert len(touched) > 1, "hash-stripe left the fleet idle"
    # device_id on every receipt names the serving shard
    for rec in fleet.submit([ReadReq(k, kind=KV) for k in pages]):
        assert rec.device_id == fleet.owner(rec.key)


def test_sharded_precision_views_byte_identical():
    """Precision-scaled reads (the paper's elastic KV) survive sharding
    bit-for-bit on the plane-aligned trace device."""
    solo = ShardedTierStore(1, kind="trace", kv_window=16)
    fleet = ShardedTierStore(4, kind="trace", kv_window=16)
    pages = {f"p{i}": synth.kv_cache(16, 64, seed=50 + i) for i in range(8)}
    for dev in (solo, fleet):
        dev.submit([WriteReq(k, v, kind=KV) for k, v in pages.items()])
    for view in (FULL, VIEWS["man4"], VIEWS["man0"]):
        reqs = [ReadReq(k, kind=KV, view=view) for k in pages]
        for a, b in zip(solo.submit(reqs), fleet.submit(reqs)):
            np.testing.assert_array_equal(a.data, b.data)


def test_sharded_fleet_rejects_bad_batch_atomically():
    """A malformed request anywhere in a fleet batch must reject before
    ANY shard commits — same all-or-nothing contract as one device."""
    fleet = ShardedTierStore(3, kind="trace", kv_window=16)
    fleet.submit([WriteReq("ok", synth.kv_cache(16, 32, seed=60), kind=KV)])
    before = [_stats_dict(s) for s in fleet.per_device_stats()]
    with pytest.raises(KeyError):
        fleet.submit([
            WriteReq("new", synth.kv_cache(16, 32, seed=61), kind=KV),
            ReadReq("never-written", kind=KV),
        ])
    assert [_stats_dict(s) for s in fleet.per_device_stats()] == before
