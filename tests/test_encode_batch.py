"""Batched encode pipeline: parity with the scalar path, bypass accounting.

The hard invariant of the write-path refactor: for every layout, the
vectorized batched encoder (one plane pack + one compress_batch per encode
slab, batched KV transform) produces byte-identical stored payloads, flags,
index entries and receipts to the scalar O(blocks x planes) reference
pipeline.  Also covers the codec-level batch primitives and the bypass
pre-screen / threshold accounting.
"""

import time

import numpy as np
import pytest

from repro.core import codec, synth
from repro.core.kv_transform import kv_forward, kv_forward_batch
from repro.core.tier import (
    KV,
    LAYOUTS,
    ReadReq,
    TENSOR,
    TierStore,
    WriteReq,
)

RECEIPT_FIELDS = (
    "dram_bytes_read", "dram_bytes_written", "dram_bytes_stored",
    "raw_bytes_stored", "link_bytes_in", "link_bytes_out",
    "index_bytes", "index_hits", "index_misses", "blocks",
    "codec_blocks", "codec_bypass",
)


def _mixed_write_batch(kv_window):
    return [
        WriteReq("w0", synth.weights(6_000, seed=0)),
        WriteReq("s0", synth.kv_cache(2 * kv_window, 64, seed=1), kind=KV),
        WriteReq("w1", synth.weights(2_048, seed=2)),
        WriteReq("s1", synth.kv_cache(kv_window, 32, seed=3), kind=KV),
        WriteReq("s0", synth.kv_cache(kv_window, 64, seed=4), kind=KV),
        WriteReq("part", synth.kv_cache(kv_window // 2, 32, seed=5),
                 kind=KV, flush=False),
        # random (incompressible) payload exercises the bypass pre-screen
        WriteReq("rnd", np.random.default_rng(9).integers(
            0, 1 << 16, 4096).astype(np.uint16)),
    ]


def _storage_state(dev):
    """Everything a differential comparison should see: per-key payload
    bytes + flags + block geometry + KV metadata + shapes."""
    out = {}
    for key, blocks in dev._tensors.items():
        out[key] = [
            (b.payloads, b.flags, b.valid_elems, b.padded_elems,
             None if b.kv_meta is None else
             (b.kv_meta.beta.tobytes(), b.kv_meta.n_tokens,
              b.kv_meta.n_channels))
            for b in blocks
        ]
    return out, dict(dev._shapes)


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_batched_encode_byte_identical_to_scalar(layout):
    """Stored bytes, index entries and receipts agree exactly between the
    batched and scalar encoders, for sync and async write posting."""
    kv_window = 16
    scalar_dev = TierStore(layout=layout, kv_window=kv_window,
                           batched_encode=False)
    batched_dev = TierStore(layout=layout, kv_window=kv_window,
                            batched_encode=True)
    batch = _mixed_write_batch(kv_window)
    s_recs = scalar_dev.submit(batch)
    b_recs = [t.wait() for t in batched_dev.submit_async(batch)]

    assert _storage_state(scalar_dev) == _storage_state(batched_dev)
    for s, b in zip(s_recs, b_recs):
        for f in RECEIPT_FIELDS:
            assert getattr(s, f) == getattr(b, f), f
    for f in RECEIPT_FIELDS:
        assert getattr(scalar_dev.stats, f) == getattr(batched_dev.stats, f)

    # ... and reads of the stored data agree bit for bit
    for key, kind in (("w0", TENSOR), ("s0", KV), ("part", KV)):
        a, = scalar_dev.submit([ReadReq(key, kind=kind)])
        b, = batched_dev.submit([ReadReq(key, kind=kind)])
        np.testing.assert_array_equal(a.data, b.data)


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_single_vs_multi_request_posting_identical(layout):
    """Slab-batching across a posting group must not change what any
    individual request stores: one submit of N writes == N submits."""
    kv_window = 16
    one = TierStore(layout=layout, kv_window=kv_window)
    many = TierStore(layout=layout, kv_window=kv_window)
    batch = _mixed_write_batch(kv_window)
    one.submit(batch)
    for req in batch:
        many.submit([req])
    assert _storage_state(one) == _storage_state(many)


def test_compress_batch_matches_compress_block():
    rng = np.random.default_rng(0)
    chunks = [
        bytes(rng.integers(0, 256, 4096, dtype=np.uint8)),   # incompressible
        b"\x00" * 4096,                                       # pure run
        b"abcd" * 1024,                                       # periodic
        b"",                                                  # empty
        b"xy",                                                # tiny
        bytes(np.tile(rng.integers(0, 256, 97).astype(np.uint8), 50)),
        (b"the quick brown fox jumps over the lazy dog. " * 120)[:4096],
    ]
    for name in codec.CODECS:
        pays, flags = codec.compress_batch(chunks, name)
        for chunk, pay, fl in zip(chunks, pays, flags):
            p2, f2 = codec.compress_block(chunk, name)
            assert (pay, fl) == (p2, f2), name
        outs = codec.decompress_batch(pays, flags, name,
                                      [len(c) for c in chunks])
        assert outs == chunks


def test_lz4_batch_identity_random_battery():
    rng = np.random.default_rng(1)
    battery = [bytes(rng.integers(0, hi, n, dtype=np.uint8))
               for hi in (2, 4, 256)
               for n in (0, 1, 5, 12, 13, 127, 128, 255, 512, 4096)]
    scalar = [codec.lz4_compress(c) for c in battery]
    batched = codec.lz4_compress_batch(battery)
    assert scalar == batched
    for data, comp in zip(battery, scalar):
        if data:
            assert codec.lz4_decompress(comp, max_out=len(data)) == data


def test_prescreen_routes_incompressible_to_bypass():
    rng = np.random.default_rng(2)
    noise = bytes(rng.integers(0, 256, 2048, dtype=np.uint8))
    assert codec.prescreen_bypass(noise)
    pay, fl = codec.compress_block(noise, "lz4")
    assert fl == codec.RAW and pay == noise
    # compressible payloads must never be pre-screened away
    for data in (b"\x00" * 2048, b"ab" * 1024,
                 bytes(np.tile(rng.integers(0, 256, 256).astype(np.uint8),
                               16))):
        assert not codec.prescreen_bypass(data)
        _, fl = codec.compress_block(data, "lz4")
        assert fl == codec.COMPRESSED
    # short blocks skip the screen entirely
    assert not codec.prescreen_bypass(noise[:64])


def test_bypass_threshold_and_counters():
    """BYPASS_THRESHOLD is the documented bypass rule; receipts and
    DeviceStats expose per-block bypass counts (paper §III-D)."""
    assert codec.BYPASS_THRESHOLD == 1.0   # never store an expanded block
    dev = TierStore(layout="bitplane-kv", kv_window=32)
    rec, = dev.submit([WriteReq("s", synth.kv_cache(64, 64, seed=7),
                                kind=KV)])
    # 16 plane streams per committed block went through the bypass rule
    assert rec.codec_blocks == rec.blocks * 16
    assert 0 < rec.codec_bypass < rec.codec_blocks
    assert dev.stats.codec_blocks == rec.codec_blocks
    assert dev.stats.codec_bypass == rec.codec_bypass
    assert 0.0 < dev.stats.bypass_rate < 1.0
    # uncompressed layouts never consult the codec
    plain = TierStore(layout="word")
    prec, = plain.submit([WriteReq("w", synth.weights(2048, seed=1))])
    assert prec.codec_blocks == prec.codec_bypass == 0
    assert plain.stats.bypass_rate == 0.0


def test_kv_forward_batch_matches_scalar():
    wins = np.stack([synth.kv_cache(16, 32, seed=i) for i in range(6)])
    streams, metas = kv_forward_batch(wins)
    for i in range(len(wins)):
        s, m = kv_forward(wins[i])
        np.testing.assert_array_equal(streams[i], s)
        np.testing.assert_array_equal(metas[i].beta, m.beta)
        assert (metas[i].n_tokens, metas[i].n_channels) == (m.n_tokens,
                                                            m.n_channels)


def test_pack_planes_slab_pallas_matches_numpy():
    from repro.core.bitplane import pack_planes
    from repro.kernels.bitplane import pack_planes_slab

    rng = np.random.default_rng(3)
    for n in (64, 2048, 2048 * 3, 97 * 8):
        flat = rng.integers(0, 1 << 16, n).astype(np.uint16)
        np.testing.assert_array_equal(pack_planes_slab(flat),
                                      pack_planes(flat))
        # the pallas kernel path (interpret mode on CPU) packs identically
        np.testing.assert_array_equal(
            pack_planes_slab(flat, force="pallas"), pack_planes(flat))


def test_batched_encode_faster_than_scalar():
    """A serving-sized KV flush through the batched encoder must beat the
    scalar O(blocks x planes) pipeline — the write-side mirror of
    test_batched_kv_stream_read_faster_than_sequential.  Generous margin
    (plain 'faster', not the benchmarked ~3x) keeps CI stable."""
    data = [synth.kv_cache(32, 64, seed=200 + i) for i in range(24)]
    reqs = [WriteReq(f"p{i}", d, kind=KV) for i, d in enumerate(data)]

    def run(batched):
        best = float("inf")
        for _ in range(3):
            dev = TierStore(layout="bitplane-kv", kv_window=32,
                            batched_encode=batched)
            t0 = time.perf_counter()
            dev.submit(reqs)
            best = min(best, time.perf_counter() - t0)
        return best

    t_scalar, t_batched = run(False), run(True)
    assert t_batched < t_scalar, (t_batched, t_scalar)
