"""Runtime accounting sanitizer: happy path stays silent through full
write/read/truncate/delete cycles, and each invariant trips — with the
violation naming it — under targeted fault injection (corrupted ledger
row, dropped receipt, rewound busy clock, oversized in-flight window,
skipped retirement cleanup).
"""

import numpy as np
import pytest

from repro.core.precision import VIEWS
from repro.core.tier import (
    ReadReq, SanitizerViolation, WriteReq,
)
from repro.core.tier import make_device as _make_device


def make_device(kind, **kw):
    # This file white-box-probes one device's _san/_ledger internals;
    # pin a bare TierStore even when TRACE_SHARDS widens the default
    # (the fleet-level sanitizer runs live in test_sharding_store.py).
    return _make_device(kind, shards=1, **kw)


def _payload(seed=0, shape=(64, 256)):
    return np.random.default_rng(seed).integers(
        0, 1 << 16, size=shape, dtype=np.uint16)


def _loaded_device(n_keys=3, **kw):
    dev = make_device("trace", sanitize=True, **kw)
    for i in range(n_keys):
        dev.submit([WriteReq(key=f"k{i}", data=_payload(i))])
    return dev


# ---------------------------------------------------------------------------
# activation plumbing
# ---------------------------------------------------------------------------

def test_env_var_activates(monkeypatch):
    monkeypatch.setenv("TRACE_SANITIZE", "1")
    assert make_device("trace").sanitize
    monkeypatch.setenv("TRACE_SANITIZE", "0")
    assert not make_device("trace").sanitize
    monkeypatch.delenv("TRACE_SANITIZE")
    assert not make_device("trace").sanitize


def test_explicit_flag_beats_env(monkeypatch):
    monkeypatch.setenv("TRACE_SANITIZE", "1")
    assert not make_device("trace", sanitize=False).sanitize
    monkeypatch.delenv("TRACE_SANITIZE")
    assert make_device("trace", sanitize=True).sanitize


def test_default_is_off(monkeypatch):
    monkeypatch.delenv("TRACE_SANITIZE", raising=False)
    dev = make_device("trace")
    assert not dev.sanitize and dev._san is None


# ---------------------------------------------------------------------------
# happy path: real workloads run clean under the sanitizer
# ---------------------------------------------------------------------------

def test_clean_lifecycle_all_devices():
    for kind in ("plain", "gcomp", "trace"):
        dev = make_device(kind, sanitize=True)
        dev.submit([WriteReq(key="a", data=_payload(1)),
                    WriteReq(key="b", data=_payload(2))])
        recs = dev.submit([ReadReq(key="a"), ReadReq(key="b")])
        assert all(np.array_equal(r.data, _payload(i + 1))
                   for i, r in enumerate(recs))
        dev.delete("a")
        assert dev.delete_prefix("") == 1
        assert dev.stats.dram_bytes_stored == 0 and dev.stats.blocks == 0


def test_clean_async_and_truncate():
    dev = _loaded_device()
    tickets = dev.submit_async([ReadReq(key="k0"), ReadReq(key="k1")])
    dev.drain()
    assert all(t.done for t in tickets)
    freed = dev.truncate_planes(["k0", "k2"], VIEWS["man4"])
    assert freed > 0
    dev.quiesce()
    dev.delete_prefix("")
    assert dev.stats.blocks == 0


def test_reset_traffic_keeps_shadow_in_sync():
    dev = _loaded_device()
    dev.stats.reset_traffic()          # the bench/test idiom must not trip
    dev.submit([ReadReq(key="k0")])
    dev.delete_prefix("")


# ---------------------------------------------------------------------------
# fault injection: each invariant trips and names itself
# ---------------------------------------------------------------------------

def test_corrupt_ledger_row_trips():
    dev = _loaded_device()
    dev._ledger["k1"].payload_bytes += 7
    with pytest.raises(SanitizerViolation) as ei:
        dev.submit([ReadReq(key="k1")])
    assert ei.value.invariant == "ledger-stored-equality"
    assert ei.value.key == "k1"
    assert "payload_bytes" in str(ei.value)


def test_orphaned_ledger_key_trips():
    dev = _loaded_device()
    dev._ledger["ghost"] = dev._ledger["k0"]
    with pytest.raises(SanitizerViolation) as ei:
        dev.submit([WriteReq(key="k3", data=_payload(3))])
    assert ei.value.invariant == "ledger-stored-equality"
    assert ei.value.key == "ghost"


def test_dropped_receipt_trips_conservation():
    dev = _loaded_device()
    # a stats poke that bypasses _apply_receipt desyncs the shadow
    dev.stats.dram_bytes_read += 100
    with pytest.raises(SanitizerViolation) as ei:
        dev.submit([WriteReq(key="k3", data=_payload(3))])
    assert ei.value.invariant == "receipt-conservation"
    assert ei.value.key == "dram_bytes_read"
    assert ei.value.actual - ei.value.expected == 100


def test_rewound_clock_trips_monotonicity():
    dev = _loaded_device()
    assert dev._ddr_free_s > 0         # the writes kept the DDR pipe busy
    dev._ddr_free_s = 0.0              # rewind it behind the remembered mark
    with pytest.raises(SanitizerViolation) as ei:
        dev.quiesce()
    assert ei.value.invariant == "busy-clock-monotonic"


def test_oversized_window_trips_bound():
    dev = _loaded_device(window=8)
    dev.submit_async([ReadReq(key="k0"), ReadReq(key="k1")])
    dev.window = 1                     # shrink under the queued tickets
    with pytest.raises(SanitizerViolation) as ei:
        dev.submit_async([WriteReq(key="k3", data=_payload(3))])
    assert ei.value.invariant == "inflight-window-bound"


def test_skipped_retirement_cleanup_trips():
    dev = _loaded_device()
    dev._forget = lambda key, evict_index=True: None   # retirement no-op
    with pytest.raises(SanitizerViolation) as ei:
        dev.delete_prefix("")
    assert ei.value.invariant == "retire-cleanup"
    assert ei.value.key == ""
    assert "orphaned" in str(ei.value)


def test_unsanitized_device_does_not_trip(monkeypatch):
    """The same faults pass silently with the sanitizer off — the checks
    are genuinely gated, not always-on overhead."""
    monkeypatch.delenv("TRACE_SANITIZE", raising=False)
    dev = make_device("trace")
    dev.submit([WriteReq(key="a", data=_payload(1))])
    dev._ledger["a"].payload_bytes += 7
    dev.stats.dram_bytes_read += 100
    dev.submit([WriteReq(key="b", data=_payload(2))])   # no raise
