"""Pipeline-parallel prefill vs the plain forward (unit stage mesh)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.launch import mesh as mesh_lib
from repro.launch.pipeline import make_pp_prefill_step
from repro.models import forward
from repro.models.model import init_params

pytestmark = pytest.mark.slow   # model-forward module


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        smoke_config(ARCHS["stablelm-12b"]), n_layers=4, remat=False
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    return cfg, params, toks


def test_pp_matches_forward_single_stage(setup):
    cfg, params, toks = setup
    ref, _, _ = jax.jit(lambda p, b: forward(cfg, p, b))(
        params, {"tokens": toks}
    )
    mesh = mesh_lib.make_mesh((1, 1, 1), ("stage", "data", "model"))
    step = make_pp_prefill_step(cfg, mesh, n_micro=2)
    out = jax.jit(step)(params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_pp_microbatch_count_invariance(setup):
    cfg, params, toks = setup
    mesh = mesh_lib.make_mesh((1, 1, 1), ("stage", "data", "model"))
    a = jax.jit(make_pp_prefill_step(cfg, mesh, n_micro=2))(
        params, {"tokens": toks}
    )
    b = jax.jit(make_pp_prefill_step(cfg, mesh, n_micro=4))(
        params, {"tokens": toks}
    )
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-3, atol=1e-3)
