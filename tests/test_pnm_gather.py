"""PNM read path: device-side top-k gather over bit-planes.

Contract under test, bottom-up:

* scoring kernel (``kernels.pnm_score``): pallas/numpy twins agree,
  tie-breaking is positional and deterministic;
* partial-attention algebra (``kernels.decode_attn``): chunked
  online-softmax statistics merge to the monolithic kernel's output;
* tier protocol (``core.tier.GatherReq``): a gather whose ``k`` covers
  every candidate is byte-identical to individual reads, winners are
  identical across sync/async submission and shard counts, and
  ``device_compute_s`` obeys receipt/aggregate conservation;
* pool (``KVPagePool.gather_topk``): frozen winner views, async parity,
  importance-feedback bookkeeping;
* engine (``ServeEngine(pnm_topk=...)``): decode tokens bit-identical
  to the classic readback when ``k`` covers the spill, bounded ``k``
  cuts link traffic, attention-mass importance wires end to end.
"""

import warnings

import numpy as np
import pytest

from repro.core import synth
from repro.core.precision import FULL, SCORE
from repro.core.tier import (
    KV,
    LAYOUTS,
    GatherReq,
    ReadReq,
    TierStore,
    WriteReq,
    make_device,
)
from repro.kernels.pnm_score import page_scores, page_scores_u16, topk_select

CH = 64          # KV channels for the tier-level tests
ROWS = 32        # tokens per written stream


def _write_pages(dev, n=6, seed=0):
    """n KV streams of (ROWS, CH) on ``dev``; returns their keys."""
    kv = synth.kv_cache(ROWS * n, CH, seed=seed)
    keys = [f"p{i}" for i in range(n)]
    dev.submit([
        WriteReq(k, kv[i * ROWS:(i + 1) * ROWS], kind=KV)
        for i, k in enumerate(keys)
    ])
    return keys


def _gather(keys, digest, k, views=None):
    return GatherReq(keys=tuple(keys), digest=digest, k=k, kind=KV,
                     views=views)


# ---------------------------------------------------------------------------
# Scoring kernel
# ---------------------------------------------------------------------------

def test_page_scores_pallas_matches_numpy():
    rng = np.random.default_rng(0)
    padded = rng.normal(size=(5, 16, CH)).astype(np.float32)
    valid = np.array([16, 9, 1, 16, 0])
    digest = rng.normal(size=CH).astype(np.float32)
    a = page_scores(padded, valid, digest, force="numpy")
    b = page_scores(padded, valid, digest, force="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-6)
    assert a[4] == -np.inf  # zero valid rows rank last


def test_topk_select_positional_tie_break():
    scores = np.array([1.0, 3.0, 3.0, 0.5, 3.0])
    assert topk_select(scores, 3) == [1, 2, 4]   # ties by position
    assert topk_select(scores, 0) == []
    assert topk_select(scores, 99) == [1, 2, 4, 0, 3]
    assert topk_select(np.array([]), 4) == []


def test_page_scores_u16_ragged_pages():
    kv = synth.kv_cache(24, CH, seed=3)
    pages = [kv[:16], kv[16:]]                    # 16 and 8 rows
    digest = np.ones(CH, np.float32)
    s = page_scores_u16(pages, digest)
    assert s.shape == (2,) and np.all(np.isfinite(s))


# ---------------------------------------------------------------------------
# Partial attention algebra
# ---------------------------------------------------------------------------

def test_combine_partials_matches_monolithic_kernel():
    from repro.kernels.decode_attn import (
        attention_partial, combine_partials, decode_attention_pallas,
    )

    rng = np.random.default_rng(1)
    B, H, KVH, hd, S = 2, 4, 2, 32, 64
    q = rng.normal(size=(B, H, hd)).astype(np.float32)
    k = rng.normal(size=(B, S, KVH, hd)).astype(np.float32)
    v = rng.normal(size=(B, S, KVH, hd)).astype(np.float32)

    import jax.numpy as jnp
    ref = np.asarray(decode_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), S, block_s=32))

    for cuts in ([64], [32, 32], [8, 24, 16, 16]):
        parts, off = [], 0
        for c in cuts:
            parts.append(attention_partial(
                q, k[:, off:off + c], v[:, off:off + c]))
            off += c
        out = combine_partials(parts)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_attention_partial_valid_len_masks_tail():
    from repro.kernels.decode_attn import attention_partial, combine_partials

    rng = np.random.default_rng(2)
    q = rng.normal(size=(1, 2, 16)).astype(np.float32)
    k = rng.normal(size=(1, 8, 2, 16)).astype(np.float32)
    v = rng.normal(size=(1, 8, 2, 16)).astype(np.float32)
    full = combine_partials([attention_partial(q, k[:, :5], v[:, :5])])
    masked = combine_partials([attention_partial(q, k, v, valid_len=5)])
    np.testing.assert_allclose(full, masked, rtol=1e-6)


# ---------------------------------------------------------------------------
# Tier protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_gather_full_k_byte_identical_to_reads(layout):
    """k >= candidates ⇒ the gather ships exactly the bytes individual
    ReadReqs at the same views would, on every storage layout."""
    dev = TierStore(layout=layout, kv_window=ROWS, sanitize=True)
    keys = _write_pages(dev)
    digest = np.ones(CH, np.float32)

    rec, = dev.submit([_gather(keys, digest, k=len(keys) + 3)])
    assert sorted(rec.gather.keys) == sorted(keys)
    plain = {k: r.data for k, r in zip(
        keys, dev.submit([ReadReq(k, kind=KV) for k in keys]))}
    for k, data in zip(rec.gather.keys, rec.gather.data):
        np.testing.assert_array_equal(data, plain[k])


def test_gather_sync_async_identical():
    digest = np.linspace(-1, 1, CH).astype(np.float32)
    dev_s = make_device("trace", shards=1, sanitize=True)
    dev_a = make_device("trace", shards=1, sanitize=True)
    keys = _write_pages(dev_s)
    _write_pages(dev_a)

    rec_s, = dev_s.submit([_gather(keys, digest, k=3)])
    t, = dev_a.submit_async([_gather(keys, digest, k=3)])
    rec_a = t.wait()

    assert rec_s.gather.keys == rec_a.gather.keys
    np.testing.assert_array_equal(rec_s.gather.scores, rec_a.gather.scores)
    for a, b in zip(rec_s.gather.data, rec_a.gather.data):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("k", [0, 2, 9])
def test_gather_sharded_matches_solo(k):
    """Per-shard local top-k + host merge == one device's global top-k,
    for bounded, zero and covering k."""
    digest = np.linspace(-1, 1, CH).astype(np.float32)
    solo = make_device("trace", shards=1, sanitize=True)
    fleet = make_device("trace", shards=4, sanitize=True)
    keys = _write_pages(solo)
    _write_pages(fleet)

    r1, = solo.submit([_gather(keys, digest, k=k)])
    r4, = fleet.submit([_gather(keys, digest, k=k)])
    assert r1.gather.keys == r4.gather.keys
    assert r1.gather.indices == r4.gather.indices
    np.testing.assert_array_equal(r1.gather.scores, r4.gather.scores)
    for a, b in zip(r1.gather.data, r4.gather.data):
        np.testing.assert_array_equal(a, b)
    if k == 0:
        assert r1.gather.keys == [] and len(r1.gather.scores) == len(keys)


@pytest.mark.parametrize("shards", [1, 4])
def test_gather_tie_break_by_candidate_position(shards):
    """Duplicate-content candidates score equal; winners must come back
    in candidate-list order regardless of shard placement."""
    dev = make_device("trace", shards=shards, sanitize=True)
    kv = synth.kv_cache(ROWS, CH, seed=7)
    keys = [f"d{i}" for i in range(5)]
    dev.submit([WriteReq(k, kv, kind=KV) for k in keys])

    rec, = dev.submit([_gather(keys, np.ones(CH, np.float32), k=2)])
    assert rec.gather.keys == keys[:2]
    assert rec.gather.indices == [0, 1]


def test_gather_receipt_conservation_includes_compute():
    """device_compute_s is a first-class accounted resource: the receipt
    sum reproduces the aggregate (sanitizer cross-checks every submit)."""
    dev = make_device("trace", sanitize=True)
    keys = _write_pages(dev)
    digest = np.ones(CH, np.float32)
    recs = dev.submit([_gather(keys, digest, k=2),
                       _gather(keys, digest, k=0)])
    assert all(r.device_compute_s > 0 for r in recs)
    base = dev.stats.device_compute_s
    assert base == pytest.approx(sum(r.device_compute_s for r in recs))
    # score-only pass reads fewer DRAM bytes than the winner pass
    assert recs[1].dram_bytes_read < recs[0].dram_bytes_read


def test_gather_score_view_cheaper_than_full_read():
    """The SCORE view (sign + exponent planes only) must make the k=0
    scoring pass touch well under half the DRAM bytes of a full read —
    the whole point of scoring near memory."""
    assert SCORE.r_m == 0 and SCORE.d_m == 0 and SCORE.r_e == 8
    dev = make_device("trace", sanitize=True)
    keys = _write_pages(dev)
    digest = np.ones(CH, np.float32)
    score_rec, = dev.submit([_gather(keys, digest, k=0)])
    read_recs = dev.submit([ReadReq(k, kind=KV, view=FULL) for k in keys])
    assert score_rec.dram_bytes_read < 0.5 * sum(
        r.dram_bytes_read for r in read_recs)
    # the score pass ships 4 B/candidate, never page payloads
    assert score_rec.link_bytes_out == 4 * len(keys)


# ---------------------------------------------------------------------------
# KVPagePool
# ---------------------------------------------------------------------------

def _pool(device="trace", n_pages=6, policy=None, **kw):
    from repro.runtime.paging import KVPagePool, LOSSLESS_POLICY

    pool = KVPagePool(
        device, page_tokens=8,
        hbm_budget_bytes=2 * 8 * CH * 2,         # keep 2 pages resident
        policy=policy or LOSSLESS_POLICY, sanitize=True, **kw,
    )
    kv = synth.kv_cache(8 * n_pages, CH, seed=5)
    for i in range(n_pages):
        pool.append_page(0, "k", i * 8, kv[i * 8:(i + 1) * 8],
                         importance=float(i))
    return pool


def test_pool_gather_covering_k_matches_readback():
    digest = np.ones(CH, np.float32)
    pool_a, pool_b = _pool(), _pool()
    spilled = [p for p in pool_a._pages if p.resident is None]
    base = {p.key: d for p, d in zip(spilled, pool_a.read_pages(spilled))}
    winners, data = pool_b.gather_topk(digest, len(base) + 1)
    assert {p.key for p in winners} == set(base)
    for p, d in zip(winners, data):
        np.testing.assert_array_equal(d, base[p.key])


def test_pool_gather_freezes_winner_views():
    """First gather pins each candidate's winner view at its CURRENT
    policy rank; later rank churn must not change fetch precision (that
    is what keeps sync/async/shard runs bit-identical)."""
    from repro.runtime import PAPER_POLICY

    pool = _pool(policy=PAPER_POLICY)
    digest = np.ones(CH, np.float32)
    pool.gather_topk(digest, 1)
    frozen = {p.key: p.gather_view for p in pool._pages
              if p.resident is None}
    assert all(v is not None for v in frozen.values())
    # churn the ranking, gather again: views must not move
    pool.update_importance({k: 100.0 for k in list(frozen)[:2]})
    pool.gather_topk(digest, 1)
    for p in pool._pages:
        if p.key in frozen:
            assert p.gather_view is frozen[p.key]


def test_pool_gather_async_matches_sync():
    digest = np.linspace(0, 1, CH).astype(np.float32)
    pool_s, pool_a = _pool(), _pool()
    w_s, d_s = pool_s.gather_topk(digest, 2)
    cands, ticket = pool_a.gather_topk_async(digest, 2)
    w_a, d_a = pool_a.drain_gather(cands, ticket)
    assert [p.key for p in w_s] == [p.key for p in w_a]
    for a, b in zip(d_s, d_a):
        np.testing.assert_array_equal(a, b)
    # traffic attribution stays conservative on both paths
    for pool in (pool_s, pool_a):
        assert sum(t.device_compute_s
                   for t in pool.page_traffic.values()) > 0


def test_pool_gather_no_spilled_candidates():
    from repro.runtime.paging import KVPagePool, LOSSLESS_POLICY

    pool = KVPagePool("trace", page_tokens=8, hbm_budget_bytes=1 << 20,
                      policy=LOSSLESS_POLICY, sanitize=True)
    kv = synth.kv_cache(8, CH, seed=6)
    pool.append_page(0, "k", 0, kv)              # stays resident
    winners, data = pool.gather_topk(np.ones(CH, np.float32), 4)
    assert winners == [] and data == []
    cands, ticket = pool.gather_topk_async(np.ones(CH, np.float32), 4)
    assert cands == [] and ticket is None
    assert pool.drain_gather(cands, ticket) == ([], [])


def test_update_importance_unknown_keys_counted_and_strict():
    pool = _pool()
    known = pool._pages[0].key
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        pool.update_importance({known: 1.0, "ghost": 2.0})
        pool.update_importance({"phantom": 3.0})
    assert pool.unknown_importance_keys == 2
    assert len(w) == 1                            # warn once, then count
    with pytest.raises(KeyError):
        pool.update_importance({"ghost": 1.0}, strict=True)

    strict_pool = _pool(strict_importance=True)
    with pytest.raises(KeyError):
        strict_pool.update_importance({"ghost": 1.0})
    strict_pool.update_importance({"ghost": 0.0}, strict=False)
    assert strict_pool.unknown_importance_keys == 2  # raise still counts


# ---------------------------------------------------------------------------
# ServeEngine end-to-end (model forward: slow lane)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_pair(smoke_model):
    return smoke_model("qwen2-0.5b")


def _gen(cfg, params, n=10, **kw):
    from repro.runtime import ServeEngine
    from repro.runtime.paging import LOSSLESS_POLICY

    eng = ServeEngine(
        cfg, params, max_seq=96, batch=1, page_tokens=16,
        hbm_kv_budget=1 << 12, policy=LOSSLESS_POLICY, sanitize=True, **kw,
    )
    prompt = np.arange(48, dtype=np.int32).reshape(1, 48) % cfg.vocab
    toks = eng.generate(prompt, n)
    return eng, toks


@pytest.mark.slow
@pytest.mark.parametrize("async_io", [False, True])
@pytest.mark.parametrize("shards", [1, 4])
def test_pnm_covering_k_decodes_bit_identical(engine_pair, async_io, shards):
    """pnm_topk >= spilled pages ⇒ the PNM engine fetches exactly what
    the classic readback engine fetches ⇒ identical greedy tokens."""
    cfg, params = engine_pair
    dev_base = make_device("trace", shards=shards, sanitize=True)
    dev_pnm = make_device("trace", shards=shards, sanitize=True)
    _, t_base = _gen(cfg, params, device_kind=dev_base, async_io=async_io)
    eng, t_pnm = _gen(cfg, params, device_kind=dev_pnm, async_io=async_io,
                      pnm_topk=1_000)
    np.testing.assert_array_equal(t_base, t_pnm)
    assert eng.stats().tier_device_compute_s > 0


@pytest.mark.slow
@pytest.mark.parametrize("async_io", [False, True])
def test_pnm_bounded_k_cuts_link_bytes(engine_pair, async_io):
    cfg, params = engine_pair
    e_base, _ = _gen(cfg, params, device_kind="trace", async_io=async_io)
    e_pnm, toks = _gen(cfg, params, device_kind="trace", async_io=async_io,
                       pnm_topk=2)
    assert e_base.stats().spilled_pages > 2      # the sweep regime exists
    assert e_pnm.stats().tier_link_out < e_base.stats().tier_link_out
    assert toks.min() >= 0 and toks.max() < cfg.vocab


@pytest.mark.slow
def test_attention_importance_wires_end_to_end(engine_pair):
    """importance='attention' folds digest-proxy attention mass into the
    pool ledger with zero unknown-key drops."""
    cfg, params = engine_pair
    eng, toks = _gen(cfg, params, device_kind="trace",
                     importance="attention", pnm_topk=2)
    assert toks.min() >= 0 and toks.max() < cfg.vocab
    assert eng._imp_acc                          # masses accumulated
    assert eng.pool.unknown_importance_keys == 0  # S1/S2: no silent drops


@pytest.mark.slow
def test_engine_rejects_bad_pnm_args(engine_pair):
    cfg, params = engine_pair
    with pytest.raises(ValueError):
        _gen(cfg, params, n=0, importance="nonsense")
    with pytest.raises(ValueError):
        _gen(cfg, params, n=0, pnm_topk=-1)
