"""Per-architecture smoke tests: reduced config of the same family runs one
forward + one train step on CPU; output shapes correct, no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, smoke_config
from repro.models import (
    abstract_params, decode_step, forward, init_cache, init_params, lm_loss,
    param_axes,
)
from repro.optim import AdamWConfig, init as opt_init, update as opt_update

pytestmark = pytest.mark.slow   # model-forward module

B, S = 2, 32


def _batch(cfg, key):
    if cfg.uses_tokens:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch = {"tokens": toks}
    else:
        batch = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                             jnp.bfloat16)}
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(name, rng):
    cfg = smoke_config(ARCHS[name])
    params = init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits, cache, aux = jax.jit(
        lambda p, b: forward(cfg, p, b)
    )(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert cache is None
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_decreases_loss_signal(name, rng):
    cfg = smoke_config(ARCHS[name])
    params = init_params(cfg, rng)
    batch = _batch(cfg, rng)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt_init(ocfg, params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, state, metrics = opt_update(ocfg, grads, state, params)
        return params, state, loss, metrics

    params, state, loss0, m0 = step(params, state, batch)
    params, state, loss1, _ = step(params, state, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(loss1) < float(loss0) + 0.5  # moves, no blowup
    assert float(m0["grad_norm"]) > 0


@pytest.mark.parametrize(
    "name",
    [n for n, c in sorted(ARCHS.items()) if not c.is_encoder_only],
)
def test_decode_step_matches_forward(name, rng):
    """Teacher-forced decode must reproduce the training-forward logits."""
    cfg = smoke_config(ARCHS[name])
    params = init_params(cfg, rng)
    batch = _batch(cfg, rng)
    full_logits, _, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)

    cache = init_cache(cfg, B, max_seq=S)
    step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))
    outs = []
    for t in range(S):
        if cfg.uses_tokens:
            sb = {"tokens": batch["tokens"][:, t : t + 1],
                  "cache_pos": jnp.int32(t)}
        else:
            sb = {"embeds": batch["embeds"][:, t : t + 1],
                  "cache_pos": jnp.int32(t)}
        logits, cache = step(params, sb, cache)
        outs.append(np.asarray(logits[:, 0], dtype=np.float32))
    dec = np.stack(outs, axis=1)
    ref = np.asarray(full_logits, dtype=np.float32)
    np.testing.assert_allclose(dec, ref, rtol=0.15, atol=0.15)


def test_param_axes_congruent_with_params():
    for name, arch in ARCHS.items():
        cfg = smoke_config(arch)
        p = abstract_params(cfg)
        a = param_axes(cfg)
        td_p = jax.tree.structure(p)
        td_a = jax.tree.structure(a, is_leaf=lambda x: isinstance(x, tuple))
        assert td_p == td_a, name
        for leaf, axes in zip(jax.tree.leaves(p),
                              jax.tree.leaves(a, is_leaf=lambda x: isinstance(x, tuple))):
            assert len(leaf.shape) == len(axes), (name, leaf.shape, axes)


def test_applicable_shapes_rules():
    from repro.configs import ARCHS

    names = {n: [s.name for s in applicable_shapes(c)] for n, c in ARCHS.items()}
    assert names["hubert-xlarge"] == ["train_4k", "prefill_32k"]
    assert "long_500k" in names["falcon-mamba-7b"]
    assert "long_500k" in names["zamba2-7b"]
    assert "long_500k" not in names["qwen1.5-32b"]
    total = sum(len(v) for v in names.values())
    assert total == 8 * 3 + 2 * 4 - 1  # 31 runnable cells
