"""Unit tests for the mesh/sharding layer (no 512-device requirement —
a small host mesh exercises the same rule logic)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.launch import mesh as mesh_lib
from repro.models.model import Spec, schema
from repro.models.sharding import MeshRules


@pytest.fixture(scope="module")
def mesh():
    # 1 device: (1, 1) mesh — rule LOGIC is device-count independent
    return mesh_lib.make_mesh((1, 1), ("data", "model"))


def test_param_embed_fsdp_and_act_override(mesh):
    cfg = ARCHS["qwen2-0.5b"]
    rules = mesh_lib.rules_for(cfg, SHAPES["train_4k"], mesh)
    assert rules.rules["embed"] == "data"          # FSDP on params
    assert rules.act().rules["embed"] is None      # not on activations


def test_decode_kv_seq_takes_model_axis(mesh):
    cfg = ARCHS["qwen2-0.5b"]
    rules = mesh_lib.rules_for(cfg, SHAPES["decode_32k"], mesh)
    assert rules.rules["kv_seq"] == "model"
    assert rules.act().rules["kv_heads"] is None   # no dup with kv_seq
    assert rules.rules["kv_heads"] == "model"      # params keep TP


def test_long_context_spreads_state(mesh):
    cfg = ARCHS["falcon-mamba-7b"]
    rules = mesh_lib.rules_for(cfg, SHAPES["long_500k"], mesh)
    assert rules.act().rules["d_inner"] == ("data", "model")
    assert rules.rules["d_inner"] == "model"       # params: no dup w/ embed
    assert rules.rules["batch"] is None            # batch=1


def test_spec_for_shape_divisibility():
    m = mesh_lib.make_mesh((1, 1), ("data", "model"))
    r = MeshRules(m, {"vocab": "model", "embed": "data",
                      "wide": ("data", "model")})
    # mesh extents are 1 → everything divides; logic test with fake sizes
    big = mesh_lib.make_mesh((1, 1), ("data", "model"))
    rr = MeshRules(big, {"vocab": "model"})
    assert rr.spec_for_shape(("vocab",), (504,)) == P("model")  # 504 % 1 == 0

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fr = MeshRules.__new__(MeshRules)
    fr.mesh = FakeMesh()
    fr.rules = {"vocab": "model", "wide": ("data", "model")}
    assert fr.spec_for_shape(("vocab",), (504,)) == P(None)
    assert fr.spec_for_shape(("vocab",), (512,)) == P("model")
    # tuple degrades to longest dividing prefix
    assert fr.spec_for_shape(("wide",), (7296,)) == P("data")
    assert fr.spec_for_shape(("wide",), (7168,)) == P(("data", "model"))


def test_param_shardings_cover_every_leaf(mesh):
    for name in ("qwen2-0.5b", "grok-1-314b", "falcon-mamba-7b",
                 "zamba2-7b", "deepseek-v2-lite-16b", "hubert-xlarge"):
        cfg = ARCHS[name]
        rules = mesh_lib.rules_for(cfg, SHAPES["train_4k"], mesh)
        sh = mesh_lib.param_shardings(cfg, rules)
        n_specs = len(jax.tree.leaves(
            schema(cfg), is_leaf=lambda x: isinstance(x, Spec)))
        n_sh = len(jax.tree.leaves(sh))
        assert n_specs == n_sh, name


def test_expert_parallelism_rule(mesh):
    grok = ARCHS["grok-1-314b"]          # 8 experts — needs 8 | model size
    rules = mesh_lib.rules_for(grok, SHAPES["train_4k"], mesh)
    # model axis size 1 → 8 % 1 == 0 → EP on
    assert rules.rules["experts"] == "model"

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    # deepseek 64 experts % 16 == 0 → EP; grok 8 % 16 != 0 → TP fallback
    r2 = mesh_lib.rules_for(ARCHS["deepseek-v2-lite-16b"],
                            SHAPES["train_4k"], FakeMesh())
    assert r2.rules["experts"] == "model"
    r3 = mesh_lib.rules_for(grok, SHAPES["train_4k"], FakeMesh())
    assert r3.rules["experts"] is None
    assert r3.rules["moe_mlp"] == "model"


def test_batch_axes_single_vs_multipod(mesh):
    assert mesh_lib.batch_axes(mesh) == ("data",)

    class FakeMesh:
        axis_names = ("pod", "data", "model")
    assert mesh_lib.batch_axes(FakeMesh()) == ("pod", "data")
