"""Elastic weight offload (runtime/weights.py) — Granularity I/II live."""

import numpy as np
import pytest

from repro.core import synth
from repro.runtime import WeightStore
from repro.core.precision import FULL


def _units(n=10, sz=(64, 128), seed=0):
    import ml_dtypes

    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        w = synth.weights(sz[0] * sz[1], "bf16", seed=seed + i)
        out[f"expert{i}"] = (
            w.view(ml_dtypes.bfloat16).reshape(sz), float(n - i)
        )
    return out


def test_full_view_byte_exact_roundtrip():
    ws = WeightStore("trace", tiers=((1.0, FULL),))
    units = _units(4)
    for name, (w, imp) in units.items():
        ws.put(name, w, imp)
    for name, (w, _) in units.items():
        np.testing.assert_array_equal(
            ws.fetch(name).view(np.uint16), np.asarray(w).view(np.uint16)
        )


def test_importance_ranked_views_scale_traffic():
    """Cold units must cost fewer DRAM bytes than hot ones (plane fetch)."""
    ws = WeightStore("trace")
    for name, (w, imp) in _units(10).items():
        ws.put(name, w, imp)
    # hottest unit = full view, coldest = man0
    assert ws.view_for("expert0").name == "bf16"
    assert ws.view_for("expert9").name == "man0"

    ws.stats.reset_traffic()
    ws.fetch("expert0")
    hot = ws.stats.dram_bytes_read
    ws.stats.reset_traffic()
    ws.fetch("expert9")
    cold = ws.stats.dram_bytes_read
    assert cold < hot * 0.85
    assert 9 <= ws.avg_bits() < 16


def test_word_device_cannot_scale_traffic():
    """CXL-Plain always moves full containers (paper Issue 2)."""
    tr = WeightStore("trace")
    pl = WeightStore("plain")
    for store in (tr, pl):
        for name, (w, imp) in _units(10, seed=3).items():
            store.put(name, w, imp)
        store.stats.reset_traffic()
        store.fetch_all()
    assert tr.stats.dram_bytes_read < 0.8 * pl.stats.dram_bytes_read


def test_importance_update_changes_views():
    ws = WeightStore("trace")
    for name, (w, imp) in _units(10).items():
        ws.put(name, w, imp)
    assert ws.view_for("expert9").name == "man0"
    ws.set_importance({"expert9": 100.0})
    assert ws.view_for("expert9").name == "bf16"
