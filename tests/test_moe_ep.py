"""EP shard_map MoE vs the single-device reference path.

On a (1, 1) mesh shard_map is local and the all_to_all is identity, and
the local capacity equals the global capacity — the EP path must then be
numerically IDENTICAL to the plain moe_block."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.launch import mesh as mesh_lib
from repro.models import layers as L
from repro.models.model import init_params
from repro.models.sharding import MeshRules, use_rules

pytestmark = pytest.mark.slow   # model-forward module


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(ARCHS["deepseek-v2-lite-16b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    # single layer's MoE params
    p_moe = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    return cfg, p_moe, x


def test_ep_matches_reference_on_unit_mesh(setup):
    cfg, p_moe, x = setup
    ref, aux_ref = jax.jit(lambda x, p: L.moe_block(x, p, cfg))(x, p_moe)

    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh, {"capacity": "data"})
    with use_rules(rules):
        out, aux = jax.jit(lambda x, p: L.moe_block(x, p, cfg))(x, p_moe)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2,
    )
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)


def test_ep_grads_flow(setup):
    cfg, p_moe, x = setup
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh, {"capacity": "data"})

    def loss(p):
        with use_rules(rules):
            out, aux = L.moe_block(x, p, cfg)
        return jnp.sum(jnp.square(out.astype(jnp.float32))) + 0.01 * aux

    g = jax.jit(jax.grad(loss))(p_moe)
    for key in ("w1", "w2", "router"):
        arr = np.asarray(g[key], np.float32)
        assert np.isfinite(arr).all()
        assert np.abs(arr).max() > 0, key
