"""Physical-footprint residency ledger + precision-elastic reclamation.

Covers the ledger invariant (resident_bytes == stored payload+index
bytes under arbitrary write/delete/truncate interleavings), in-place
plane truncation (reclaimed bytes reconcile exactly with the ledger
delta; degraded blocks decode bit-identically to ``reconstruct_u16`` at
the surviving view), the pool's degradation-ladder ``reclaim`` walk,
and the explicit empty-denominator values of the stats properties.
"""

import numpy as np
import pytest

from repro.core import synth
from repro.core.bitplane import BLOCK_ELEMS
from repro.core.precision import FULL, MAN0, MAN2, MAN4, VIEWS
from repro.core.tier import (
    DeviceStats, KV, LinkModel, ReadReq, WriteReq,
)
from repro.core.tier import make_device as _make_device


def make_device(kind, **kw):
    # This file walks one device's ledger/_tensors internals; pin a bare
    # TierStore even when TRACE_SHARDS widens the default (fleet-ledger
    # conservation has its own battery in test_sharding_store.py).
    return _make_device(kind, shards=1, **kw)
from repro.core.precision import truncate_reference
from repro.runtime.paging import (
    DEFAULT_DEGRADE_LADDER, KVPagePool, LOSSLESS_POLICY,
)


def _physical_bytes(dev, prefix=""):
    """Ground truth the ledger must equal: walk the stored blocks."""
    total = 0
    for key, blocks in dev._tensors.items():
        if key.startswith(prefix):
            total += sum(b.stored_bytes + 64 for b in blocks)
    return total


def _assert_ledger(dev):
    assert dev.resident_bytes() == _physical_bytes(dev)
    # the ledger also ties out against the receipt-fed aggregates
    assert dev.resident_bytes() == (dev.stats.dram_bytes_stored
                                    + 64 * dev.stats.blocks)


# ---------------------------------------------------------------------------
# ledger bookkeeping
# ---------------------------------------------------------------------------

def test_ledger_tracks_writes_and_deletes():
    dev = make_device("trace", kv_window=16)
    assert dev.resident_bytes() == 0
    assert dev.compression_ratio() == 1.0
    dev.submit([
        WriteReq("a.x", synth.kv_cache(32, 64, seed=0), kind=KV),
        WriteReq("b.y", np.arange(4096, dtype=np.uint16)),
    ])
    _assert_ledger(dev)
    assert dev.resident_bytes("a.") + dev.resident_bytes("b.") \
        == dev.resident_bytes()
    assert dev.compression_ratio("a.") > 1.0   # KV transform compresses
    # namespace delete returns that namespace's ledger to exactly zero
    dev.delete_prefix("a.")
    assert dev.resident_bytes("a.") == 0
    _assert_ledger(dev)
    dev.delete("b.y")
    assert dev.resident_bytes() == 0
    assert dev.compression_ratio() == 1.0


def test_ledger_invariant_random_interleavings():
    """Property: any interleaving of writes, deletes and truncations
    keeps resident_bytes == stored payload+index bytes, and a namespace
    delete zeroes exactly that namespace."""
    rng = np.random.default_rng(7)
    dev = make_device("trace", kv_window=16)
    ladder = [MAN4, MAN2, MAN0]
    live = set()
    for step in range(120):
        op = rng.integers(0, 10)
        ns = f"n{rng.integers(0, 4)}."
        key = f"{ns}k{rng.integers(0, 3)}"
        if op < 5:                                   # write (tensor or KV)
            if rng.integers(0, 2):
                dev.submit([WriteReq(key, synth.kv_cache(
                    16 * int(rng.integers(1, 4)), 64,
                    seed=int(rng.integers(1 << 16))), kind=KV)])
            else:
                n = 8 * int(rng.integers(1, 600))
                dev.submit([WriteReq(
                    key, rng.integers(0, 1 << 16, n).astype(np.uint16))])
            live.add(key)
        elif op < 7 and live:                        # truncate a live key
            victim = sorted(live)[int(rng.integers(len(live)))]
            view = ladder[int(rng.integers(len(ladder)))]
            before = dev.resident_bytes()
            reclaimed = dev.truncate_planes([victim], view)
            assert reclaimed == before - dev.resident_bytes()
        elif op < 9:                                 # namespace delete
            dev.delete_prefix(ns)
            live = {k for k in live if not k.startswith(ns)}
            assert dev.resident_bytes(ns) == 0
        elif live:                                   # single-key delete
            victim = sorted(live)[int(rng.integers(len(live)))]
            dev.delete(victim)
            live.discard(victim)
        _assert_ledger(dev)
    dev.delete_prefix("")
    assert dev.resident_bytes() == 0 and dev.stats.blocks == 0


# ---------------------------------------------------------------------------
# in-place plane truncation
# ---------------------------------------------------------------------------

def test_truncate_reclaims_and_reconciles_with_ledger():
    dev = make_device("trace", kv_window=32)
    dev.submit([WriteReq("s.p", synth.kv_cache(64, 64, seed=3), kind=KV)])
    before = dev.resident_bytes("s.")
    stored_before = dev.stats.dram_bytes_stored
    reclaimed = dev.truncate_planes(["s.p"], MAN4)
    assert reclaimed > 0
    assert dev.resident_bytes("s.") == before - reclaimed
    assert dev.stats.dram_bytes_stored == stored_before - reclaimed
    # logical footprint unchanged: same elements, fewer stored planes
    assert dev.logical_bytes("s.p") == dev.stats.raw_bytes_stored
    assert dev.compression_ratio("s.") > before / max(before, 1)
    # idempotent at the same rung; deeper rungs reclaim more
    assert dev.truncate_planes(["s.p"], MAN4) == 0
    assert dev.truncate_planes(["s.p"], MAN0) > 0
    # unknown keys are ignored
    assert dev.truncate_planes(["s.missing"], MAN0) == 0


def test_truncated_kv_decodes_at_surviving_view():
    """Differential: after truncation to view V, a FULL read returns
    exactly what an untruncated device serves at V (same plane-aligned
    fetch + guard rounding, i.e. ``reconstruct_u16`` at V)."""
    kv = synth.kv_cache(64, 64, seed=11)
    for view in (MAN4, MAN2, MAN0):
        cut, ref = (make_device("trace", kv_window=16) for _ in range(2))
        for d in (cut, ref):
            d.submit([WriteReq("s.p", kv, kind=KV)])
        cut.truncate_planes(["s.p"], view)
        got = cut.submit([ReadReq("s.p", kind=KV)])[0].data
        want = ref.submit([ReadReq("s.p", kind=KV, view=view)])[0].data
        np.testing.assert_array_equal(got, want)
        # narrower requested views still work against the truncated store
        got2 = cut.submit([ReadReq("s.p", kind=KV, view=MAN0)])[0].data
        want2 = ref.submit([ReadReq("s.p", kind=KV, view=MAN0)])[0].data
        np.testing.assert_array_equal(got2, want2)


def test_truncated_tensor_matches_reconstruct_reference():
    """Tensor path, against the precision oracle directly: a degraded
    block read back at FULL is bit-identical to ``truncate_reference``
    (mask to fetched planes + ``reconstruct_u16``) on the host copy."""
    rng = np.random.default_rng(5)
    data = rng.integers(0, 1 << 16, BLOCK_ELEMS * 2).astype(np.uint16)
    dev = make_device("trace")    # bitplane-kv layout; tensor keeps raw exp
    dev.submit([WriteReq("t", data)])
    for view in (MAN4, MAN2):
        dev.truncate_planes(["t"], view)
        got = dev.submit([ReadReq("t")])[0].data
        np.testing.assert_array_equal(got, truncate_reference(data, view))


def test_truncate_cuts_read_traffic_and_link_bits():
    dev = make_device("trace", kv_window=32)
    dev.submit([WriteReq("s.p", synth.kv_cache(64, 64, seed=9), kind=KV)])
    full = dev.submit([ReadReq("s.p", kind=KV)])[0]
    dev.truncate_planes(["s.p"], MAN0)
    cut = dev.submit([ReadReq("s.p", kind=KV)])[0]
    assert cut.dram_bytes_read < full.dram_bytes_read
    # link carries the surviving view's bits, not the full container
    assert cut.link_bytes_out == cut.data.size * MAN0.bits // 8


def test_truncate_unsupported_on_word_layouts():
    for kind in ("plain", "gcomp"):
        dev = make_device(kind)
        dev.submit([WriteReq("t", np.arange(4096, dtype=np.uint16))])
        with pytest.raises(NotImplementedError):
            dev.truncate_planes(["t"], MAN4)


def test_truncate_kv_must_keep_delta_exponent():
    dev = make_device("trace", kv_window=16)
    dev.submit([WriteReq("s.p", synth.kv_cache(16, 64, seed=1), kind=KV)])
    with pytest.raises(ValueError):
        dev.truncate_planes(["s.p"], VIEWS["man4"].__class__(r_e=4, r_m=4))


def test_blocks_after_truncation_store_full_precision():
    """Truncation degrades only already-stored blocks: later appends to
    the same stream commit (and read back) at full precision."""
    dev = make_device("trace", kv_window=16)
    first = synth.kv_cache(16, 64, seed=2)
    dev.submit([WriteReq("s.p", first, kind=KV)])
    dev.truncate_planes(["s.p"], MAN0)
    second = synth.kv_cache(16, 64, seed=3)
    dev.submit([WriteReq("s.p", second, kind=KV)])
    out = dev.submit([ReadReq("s.p", kind=KV)])[0].data
    np.testing.assert_array_equal(out[16:], second)   # new window exact
    _assert_ledger(dev)


# ---------------------------------------------------------------------------
# pool-level reclamation (degradation ladder)
# ---------------------------------------------------------------------------

def _spilled_pool(n_pages=6, device="trace",
                  ladder=DEFAULT_DEGRADE_LADDER):
    pool = KVPagePool(device, page_tokens=16, hbm_budget_bytes=0,
                      policy=LOSSLESS_POLICY, key_prefix="r0.",
                      degrade_ladder=ladder)
    rng = np.random.default_rng(3)
    pool.append_pages([
        (0, "k", 16 * i,
         synth.kv_cache(16, 64, seed=40 + i), float(i))
        for i in range(n_pages)
    ])
    return pool


def test_pool_reclaim_walks_ladder_and_reports_ledger_delta():
    pool = _spilled_pool()
    assert pool.spilled_pages == 6 and pool.hbm_bytes == 0
    before = pool.device_resident_bytes
    assert pool.physical_kv_bytes == before
    freed = pool.reclaim(1)            # one rung of the coldest page
    assert freed > 0
    assert pool.device_resident_bytes == before - freed
    assert pool._pages[0].degrade_level == 0
    # a big target walks every page through every rung, then dries up
    freed2 = pool.reclaim(1 << 30)
    assert freed2 > 0
    assert all(p.degrade_level == len(DEFAULT_DEGRADE_LADDER) - 1
               for p in pool._pages)
    assert pool.reclaim(1 << 30) == 0  # ladder exhausted
    assert pool.release() > 0
    assert pool.device_resident_bytes == 0


def test_pool_reclaim_zero_on_word_device_and_empty_ladder():
    pool = _spilled_pool(device="gcomp")
    assert pool.reclaim(1 << 20) == 0       # word layout cannot shed planes
    pool2 = _spilled_pool()
    assert pool2.reclaim(1 << 20, ladder=()) == 0
    assert pool2.reclaim(0) == 0
    # lossy shedding is strictly opt-in: a default-constructed pool has
    # no ladder and reclaim never touches stored data
    bare = _spilled_pool(ladder=())
    assert bare.degrade_ladder == ()
    assert bare.reclaim(1 << 20) == 0


def test_scheduler_rejects_ladder_without_physical_model():
    from repro.runtime import ServeScheduler

    with pytest.raises(ValueError):
        ServeScheduler(None, None, capacity_model="logical",
                       degrade_ladder=DEFAULT_DEGRADE_LADDER)


# ---------------------------------------------------------------------------
# explicit empty-denominator values
# ---------------------------------------------------------------------------

def test_bypass_rate_zero_without_codec_blocks():
    assert DeviceStats().bypass_rate == 0.0
    dev = make_device("plain")               # no codec in the word layout
    dev.submit([WriteReq("t", np.arange(4096, dtype=np.uint16))])
    assert dev.stats.codec_blocks == 0
    assert dev.stats.bypass_rate == 0.0


def test_scheduler_report_empty_denominators():
    from repro.runtime.serving import SchedulerReport

    rep = SchedulerReport(records=[], steps=0, model_time_s=0.0,
                          decode_tokens=0, prefill_tokens=0)
    assert rep.tok_s == 0.0
    assert np.isnan(rep.p50_ttft_s) and np.isnan(rep.p99_ttft_s)
    assert np.isnan(rep.mean_tpot_s)
    assert np.isnan(rep.latency_percentile(90))
    assert rep.peak_active == 0 and rep.reclaimed_bytes == 0


def test_link_model_design_anchors():
    """Named devices derive base_s from the calibrated load-to-use
    pipeline (71/84/89 cycles @ 2 GHz); an explicit link_model kwarg
    overrides the anchor with a constant."""
    assert make_device("plain").link_model.base_s == pytest.approx(35.5e-9)
    assert make_device("gcomp").link_model.base_s == pytest.approx(42e-9)
    assert make_device("trace").link_model.base_s == pytest.approx(44.5e-9)
    assert LinkModel.for_design("trace", comp_ratio=3.0).base_s \
        == pytest.approx(42.5e-9)
    const = make_device("trace", link_model=LinkModel(base_s=1e-6))
    assert const.link_model.base_s == 1e-6
