"""Quickstart: TRACE's two mechanisms on a real tensor, in 60 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import ml_dtypes

from repro.core import synth
from repro.core.precision import MAN4, VIEWS
from repro.core.tier import KV, ReadReq, WriteReq, make_device

# --- a KV block with LLM-like structure (smooth channels, mixed scales) ----
kv = synth.kv_cache(n_tokens=512, n_channels=256, seed=0)   # (512, 256) u16

# --- Mechanism I: why the layout matters ------------------------------------
# Devices are TierStore configurations: a layout strategy + a codec behind
# the same batched request API.
plain = make_device("plain")    # word layout, no compression
gcomp = make_device("gcomp")    # word layout + inline LZ4 (4 KB blocks)
trace = make_device("trace")    # bit-plane layout + KV transform + same LZ4

for dev in (plain, gcomp, trace):
    rec, = dev.submit([WriteReq("kv", kv, kind=KV)])
    print(f"{dev.name:>6}: stored {rec.dram_bytes_stored:7d} B "
          f"for {rec.raw_bytes_stored} B logical "
          f"(ratio {dev.stats.compression_ratio:.2f}x)")

# byte-exact round trip (the paper's correctness invariant)
out = trace.read_kv("kv")
np.testing.assert_array_equal(out, kv)
print("lossless round-trip: OK")

# --- Mechanism II: precision-proportional fetch ------------------------------
# One batched submit; each receipt carries that request's traffic.
full_rec, low_rec = trace.submit([
    ReadReq("kv", kind=KV),                      # all 16 planes
    ReadReq("kv", kind=KV, view=VIEWS["man4"]),  # sign+exp+4 mantissa (+guard)
])
full, low = full_rec.data, low_rec.data
full_bytes, low_bytes = full_rec.dram_bytes_read, low_rec.dram_bytes_read
print(f"full-precision read: {full_bytes} B DRAM; "
      f"man4 view: {low_bytes} B ({low_bytes / full_bytes:.0%})")

err = (low.view(ml_dtypes.bfloat16).astype(np.float32)
       - full.view(ml_dtypes.bfloat16).astype(np.float32))
ref = np.abs(full.view(ml_dtypes.bfloat16).astype(np.float32)) + 1e-9
print(f"man4 median relative error: {np.median(np.abs(err) / ref):.2e} "
      f"(guard-plane round-to-nearest)")
