"""Serving with elastic KV precision: quality/traffic trade-off sweep.

Runs the same prompt through three KV page policies on a TRACE tier and
prints the quality (logit divergence vs lossless) / tier-traffic frontier
— the end-to-end demonstration of the paper's Table II + Mechanism II.

Run: PYTHONPATH=src python examples/serve_elastic.py
"""

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.precision import FULL, MAN0, MAN2, MAN4
from repro.models.model import init_params
from repro.runtime import ServeEngine
from repro.runtime.paging import LOSSLESS_POLICY, PagePolicy

POLICIES = {
    "lossless (all BF16)": LOSSLESS_POLICY,
    "paper mix (5 BF16 / 3 ~FP8 / rest ~FP4)": PagePolicy(
        tiers=((5, FULL), (3, MAN4), (2, MAN0)), tail_view=MAN0
    ),
    "mid (all man2+guard)": PagePolicy(tiers=((1 << 30, MAN2),), tail_view=MAN2),
    "aggressive (all man0+guard)": PagePolicy(
        tiers=((1 << 30, MAN0),), tail_view=MAN0
    ),
}


def main():
    cfg = smoke_config(ARCHS["stablelm-12b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (1, 96)).astype(np.int32)
    follow = rng.integers(0, cfg.vocab, (24, 1, 1)).astype(np.int32)

    results = {}
    last_eng = None
    for name, pol in POLICIES.items():
        eng = ServeEngine(
            cfg, params, max_seq=160, batch=1, page_tokens=16,
            hbm_kv_budget=1 << 11, device_kind="trace", policy=pol,
        )
        logits = [eng.prefill(prompt)]
        for t in follow:                      # teacher-forced comparison
            logits.append(eng.decode(t))
        results[name] = (np.stack(logits), eng.stats())
        last_eng = eng

    base = results["lossless (all BF16)"][0]
    print(f"{'policy':45s} {'logit MSE':>10s} {'top1 agree':>10s} "
          f"{'tier DRAM read':>14s}")
    for name, (lg, st) in results.items():
        mse = float(np.mean((lg - base) ** 2))
        top1 = float(np.mean(lg.argmax(-1) == base.argmax(-1)))
        print(f"{name:45s} {mse:10.4f} {top1:10.2%} {st.tier_dram_read:12d} B")

    # Receipts attribute tier traffic per layer — no global-counter diffing.
    print("\nper-layer tier DRAM traffic (aggressive policy, from receipts):")
    for layer, t in sorted(last_eng.layer_traffic().items()):
        print(f"  layer {layer}: read {t.dram_bytes_read:9d} B  "
              f"written {t.dram_bytes_written:9d} B  "
              f"({t.requests} requests)")


if __name__ == "__main__":
    main()
