"""Multi-stream serving over one shared tier device queue.

Three demonstrations of the queued async front-end, smallest first:

1. raw tickets  — submit_async / wait / drain on a TierStore, showing the
   in-flight window, coalesced execution, and queue-delay receipts;
2. overlap      — one ServeEngine with async_io on vs off: identical
   tokens and traffic, but the async receipts price the decode/fetch
   overlap (serialized service vs windowed completion);
3. many streams — a MultiStreamEngine serving several sequences whose
   page pools share ONE device queue, with per-stream traffic receipts
   summing exactly to the shared device totals.

Run: PYTHONPATH=src python examples/serve_async.py
"""

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core import synth
from repro.core.tier import KV, ReadReq, WriteReq, make_device
from repro.models.model import init_params
from repro.runtime import MultiStreamEngine, ServeEngine
from repro.runtime.paging import LOSSLESS_POLICY


def raw_tickets():
    print("== raw tickets on a TierStore (window = 4) ==")
    dev = make_device("trace", kv_window=16, window=4)
    dev.submit([
        WriteReq(f"p{i}", synth.kv_cache(16, 64, seed=i), kind=KV)
        for i in range(8)
    ])
    tickets = [dev.submit_async([ReadReq(f"p{i}", kind=KV)])[0]
               for i in range(8)]
    done = sum(t.done for t in tickets)
    print(f"submitted 8 reads: {done} executed by window overflow, "
          f"{dev.pending} still queued")
    dev.drain(tickets)
    # time one coalesced group: widen the window so all 8 reads flush as a
    # single in-flight batch, then compare against serialized service
    dev.window = 64
    recs = dev.drain(dev.submit_async([ReadReq(f"p{i}", kind=KV)
                                       for i in range(8)]))
    total = max(r.latency_s for r in recs)     # one group: last delivery
    serial = sum(r.service_s for r in recs)
    print(f"one 8-read in-flight group: completion {total * 1e6:.2f} us vs "
          f"serialized {serial * 1e6:.2f} us ({serial / total:.1f}x overlap "
          "win)\n")


def overlap_single_stream(cfg, params):
    print("== one stream, async_io on vs off (lossless policy) ==")
    prompt = (np.arange(48, dtype=np.int32) % cfg.vocab).reshape(1, 48)
    rows = {}
    for async_io in (False, True):
        eng = ServeEngine(
            cfg, params, max_seq=96, batch=1, page_tokens=16,
            hbm_kv_budget=1 << 12, device_kind="trace",
            policy=LOSSLESS_POLICY, async_io=async_io,
        )
        toks = eng.generate(prompt, 12)
        rows[async_io] = (toks, eng.stats())
    t_sync, s_sync = rows[False]
    t_async, s_async = rows[True]
    assert np.array_equal(t_sync, t_async), "async must not change tokens"
    print(f"tokens identical; tier DRAM read {s_async.tier_dram_read} B "
          f"(sync {s_sync.tier_dram_read} B)")
    print(f"async I/O: serialized {s_async.tier_io_service_s * 1e6:.1f} us, "
          f"queue delay {s_async.tier_io_queue_delay_s * 1e6:.1f} us\n")


def many_streams(cfg, params, n=3):
    print(f"== {n} streams sharing one device queue ==")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (1, 48)).astype(np.int32)
               for _ in range(n)]
    eng = MultiStreamEngine(
        cfg, params, n, device_kind="trace", max_seq=96, batch=1,
        page_tokens=16, hbm_kv_budget=1 << 12, policy=LOSSLESS_POLICY,
    )
    toks = eng.generate(prompts, 8)
    d = eng.device_stats()
    print(f"generated {[t.shape for t in toks]} tokens")
    per_read = [
        sum(t.dram_bytes_read for t in s.pool.page_traffic.values())
        for s in eng.streams
    ]
    print(f"per-stream DRAM reads {per_read} B  (sum {sum(per_read)} B "
          f"== device {d.dram_bytes_read} B)")
    assert sum(per_read) == d.dram_bytes_read
    print(f"aggregate tok/s ceiling: {eng.throughput_ceiling():.1f}")


def main():
    raw_tickets()
    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    overlap_single_stream(cfg, params)
    many_streams(cfg, params)


if __name__ == "__main__":
    main()
