"""End-to-end training driver: ~100M-param qwen2-family model, a few
hundred steps on the synthetic corpus, with checkpoint/restart.

The config is the real qwen2-0.5b architecture scaled to ~100M params
(depth/width reduced, same family code path as the full model).  Loss on
the repeated-ngram synthetic corpus should fall well below the unigram
entropy, proving the whole stack (data → model → optimizer → checkpoint)
learns.

Run: PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs import ARCHS
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/trace_train_100m")
    args = ap.parse_args()

    base = ARCHS["qwen2-0.5b"]
    cfg_100m = dataclasses.replace(
        base, n_layers=6, d_model=512, n_heads=8, n_kv_heads=2,
        head_dim=64, d_ff=2048, vocab=32000, remat=False,
    )
    # ~ 32000*512*2 + 6*(512*1024*... ) ≈ 1.0e8 params
    import repro.configs as C

    C.ARCHS["qwen2-100m"] = cfg_100m

    out = train(
        arch="qwen2-100m", steps=args.steps, smoke=False,
        seq_len=128, global_batch=8,
        ckpt_dir=args.ckpt_dir, ckpt_every=100,
        grad_compression=True, log_every=10,
    )
    losses = out["losses"]
    print(f"loss: start {losses[0]:.3f} → end {losses[-1]:.3f}")
    if args.steps >= 100:
        assert losses[-1] < losses[0] - 0.5, "model failed to learn"
        print("OK: end-to-end training learns on this stack")


if __name__ == "__main__":
    main()
