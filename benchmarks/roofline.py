"""§Roofline — three-term roofline per (arch × shape) from the dry-run.

Reads the dry-run JSON (launch/dryrun.py --out) and derives, per cell on
the single-pod mesh:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

(the compiled module is the post-SPMD per-device program, so
cost_analysis() numbers are already per-chip).  Also reports
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) against HLO FLOPs, the
dominant term, and one-line guidance — the §Perf loop iterates on the
dominant term.

Hardware constants (TPU v5e-class, per chip):
    197 TFLOP/s bf16; 819 GB/s HBM; ICI 2 links/axis × 50 GB/s = 100 GB/s
    effective per chip (bidirectional ring transfers; conservative since
    v5e has 4 links usable across 2 mesh axes).
"""

from __future__ import annotations

import json
import os

from repro.configs import ARCHS, SHAPES

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 100e9

DRYRUN_JSON = os.environ.get("DRYRUN_JSON", "dryrun_baseline.json")


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D (train) / 2·N·D (one forward token batch, decode/prefill)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.seq_len * shape.global_batch
        return 6.0 * n * d
    if shape.is_decode:
        return 2.0 * n * shape.global_batch
    return 2.0 * n * shape.seq_len * shape.global_batch


def analyse(rec: dict) -> dict:
    """Three roofline terms per chip.

    Two memory readings are reported:
      * ``t_memory_hlo``  — cost_analysis 'bytes accessed' (trip-count
        corrected).  On the CPU lowering this counts every HLO op's
        operand/result traffic with almost no fusion, so it overstates
        TPU HBM traffic by roughly the fusion factor.
      * ``t_memory_min``  — mandatory device traffic from
        memory_analysis: arguments read + outputs written + temp
        working set, i.e. what a perfectly-fused program still moves.
    The dominant-term decision and roofline fraction use
    max(compute, memory_min, collective); memory_hlo is kept as the
    fusion-waste signal (§Perf iterates it down where it dominates).
    """
    chips = rec["chips"]
    flops = rec.get("hlo_flops_corrected", rec.get("hlo_flops", 0.0))
    bytes_hlo = rec.get("hlo_bytes_corrected", rec.get("hlo_bytes", 0.0))
    man_bytes = (
        rec.get("argument_size_in_bytes", 0)
        + rec.get("output_size_in_bytes", 0)
        + rec.get("temp_size_in_bytes", 0)
    )
    coll_b = rec.get(
        "collective_bytes_corrected",
        rec.get("collectives", {}).get("total", 0.0),
    )
    comp = flops / PEAK_FLOPS
    mem_hlo = bytes_hlo / HBM_BW
    mem_min = man_bytes / HBM_BW
    coll = coll_b / ICI_BW
    terms = {"compute": comp, "memory": mem_min, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"]) / chips
    useful = mf / max(flops, 1.0)
    step = max(terms.values())
    frac = (mf / PEAK_FLOPS) / step if step > 0 else 0.0   # MFU-style roofline fraction
    return {
        **rec,
        "t_compute_s": comp, "t_memory_s": mem_min,
        "t_memory_hlo_s": mem_hlo, "t_collective_s": coll,
        "dominant": dom, "model_flops_per_chip": mf,
        "useful_flops_ratio": useful, "roofline_fraction": frac,
    }


def guidance(row: dict) -> str:
    d = row["dominant"]
    if d == "memory":
        if row["kind"] == "train":
            return "cut HLO bytes: less remat recompute / fuse optimizer"
        return "KV-cache bytes dominate: quantize KV or shard seq wider"
    if d == "collective":
        return "reduce all-gather volume: better FSDP/TP split or overlap"
    return "compute-bound: good; raise useful-flops ratio"


def run(path: str = DRYRUN_JSON):
    if not os.path.exists(path):
        emit("roofline", "dryrun_json_missing", 0, "", f"run dryrun --out {path}")
        return []
    with open(path) as f:
        recs = json.load(f)
    rows = []
    for rec in recs:
        if not rec.get("ok") or rec["mesh"] != "16x16":
            continue
        row = analyse(rec)
        rows.append(row)
        emit(
            "roofline",
            f"{row['arch']}|{row['shape']}",
            row["roofline_fraction"],
            "frac",
            f"dom={row['dominant']} comp={row['t_compute_s']:.3e}s "
            f"mem={row['t_memory_s']:.3e}s coll={row['t_collective_s']:.3e}s "
            f"useful={row['useful_flops_ratio']:.2f}",
        )
    ok_multi = sum(1 for r in recs if r.get("ok") and r["mesh"] == "2x16x16")
    emit("roofline", "multi_pod_cells_ok", ok_multi, "cells")
    return rows


def table(path: str = DRYRUN_JSON) -> str:
    """Markdown table for EXPERIMENTS.md."""
    rows = run(path)
    out = [
        "| arch | shape | compute s | memory(min) s | memory(hlo) s | "
        "collective s | dominant | useful FLOPs | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_memory_hlo_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {guidance(r)} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    print(table())
