"""Figs. 18-21 — device-side DRAM energy/latency under elastic precision.

Plane-aligned fetch (TRACE) vs full-container word fetch (CXL-Plain) on the
structural DRAM model (DRAMSim3 is unavailable offline; see DESIGN.md §2).

Paper anchors: per-expert energy savings up to 29.9% (BF16 bases), taper
for FP8/INT4; OPT-30B per-head up to 40.9%/40.4%/30.5% at 8.0/4.8/1.6
bits; per-neuron 19-34%; model-load latency −25.9..−30.0%.
"""

from __future__ import annotations

from repro.core.dram_model import (
    EXPERT,
    HEAD,
    NEURON,
    energy_per_weight_pj,
    load_latency_s,
    model_load_energy_j,
)

from .common import emit


def run():
    # ---- Fig. 18/19 per-expert granularity ------------------------------------
    # avg bits/weight targets matching Fig. 17's mixes; the admissible
    # precision tiers shrink with the base format (savings taper, paper)
    for base, bits, levels in (("bf16", 9.0, (1, 2, 4, 8, 16)),
                               ("fp8", 5.0, (1, 2, 4, 8)),
                               ("int4", 3.2, (1, 2, 4))):
        e_p = energy_per_weight_pj(EXPERT, bits, "plain", levels=levels)
        e_t = energy_per_weight_pj(EXPERT, bits, "trace", levels=levels)
        sav = (1 - e_t / e_p) * 100
        emit("fig18", f"expert_{base}_energy_savings", sav, "%",
             "paper bf16 25.9-29.9%, fp8 ~19.6%, int4 ~17.9%")
    t_p = load_latency_s(8 * 2, EXPERT, 9.0, "plain")
    t_t = load_latency_s(8 * 2, EXPERT, 9.0, "trace")
    emit("fig19", "expert_bf16_load_latency_savings",
         (1 - t_t / t_p) * 100, "%", "paper up to 30.0%")

    # ---- Fig. 20/21 per-head / per-neuron (OPT-30B) ----------------------------
    for unit, name, n_units in ((HEAD, "head", 48 * 7), (NEURON, "neuron", 48 * 4 * 7168)):
        for bits in (1.6, 4.8, 8.0):
            e_p = energy_per_weight_pj(unit, bits, "plain")
            e_t = energy_per_weight_pj(unit, bits, "trace")
            emit("fig21", f"{name}_{bits}b_plain_pj", e_p, "pJ/w",
                 "paper head 49.6/118.9/238.9")
            emit("fig21", f"{name}_{bits}b_trace_pj", e_t, "pJ/w",
                 "paper head 34.5/70.8/141.2")
            emit("fig21", f"{name}_{bits}b_savings",
                 (1 - e_t / e_p) * 100, "%",
                 "paper head 30.5/40.4/40.9, neuron 19.4/20.3/33.9")
        e_full_p = model_load_energy_j(n_units, unit, 8.0, "plain")
        e_full_t = model_load_energy_j(n_units, unit, 8.0, "trace")
        emit("fig20", f"{name}_model_load_savings",
             (1 - e_full_t / e_full_p) * 100, "%", "paper up to 40.3%")

    # ---- live-bytes cross-check: the ACTUAL device pipeline ------------------
    # (runtime/weights.py pushes real tensors through bit-plane compression
    #  + plane-aligned fetch; the structural model above predicts energy,
    #  this measures bytes end to end)
    from repro.core import synth
    from repro.runtime import WeightStore
    import ml_dtypes
    import numpy as np

    tr, pl = WeightStore("trace"), WeightStore("plain")
    for store in (tr, pl):
        # one batched load → the device encodes the whole model as a few
        # vectorized slab passes (the write-path mirror of fetch_all)
        store.put_many({
            f"u{i}": (synth.weights(1 << 16, "bf16", seed=40 + i)
                      .view(ml_dtypes.bfloat16).reshape(256, 256),
                      float(16 - i))
            for i in range(16)
        })
        store.stats.reset_traffic()
        store.fetch_all()
    emit("fig18", "live_weight_dram_bytes_savings",
         (1 - tr.stats.dram_bytes_read / pl.stats.dram_bytes_read) * 100,
         "%", f"measured plane-fetch @ avg {tr.avg_bits():.1f} bits/unit")


if __name__ == "__main__":
    run()
