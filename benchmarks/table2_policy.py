"""Table II analogue — KV page-policy quality through the REAL device path.

The paper reports perplexity on LLaMA-3.1-8B; offline we cannot load that
checkpoint, so the measurable analogue is logit fidelity on this repo's
models: run decode with (a) everything lossless, (b) the paper's mixed
policy, (c) truncation-only (no guard rounding), and report logit MSE /
top-1 agreement vs the lossless baseline.  The ordering the paper claims
(mixed precision ≻ aggressive drop) must hold here too.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.precision import FULL, MAN0, MAN4, PrecisionView
from repro.models.model import init_params
from repro.runtime import ServeEngine
from repro.runtime.paging import LOSSLESS_POLICY, PagePolicy

from .common import emit

PAPER = PagePolicy(tiers=((5, FULL), (3, MAN4), (2, MAN0)), tail_view=MAN0)
TRUNC = PagePolicy(
    tiers=((5, FULL), (3, PrecisionView(r_m=4, name="t4")),
           (2, PrecisionView(r_m=0, name="t0"))),
    tail_view=PrecisionView(r_m=0, name="t0"),
)
ALL_MAN0 = PagePolicy(tiers=((1 << 30, MAN0),), tail_view=MAN0)


def _logits(policy, params, cfg, n=16):
    eng = ServeEngine(
        cfg, params, max_seq=160, batch=1, page_tokens=16,
        hbm_kv_budget=1 << 11, device_kind="trace", policy=policy,
    )
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (1, 96)).astype(np.int32)
    logits = [eng.prefill(prompt)]
    toks = rng.integers(0, cfg.vocab, (n, 1, 1)).astype(np.int32)
    for t in toks:  # teacher-forced: same inputs across policies
        logits.append(eng.decode(t))
    return np.stack(logits), eng


def run():
    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))

    base, eng_b = _logits(LOSSLESS_POLICY, params, cfg)
    rows = {}
    eng_m = None
    for name, pol in (("paper_mixed", PAPER), ("truncate_only", TRUNC),
                      ("all_man0", ALL_MAN0)):
        got, eng = _logits(pol, params, cfg)
        if name == "paper_mixed":
            eng_m = eng
        mse = float(np.mean((got - base) ** 2))
        top1 = float(np.mean(got.argmax(-1) == base.argmax(-1)))
        dram = eng.stats().tier_dram_read
        rows[name] = (mse, top1, dram)
        emit("table2", f"{name}_logit_mse", mse, "", "vs lossless decode")
        emit("table2", f"{name}_top1_agreement", top1 * 100, "%")
        emit("table2", f"{name}_tier_dram_read", dram, "B")
    emit("table2", "lossless_tier_dram_read", eng_b.stats().tier_dram_read, "B")

    # Per-request receipts attribute tier traffic per layer (not one
    # global counter): report the hottest/coldest layer for the paper mix.
    per_layer = {
        layer: t.dram_bytes_read + t.dram_bytes_written
        for layer, t in eng_m.layer_traffic().items()
    }
    if per_layer:
        emit("table2", "paper_mixed_layers_attributed", len(per_layer), "",
             "layers with receipt-attributed tier traffic")
        emit("table2", "paper_mixed_max_layer_dram",
             max(per_layer.values()), "B")
        emit("table2", "paper_mixed_min_layer_dram",
             min(per_layer.values()), "B")

    # paper's ordering: guard-rounded mixed ≻ truncation at same planes;
    # both ≻ uniformly aggressive
    assert rows["paper_mixed"][0] <= rows["truncate_only"][0] * 1.05
    assert rows["paper_mixed"][0] < rows["all_man0"][0]


if __name__ == "__main__":
    run()
