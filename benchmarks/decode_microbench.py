"""SLO-grade decode microbenchmark — prefill vs autoregressive phases.

maxtext-style phase split: a serving run is two regimes with different
bottlenecks — prefill (one big batched forward, write-dominated tier
traffic) and autoregressive decode (one token per step, read-dominated
spill fetch) — and a codec win that only shows up as aggregate MB/s can
hide a TPOT regression.  This module runs the same engine workload per
device config (plain / gcomp / trace), times the two phases separately
(host wall-clock AND the modeled tier-I/O seconds the receipts carry),
and reports per-phase throughput: TTFT-shaped numbers for prefill,
TPOT for decode.

The HBM KV budget is deliberately tiny so the KV working set spills to
the tier and the decode phase actually exercises the readback path —
wall-clock therefore includes the host-side encode/decode pipeline this
PR moved into ``kernels/lz4.py``, which is the point: the kernel win is
visible as time-per-output-token, not just codec MB/s.

``--smoke`` shrinks the workload for CI; with ``BENCH_JSON_DIR`` set the
rows land in ``BENCH_decode_microbench.json`` and
``tools/bench_diff.py`` bands them against the committed baseline.
"""

from __future__ import annotations

import time

import numpy as np

from .common import emit

DEVICE_CONFIGS = ("plain", "gcomp", "trace")


def _phase_stats(eng):
    s = eng.stats()
    return (s.tier_io_service_s, s.tier_dram_read, s.tier_dram_stored)


def run_device(device: str, prompt_len: int, new_tokens: int,
               page_tokens: int, reps: int):
    """One device config: reps runs of prefill + decode, best-of per
    phase; emits wall-clock, modeled tier I/O and derived TTFT/TPOT."""
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.models.model import init_params
    from repro.runtime import ServeEngine
    from repro.runtime.paging import LOSSLESS_POLICY

    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, (1, prompt_len)).astype(np.int32)

    best = None
    for _ in range(reps):
        eng = ServeEngine(
            cfg, params, max_seq=prompt_len + new_tokens + page_tokens,
            batch=1, page_tokens=page_tokens, hbm_kv_budget=1 << 12,
            device_kind=device, policy=LOSSLESS_POLICY,
        )
        t0 = time.perf_counter()
        logits = eng.prefill(prompt)
        eng.flush_io()                      # charge in-flight readback here
        t_prefill = time.perf_counter() - t0
        io_prefill, read_prefill, stored_prefill = _phase_stats(eng)

        t0 = time.perf_counter()
        for _ in range(new_tokens):
            nxt = logits.argmax(-1).astype(np.int32)
            logits = eng.decode(nxt.reshape(-1, 1))
        eng.flush_io()
        t_decode = time.perf_counter() - t0
        io_total, read_total, stored_total = _phase_stats(eng)
        run = (t_prefill, t_decode, io_prefill, io_total - io_prefill,
               read_total - read_prefill, stored_prefill)
        # best-of on the wall-clock sum: phases from the same run stay
        # paired (mixing phase minima across runs would misstate TPOT)
        if best is None or t_prefill + t_decode < best[0] + best[1]:
            best = run
    (t_prefill, t_decode, io_prefill, io_decode, decode_read,
     prefill_stored) = best

    emit("decode_microbench", f"{device}_prefill_wall_ms", t_prefill * 1e3,
         "ms", f"{prompt_len}-token prompt, host wall-clock (TTFT proxy)")
    emit("decode_microbench", f"{device}_prefill_tok_s",
         prompt_len / t_prefill, "tok/s", "prefill phase")
    emit("decode_microbench", f"{device}_prefill_tier_io_ms",
         io_prefill * 1e3, "ms", "modeled DDR/link service time, receipts")
    emit("decode_microbench", f"{device}_decode_tpot_ms",
         t_decode / new_tokens * 1e3, "ms/tok",
         f"{new_tokens} autoregressive steps, host wall-clock")
    emit("decode_microbench", f"{device}_decode_tok_s",
         new_tokens / t_decode, "tok/s", "autoregressive phase")
    emit("decode_microbench", f"{device}_decode_tier_io_ms",
         io_decode * 1e3, "ms", "modeled DDR/link service time, receipts")
    emit("decode_microbench", f"{device}_decode_dram_read_kb",
         decode_read / 1e3, "KB",
         "device-DRAM bytes the decode phase fetched (spill readback)")
    emit("decode_microbench", f"{device}_prefill_stored_kb",
         prefill_stored / 1e3, "KB",
         "stored footprint after prefill (compression on-device)")


def run(smoke: bool = False):
    # new_tokens must cross at least one page boundary (page_tokens=16)
    # or the decode phase never touches the spill-readback path
    prompt_len, new_tokens, reps = (64, 16, 2) if smoke else (192, 32, 3)
    for device in DEVICE_CONFIGS:
        run_device(device, prompt_len, new_tokens, page_tokens=16,
                   reps=reps)


if __name__ == "__main__":
    import sys

    run(smoke="--smoke" in sys.argv)
    from .common import dump_json

    dump_json("decode_microbench")     # no-op unless BENCH_JSON_DIR is set
