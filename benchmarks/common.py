"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import synth
from repro.core.tier import KV, TENSOR, WriteReq, make_device

ROWS = []


def emit(table: str, name: str, value, unit: str = "", note: str = ""):
    ROWS.append((table, name, value, unit, note))
    val = f"{value:.4g}" if isinstance(value, float) else value
    print(f"{table},{name},{val},{unit},{note}", flush=True)


def dump_json(module: str, first_row: int = 0,
              duration_s: float | None = None,
              out_dir: str | None = None) -> str | None:
    """Write the rows emitted since ``first_row`` as ``BENCH_<module>.json``.

    Destination: ``out_dir``, else the ``BENCH_JSON_DIR`` environment
    variable; a no-op (returns None) when neither is set, so the CSV
    stream on stdout stays the primary interface.  The artifact is one
    JSON object per benchmark module — ``{"module", "rows",
    "duration_s"}`` with each row a ``table/name/value/unit/note`` dict
    — which CI uploads from the smoke jobs so every figure's numbers
    are tracked across PRs instead of scrolling away in the job log.
    """
    dest = out_dir or os.environ.get("BENCH_JSON_DIR")
    if not dest:
        return None
    os.makedirs(dest, exist_ok=True)
    payload = {
        "module": module,
        "rows": [dict(zip(("table", "name", "value", "unit", "note"), r))
                 for r in ROWS[first_row:]],
    }
    if duration_s is not None:
        payload["duration_s"] = round(duration_s, 3)
    path = os.path.join(dest, f"BENCH_{module}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path


def timed(fn, *args, reps: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def device_ratio(kind: str, codec: str, u16: np.ndarray, kv: bool = False) -> float:
    """Stored-footprint compression ratio of one tensor on one device.

    The write goes through the request-batched TierStore API; the ratio
    could equally be read off the returned receipt
    (``raw_bytes_stored / dram_bytes_stored``).
    """
    dev = make_device(kind, codec=codec)
    rec, = dev.submit([WriteReq("t", u16, kind=KV if kv else TENSOR)])
    assert rec.raw_bytes_stored / max(rec.dram_bytes_stored, 1) == \
        dev.stats.compression_ratio
    return dev.stats.compression_ratio


# Synthetic corpora: one "layer" per (smoothness, scale_spread) pair drawn
# from ranges matching the paper's per-layer diversity (Fig. 15: ratios
# 1.2-2.7 across 32 layers).
def kv_corpus(n_layers: int = 32, tokens: int = 1024, channels: int = 512):
    out = []
    rng = np.random.default_rng(42)
    for layer in range(n_layers):
        smooth = rng.uniform(0.90, 0.995)
        spread = rng.uniform(0.5, 1.6)
        snr = rng.uniform(1.0, 5.0)
        out.append(
            synth.kv_cache(tokens, channels, smooth=smooth,
                           scale_spread=spread, mean_snr=snr, seed=layer)
        )
    return out


def model_kv(arch: str = "qwen2-0.5b", tokens: int = 256):
    """KV captured from an actual forward pass (random-init reduced model) —
    cross-check that results don't hinge on the AR(1) synthesiser."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, smoke_config
    from repro.models import forward
    from repro.models.model import init_cache, init_params

    cfg = smoke_config(ARCHS[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, tokens), 0, cfg.vocab)
    cache = init_cache(cfg, 1, tokens)
    _, cache, _ = forward(
        cfg, params, {"tokens": toks, "cache_pos": jnp.int32(0)}, cache=cache
    )
    k = np.asarray(cache["layers"]["k"])     # (L, 1, S, KV, hd)
    L = k.shape[0]
    return [
        np.ascontiguousarray(k[l, 0].reshape(tokens, -1)).view(np.uint16)
        for l in range(L)
    ]
