"""PNM long-context sweep: device-side top-k gather vs link-bound readback.

Past ~128k tokens of context even compressed KV is link-bound: the host
pulls O(context) bytes per decode step no matter how well the planes
pack.  The PNM read mode (``core.tier.GatherReq``) moves candidate
scoring onto the device — a plane-subset decode at the ``score`` view
(sign + the delta-transformed exponent planes, the compressible ones)
feeds ``kernels/pnm_score.py`` and only the top-k winner pages cross the
link — so per-step traffic drops to O(k · page) + 4 B/candidate.

Two stages:

* **measured** — a real ``KVPagePool`` on a trace device: per-page DRAM
  and link costs of (a) the classic full readback, (b) the score-only
  pass (a ``k=0`` gather), plus the inline byte-identity check that a
  ``k >= candidates`` gather returns exactly the readback bytes.
* **modeled** — those measured per-page constants scaled across a
  128k → 1M context sweep under the paper's §IV-B SystemSpec: the
  baseline's tok/s collapses as O(context) while PNM holds, and the
  512k gain row (``pnm_tok_s_gain_512k``) gates in CI via
  ``tools/bench_diff.py`` with an absolute ≥3x floor.

``--smoke`` shrinks the measured stage for CI; with ``BENCH_JSON_DIR``
set the rows land in ``BENCH_fig_pnm_longctx.json``.
"""

from __future__ import annotations

import time

import numpy as np

from .common import emit

PAGE_TOKENS = 32
CHANNELS = 256             # measured-page channels (costs scale linearly)
HBM_TOKENS = 8192          # resident context the sweep never spills
CONTEXTS = (131072, 262144, 524288, 1048576)

# Modeled serving footprint: a 7B-class decoder — every spilled token
# carries K and V across all layers, at MODEL_CHANNELS per kind per
# layer.  Per-page tier costs scale linearly in channels, so a model
# page costs CH_RATIO measured pages.
MODEL_LAYERS = 32
MODEL_KINDS = 2            # k and v
MODEL_CHANNELS = 1024      # kv_heads * head_dim per kind
CH_RATIO = MODEL_CHANNELS // CHANNELS
K_PER_GROUP = 8            # winner pages per (layer, kind) per step


def _build_pool(kv: np.ndarray, n_pages: int):
    from repro.runtime.paging import KVPagePool, LOSSLESS_POLICY

    # Lossless policy = the link-bound baseline regime the sweep models:
    # every spilled page round-trips at full precision, so the host
    # pulls O(context) full-container bytes per step.
    pool = KVPagePool(
        "trace", page_tokens=PAGE_TOKENS,
        hbm_budget_bytes=2 * PAGE_TOKENS * CHANNELS * 2,
        policy=LOSSLESS_POLICY, sanitize=True,
    )
    for i in range(n_pages):
        pool.append_page(0, "k", i * PAGE_TOKENS,
                         kv[i * PAGE_TOKENS:(i + 1) * PAGE_TOKENS])
    return pool


def measure(n_pages: int):
    """Per-page tier costs from a real device, plus the identity check.

    Returns (dram_full, link_full, dram_score, compute_s_page): DRAM and
    link bytes to ship one spilled page at full precision, DRAM bytes the
    device touches to SCORE one candidate, and the modeled on-device
    scoring seconds per page.
    """
    from repro.core import synth

    kv = synth.kv_cache(PAGE_TOKENS * n_pages, CHANNELS, smooth=0.99,
                        mean_snr=5.0, seed=0)
    digest = np.ones(CHANNELS, np.float32)

    pool = _build_pool(kv, n_pages)
    spilled = [p for p in pool.iter_pages() if p.resident is None]
    n = len(spilled)
    d = pool.device.stats
    mark = (d.dram_bytes_read, d.link_bytes_out)
    base_data = pool.read_pages(spilled)
    d = pool.device.stats
    dram_full = (d.dram_bytes_read - mark[0]) / n
    link_full = (d.link_bytes_out - mark[1]) / n

    pool_sc = _build_pool(kv, n_pages)
    d = pool_sc.device.stats
    mark = (d.dram_bytes_read, d.device_compute_s)
    pool_sc.gather_topk(digest, 0)
    d = pool_sc.device.stats
    dram_score = (d.dram_bytes_read - mark[0]) / n
    compute_s = (d.device_compute_s - mark[1]) / n

    # Hard invariant, not a perf number: a gather whose k covers every
    # candidate ships exactly the bytes the classic readback would.
    pool_id = _build_pool(kv, n_pages)
    winners, data = pool_id.gather_topk(digest, n_pages + 1)
    by_key = {p.key: a for p, a in zip(spilled, base_data)}
    identical = (len(winners) == n and all(
        np.array_equal(by_key[p.key], a) for p, a in zip(winners, data)))

    emit("fig_pnm_longctx", "pnm_topk_byte_identical", float(identical), "",
         "k >= candidates gather bytes == full readback bytes")
    emit("fig_pnm_longctx", "baseline_dram_bytes_page", float(dram_full),
         "B", "compressed plane bytes read per full-precision page")
    emit("fig_pnm_longctx", "baseline_link_bytes_page", float(link_full),
         "B", "decoded BF16 bytes shipped per page (link-bound baseline)")
    emit("fig_pnm_longctx", "pnm_score_dram_bytes_page", float(dram_score),
         "B", "score-view plane bytes the device reads per candidate")
    return dram_full, link_full, dram_score, compute_s


def sweep(dram_full: float, link_full: float, dram_score: float,
          compute_s: float):
    """Scale the measured per-page constants across the context sweep."""
    from repro.core.system_model import SystemSpec

    sys_ = SystemSpec()
    groups = MODEL_LAYERS * MODEL_KINDS
    for ctx in CONTEXTS:
        # Real candidate pages per step (one per page window per layer
        # per kind) and their cost in measured-page equivalents.
        n_cand = max(ctx - HBM_TOKENS, 0) // PAGE_TOKENS * groups
        n_eq = n_cand * CH_RATIO
        n_read = sys_.f_rd * n_eq             # baseline touches f_rd/step
        t_base = max(n_read * dram_full / sys_.cxl_ddr_bw,
                     n_read * link_full / sys_.cxl_link_bw,
                     1.0 / sys_.cap_tok_s)
        k_eq = min(K_PER_GROUP * groups, n_cand) * CH_RATIO
        pnm_link = 4.0 * n_cand + k_eq * link_full
        pnm_dram = n_eq * dram_score + k_eq * dram_full
        t_pnm = max(pnm_dram / sys_.cxl_ddr_bw,
                    pnm_link / sys_.cxl_link_bw,
                    n_eq * compute_s,
                    1.0 / sys_.cap_tok_s)
        tag = f"{ctx // 1024}k"
        emit("fig_pnm_longctx", f"baseline_link_kb_step_{tag}",
             n_read * link_full / 1e3, "KB",
             "link bytes per decode step, full readback (O(context))")
        emit("fig_pnm_longctx", f"pnm_link_kb_step_{tag}",
             pnm_link / 1e3, "KB",
             f"link bytes per decode step, top-{K_PER_GROUP}/group "
             f"gather (O(k))")
        emit("fig_pnm_longctx", f"baseline_tok_s_{tag}", 1.0 / t_base,
             "tok/s", "modeled decode throughput, full readback")
        emit("fig_pnm_longctx", f"pnm_tok_s_{tag}", 1.0 / t_pnm,
             "tok/s", "modeled decode throughput, PNM gather")
        if ctx == 524288:
            emit("fig_pnm_longctx", "pnm_tok_s_gain_512k",
                 t_base / t_pnm, "x",
                 "PNM over link-bound baseline at 512k (CI floor: 3x)")


def run(smoke: bool = False):
    t0 = time.perf_counter()
    constants = measure(n_pages=12 if smoke else 24)
    sweep(*constants)
    emit("fig_pnm_longctx", "measure_wall_ms",
         (time.perf_counter() - t0) * 1e3, "ms",
         "measured-stage host wall-clock (track only)")


if __name__ == "__main__":
    import sys

    run(smoke="--smoke" in sys.argv)
    from .common import dump_json

    dump_json("fig_pnm_longctx")       # no-op unless BENCH_JSON_DIR is set
