"""Table IV — lossless weight compression under TRACE, by offline format.

Paper anchors: BF16 ratio 1.32-1.34 (24-25.6% savings); FP8 1.09-1.11
(8-10%); INT4 1.01-1.02 (0.9-2.1%); total savings vs BF16 at INT4 ≈ 75%.
"""

from __future__ import annotations

from repro.core import synth
from repro.core.tier import make_device

from .common import emit


def run():
    n = 2 << 20
    for fmt, anchor in (("bf16", "1.32-1.34"), ("fp8", "1.09-1.11"),
                        ("int4", "1.01-1.02")):
        if fmt == "bf16":
            # BF16 containers through the bit-plane path
            u = synth.weights(n, "bf16", seed=1)
            dev = make_device("trace", codec="zstd")
            dev.write_tensor("w", u)
            ratio = dev.stats.compression_ratio
            stored = n * 2 / ratio
        else:
            # native packed quantized bitstream → byte-plane compression
            u = synth.weights(n, fmt, seed=1)
            q = synth.quantized_bits(u, fmt)
            dev = make_device("trace", codec="zstd", block_elems=2048)
            # device sees the packed bytes as u16 containers two-at-a-time
            import numpy as np

            qq = q if q.size % 2 == 0 else np.pad(q, (0, 1))
            dev.write_tensor("w", qq.view(np.uint16))
            ratio = dev.stats.compression_ratio
            stored = q.size / ratio
        emit("table4", f"weights_{fmt}_trace_zstd_ratio", ratio, "x",
             f"paper {anchor}")
        total_sav = (1 - stored / (n * 2)) * 100
        emit("table4", f"weights_{fmt}_total_savings_vs_bf16", total_sav, "%",
             "paper bf16 25%, fp8 54%, int4 75%")


if __name__ == "__main__":
    run()
