"""Benchmark driver: one module per paper table/figure + roofline.

Prints ``table,name,value,unit,note`` CSV rows.  Run with
``PYTHONPATH=src python -m benchmarks.run`` (optionally ``--only fig15``).
With ``--json-dir DIR`` (or ``BENCH_JSON_DIR`` in the environment) each
module additionally writes its rows as a ``BENCH_<module>.json``
artifact — the per-PR perf trajectory CI uploads.
"""

from __future__ import annotations

import argparse
import sys
import time

from .common import ROWS, dump_json

MODULES = [
    "table1_direct",
    "table2_policy",
    "table4_weights",
    "fig12_14_throughput",
    "fig15_kv_ratio",
    "fig16_planes",
    "fig18_21_dram",
    "table5_ppa",
    "kernels_bench",
    "decode_microbench",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module filter")
    ap.add_argument("--json-dir", default=None,
                    help="also write per-module BENCH_<module>.json "
                         "artifacts here (defaults to $BENCH_JSON_DIR; "
                         "unset = CSV only)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("table,name,value,unit,note")
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        mark = len(ROWS)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            dt = time.perf_counter() - t0
            path = dump_json(name, first_row=mark, duration_s=dt,
                             out_dir=args.json_dir)
            print(f"# {name} done in {dt:.1f}s"
                  + (f" → {path}" if path else ""),
                  file=sys.stderr, flush=True)
        # tracecheck: allow-broad-except(one failing benchmark is reported at exit; the rest of the suite still runs)
        except Exception as e:  # keep the suite running
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
