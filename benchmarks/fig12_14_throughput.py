"""Figs. 12-14 — trace-driven decoding-throughput modeling.

Validates the reimplemented first-order model against the paper's published
anchor points, then reproduces the three experiments:
  Fig. 12: GPT-OSS-120B-MXFP4, weights fit in HBM, KV spills.
  Fig. 13: GPT-OSS-120B BF16, alpha=0.8, weights also spill.
  Fig. 14: alpha sweep (unimodal; TRACE peak higher and at larger alpha).

Plus three measured (receipt-driven) sections: async-vs-sync
multi-stream tok/s on the device model, a continuous-batching
offered-load sweep (ServeScheduler: tok/s + p50/p99 request latency at
several Poisson arrival rates), and a capacity-model sweep — at a fixed
``kv_capacity_bytes`` on the trace device, ratio-aware (`physical`)
admission against the residency ledger must admit a strictly larger
concurrent batch, and deliver more tok/s, than the `logical` BF16
projection.  ``--smoke`` runs just that sweep as the CI
admission-regression gate.
"""

from __future__ import annotations

import numpy as np

from repro.core import synth
from repro.core.system_model import (
    PAPER_ANCHORS_FIG12,
    PAPER_ANCHORS_FIG13,
    SystemSpec,
    gpt_oss_120b,
    sweep_alpha,
    sweep_context,
    throughput,
)
from repro.core.tier import KV, ReadReq, WriteReq, make_device

from .common import emit


def _measured_step_traffic(sys: SystemSpec):
    """Cross-check the analytic model with real device receipts: spill a
    small KV context, read it back as one batched submit (the per-decode-
    step stream), and convert receipt bytes to a tok/s ceiling."""
    tokens, channels, pages = 64, 256, 16
    dev = make_device("trace", kv_window=tokens)
    dev.submit([
        WriteReq(f"ctx.{i}", synth.kv_cache(tokens, channels, seed=300 + i),
                 kind=KV)
        for i in range(pages)
    ])
    receipts = dev.submit([ReadReq(f"ctx.{i}", kind=KV) for i in range(pages)])
    dram = sum(r.dram_bytes_read for r in receipts)
    link = sum(r.link_bytes_out for r in receipts)
    raw = tokens * channels * pages * 2
    t = max(dram / sys.cxl_ddr_bw, link / sys.cxl_link_bw, 1e-12)
    emit("fig12", "measured_kv_dram_per_step", dram, "B",
         f"batched receipts; raw {raw} B")
    emit("fig12", "measured_kv_read_reduction", 1 - dram / raw, "",
         "device-DRAM bytes saved vs raw (trace, lossless view)")
    emit("fig12", "measured_tok_s_ceiling_1step", min(1.0 / t, sys.cap_tok_s),
         "tok/s", "if the whole KV readback were one decode step")


def _async_multistream_throughput(sys: SystemSpec):
    """Model the paper's decode/fetch overlap with real device receipts:
    the same per-step KV readback for several streams, once as serialized
    sync submits (one request at a time, full request overhead each) and
    once through the queued async front-end (one in-flight window, shared
    pipes, overhead amortized).  Throughput = tokens serviced per modeled
    second of tier I/O; async must dominate — that is the mechanism behind
    Fig. 12's 16.28 → 68.99 tok/s at 128k."""
    tokens, channels, streams, pages = 64, 512, 4, 16
    sync_dev = make_device("trace", kv_window=tokens)
    async_dev = make_device("trace", kv_window=tokens, window=128)
    keys = [f"s{s}.ctx.{i}" for s in range(streams) for i in range(pages)]
    for dev in (sync_dev, async_dev):
        dev.submit([
            WriteReq(k, synth.kv_cache(tokens, channels, seed=400 + i), kind=KV)
            for i, k in enumerate(keys)
        ])
        # setup writes are posted; idle the busy clock so the sync/async
        # comparison below prices read scheduling, not write backlog
        dev.quiesce()

    # sync-sequential: each stream's pages read one submit at a time
    t_sync = sum(
        r.latency_s
        for k in keys
        for r in sync_dev.submit([ReadReq(k, kind=KV)])
    )
    # async: every stream enqueues before anyone drains (one shared window)
    tickets = async_dev.submit_async([ReadReq(k, kind=KV) for k in keys])
    recs = async_dev.drain(tickets)
    t_async = max(r.latency_s for r in recs)   # overlap: last delivery
    q_delay = sum(r.queue_delay_s for r in recs)

    # One decode step per stream, each fetching its spilled context.  The
    # small synthetic context keeps both designs above the compute cap, so
    # report the *uncapped* tier-I/O ceiling — the quantity the queued
    # front-end changes (compute overlap hides anything below the cap).
    tok_s_sync = streams / t_sync
    tok_s_async = streams / t_async
    emit("fig12", "measured_sync_sequential_tok_s", tok_s_sync, "tok/s",
         f"I/O-only ceiling, uncapped; {streams} streams x {pages} pages, "
         "serialized submits")
    emit("fig12", "measured_async_multistream_tok_s", tok_s_async, "tok/s",
         "I/O-only ceiling, uncapped; same workload, one in-flight window")
    emit("fig12", "measured_async_speedup", tok_s_async / tok_s_sync, "x",
         f"queue delay {q_delay * 1e6:.2f} us across {len(recs)} receipts")
    assert tok_s_async >= tok_s_sync, (tok_s_async, tok_s_sync)


def _continuous_batching_sweep():
    """Throughput + latency vs offered load under continuous batching:
    the same request population (smoke model, tiny HBM budget so KV spills
    to the shared trace tier) replayed at several Poisson arrival rates
    through the ServeScheduler.  As offered load rises, batch slots and
    KV capacity saturate, queueing delay dominates p99, and tok/s climbs
    toward the shared-device ceiling — the many-user regime in which the
    paper's 4.24x decode-throughput recovery at 128k actually matters."""
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.models.model import init_params
    from repro.runtime import ServeScheduler
    from repro.runtime.paging import LOSSLESS_POLICY

    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req, new_tok = 6, 6
    for rate in (0.1, 0.3, 0.8):
        trace = synth.request_trace(
            n_req, cfg.vocab, rate=rate, prompt_len=32, new_tokens=new_tok,
            seed=7,
        )
        sched = ServeScheduler(
            cfg, params, max_batch=2, device_kind="trace",
            policy=LOSSLESS_POLICY, page_tokens=16, hbm_kv_budget=1 << 12,
        )
        rep = sched.run(trace)
        tag = f"load{rate:g}"
        emit("fig12", f"cb_{tag}_tok_s", rep.tok_s, "tok/s",
             f"{n_req} reqs x {new_tok} tok, poisson {rate}/round, "
             "max_batch 2")
        emit("fig12", f"cb_{tag}_p50_latency", rep.p50_latency_s * 1e3, "ms",
             "arrival→last-token, modeled")
        emit("fig12", f"cb_{tag}_p99_latency", rep.p99_latency_s * 1e3, "ms",
             f"mean queue delay {rep.mean_queue_delay_s * 1e3:.2f} ms")
        d = sched.device_stats()
        assert d.dram_bytes_stored == 0 and d.blocks == 0, \
            "retired requests must free their tier namespaces"


def _capacity_model_sweep(smoke: bool = False):
    """Physical vs logical admission at fixed KV capacity (trace device).

    Capacity is sized to 1.7x one request's logical projection: the
    logical model can never overlap two requests (2x > 1.7x), while the
    physical model admits a second as soon as the ledger-observed
    compression ratio clears 2/1.7 ≈ 1.18 — comfortably below what the
    trace layout achieves on model KV.  The run asserts the
    admitted-batch and tok/s wins, making it the admission-regression
    gate CI runs via ``--smoke``.  Tokens stay bit-identical to solo
    runs: the degrade ladder is disabled, admission only changes
    membership (the scheduler differential tests prove that invariant).
    """
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.models.model import init_params
    from repro.runtime import ServeScheduler, projected_kv_bytes
    from repro.runtime.paging import DEFAULT_DEGRADE_LADDER, LOSSLESS_POLICY

    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req, new_tok, prompt_len = (3, 4, 32) if smoke else (5, 6, 32)
    proj = projected_kv_bytes(cfg, 1, prompt_len + new_tok, 16)
    cap = int(1.7 * proj)

    def _requests():
        rng = np.random.default_rng(23)
        return [
            dict(arrival=0.0,
                 prompt=rng.integers(0, cfg.vocab, (1, prompt_len)).astype(
                     np.int32),
                 max_new_tokens=new_tok, seed=500 + i)
            for i in range(n_req)
        ]

    results = {}
    for model in ("logical", "physical"):
        sched = ServeScheduler(
            cfg, params, max_batch=3, device_kind="trace",
            policy=LOSSLESS_POLICY, page_tokens=16, hbm_kv_budget=1 << 12,
            kv_capacity_bytes=cap, capacity_model=model,
        )
        rep = sched.run(_requests())
        results[model] = rep
        emit("fig12", f"cap_{model}_peak_batch", rep.peak_active, "req",
             f"{n_req} reqs at kv_capacity 1.7x one projection")
        emit("fig12", f"cap_{model}_tok_s", rep.tok_s, "tok/s",
             f"ratio estimate {rep.kv_ratio_estimate:.2f}x")
        emit("fig12", f"cap_{model}_p50_ttft", rep.p50_ttft_s * 1e3, "ms",
             f"TPOT {rep.mean_tpot_s * 1e3:.2f} ms/tok")
        d = sched.device_stats()
        assert d.dram_bytes_stored == 0 and d.blocks == 0, \
            "retired requests must free their tier namespaces"
        assert sched.device.resident_bytes() == 0, \
            "residency ledger must drain with the device"
    log_rep, phy_rep = results["logical"], results["physical"]
    # The admission-regression gate: ratio-aware admission must beat the
    # logical projection on a compressing device — in admitted batch
    # (strictly) and throughput.
    assert phy_rep.peak_active > log_rep.peak_active, \
        (phy_rep.peak_active, log_rep.peak_active)
    assert phy_rep.tok_s > log_rep.tok_s, (phy_rep.tok_s, log_rep.tok_s)
    emit("fig12", "cap_physical_admission_gain",
         phy_rep.peak_active / log_rep.peak_active, "x",
         "physical admits a strictly larger concurrent batch")
    emit("fig12", "cap_physical_tok_s_gain", phy_rep.tok_s / log_rep.tok_s,
         "x", "at identical kv_capacity_bytes on the trace device")

    # Precision-elastic reclamation: same capacity, degrade ladder on —
    # blocked admissions shed cold mantissa planes instead of stalling.
    sched = ServeScheduler(
        cfg, params, max_batch=3, device_kind="trace",
        policy=LOSSLESS_POLICY, page_tokens=16, hbm_kv_budget=1 << 12,
        kv_capacity_bytes=int(1.5 * proj), capacity_model="physical",
        degrade_ladder=DEFAULT_DEGRADE_LADDER,
    )
    rep = sched.run(_requests())
    emit("fig12", "cap_ladder_peak_batch", rep.peak_active, "req",
         "1.5x capacity + man4→man2→man0 reclamation")
    emit("fig12", "cap_ladder_reclaimed", rep.reclaimed_bytes, "B",
         "physical bytes shed in place by truncate_planes")


def _prefix_share_sweep(smoke: bool = False):
    """Shared-prefix KV reuse vs the store-per-request baseline.

    The many-user workload: every prompt opens with the same system
    prefix (≥50% overlap), capacity is fixed at 1.5x one request's
    logical projection.  Without sharing the scheduler can only
    serialize (2x > 1.5x); with ``prefix_share=True`` the first request
    stores the prefix pages once under the content-addressed ``shared.``
    namespace and every follower is charged only its novel-KV
    projection, so requests overlap at the same capacity — the
    effective-capacity multiplication the refcounted ledger buys.  The
    run asserts the gate: ≥1.5x admitted concurrent batch AND lower p50
    TTFT than the no-sharing baseline, per-request tokens bit-identical
    to solo runs, and a drained ledger (``resident_bytes("") == 0``)
    after the last retirement.  The non-smoke path additionally sweeps
    the share ratio to chart how the win scales with prompt overlap.
    """
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.models.model import init_params
    from repro.runtime import ServeEngine, ServeScheduler, projected_kv_bytes
    from repro.runtime.paging import LOSSLESS_POLICY

    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req, new_tok, prompt_len, page = 3, 4, 64, 16
    proj = projected_kv_bytes(cfg, 1, prompt_len + new_tok, page)
    cap = int(1.5 * proj)

    def _requests(share_tokens):
        rng = np.random.default_rng(29)
        head = rng.integers(0, cfg.vocab, (1, share_tokens)).astype(np.int32)
        return [
            dict(arrival=0.0,
                 prompt=np.concatenate([head, rng.integers(
                     0, cfg.vocab, (1, prompt_len - share_tokens)).astype(
                         np.int32)], axis=1),
                 max_new_tokens=new_tok, seed=600 + i)
            for i in range(n_req)
        ]

    def _run(share, share_tokens):
        sched = ServeScheduler(
            cfg, params, max_batch=n_req, device_kind="trace",
            policy=LOSSLESS_POLICY, page_tokens=page, hbm_kv_budget=1 << 12,
            kv_capacity_bytes=cap, prefix_share=share,
        )
        rep = sched.run(_requests(share_tokens))
        assert sched.device.resident_bytes("") == 0, \
            "residency ledger must drain after the last retirement"
        assert sched.kv_committed_bytes == 0
        return sched, rep

    # the CI gate: 50% overlap, sharing on vs off at equal capacity
    base_sched, base = _run(False, prompt_len // 2)
    shared_sched, rep = _run(True, prompt_len // 2)
    emit("fig14", "share_baseline_peak_batch", base.peak_active, "req",
         f"no sharing, capacity 1.5x one projection ({cap} B)")
    emit("fig14", "share_peak_batch", rep.peak_active, "req",
         "prefix_share=True, 50% prompt overlap, same capacity")
    emit("fig14", "share_admission_gain",
         rep.peak_active / base.peak_active, "x",
         "admitted concurrent batch, sharing vs baseline")
    emit("fig14", "share_baseline_p50_ttft", base.p50_ttft_s * 1e3, "ms",
         "followers queue behind full-projection admissions")
    emit("fig14", "share_p50_ttft", rep.p50_ttft_s * 1e3, "ms",
         "followers admit immediately, charged novel KV only")
    charged = sum(r.kv_charged_bytes for r in rep.records)
    projected = sum(r.kv_projected_bytes for r in rep.records)
    emit("fig14", "share_charged_fraction", charged / projected, "",
         f"{projected - charged} of {projected} projected B already "
         "resident as shared pages")
    assert rep.peak_active >= 1.5 * base.peak_active, \
        (rep.peak_active, base.peak_active)
    assert rep.p50_ttft_s < base.p50_ttft_s, \
        (rep.p50_ttft_s, base.p50_ttft_s)
    # sharing must not change a single token vs solo runs
    for req, rec in zip(_requests(prompt_len // 2), rep.records):
        solo = ServeEngine(
            cfg, params, max_seq=shared_sched.max_seq, batch=1,
            page_tokens=page, hbm_kv_budget=1 << 12, device_kind="trace",
            policy=LOSSLESS_POLICY,
        ).generate(req["prompt"], req["max_new_tokens"], seed=req["seed"])
        assert np.array_equal(solo, rec.tokens), \
            f"req {req['seed']}: shared-prefix run diverged from solo"
    if smoke:
        return
    # share-ratio sweep: how the win scales with prompt overlap
    for ratio in (0.0, 0.25, 0.5, 0.75, 1.0):
        share_tokens = int(prompt_len * ratio)
        _, r = _run(True, share_tokens)
        tag = f"ratio{int(ratio * 100)}"
        emit("fig14", f"share_{tag}_peak_batch", r.peak_active, "req",
             f"{share_tokens} of {prompt_len} prompt tokens shared")
        emit("fig14", f"share_{tag}_p50_ttft", r.p50_ttft_s * 1e3, "ms",
             "lower as more prefix pages are already resident")


def _shard_sweep(smoke: bool = False):
    """Aggregate tok/s vs fleet width, plus imbalance sensitivity.

    The same arrival trace is replayed against 1, 2 and 4 tier devices
    behind the ShardedTierStore front-end (hash-stripe placement).  Per-
    device KV capacity is held fixed — one device can admit one request
    — so the fleet both admits a larger concurrent batch AND divides the
    per-step I/O wall-clock across independent link pipes (the
    scheduler's straggler model charges the slowest device).  The run
    asserts the scaling gate (≥1.5x aggregate tok/s at 4 shards vs 1)
    and that every request's tokens are bit-identical to the
    single-device run — placement moves bytes, never values.

    Imbalance sensitivity is receipt-driven: the identical page
    population is read back through a balanced 4-fleet and through one
    whose shard 0 has 8x-slower pipes; bytes must not change, only the
    completion time (gated by the straggler's queue).
    """
    import jax

    from repro.configs import ARCHS, smoke_config
    from repro.core.sharding import ShardedTierStore
    from repro.core.tier import LinkModel
    from repro.models.model import init_params
    from repro.runtime import ServeScheduler, projected_kv_bytes
    from repro.runtime.paging import LOSSLESS_POLICY

    cfg = smoke_config(ARCHS["qwen2-0.5b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req, new_tok, prompt_len, page = 6, 4, 32, 16
    proj = projected_kv_bytes(cfg, 1, prompt_len + new_tok, page)
    cap_per_dev = int(1.1 * proj)   # one device's capacity ≈ one request

    def _requests():
        rng = np.random.default_rng(31)
        return [
            dict(arrival=0.0,
                 prompt=rng.integers(0, cfg.vocab, (1, prompt_len)).astype(
                     np.int32),
                 max_new_tokens=new_tok, seed=700 + i)
            for i in range(n_req)
        ]

    reps = {}
    for n in (1, 2, 4):
        sched = ServeScheduler(
            cfg, params, max_batch=4, device_kind="trace",
            policy=LOSSLESS_POLICY, page_tokens=page, hbm_kv_budget=1 << 12,
            kv_capacity_bytes=cap_per_dev * n, capacity_model="logical",
            shards=n, placement="hash-stripe",
        )
        rep = sched.run(_requests())
        reps[n] = rep
        emit("fig12", f"shard{n}_tok_s", rep.tok_s, "tok/s",
             f"{n_req} reqs, {n} device(s), per-device capacity fixed")
        emit("fig12", f"shard{n}_peak_batch", rep.peak_active, "req",
             f"fleet capacity {n}x one device")
        d = sched.device_stats()
        assert d.dram_bytes_stored == 0 and d.blocks == 0, \
            "retired requests must free their namespaces on every shard"
        assert sched.device.resident_bytes("") == 0, \
            "fleet residency ledger must drain after the last retirement"
    # sharding moves bytes, never values: per-request tokens bit-identical
    for n in (2, 4):
        for r1, rn in zip(reps[1].records, reps[n].records):
            assert np.array_equal(r1.tokens, rn.tokens), \
                f"shard{n} run diverged from single-device tokens"
    gain = reps[4].tok_s / reps[1].tok_s
    emit("fig12", "shard4_tok_s_gain", gain, "x",
         "aggregate throughput, 4 devices vs 1 (scaling gate >= 1.5x)")
    assert gain >= 1.5, (reps[4].tok_s, reps[1].tok_s)
    emit("fig12", "shard4_fleet_skew", reps[4].fleet_skew, "x",
         "max/mean moved bytes across the 4-device fleet (hash-stripe)")

    # imbalance sensitivity: one 8x-slower shard, receipt-driven
    tokens, channels, pages = 64, 256, 16
    fast = LinkModel()
    slow = LinkModel(ddr_bw=fast.ddr_bw / 8, link_bw=fast.link_bw / 8,
                     base_s=fast.base_s * 8)
    done, payloads = {}, {}
    for tag, models in (("balanced", [fast] * 4),
                        ("slow1", [slow] + [fast] * 3)):
        dev = ShardedTierStore(4, kind="trace", kv_window=tokens,
                               window=64, link_models=models)
        dev.submit([
            WriteReq(f"ctx.{i}", synth.kv_cache(tokens, channels,
                                                seed=800 + i), kind=KV)
            for i in range(pages)
        ])
        dev.quiesce()
        recs = dev.drain(dev.submit_async(
            [ReadReq(f"ctx.{i}", kind=KV) for i in range(pages)]))
        done[tag] = max(r.latency_s for r in recs)
        payloads[tag] = [r.data.tobytes() for r in recs]
    assert payloads["balanced"] == payloads["slow1"], \
        "a slow shard may cost time, never bits"
    emit("fig12", "shard_slow1_slowdown", done["slow1"] / done["balanced"],
         "x", "readback completion, one 8x-slower shard vs balanced 4-fleet")


def run():
    sys = SystemSpec()
    _measured_step_traffic(sys)
    _async_multistream_throughput(sys)
    _continuous_batching_sweep()
    _capacity_model_sweep()
    _prefix_share_sweep()
    _shard_sweep()

    # ---- Fig. 12 -------------------------------------------------------------
    m = gpt_oss_120b("mxfp4")
    ctxs = [65536, 131072, 196608, 262144]
    tw = sweep_context(m, ctxs)
    err = []
    for design in ("plain", "trace"):
        for ctx, want in PAPER_ANCHORS_FIG12[design].items():
            got = tw[design][ctxs.index(ctx)]
            err.append(abs(got - want) / want)
            emit("fig12", f"{design}_{ctx // 1024}k_tok_s", got, "tok/s",
                 f"paper {want}")
    emit("fig12", "anchor_mean_rel_err", float(np.mean(err)) * 100, "%",
         "calibration quality")
    speedup_128k = tw["trace"][1] / tw["plain"][1]
    emit("fig12", "trace_speedup_128k", speedup_128k, "x", "paper 4.24x")
    # GComp ≈ Plain in the KV-bound regime (LZ4 useless on token-major KV)
    emit("fig12", "gcomp_vs_plain_128k",
         tw["gcomp"][1] / tw["plain"][1], "x", "paper ~1.0")

    # ---- Fig. 13 -------------------------------------------------------------
    mb = gpt_oss_120b("bf16")
    for design in ("plain", "gcomp", "trace"):
        for ctx, want in PAPER_ANCHORS_FIG13[design].items():
            got = throughput(mb, ctx, design, alpha=0.8).tok_s
            emit("fig13", f"{design}_{ctx // 1024}k_tok_s", got, "tok/s",
                 f"paper {want}")

    # ---- Fig. 14 -------------------------------------------------------------
    alphas = list(np.linspace(0.1, 0.95, 18))
    sw = sweep_alpha(mb, 131072, alphas)
    for design in ("plain", "gcomp", "trace"):
        arr = np.array(sw[design])
        best = int(arr.argmax())
        emit("fig14", f"{design}_peak_tok_s", float(arr.max()), "tok/s",
             "paper plain 30.89 gcomp 33.98 trace 41.51")
        emit("fig14", f"{design}_peak_alpha", alphas[best], "",
             "paper plain/gcomp 0.592, trace 0.771")
        # unimodality check (allow flat tails)
        d = np.sign(np.diff(np.round(arr, 6)))
        changes = int(np.sum(np.abs(np.diff(d[d != 0]))) // 2)
        emit("fig14", f"{design}_unimodal", int(changes <= 1), "bool")
    assert sw["trace"][np.argmax(sw["trace"])] > sw["gcomp"][np.argmax(sw["gcomp"])]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run only the capacity-model and prefix-share "
                         "sweeps (CI regression gates: physical must "
                         "admit a larger batch than logical, and sharing "
                         "must multiply the admitted batch and cut TTFT "
                         "at 50% prompt overlap)")
    if ap.parse_args().smoke:
        _capacity_model_sweep(smoke=True)
        _prefix_share_sweep(smoke=True)
        _shard_sweep(smoke=True)
    else:
        run()
    from .common import dump_json

    dump_json("fig12_14_throughput")   # no-op unless BENCH_JSON_DIR is set
