"""Kernel-layer benchmark: bytes-scaling + throughput of the TRACE kernels.

Wall-clock on CPU interpret mode is NOT TPU performance; the meaningful
numbers here are (i) bytes moved per view (the paper's proportional-fetch
claim, exact by construction) and (ii) oracle agreement.  We also time the
jnp fallback path to show the host-side cost structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import elastic_matmul, elastic_unpack
from repro.kernels import ref as kref

from .common import emit, timed


def write_path(smoke: bool = False):
    """Write-path (encode) throughput: scalar O(blocks x planes) pipeline
    vs the batched slab encoder, on a KV flush through the TRACE device.

    Emits blocks/s and MB/s for both paths plus the measured speedup and
    bypass rate.  The acceptance workload is a 64-block KV flush (64
    windows of 64 tokens x 64 channels); ``smoke`` shrinks it so CI can
    catch encode regressions fast under ``-m "not slow"`` timing.
    """
    import time

    import numpy as np

    from repro.core import synth
    from repro.core.tier import KV, TierStore, WriteReq

    pages, tokens, ch = (12, 16, 32) if smoke else (64, 64, 64)
    reps = 2 if smoke else 4
    data = [synth.kv_cache(tokens, ch, seed=100 + i) for i in range(pages)]
    reqs = [WriteReq(f"p{i}", d, kind=KV) for i, d in enumerate(data)]
    mbytes = pages * tokens * ch * 2 / 1e6

    def run_once(batched):
        dev = TierStore(layout="bitplane-kv", kv_window=tokens,
                        batched_encode=batched)
        t0 = time.perf_counter()
        dev.submit(reqs)
        return time.perf_counter() - t0, dev

    run_once(False), run_once(True)          # warm both paths
    t_scalar = min(run_once(False)[0] for _ in range(reps))
    t_batched, dev_b = float("inf"), None
    for _ in range(reps):
        t, dev = run_once(True)
        if t < t_batched:
            t_batched, dev_b = t, dev
    blocks = dev_b.stats.blocks
    emit("write", "encode_scalar_blocks_per_s", blocks / t_scalar, "blocks/s",
         f"{pages}-page KV flush, per-block pack+codec")
    emit("write", "encode_scalar_mb_per_s", mbytes / t_scalar, "MB/s")
    emit("write", "encode_batched_blocks_per_s", blocks / t_batched,
         "blocks/s", "same flush, vectorized slab encode")
    emit("write", "encode_batched_mb_per_s", mbytes / t_batched, "MB/s")
    emit("write", "encode_batched_speedup", t_scalar / t_batched, "x",
         "byte-identical stored payloads (differential-tested)")
    emit("write", "encode_bypass_rate", dev_b.stats.bypass_rate, "",
         "payload streams stored raw via pre-screen/threshold (§III-D)")
    # regression gating moved to tools/bench_diff.py: the smoke run used
    # to hard-fail on batched >= scalar here, but a committed-baseline
    # tolerance band catches slow drift the binary check missed


def lz4_encode_path(smoke: bool = False):
    """Codec-stage LZ4 throughput: vectorized match kernel vs the PR 3
    fused slab encoder (the scalar oracle behind ``TRACE_SCALAR_LZ4``).

    Captures the exact (slab, starts, ends) codec calls a KV flush
    makes, asserts byte identity between the two paths over the whole
    flush, then times both best-of-N in one process — the *ratio* is
    stable on noisy shared hosts even when absolute times swing, which
    is what lets ``tools/bench_diff.py`` gate the speedup row.  The
    workload is NOT shrunk under ``smoke``: sub-KB streams would time
    kernel dispatch overhead instead of the match path, and the full
    flush costs well under a second.
    """
    from repro.core import codec, synth
    from repro.core.tier import KV, TierStore, WriteReq
    from repro.kernels import lz4 as klz4

    pages, tokens, ch = 64, 64, 64
    # best-of over enough reps to shake scheduler noise out of the gated
    # speedup row (one rep is ~60ms for both paths together)
    reps = 9 if smoke else 15
    captured = []
    orig = codec._lz4_slab_streams

    def spy(slab, buf, starts, ends, force=None):
        captured.append((np.array(buf), np.array(starts), np.array(ends)))
        return orig(slab, buf, starts, ends, force=force)

    codec._lz4_slab_streams = spy
    try:
        dev = TierStore(layout="bitplane-kv", kv_window=tokens,
                        batched_encode=True)
        data = [synth.kv_cache(tokens, ch, seed=100 + i)
                for i in range(pages)]
        dev.submit([WriteReq(f"p{i}", d, kind=KV)
                    for i, d in enumerate(data)])
    finally:
        codec._lz4_slab_streams = orig
    nstreams = sum(s.size for _, s, _ in captured)
    nbytes = sum(int((e - s).sum()) for _, s, e in captured)

    def run_kernel():
        # the production kernel path end to end: gap compaction + match
        # kernel + ragged emit (klz4 imported above pins availability)
        assert klz4 is not None
        return [codec._lz4_slab_streams(buf, buf, s, e)
                for buf, s, e in captured]

    def run_scalar():
        # exactly the PR 3 fallback in codec._lz4_slab_streams: the slab
        # addresses streams with gaps (bypassed ones), so the fused
        # encoder gets the materialized gapless concatenation it expects
        out = []
        for buf, s, e in captured:
            chunks = [buf[a:b].tobytes() for a, b in zip(s, e)]
            out.append(codec._lz4_compress_slab(
                np.frombuffer(b"".join(chunks), dtype=np.uint8), chunks))
        return out

    identical = run_kernel() == run_scalar()     # also warms both paths
    emit("write", "lz4_kernel_byte_identical", int(identical), "bool",
         "kernel path vs scalar oracle, whole flush")
    if not identical:
        raise SystemExit("lz4 kernel/oracle byte divergence")
    _, t_k = timed(run_kernel, reps=reps)
    _, t_s = timed(run_scalar, reps=reps)
    emit("write", "lz4_scalar_streams_per_s", nstreams / t_s, "streams/s",
         "PR 3 fused slab encoder (TRACE_SCALAR_LZ4 oracle)")
    emit("write", "lz4_kernel_streams_per_s", nstreams / t_k, "streams/s",
         "vectorized match kernel + ragged emit")
    emit("write", "lz4_kernel_mb_per_s", nbytes / t_k / 1e6, "MB/s")
    emit("write", "lz4_kernel_speedup", t_s / t_k, "x",
         "gated >= 2x by tools/bench_diff.py")


def run(smoke: bool = False):
    write_path(smoke=smoke)
    lz4_encode_path(smoke=smoke)
    if smoke:
        return
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    M, K, N = 128, 1024, 512
    x = jax.random.normal(kx, (M, K), jnp.bfloat16)
    w = jax.random.normal(kw, (K, N), jnp.bfloat16)
    planes = kref.pack_weights_kmajor(w)
    dense = np.asarray(jnp.dot(x, w, preferred_element_type=jnp.float32))

    for r_m, d_m in ((7, 0), (4, 1), (2, 1), (0, 1)):
        nplanes = 1 + 8 + min(r_m + d_m, 7)
        frac = nplanes / 16
        out = np.asarray(elastic_matmul(x, planes, r_m=r_m, d_m=d_m))
        rel = np.abs(out - dense).mean() / (np.abs(dense).mean() + 1e-12)
        emit("kernels", f"elastic_matmul_rm{r_m}_weight_bytes_frac", frac,
             "of bf16", "HBM→VMEM bytes ∝ planes fetched")
        emit("kernels", f"elastic_matmul_rm{r_m}_rel_err", float(rel), "")

    # oracle agreement timing (jnp fallback path)
    _, t_ref = timed(
        lambda: jax.block_until_ready(
            kref.elastic_matmul_ref(x, planes, 4, 1)), reps=3
    )
    emit("kernels", "elastic_matmul_ref_jnp_ms", t_ref * 1e3, "ms",
         "host fallback path (CPU)")

    # fp8-KV decode attention: cache bytes halve, oracle agreement holds
    from repro.kernels import decode_attention
    from repro.kernels.ref import decode_attention_ref

    kq, kk, kv2 = jax.random.split(jax.random.PRNGKey(7), 3)
    qd = jax.random.normal(kq, (1, 8, 128), jnp.bfloat16)
    k16 = jax.random.normal(kk, (1, 1024, 2, 128), jnp.bfloat16)
    v16 = jax.random.normal(kv2, (1, 1024, 2, 128), jnp.bfloat16)
    k8, v8 = k16.astype(jnp.float8_e4m3fn), v16.astype(jnp.float8_e4m3fn)
    out8 = np.asarray(decode_attention(qd, k8, v8, valid_len=900))
    ref16 = np.asarray(decode_attention_ref(qd, k16, v16, 900))
    emit("kernels", "decode_attn_fp8_cache_bytes_frac",
         (k8.nbytes + v8.nbytes) / (k16.nbytes + v16.nbytes), "of bf16",
         "HBM traffic = stored precision")
    emit("kernels", "decode_attn_fp8_vs_bf16_rel_err",
         float(np.abs(out8 - ref16).mean() / (np.abs(ref16).mean() + 1e-9)),
         "", "quality cost of fp8 KV storage")

    # unpack view correctness proxy: planes zeroed == bytes not moved
    xu = jax.random.randint(key, (64, 1024), 0, 1 << 16, jnp.uint32).astype(jnp.uint16)
    from repro.kernels import bitplane_pack

    st = bitplane_pack(xu)
    full = np.asarray(elastic_unpack(st))
    np.testing.assert_array_equal(full, np.asarray(xu))
    emit("kernels", "bitplane_roundtrip_bitexact", 1, "bool")


if __name__ == "__main__":
    import sys

    run(smoke="--smoke" in sys.argv)
    from .common import dump_json

    dump_json("kernels_bench")         # no-op unless BENCH_JSON_DIR is set
