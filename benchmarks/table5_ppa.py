"""Table V / Figs. 22-23 — controller PPA and load-to-use latency.

Area/power constants are the paper's ASAP7 synthesis data (labelled as
such in core/controller.py); the cycle model is exercised here and checked
against every published operating point.
"""

from __future__ import annotations

from repro.core.controller import (
    PPA_TABLE,
    load_to_use_cycles,
    staging_sram_bytes,
)

from .common import emit


def run():
    # Table V anchors
    emit("table5", "plain_cycles", load_to_use_cycles("plain"), "cyc", "paper 71")
    emit("table5", "gcomp_cycles", load_to_use_cycles("gcomp"), "cyc", "paper 84")
    emit("table5", "trace_cycles", load_to_use_cycles("trace"), "cyc", "paper 89")
    t, g = PPA_TABLE["trace"], PPA_TABLE["gcomp"]
    emit("table5", "trace_area_overhead", (t.area_mm2 / g.area_mm2 - 1) * 100,
         "%", "paper 7.2%")
    emit("table5", "trace_power_overhead", (t.power_w / g.power_w - 1) * 100,
         "%", "paper 4.7%")
    emit("table5", "trace_latency_overhead",
         (load_to_use_cycles("trace") / load_to_use_cycles("gcomp") - 1) * 100,
         "%", "paper 6.0%")

    # Fig. 23: latency vs compression ratio + bypass
    emit("fig23", "trace_cycles_at_1.5x",
         load_to_use_cycles("trace", comp_ratio=1.5), "cyc", "paper 89")
    emit("fig23", "trace_cycles_at_3.0x",
         load_to_use_cycles("trace", comp_ratio=3.0), "cyc", "paper 85")
    emit("fig23", "trace_cycles_bypass",
         load_to_use_cycles("trace", bypass=True), "cyc", "paper 76")
    emit("fig23", "trace_cycles_meta_miss",
         load_to_use_cycles("trace", meta_hit=False), "cyc",
         "+1 DRAM window (paper §IV-E)")

    # Eq. 4 staging buffer sizing
    emit("table5", "kv_staging_sram_64tok_1024ch",
         staging_sram_bytes(64, 1024), "B", "Eq. 4")


if __name__ == "__main__":
    run()
