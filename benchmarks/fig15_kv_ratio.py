"""Fig. 15 — per-layer KV lossless compression: TRACE (channel grouping +
exponent delta + bit-planes) vs CXL-GComp (direct word-major), LZ4 & ZSTD.

Paper anchors (LLaMA-3.1-8B): GComp ZSTD overall 1.21 (WikiText) / 1.33
(BookSum); TRACE ZSTD 1.81 / 1.88 (44.8% / 46.9% reduction); best layers
2.69x (ZSTD) / 2.31x (LZ4).
"""

from __future__ import annotations

import numpy as np

from .common import device_ratio, emit, kv_corpus, model_kv


def run():
    layers = kv_corpus(n_layers=32, tokens=1024, channels=512)

    for codec in ("lz4", "zstd"):
        for kind in ("gcomp", "trace"):
            ratios = [
                device_ratio(kind, codec, kv, kv=True) for kv in layers
            ]
            overall = (
                sum(kv.size * 2 for kv in layers)
                / sum(kv.size * 2 / r for kv, r in zip(layers, ratios))
            )
            emit("fig15", f"kv_{kind}_{codec}_overall_ratio", overall, "x",
                 "paper trace-zstd 1.81-1.88, gcomp-zstd 1.21-1.33")
            emit("fig15", f"kv_{kind}_{codec}_best_layer", max(ratios), "x",
                 "paper trace peaks 2.31 (lz4) / 2.69 (zstd)")
            emit("fig15", f"kv_{kind}_{codec}_worst_layer", min(ratios), "x")

    # per-layer uplift vs GComp at the same codec (paper: +41.7-50.3%)
    for codec in ("lz4", "zstd"):
        g = [device_ratio("gcomp", codec, kv, kv=True) for kv in layers]
        t = [device_ratio("trace", codec, kv, kv=True) for kv in layers]
        uplift = (np.mean(t) / np.mean(g) - 1) * 100
        emit("fig15", f"kv_trace_vs_gcomp_{codec}_uplift", uplift, "%",
             "paper +41.7% (booksum) / +50.3% (wikitext) zstd")

    # forward-pass KV corpus cross-check
    real = model_kv(tokens=256)
    g = [device_ratio("gcomp", "zstd", kv, kv=True) for kv in real]
    t = [device_ratio("trace", "zstd", kv, kv=True) for kv in real]
    emit("fig15", "kv_modelfwd_gcomp_zstd", float(np.mean(g)), "x")
    emit("fig15", "kv_modelfwd_trace_zstd", float(np.mean(t)), "x",
         "trace must beat gcomp on real KV too")


if __name__ == "__main__":
    run()
