"""Fig. 15 — per-layer KV lossless compression: TRACE (channel grouping +
exponent delta + bit-planes) vs CXL-GComp (direct word-major), LZ4 & ZSTD.

Paper anchors (LLaMA-3.1-8B): GComp ZSTD overall 1.21 (WikiText) / 1.33
(BookSum); TRACE ZSTD 1.81 / 1.88 (44.8% / 46.9% reduction); best layers
2.69x (ZSTD) / 2.31x (LZ4).
"""

from __future__ import annotations

import numpy as np

from repro.core import synth
from repro.core.tier import KV, ReadReq, WriteReq, make_device

from .common import device_ratio, emit, kv_corpus, model_kv, timed


def _batch_read_timing():
    """Batched submit vs sequential read_kv over a 64-page KV stream set —
    the TierStore batch path must amortize plane unpack/reconstruction."""
    dev = make_device("trace", kv_window=64)
    pages = {f"p{i}": synth.kv_cache(64, 128, seed=200 + i)
             for i in range(64)}
    dev.submit([WriteReq(k, v, kind=KV) for k, v in pages.items()])
    reqs = [ReadReq(k, kind=KV) for k in pages]

    def batched():
        return [r.data for r in dev.submit(reqs)]

    def sequential():
        return [dev.read_kv(k) for k in pages]

    for b, s in zip(batched(), sequential()):   # warm + verify identical
        np.testing.assert_array_equal(b, s)

    t_b = timed(batched)[1]
    t_s = timed(sequential)[1]
    emit("fig15", "kv_batch_read_ms", t_b * 1e3, "ms",
         "one submit, 64 KV pages (64 tok x 128 ch)")
    emit("fig15", "kv_sequential_read_ms", t_s * 1e3, "ms",
         "64 read_kv calls, same pages")
    emit("fig15", "kv_batch_read_speedup", t_s / t_b, "x",
         "batched submit vs sequential (byte-identical)")


def run():
    layers = kv_corpus(n_layers=32, tokens=1024, channels=512)

    for codec in ("lz4", "zstd"):
        for kind in ("gcomp", "trace"):
            ratios = [
                device_ratio(kind, codec, kv, kv=True) for kv in layers
            ]
            overall = (
                sum(kv.size * 2 for kv in layers)
                / sum(kv.size * 2 / r for kv, r in zip(layers, ratios))
            )
            emit("fig15", f"kv_{kind}_{codec}_overall_ratio", overall, "x",
                 "paper trace-zstd 1.81-1.88, gcomp-zstd 1.21-1.33")
            emit("fig15", f"kv_{kind}_{codec}_best_layer", max(ratios), "x",
                 "paper trace peaks 2.31 (lz4) / 2.69 (zstd)")
            emit("fig15", f"kv_{kind}_{codec}_worst_layer", min(ratios), "x")

    # per-layer uplift vs GComp at the same codec (paper: +41.7-50.3%)
    for codec in ("lz4", "zstd"):
        g = [device_ratio("gcomp", codec, kv, kv=True) for kv in layers]
        t = [device_ratio("trace", codec, kv, kv=True) for kv in layers]
        uplift = (np.mean(t) / np.mean(g) - 1) * 100
        emit("fig15", f"kv_trace_vs_gcomp_{codec}_uplift", uplift, "%",
             "paper +41.7% (booksum) / +50.3% (wikitext) zstd")

    # forward-pass KV corpus cross-check
    real = model_kv(tokens=256)
    g = [device_ratio("gcomp", "zstd", kv, kv=True) for kv in real]
    t = [device_ratio("trace", "zstd", kv, kv=True) for kv in real]
    emit("fig15", "kv_modelfwd_gcomp_zstd", float(np.mean(g)), "x")
    emit("fig15", "kv_modelfwd_trace_zstd", float(np.mean(t)), "x",
         "trace must beat gcomp on real KV too")

    _batch_read_timing()


if __name__ == "__main__":
    run()
