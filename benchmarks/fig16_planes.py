"""Fig. 16 — plane-level compressibility (ZSTD, 4 KB blocks).

Paper: high-order exponent planes are consistently the most compressible;
KV exponent planes benefit further from channel grouping + exponent delta.
"""

from __future__ import annotations

import numpy as np

from repro.core import synth
from repro.core.bitplane import pack_planes
from repro.core.codec import compress_block
from repro.core.kv_transform import kv_forward

from .common import emit


def _plane_ratios(u16: np.ndarray) -> list[float]:
    """Per-plane ZSTD ratio over 4 KB blocks of a flat u16 stream."""
    total_raw = np.zeros(16)
    total_comp = np.zeros(16)
    flat = u16.ravel()
    for s in range(0, flat.size - 2047, 2048):
        planes = pack_planes(flat[s : s + 2048])
        for p in range(16):
            raw = planes[p].tobytes()
            comp, _ = compress_block(raw, "zstd")
            total_raw[p] += len(raw)
            total_comp[p] += len(comp)
    return list(total_raw / np.maximum(total_comp, 1))


def run():
    # BF16 weights
    w = synth.weights(1 << 20, "bf16", seed=3)
    r = _plane_ratios(w)
    exp_mean = float(np.mean(r[7:15]))
    man_mean = float(np.mean(r[0:7]))
    emit("fig16", "weights_bf16_exp_planes_mean_ratio", exp_mean, "x",
         "paper: exponent planes dominate")
    emit("fig16", "weights_bf16_man_planes_mean_ratio", man_mean, "x",
         "mantissa ~ noise (ratio ~1)")
    emit("fig16", "weights_bf16_sign_plane_ratio", r[15], "x")
    assert exp_mean > man_mean, "exponent planes must dominate"

    # quantized weights — headroom narrows (paper)
    for fmt in ("fp8", "int4"):
        u = synth.weights(1 << 20, fmt, seed=3)
        r_q = _plane_ratios(u)
        emit("fig16", f"weights_{fmt}_exp_planes_mean_ratio",
             float(np.mean(r_q[7:15])), "x", "narrower than bf16")

    # KV: raw token-major planes vs TRACE-transformed planes
    kv = synth.kv_cache(2048, 512, seed=4)
    r_raw = _plane_ratios(kv)
    stream, _ = kv_forward(kv)
    r_tr = _plane_ratios(stream)
    emit("fig16", "kv_raw_exp_planes_mean_ratio",
         float(np.mean(r_raw[7:15])), "x")
    emit("fig16", "kv_trace_exp_planes_mean_ratio",
         float(np.mean(r_tr[7:15])), "x",
         "delta-transformed exponent planes compress far better")
    assert np.mean(r_tr[7:15]) > np.mean(r_raw[7:15])


if __name__ == "__main__":
    run()
