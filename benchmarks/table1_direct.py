"""Table I — direct lossless compression on the standard word-major layout
is weak, especially LZ4 on token-major KV (the paper's motivating failure).

Paper anchors: LZ4 weights 0-18% (mostly 0), ZSTD weights 17-23%;
LZ4 KV 0.0% everywhere, ZSTD KV 0.9-6.5%.
"""

from __future__ import annotations

import numpy as np

from repro.core import synth

from .common import device_ratio, emit, kv_corpus, model_kv


def run():
    w = synth.weights(2 << 20, "bf16", seed=0)
    kv_layers = kv_corpus(n_layers=8, tokens=512, channels=512)
    kv = np.concatenate([k.ravel() for k in kv_layers])

    for codec in ("lz4", "zstd"):
        r_w = device_ratio("gcomp", codec, w)
        sav_w = (1 - 1 / r_w) * 100
        emit("table1", f"weights_bf16_{codec}_direct_savings", sav_w, "%",
             "paper: lz4 ~0-18%, zstd 17-23%")
        r_kv = device_ratio("gcomp", codec, kv, kv=False)
        sav_kv = (1 - 1 / r_kv) * 100
        emit("table1", f"kv_tokenmajor_{codec}_direct_savings", sav_kv, "%",
             "paper: lz4 0.0%, zstd 0.9-6.5%")

    # cross-check with KV from a real forward pass
    real = np.concatenate([k.ravel() for k in model_kv()])
    for codec in ("lz4", "zstd"):
        r = device_ratio("gcomp", codec, real)
        emit("table1", f"kv_modelfwd_{codec}_direct_savings",
             (1 - 1 / r) * 100, "%", "forward-pass KV corpus")


if __name__ == "__main__":
    run()
