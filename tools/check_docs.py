#!/usr/bin/env python
"""Docs-consistency gate: every `symbol` in the architecture docs must
resolve to something real in the ``repro`` package.

Scans docs/ARCHITECTURE.md and README.md for backtick-quoted tokens that
look like Python identifiers (bare ``submit`` or dotted
``TierStore.delete_prefix``) and verifies each one resolves:

* as a module path under ``repro`` (``repro.core.tier``);
* as a module-level attribute of any ``repro`` module (``ServeScheduler``);
* as an attribute / method / dataclass field of any class defined in
  ``repro`` (``submit``, ``queue_delay_s``);
* via attribute walk for dotted names (``LinkModel.schedule``);
* as a registered string name — layout (``bitplane-kv``), device kind
  (``trace``), codec (``lz4``), request kind (``kv``) or arrival kind
  (``poisson``) — so the docs can quote the vocabulary users actually
  pass in.

Tokens that are clearly not symbols are skipped: anything with spaces,
``/``, CLI ``--flags``, file names with known extensions, pure numbers,
and Python keywords/literals.  Unresolved tokens fail the run (exit 1)
with file:line positions — CI runs this after the test suite, so the
docs cannot silently drift from the code.

Run: PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import importlib
import inspect
import keyword
import pkgutil
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "docs" / "ARCHITECTURE.md", ROOT / "README.md"]

# Modules whose import has side effects unfit for a checker process
# (dryrun forces a 512-device XLA host platform).
SKIP_MODULES = {"repro.launch.dryrun"}

# A backtick token must fully match this to be treated as a symbol.
IDENT = re.compile(
    r"^[A-Za-z_][A-Za-z0-9_-]*(\.[A-Za-z_][A-Za-z0-9_-]*)*$"
)
FILE_EXT = re.compile(r"\.(md|py|yml|yaml|json|toml|txt|sh|cfg)$")
SKIP_WORDS = set(keyword.kwlist) | {"True", "False", "None",
                                    "isinstance", "setattr", "getattr"}


def iter_backtick_tokens(path: Path):
    """Yield (lineno, token) for every single-backtick span, skipping
    fenced code blocks (``` ... ```) — those are illustrative code/ascii
    art, not symbol references."""
    fenced = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if fenced:
            continue
        for m in re.finditer(r"`([^`]+)`", line):
            yield lineno, m.group(1).strip()


def is_candidate(tok: str) -> bool:
    if not IDENT.match(tok):
        return False
    if FILE_EXT.search(tok):
        return False
    if tok in SKIP_WORDS:
        return False
    return True


def build_symbol_tables():
    """Import every repro module; return (modules, bare_names, objects,
    string_names)."""
    import repro

    modules = {"repro": repro}
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        if info.name in SKIP_MODULES:
            continue
        try:
            modules[info.name] = importlib.import_module(info.name)
        # tracecheck: allow-broad-except(imports of optional env-specific modules may fail arbitrarily; warn and keep checking)
        except Exception as e:  # pragma: no cover - env-specific deps
            print(f"[check_docs] warning: cannot import {info.name}: {e}")

    bare: dict[str, list] = {}

    def add(name: str, obj):
        bare.setdefault(name, []).append(obj)

    for mod_name, mod in modules.items():
        add(mod_name.rsplit(".", 1)[-1], mod)
        for name in dir(mod):
            if name.startswith("__"):
                continue
            obj = getattr(mod, name)
            add(name, obj)
            if isinstance(obj, type):
                for attr in dir(obj):
                    if not attr.startswith("__"):
                        add(attr, None)
                for field in getattr(obj, "__dataclass_fields__", {}):
                    add(field, None)
            # constructor / function parameters are part of the documented
            # surface (``page_tokens``, ``batched_encode``)
            target = obj.__init__ if isinstance(obj, type) else obj
            if callable(target):
                try:
                    for p in inspect.signature(target).parameters:
                        add(p, None)
                except (TypeError, ValueError):
                    pass

    # Registered string vocabularies the docs may quote.
    strings: set[str] = set()
    from repro.core import codec as codecs
    from repro.core import tier

    strings.update(tier.LAYOUTS)
    strings.update(tier.DEVICE_KINDS)
    strings.update(codecs.CODECS)
    strings.update((tier.TENSOR, tier.KV))
    strings.update(("poisson", "bursty"))   # synth.request_trace kinds
    strings.update(("logical", "physical"))  # ServeScheduler capacity models
    strings.update(("none", "default"))      # --degrade-ladder specs
    from repro.core import sharding
    strings.update(sharding.PLACEMENTS)      # fleet placement policies
    strings.add("TRACE_SHARDS")              # sharded-fleet env default
    # tracecheck rule ids + the sanitizer's invariant names (structured
    # vocabulary of tools/tracecheck and TierStore(sanitize=True))
    strings.update(("R1", "R2", "R3", "R4", "R5", "R6", "R1-R6",
                    "tracecheck", "tools.tracecheck", "tools/tracecheck",
                    "TRACE_SANITIZE"))
    strings.update(("recency", "attention"))  # ServeEngine importance modes
    # bench rows gated by absolute floors (tools/bench_diff.py FLOORS)
    strings.update(("lz4_kernel_speedup", "lz4_kernel_byte_identical",
                    "encode_batched_speedup", "shard4_tok_s_gain",
                    "pnm_tok_s_gain_512k", "pnm_topk_byte_identical"))
    strings.update(("ledger-stored-equality", "receipt-conservation",
                    "busy-clock-monotonic", "inflight-window-bound",
                    "retire-cleanup", "refcount-conservation"))
    # jax public API the docs reference when describing R6 (not part of
    # repro's surface, but real names all the same)
    strings.update(("pallas_call", "block_until_ready"))
    return modules, bare, strings


def resolve(tok: str, modules, bare, strings) -> bool:
    if tok in strings:
        return True
    if "-" in tok:          # non-string-name tokens never contain dashes
        return False
    if tok in modules or f"repro.{tok}" in modules:
        return True
    parts = tok.split(".")
    if parts[0] == "repro":
        # longest importable module prefix, then attribute walk
        for cut in range(len(parts), 0, -1):
            prefix = ".".join(parts[:cut])
            if prefix in modules:
                return walk(modules[prefix], parts[cut:])
        return False
    if parts[0] not in bare:
        return False
    if len(parts) == 1:
        return True
    return any(obj is not None and walk(obj, parts[1:])
               for obj in bare[parts[0]])


def walk(obj, attrs) -> bool:
    for a in attrs:
        fields = getattr(obj, "__dataclass_fields__", {})
        if a in fields:
            obj = None      # fields are leaves: nothing to walk further
            continue
        if obj is None or not hasattr(obj, a):
            return False
        obj = getattr(obj, a)
    return True


def main() -> int:
    modules, bare, strings = build_symbol_tables()
    failures = []
    checked = 0
    for path in DOC_FILES:
        if not path.exists():
            failures.append((path, 0, "<file missing>"))
            continue
        for lineno, tok in iter_backtick_tokens(path):
            if not is_candidate(tok):
                continue
            checked += 1
            if not resolve(tok, modules, bare, strings):
                failures.append((path, lineno, tok))
    if failures:
        print(f"[check_docs] {len(failures)} unresolved symbol(s) "
              f"(of {checked} checked):")
        for path, lineno, tok in failures:
            print(f"  {path.relative_to(ROOT)}:{lineno}: `{tok}`")
        return 1
    print(f"[check_docs] OK: {checked} symbols resolve against repro")
    return 0


if __name__ == "__main__":
    sys.exit(main())
