"""Boundary rules: private-attribute access (R1), subtype dispatch (R2)
and accounting-field mutation (R3)."""

from __future__ import annotations

import ast
from typing import Iterator, Set

from .core import (
    SANCTIONED_ACCOUNTING_FILE,
    Diagnostic,
    FileContext,
    Rule,
)

# The closed protocol vocabulary R2 protects: consumers must speak the
# TierStore request API, never dispatch on which concrete device or
# layout is behind it.
TIER_SUBTYPES = frozenset({
    "Layout", "WordLayout", "BitplaneLayout",
    "TierStore", "BaseDevice",
    "PlainDevice", "GCompDevice", "TraceDevice",
})


def _is_private(name: str) -> bool:
    return name.startswith("_") and not name.startswith("__")


class R1PrivateAccess(Rule):
    id = "R1"
    name = "private-attribute-access"
    doc = ("no access to _-private attributes of repro.core/repro.runtime "
           "objects from outside their defining module")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        table = ctx.index.private_attrs
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Attribute) or not _is_private(node.attr):
                continue
            if isinstance(node.value, ast.Name) and node.value.id in ("self",
                                                                      "cls"):
                continue
            owners = table.get(node.attr)
            if not owners:
                continue
            if ctx.rel in owners or node.attr in ctx.own_private_attrs:
                continue
            yield self.diag(
                ctx, node,
                f"access to private attribute `{node.attr}` of "
                f"{' / '.join(sorted(owners))} from outside its defining "
                f"module — use the public API",
            )


def _type_names(node: ast.AST) -> Set[str]:
    """Class names referenced by an isinstance() second argument."""
    names: Set[str] = set()
    work = list(node.elts) if isinstance(node, ast.Tuple) else [node]
    for n in work:
        if isinstance(n, ast.Name):
            names.add(n.id)
        elif isinstance(n, ast.Attribute):
            names.add(n.attr)
    return names


class R2IsinstanceDispatch(Rule):
    id = "R2"
    name = "tier-subtype-dispatch"
    doc = ("no isinstance dispatch on Layout/TierStore subtypes outside "
           "core/tier.py — behavior differences belong behind the layout/"
           "device protocol")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        if ctx.rel == SANCTIONED_ACCOUNTING_FILE:
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "isinstance"
                    and len(node.args) == 2):
                continue
            hits = _type_names(node.args[1]) & TIER_SUBTYPES
            if hits:
                yield self.diag(
                    ctx, node,
                    f"isinstance dispatch on tier subtype(s) "
                    f"{', '.join(sorted(hits))} outside core/tier.py — "
                    f"extend the Layout/TierStore protocol instead",
                )


class R3AccountingMutation(Rule):
    id = "R3"
    name = "accounting-field-mutation"
    doc = ("Receipt/DeviceStats accounting fields mutate only through the "
           "sanctioned helpers in core/tier.py (TierStore._apply_receipt / "
           "TierStore._adjust_stored)")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        fields = ctx.index.accounting_fields
        if not fields or ctx.rel == SANCTIONED_ACCOUNTING_FILE:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            else:
                continue
            for t in targets:
                for leaf in ast.walk(t):
                    if (isinstance(leaf, ast.Attribute)
                            and leaf.attr in fields):
                        yield self.diag(
                            ctx, leaf,
                            f"direct mutation of accounting field "
                            f"`{leaf.attr}` — route it through the "
                            f"sanctioned helpers in core/tier.py",
                        )
