"""Core machinery: diagnostics, pragmas, the cross-file project index
and the rule runner.

Pragmas (comment directives, same line or the line directly above the
construct they cover):

* ``# tracecheck: disable=R1[,R3]`` — suppress specific rules
* ``# tracecheck: allow-broad-except(<reason>)`` — R5's escape hatch; a
  non-empty reason is mandatory, it is the reviewable justification
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

REPO_ROOT = Path(__file__).resolve().parents[2]

# Modules owning the private attributes R1 protects, and the one file
# allowed to touch accounting fields / subtype dispatch (R2, R3).
PRIVATE_MODULE_DIRS = ("src/repro/core", "src/repro/runtime")
SANCTIONED_ACCOUNTING_FILE = "src/repro/core/tier.py"

_PRAGMA = re.compile(r"#\s*tracecheck:\s*(.*)$")
_DISABLE = re.compile(r"disable=([A-Z0-9,\s]+)")
_ALLOW_BROAD = re.compile(r"allow-broad-except\(([^)]*)\)")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One ``file:line`` finding from one rule."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class Rule:
    """A pluggable check: walk one file's AST, yield diagnostics.

    Subclasses set ``id`` (the stable ``R<n>`` the CLI toggles and the
    pragmas name) and implement :meth:`check`.
    """

    id = ""
    name = ""
    doc = ""

    def check(self, ctx: "FileContext") -> Iterator[Diagnostic]:
        raise NotImplementedError

    def diag(self, ctx: "FileContext", node: ast.AST, message: str) -> Diagnostic:
        return Diagnostic(self.id, ctx.rel, getattr(node, "lineno", 1),
                          getattr(node, "col_offset", 0) + 1, message)


def _private_attr_defs(tree: ast.AST) -> Set[str]:
    """Private attribute names a module's classes define: ``self._x``
    assignments, class-level ``_x`` bindings, ``__slots__`` entries and
    ``def _method`` members.  Dunders are public protocol, not private."""

    def is_private(name: str) -> bool:
        return name.startswith("_") and not name.startswith("__")

    defs: Set[str] = set()
    for cls in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        for node in ast.walk(cls):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if is_private(node.name):
                    defs.add(node.name)
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for leaf in ast.walk(t):
                        if (isinstance(leaf, ast.Attribute)
                                and isinstance(leaf.value, ast.Name)
                                and leaf.value.id in ("self", "cls")
                                and is_private(leaf.attr)):
                            defs.add(leaf.attr)
                        elif (isinstance(leaf, ast.Name) and leaf is t
                                and is_private(leaf.id)):
                            defs.add(leaf.id)
        for stmt in cls.body:     # __slots__ = ("_a", "_b")
            if (isinstance(stmt, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "__slots__"
                            for t in stmt.targets)):
                for leaf in ast.walk(stmt.value):
                    if (isinstance(leaf, ast.Constant)
                            and isinstance(leaf.value, str)
                            and is_private(leaf.value)):
                        defs.add(leaf.value)
    return defs


def _dataclass_fields(tree: ast.AST, class_names: Sequence[str]) -> Set[str]:
    fields: Set[str] = set()
    for cls in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        if cls.name not in class_names:
            continue
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                              ast.Name):
                fields.add(stmt.target.id)
    return fields


class ProjectIndex:
    """Cross-file facts the rules consult.

    ``private_attrs`` maps each private attribute name to the set of
    repo-relative module paths that define it (R1's ownership table);
    ``accounting_fields`` is the ``Receipt`` / ``DeviceStats`` field
    vocabulary R3 guards, read from ``core/tier.py`` itself so the rule
    cannot drift from the dataclasses.  Tests may construct an empty
    index and populate both directly.
    """

    # Fields shared with unrelated request/descriptor types; mutating a
    # ``.key`` or ``.data`` is not accounting.
    NON_ACCOUNTING_FIELDS = frozenset({"key", "op", "kind", "tag", "data"})

    def __init__(self) -> None:
        self.private_attrs: Dict[str, Set[str]] = {}
        self.accounting_fields: Set[str] = set()

    @classmethod
    def scan(cls, repo_root: Path = REPO_ROOT) -> "ProjectIndex":
        index = cls()
        for rel_dir in PRIVATE_MODULE_DIRS:
            base = repo_root / rel_dir
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.py")):
                try:
                    tree = ast.parse(path.read_text())
                except SyntaxError:
                    continue
                rel = path.relative_to(repo_root).as_posix()
                for attr in _private_attr_defs(tree):
                    index.private_attrs.setdefault(attr, set()).add(rel)
        tier = repo_root / SANCTIONED_ACCOUNTING_FILE
        if tier.is_file():
            tree = ast.parse(tier.read_text())
            index.accounting_fields = (
                _dataclass_fields(tree, ("Receipt", "DeviceStats"))
                - cls.NON_ACCOUNTING_FIELDS
            )
        return index


class FileContext:
    """One parsed file plus its pragma tables, handed to every rule."""

    def __init__(self, path: Path, source: str, index: ProjectIndex,
                 repo_root: Path = REPO_ROOT) -> None:
        self.path = path
        self.source = source
        self.index = index
        try:
            self.rel = path.resolve().relative_to(repo_root).as_posix()
        except ValueError:
            self.rel = path.as_posix()
        self.tree = ast.parse(source)
        # line -> suppressed rule ids; line -> broad-except reason
        self.disabled: Dict[int, Set[str]] = {}
        self.broad_except_ok: Dict[int, str] = {}
        for lineno, line in enumerate(source.splitlines(), 1):
            m = _PRAGMA.search(line)
            if not m:
                continue
            body = m.group(1)
            d = _DISABLE.search(body)
            if d:
                self.disabled[lineno] = {r.strip() for r in
                                         d.group(1).split(",") if r.strip()}
            a = _ALLOW_BROAD.search(body)
            if a:
                self.broad_except_ok[lineno] = a.group(1).strip()
        # Private attrs this file's own classes define: accessing a
        # sibling instance of your own class is not a boundary crossing.
        self.own_private_attrs = _private_attr_defs(self.tree)

    def suppressed(self, rule_id: str, line: int) -> bool:
        for at in (line, line - 1):
            if rule_id in self.disabled.get(at, ()):
                return True
        return False

    def broad_except_reason(self, line: int) -> Optional[str]:
        """The allow-broad-except reason covering ``line`` (same line or
        the line above), or None."""
        for at in (line, line - 1):
            reason = self.broad_except_ok.get(at)
            if reason:
                return reason
        return None


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if any(part.startswith(".") or part == "__pycache__"
                       for part in f.parts):
                    continue
                yield f


def run_paths(paths: Sequence[str], rules: Sequence[Rule],
              index: Optional[ProjectIndex] = None,
              repo_root: Path = REPO_ROOT) -> List[Diagnostic]:
    """Lint every ``.py`` under ``paths`` with ``rules``; returns the
    surviving (unsuppressed) diagnostics sorted by position."""
    if index is None:
        index = ProjectIndex.scan(repo_root)
    out: List[Diagnostic] = []
    for path in iter_python_files(paths):
        source = path.read_text()
        try:
            ctx = FileContext(path, source, index, repo_root)
        except SyntaxError as e:
            out.append(Diagnostic("E0", str(path), e.lineno or 1,
                                  (e.offset or 0) + 1,
                                  f"syntax error: {e.msg}"))
            continue
        for rule in rules:
            for diag in rule.check(ctx):
                if not ctx.suppressed(diag.rule, diag.line):
                    out.append(diag)
    out.sort(key=lambda d: (d.path, d.line, d.col, d.rule))
    return out
