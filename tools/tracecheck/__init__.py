"""tracecheck — architectural lint for the TierStore stack.

Static AST analysis enforcing the repo's structural contracts (the
boundaries that make the accounting invariants provable):

* R1  no cross-module access to ``_``-private attributes of
      ``repro.core`` / ``repro.runtime`` objects
* R2  no ``isinstance`` dispatch on ``Layout`` / ``TierStore`` subtypes
      outside ``core/tier.py``
* R3  ``Receipt`` / ``DeviceStats`` accounting fields mutate only
      through the sanctioned helpers in ``core/tier.py``
* R4  async discipline: every ``submit_async`` result reaches a
      ``wait()`` / ``drain()`` / ``quiesce()`` (or escapes to a caller
      that can) on all paths
* R5  no broad ``except Exception:`` without a
      ``# tracecheck: allow-broad-except(<reason>)`` pragma
* R6  no host-sync or Python RNG inside ``jax.jit`` / ``pallas_call``
      bodies

Run: ``python -m tools.tracecheck src benchmarks examples``
The runtime counterpart of this lint is ``TierStore(sanitize=True)`` /
``TRACE_SANITIZE=1`` (see ``repro.core.tier``).
"""

from .core import Diagnostic, FileContext, ProjectIndex, Rule, run_paths
from .rules_flow import R4AsyncDiscipline, R5BroadExcept, R6JitPurity
from .rules_privacy import (
    R1PrivateAccess,
    R2IsinstanceDispatch,
    R3AccountingMutation,
)

ALL_RULES = (
    R1PrivateAccess,
    R2IsinstanceDispatch,
    R3AccountingMutation,
    R4AsyncDiscipline,
    R5BroadExcept,
    R6JitPurity,
)

__all__ = [
    "ALL_RULES",
    "Diagnostic",
    "FileContext",
    "ProjectIndex",
    "Rule",
    "run_paths",
    "R1PrivateAccess",
    "R2IsinstanceDispatch",
    "R3AccountingMutation",
    "R4AsyncDiscipline",
    "R5BroadExcept",
    "R6JitPurity",
]
