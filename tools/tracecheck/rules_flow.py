"""Flow rules: async discipline (R4), broad excepts (R5) and jit/kernel
purity (R6)."""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterator, List, Optional, Set, Tuple

from .core import Diagnostic, FileContext, Rule

# ---------------------------------------------------------------------------
# R4 — submit_async must reach a wait on all paths
# ---------------------------------------------------------------------------

# Calls that discharge in-flight tickets: direct waits, whole-queue
# drains, and the pool/engine wrappers over them.
WAIT_SINKS = frozenset({
    "wait", "drain", "drain_reads", "quiesce", "flush_io",
    "settle_prefetched",
})

Pending = Dict[ast.Call, FrozenSet[str]]
Exit = Tuple[str, Pending]          # ("fall"|"return"|"break"|"continue", _)


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _target_names(target: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(target)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)}


def _has_wait(region: ast.AST) -> bool:
    return any(isinstance(c, ast.Call) and _call_name(c) in WAIT_SINKS
               for c in ast.walk(region))


def _submits_in(region: ast.AST) -> List[ast.Call]:
    return [n for n in ast.walk(region)
            if isinstance(n, ast.Call) and _call_name(n) == "submit_async"]


class _FuncAnalysis:
    """Path walk of one function for R4.

    ``pending`` maps each live ``submit_async`` call node to the names
    its tickets are bound to.  A statement discharges pending tickets
    when it waits (any :data:`WAIT_SINKS` call) or when they *escape* to
    code that can wait them — returned/yielded, stored into an attribute
    or subscript, or passed as a call argument.  ``raise`` paths are
    teardown, not violations.  Loops are walked as zero-or-one
    iterations (tickets born in a loop header are clean on the
    zero-iteration path: an empty iterable issued no tickets) and
    ``try`` handlers start from the pending set at try entry — a simple,
    documented over-approximation.
    """

    def __init__(self, fn: ast.AST) -> None:
        self.fn = fn

    # -- per-region transfer -------------------------------------------------
    def _discharge(self, region: ast.AST, shape: Optional[ast.stmt],
                   pending: Pending) -> Pending:
        out = dict(pending)
        if _has_wait(region):
            return {}
        if not out:
            return out
        bound_names = set().union(*out.values())
        mentioned = _names_in(region) & bound_names
        if not mentioned:
            return out
        escapes = False
        if isinstance(shape, ast.Return) and shape.value is not None:
            escapes = True
        elif isinstance(shape, ast.Expr) and isinstance(
                shape.value, (ast.Yield, ast.YieldFrom)):
            escapes = True
        elif isinstance(shape, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (shape.targets if isinstance(shape, ast.Assign)
                       else [shape.target])
            if any(isinstance(leaf, (ast.Attribute, ast.Subscript))
                   for t in targets for leaf in ast.walk(t)):
                escapes = True
        if not escapes:
            # passed onward as a call argument (self._account(tickets),
            # lst.append(t)) — the receiver owns the wait now
            for call in (c for c in ast.walk(region)
                         if isinstance(c, ast.Call)):
                arg_names: Set[str] = set()
                for a in call.args:
                    arg_names |= _names_in(a)
                for kw in call.keywords:
                    arg_names |= _names_in(kw.value)
                if arg_names & mentioned:
                    escapes = True
                    break
        if escapes:
            for call in [c for c, b in out.items() if b & mentioned]:
                out.pop(call)
        return out

    def _births(self, region: ast.AST,
                shape: Optional[ast.stmt]) -> Pending:
        """submit_async calls born (and not instantly discharged) here."""
        born: Pending = {}
        calls = _submits_in(region)
        if not calls or _has_wait(region):
            return born
        if isinstance(shape, ast.Return):
            return born                     # tickets returned to the caller
        if isinstance(shape, ast.Expr) and isinstance(
                shape.value, (ast.Yield, ast.YieldFrom)):
            return born
        nested_args: Set[ast.Call] = set()
        for c in ast.walk(region):
            if isinstance(c, ast.Call):
                for a in list(c.args) + [k.value for k in c.keywords]:
                    nested_args.update(
                        n for n in ast.walk(a)
                        if isinstance(n, ast.Call)
                        and _call_name(n) == "submit_async")
        names: FrozenSet[str] = frozenset()
        if isinstance(shape, (ast.Assign, ast.AnnAssign)):
            targets = (shape.targets if isinstance(shape, ast.Assign)
                       else [shape.target])
            if any(isinstance(t, (ast.Attribute, ast.Subscript, ast.Starred))
                   for t in targets):
                return born                 # stored outward: escapes
            got: Set[str] = set()
            for t in targets:
                got |= _target_names(t)
            names = frozenset(got)
        for call in calls:
            if call not in nested_args:
                born[call] = names
        return born

    def _transfer(self, region: ast.AST, shape: Optional[ast.stmt],
                  pending: Pending) -> Pending:
        out = self._discharge(region, shape, pending)
        out.update(self._births(region, shape))
        return out

    # -- block walk ----------------------------------------------------------
    def walk_block(self, stmts: List[ast.stmt],
                   pending: Pending) -> List[Exit]:
        paths: List[Pending] = [pending]
        exits: List[Exit] = []
        for stmt in stmts:
            nxt: List[Pending] = []
            for p in paths:
                for kind, out in self._walk_stmt(stmt, dict(p)):
                    if kind == "fall":
                        nxt.append(out)
                    else:
                        exits.append((kind, out))
            paths = nxt
            if not paths:
                break
        exits.extend(("fall", p) for p in paths)
        return self._dedup(exits)

    @staticmethod
    def _dedup(exits: List[Exit]) -> List[Exit]:
        seen = set()
        out = []
        for kind, p in exits:
            key = (kind, frozenset(p.keys()))
            if key not in seen:
                seen.add(key)
                out.append((kind, p))
        return out

    def _walk_stmt(self, stmt: ast.stmt, pending: Pending) -> List[Exit]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return [("fall", pending)]      # nested defs analyzed separately
        if isinstance(stmt, ast.If):
            head = self._transfer(stmt.test, None, pending)
            out = self.walk_block(stmt.body, dict(head))
            out += self.walk_block(stmt.orelse, dict(head))
            return self._dedup(out)
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            return self._walk_loop(stmt, pending)
        if isinstance(stmt, ast.Try):
            return self._walk_try(stmt, pending)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            head = dict(pending)
            for item in stmt.items:
                head = self._transfer(item.context_expr, None, head)
            return self.walk_block(stmt.body, head)
        if isinstance(stmt, ast.Raise):
            return []                       # teardown path, not a violation
        if isinstance(stmt, ast.Break):
            return [("break", pending)]
        if isinstance(stmt, ast.Continue):
            return [("continue", pending)]
        out = self._transfer(stmt, stmt, pending)
        if isinstance(stmt, ast.Return):
            return [("return", out)]
        return [("fall", out)]

    def _walk_loop(self, stmt, pending: Pending) -> List[Exit]:
        header = (stmt.iter if isinstance(stmt, (ast.For, ast.AsyncFor))
                  else stmt.test)
        head = self._discharge(header, None, pending)
        body_entry = dict(head)
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            # tickets from a header submit bind to the loop target; the
            # zero-iteration path had no tickets, so `head` stays clean
            targets = frozenset(_target_names(stmt.target))
            for call in _submits_in(stmt.iter):
                body_entry[call] = targets
        else:
            body_entry.update(self._births(header, None))
        exits: List[Exit] = []
        for kind, p in self.walk_block(stmt.body, body_entry):
            exits.append(("fall" if kind in ("continue", "break") else kind,
                          p))
        exits += self.walk_block(stmt.orelse, dict(head))
        exits.append(("fall", head))        # zero-iteration path
        return self._dedup(exits)

    def _walk_try(self, stmt: ast.Try, pending: Pending) -> List[Exit]:
        exits: List[Exit] = []
        for kind, p in self.walk_block(stmt.body, dict(pending)):
            if kind == "fall" and stmt.orelse:
                exits.extend(self.walk_block(stmt.orelse, p))
            else:
                exits.append((kind, p))
        for handler in stmt.handlers:
            exits.extend(self.walk_block(handler.body, dict(pending)))
        if stmt.finalbody:
            merged: List[Exit] = []
            for kind, p in exits:
                for fkind, fp in self.walk_block(stmt.finalbody, p):
                    merged.append((kind if fkind == "fall" else fkind, fp))
            exits = merged
        return self._dedup(exits)

    def run(self) -> Set[ast.Call]:
        violations: Set[ast.Call] = set()
        for kind, p in self.walk_block(list(getattr(self.fn, "body", [])),
                                       {}):
            if kind in ("fall", "return"):
                violations.update(p.keys())
        return violations


class R4AsyncDiscipline(Rule):
    id = "R4"
    name = "async-discipline"
    doc = ("every function calling submit_async must reach a wait()/"
           "drain()/quiesce() — or hand the tickets to a caller that "
           "can — on all paths")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(_call_name(n) == "submit_async"
                       for stmt in fn.body for n in ast.walk(stmt)
                       if isinstance(n, ast.Call)):
                continue
            for call in sorted(_FuncAnalysis(fn).run(),
                               key=lambda c: (c.lineno, c.col_offset)):
                yield self.diag(
                    ctx, call,
                    f"`submit_async` tickets in `{fn.name}` may never be "
                    f"waited on some path — reach wait()/drain()/quiesce() "
                    f"or hand them to the caller",
                )


# ---------------------------------------------------------------------------
# R5 — broad excepts need a reasoned pragma
# ---------------------------------------------------------------------------

BROAD_NAMES = frozenset({"Exception", "BaseException"})


def _handler_is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    work = list(t.elts) if isinstance(t, ast.Tuple) else [t]
    for n in work:
        if isinstance(n, ast.Name) and n.id in BROAD_NAMES:
            return True
        if isinstance(n, ast.Attribute) and n.attr in BROAD_NAMES:
            return True
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    """True when the handler unconditionally re-raises (its breadth is
    transparent to callers — cleanup-then-propagate)."""
    body = handler.body
    return bool(body) and isinstance(body[-1], ast.Raise) \
        and body[-1].exc is None


class R5BroadExcept(Rule):
    id = "R5"
    name = "broad-except"
    doc = ("no bare `except Exception:` without a "
           "`# tracecheck: allow-broad-except(<reason>)` pragma; handlers "
           "that end in a bare re-raise are exempt")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _handler_is_broad(node) or _reraises(node):
                continue
            if ctx.broad_except_reason(node.lineno):
                continue
            caught = ("bare except" if node.type is None
                      else "except " + ast.unparse(node.type))
            yield self.diag(
                ctx, node,
                f"broad `{caught}` swallows unrelated failures — narrow it "
                f"or justify with `# tracecheck: allow-broad-except(reason)`",
            )


# ---------------------------------------------------------------------------
# R6 — no host-sync / Python RNG inside jit or pallas kernels
# ---------------------------------------------------------------------------

HOST_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})
HOST_ARRAY_FNS = frozenset({"asarray", "array", "frombuffer",
                            "ascontiguousarray"})
NUMPY_ALIASES = frozenset({"np", "numpy"})


def _is_jit_decorator(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Name) and dec.id == "jit":
        return True
    if isinstance(dec, ast.Attribute) and dec.attr == "jit":
        return True
    if isinstance(dec, ast.Call):
        # functools.partial(jax.jit, ...) / partial(jit, ...)
        f = dec.func
        partial = (isinstance(f, ast.Name) and f.id == "partial") or \
            (isinstance(f, ast.Attribute) and f.attr == "partial")
        if partial and dec.args:
            return _is_jit_decorator(dec.args[0])
        return _is_jit_decorator(f)
    return False


def _traced_functions(tree: ast.AST) -> Dict[str, Tuple[ast.AST, str]]:
    """name -> (FunctionDef, why) for functions that run under tracing:
    jit-decorated, jax.jit-wrapped at module level, or passed to
    pallas_call (directly or through functools.partial).  Cross-module
    jit wrapping (``jax.jit(imported_fn)``) is out of scope — the body
    is not in this file."""
    fns = {n.name: n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    out: Dict[str, Tuple[ast.AST, str]] = {}
    for name, fn in fns.items():
        if any(_is_jit_decorator(d) for d in fn.decorator_list):
            out[name] = (fn, "jax.jit")
    partial_of: Dict[str, str] = {}     # alias = functools.partial(fn, ...)
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            f = call.func
            is_partial = (isinstance(f, ast.Name) and f.id == "partial") or \
                (isinstance(f, ast.Attribute) and f.attr == "partial")
            tgt = node.targets[0]
            if is_partial and call.args and isinstance(call.args[0], ast.Name) \
                    and isinstance(tgt, ast.Name):
                partial_of[tgt.id] = call.args[0].id
            if isinstance(f, ast.Attribute) and f.attr == "jit" and call.args \
                    and isinstance(call.args[0], ast.Name) \
                    and call.args[0].id in fns:
                out[call.args[0].id] = (fns[call.args[0].id], "jax.jit")
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_name(node) == "pallas_call" \
                and node.args:
            first = node.args[0]
            cand: Optional[str] = None
            if isinstance(first, ast.Name):
                cand = partial_of.get(first.id, first.id)
            elif isinstance(first, ast.Call):
                cf = first.func
                is_partial = (isinstance(cf, ast.Name) and cf.id == "partial") \
                    or (isinstance(cf, ast.Attribute) and cf.attr == "partial")
                if is_partial and first.args \
                        and isinstance(first.args[0], ast.Name):
                    cand = first.args[0].id
            if cand in fns:
                out[cand] = (fns[cand], "pallas_call")
    return out


def _dotted(node: ast.AST) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return list(reversed(parts))


class R6JitPurity(Rule):
    id = "R6"
    name = "jit-purity"
    doc = ("no host synchronization (np.asarray, .item(), device_get, "
           "block_until_ready) or Python-side RNG inside jax.jit / "
           "pallas_call bodies")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        for name, (fn, why) in sorted(_traced_functions(ctx.tree).items()):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                bad = self._why_banned(node)
                if bad:
                    yield self.diag(
                        ctx, node,
                        f"{bad} inside {why} body `{name}` — traced code "
                        f"must stay device-pure",
                    )

    @staticmethod
    def _why_banned(call: ast.Call) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr in HOST_SYNC_METHODS:
            return f"host-sync call `.{f.attr}()`"
        parts = _dotted(f)
        if len(parts) >= 2:
            head, rest = parts[0], parts[1:]
            if head in NUMPY_ALIASES and rest[0] == "random":
                return f"host RNG `{'.'.join(parts)}`"
            if head == "random":
                return f"host RNG `{'.'.join(parts)}`"
            if head in NUMPY_ALIASES and rest[-1] in HOST_ARRAY_FNS:
                return f"host materialization `{'.'.join(parts)}`"
            if head == "jax" and rest[-1] == "device_get":
                return "host-sync call `jax.device_get`"
        return None
