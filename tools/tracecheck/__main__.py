"""CLI: ``python -m tools.tracecheck [paths...] [--disable ...]``.

Exit 0 when every enabled rule is clean (after pragmas), 1 otherwise.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Sequence

from . import ALL_RULES
from .core import run_paths

DEFAULT_PATHS = ("src", "benchmarks", "examples", "tools")


def _rule_ids(spec: str) -> List[str]:
    return [r.strip().upper() for r in spec.split(",") if r.strip()]


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="tracecheck",
        description="architectural lint for the TierStore stack "
                    "(R1-R6; see tools/tracecheck/__init__.py)",
    )
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--select", default="",
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--disable", default="",
                    help="comma-separated rule ids to skip")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    rules = [cls() for cls in ALL_RULES]
    if args.list_rules:
        for rule in rules:
            print(f"{rule.id}  {rule.name}: {rule.doc}")
        return 0
    selected = set(_rule_ids(args.select))
    disabled = set(_rule_ids(args.disable))
    unknown = (selected | disabled) - {r.id for r in rules}
    if unknown:
        ap.error(f"unknown rule id(s): {', '.join(sorted(unknown))}")
    if selected:
        rules = [r for r in rules if r.id in selected]
    rules = [r for r in rules if r.id not in disabled]

    diags = run_paths(args.paths, rules)
    for d in diags:
        print(d.format())
    names = ",".join(r.id for r in rules)
    if diags:
        print(f"[tracecheck] {len(diags)} diagnostic(s) ({names})")
        return 1
    print(f"[tracecheck] OK ({names})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
