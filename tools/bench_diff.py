"""Benchmark regression gate: fresh BENCH_*.json vs committed baselines.

CI used to hard-code perf thresholds inline in the benchmark modules
(e.g. kernels_bench's old ``batched >= scalar`` SystemExit) — binary
checks that miss slow drift and rot as workloads change.  This tool
replaces them with a committed-baseline comparison:

* ``benchmarks/baselines/BENCH_<module>.json`` holds the accepted rows
  (seeded/refreshed with ``--update`` from a trusted run).
* A fresh run's rows are compared per name.  Two regimes, chosen by the
  row's unit:

  - **timing rows** (ms, s, tok/s, MB/s, blocks/s, streams/s, ms/tok,
    x): wall-clock on shared CI hosts is noisy, so these fail only
    past a wide regression band (default 3x worse than baseline).
    Improvements never fail — the tool prints a stale-baseline notice
    instead.
  - **structural rows** (bytes, ratios, counts, bools, error
    fractions): deterministic given the workload seeds, so these get a
    tight relative band (default 2%).

* **Floor rules** gate specific rows absolutely, independent of the
  baseline — the PR-acceptance thresholds that must hold on any host.
  ``lz4_kernel_speedup >= 2.0`` is the codec-kernel gate: the in-process
  kernel/oracle *ratio* is stable even when absolute times swing, which
  is what makes it gateable where raw ms rows are not.

Rows present only in the baseline (vanished) or only in the fresh run
(unbaselined) fail too — a renamed metric must touch the baseline file
in the same PR.

Usage:
  PYTHONPATH=src python -m tools.bench_diff --fresh bench-artifacts
  PYTHONPATH=src python -m tools.bench_diff --fresh bench-artifacts --update
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_BASELINE_DIR = os.path.join("benchmarks", "baselines")

# Units whose rows are host-wall-clock (or derived from it): wide band.
TIMING_UNITS = {"ms", "s", "tok/s", "MB/s", "blocks/s", "streams/s",
                "ms/tok", "x", "GB/s"}

# Absolute floors (row name → minimum value): PR acceptance thresholds
# that hold regardless of the committed baseline.
FLOORS: Dict[str, float] = {
    # kernel LZ4 encode must stay >= 2x over the PR 3 slab encoder,
    # measured as an in-process ratio (stable under host noise)
    "lz4_kernel_speedup": 2.0,
    # byte identity between kernel path and scalar oracle is a hard
    # invariant, not a perf number
    "lz4_kernel_byte_identical": 1.0,
    # the vectorized slab encoder must never regress to scalar
    "encode_batched_speedup": 1.0,
    # fleet scaling gate: 4 sharded devices must deliver >= 1.5x the
    # aggregate tok/s of one (modeled, deterministic — not host noise)
    "shard4_tok_s_gain": 1.5,
    # PNM read mode: at 512k context the device-side top-k gather must
    # hold >= 3x the link-bound full-readback throughput (modeled from
    # measured per-page tier costs — deterministic)
    "pnm_tok_s_gain_512k": 3.0,
    # a gather whose k covers every candidate must ship exactly the
    # classic readback bytes — an invariant, not a perf number
    "pnm_topk_byte_identical": 1.0,
}

# Rows that exist to be tracked, never gated (their value is the
# trajectory across PRs, not a pass/fail band) — matched by suffix.
TRACK_ONLY_SUFFIXES = ("_wall_ms",)


def _rows(path: str) -> Dict[str, Tuple[float, str]]:
    with open(path) as f:
        payload = json.load(f)
    out = {}
    for row in payload.get("rows", []):
        val = row.get("value")
        if isinstance(val, bool):
            val = float(val)
        if isinstance(val, (int, float)):
            out[row["name"]] = (float(val), row.get("unit", ""))
    return out


def _check_module(name: str, fresh: Dict[str, Tuple[float, str]],
                  base: Dict[str, Tuple[float, str]],
                  timing_factor: float, tight_rel: float
                  ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notices) for one module's row set."""
    fails: List[str] = []
    notes: List[str] = []
    for row, floor in FLOORS.items():
        if row in fresh and fresh[row][0] < floor:
            fails.append(
                f"{name}: {row} = {fresh[row][0]:.4g} below the absolute "
                f"floor {floor:g}")
    for row in sorted(set(base) - set(fresh)):
        fails.append(f"{name}: baseline row {row} missing from fresh run")
    for row in sorted(set(fresh) - set(base)):
        fails.append(f"{name}: fresh row {row} has no baseline "
                     f"(seed it with --update)")
    for row in sorted(set(fresh) & set(base)):
        fv, unit = fresh[row]
        bv, _ = base[row]
        if row.endswith(TRACK_ONLY_SUFFIXES):
            continue
        if unit in TIMING_UNITS:
            # direction: bigger is better for rates/speedups, smaller
            # for times — infer from the unit
            worse = (fv > bv * timing_factor
                     if unit in ("ms", "s", "ms/tok")
                     else fv * timing_factor < bv)
            better = (fv * timing_factor < bv
                      if unit in ("ms", "s", "ms/tok")
                      else fv > bv * timing_factor)
            if worse:
                fails.append(
                    f"{name}: {row} = {fv:.4g} {unit} regressed past "
                    f"{timing_factor:g}x of baseline {bv:.4g}")
            elif better:
                notes.append(
                    f"{name}: {row} = {fv:.4g} {unit} beats baseline "
                    f"{bv:.4g} by >{timing_factor:g}x — refresh with "
                    f"--update")
        else:
            denom = max(abs(bv), 1e-12)
            if abs(fv - bv) / denom > tight_rel:
                fails.append(
                    f"{name}: {row} = {fv:.6g} vs baseline {bv:.6g} "
                    f"(structural row, band ±{tight_rel:.0%})")
    return fails, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_diff",
        description="gate fresh BENCH_*.json artifacts against committed "
                    "baselines (see module docstring)")
    ap.add_argument("--fresh", required=True,
                    help="directory holding the fresh BENCH_*.json files "
                         "(a benchmark run's BENCH_JSON_DIR)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE_DIR,
                    help=f"baseline directory (default {DEFAULT_BASELINE_DIR})")
    ap.add_argument("--update", action="store_true",
                    help="write/refresh baselines from the fresh run "
                         "instead of gating (floors still checked)")
    ap.add_argument("--timing-factor", type=float, default=3.0,
                    help="allowed wall-clock regression factor for "
                         "timing-unit rows (default 3.0)")
    ap.add_argument("--tight-rel", type=float, default=0.02,
                    help="relative band for deterministic structural "
                         "rows (default 0.02)")
    args = ap.parse_args(argv)

    fresh_paths = sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json")))
    if not fresh_paths:
        print(f"[bench_diff] no BENCH_*.json under {args.fresh}")
        return 1
    failures: List[str] = []
    notices: List[str] = []
    for path in fresh_paths:
        fname = os.path.basename(path)
        module = fname[len("BENCH_"):-len(".json")]
        fresh = _rows(path)
        bpath = os.path.join(args.baseline, fname)
        if args.update:
            # floors still apply: a bad run must not become the baseline
            fails, _ = _check_module(module, fresh, fresh,
                                     args.timing_factor, args.tight_rel)
            if fails:
                failures.extend(fails)
                continue
            os.makedirs(args.baseline, exist_ok=True)
            with open(path) as src, open(bpath, "w") as dst:
                dst.write(src.read())
            print(f"[bench_diff] baseline updated: {bpath}")
            continue
        if not os.path.exists(bpath):
            failures.append(
                f"{module}: no baseline {bpath} (seed with --update)")
            continue
        fails, notes = _check_module(module, fresh, _rows(bpath),
                                     args.timing_factor, args.tight_rel)
        failures.extend(fails)
        notices.extend(notes)
    for n in notices:
        print(f"[bench_diff] note: {n}")
    if failures:
        for f in failures:
            print(f"[bench_diff] FAIL: {f}", file=sys.stderr)
        print(f"[bench_diff] {len(failures)} failure(s)", file=sys.stderr)
        return 1
    print(f"[bench_diff] OK: {len(fresh_paths)} module(s) within bands")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
