"""Test path setup: make ``repro`` (src layout) and ``benchmarks``
importable regardless of how pytest is invoked.  Deliberately does NOT
set XLA_FLAGS — tests must see the real single-device CPU environment
(only launch/dryrun.py forces 512 host devices, and it is never imported
from tests)."""

import os
import sys

ROOT = os.path.dirname(__file__)
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
