"""Serving driver: batched generation with TRACE-tiered KV offload.

Runs a (reduced or full) model with the ServeEngine, reporting tier traffic,
KV compression ratio, and the implied tok/s ceiling for each device kind —
the end-to-end integration of the paper's two mechanisms.

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --tokens 64 --device trace
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCHS, smoke_config
from ..models.model import init_params
from ..runtime import PAPER_POLICY, ServeEngine
from ..runtime.paging import LOSSLESS_POLICY


def serve(
    arch: str = "qwen2-0.5b",
    smoke: bool = True,
    device: str = "trace",
    prompt_len: int = 64,
    n_tokens: int = 32,
    batch: int = 2,
    hbm_kv_budget: int = 1 << 12,   # tiny on purpose → force KV spill to tier
    page_tokens: int = 16,
    lossless_only: bool = False,
    seed: int = 0,
):
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(
        cfg, params,
        max_seq=prompt_len + n_tokens + page_tokens,
        batch=batch,
        page_tokens=page_tokens,
        hbm_kv_budget=hbm_kv_budget,
        device_kind=device,
        policy=LOSSLESS_POLICY if lossless_only else PAPER_POLICY,
    )
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    toks = eng.generate(prompt, n_tokens)
    s = eng.stats()
    print(f"[serve] arch={arch} device={device} generated {toks.shape} tokens")
    print(f"[serve] spilled pages: {s.spilled_pages}, "
          f"tier stored {s.tier_dram_stored} B for {s.kv_logical_bytes} B logical "
          f"(ratio {s.kv_compression_ratio:.2f}x)")
    print(f"[serve] tier DRAM read {s.tier_dram_read} B, link out {s.tier_link_out} B")
    print(f"[serve] tok/s ceiling (tier-bound): {eng.throughput_ceiling():.1f}")
    return eng, toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--device", default="trace",
                    choices=["plain", "gcomp", "trace"])
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--lossless-only", action="store_true")
    args = ap.parse_args()
    serve(arch=args.arch, device=args.device, n_tokens=args.tokens,
          prompt_len=args.prompt_len, batch=args.batch,
          lossless_only=args.lossless_only)


if __name__ == "__main__":
    main()
