"""Serving driver: batched generation with TRACE-tiered KV offload.

Runs a (reduced or full) model with the ServeEngine, reporting tier traffic,
KV compression ratio, and the implied tok/s ceiling for each device kind —
the end-to-end integration of the paper's two mechanisms.  Spill readback
goes through the tier's queued async front-end by default (``--sync-io``
reverts to serialized submits); ``--streams N`` serves N sequences that
share one device queue.

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --tokens 64 --device trace --streams 2
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCHS, smoke_config
from ..models.model import init_params
from ..runtime import MultiStreamEngine, PAPER_POLICY, ServeEngine
from ..runtime.paging import LOSSLESS_POLICY


def serve(
    arch: str = "qwen2-0.5b",
    smoke: bool = True,
    device: str = "trace",
    prompt_len: int = 64,
    n_tokens: int = 32,
    batch: int = 2,
    hbm_kv_budget: int = 1 << 12,   # tiny on purpose → force KV spill to tier
    page_tokens: int = 16,
    lossless_only: bool = False,
    streams: int = 1,
    async_io: bool = True,
    seed: int = 0,
):
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    policy = LOSSLESS_POLICY if lossless_only else PAPER_POLICY
    kw = dict(
        max_seq=prompt_len + n_tokens + page_tokens,
        batch=batch,
        page_tokens=page_tokens,
        hbm_kv_budget=hbm_kv_budget,
        policy=policy,
        async_io=async_io,
    )
    rng = np.random.default_rng(seed)
    if streams > 1:
        eng = MultiStreamEngine(cfg, params, streams, device_kind=device, **kw)
        prompts = [
            rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
            for _ in range(streams)
        ]
        toks = eng.generate(prompts, n_tokens)
        per = eng.stats()
        d = eng.device_stats()
        print(f"[serve] arch={arch} device={device} streams={streams} "
              f"async_io={async_io} generated {[t.shape for t in toks]}")
        print(f"[serve] shared tier: stored {d.dram_bytes_stored} B, "
              f"DRAM read {d.dram_bytes_read} B, link out {d.link_bytes_out} B")
        io_srv = sum(s.tier_io_service_s for s in per)
        io_qd = sum(s.tier_io_queue_delay_s for s in per)
        print(f"[serve] tier I/O: serialized {io_srv * 1e3:.3f} ms, "
              f"queue delay {io_qd * 1e3:.3f} ms")
        print(f"[serve] aggregate tok/s ceiling: {eng.throughput_ceiling():.1f}")
        return eng, toks
    eng = ServeEngine(cfg, params, device_kind=device, **kw)
    prompt = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    toks = eng.generate(prompt, n_tokens)
    s = eng.stats()
    print(f"[serve] arch={arch} device={device} async_io={async_io} "
          f"generated {toks.shape} tokens")
    print(f"[serve] spilled pages: {s.spilled_pages}, "
          f"tier stored {s.tier_dram_stored} B for {s.kv_logical_bytes} B logical "
          f"(ratio {s.kv_compression_ratio:.2f}x)")
    print(f"[serve] tier DRAM read {s.tier_dram_read} B, link out {s.tier_link_out} B")
    print(f"[serve] tier I/O: serialized {s.tier_io_service_s * 1e3:.3f} ms, "
          f"queue delay {s.tier_io_queue_delay_s * 1e3:.3f} ms")
    print(f"[serve] tok/s ceiling (tier-bound): {eng.throughput_ceiling():.1f}")
    return eng, toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--device", default="trace",
                    choices=["plain", "gcomp", "trace"])
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--streams", type=int, default=1,
                    help="sequences sharing one tier device queue")
    ap.add_argument("--sync-io", action="store_true",
                    help="serialize spill readback (disable the async queue)")
    ap.add_argument("--lossless-only", action="store_true")
    args = ap.parse_args()
    serve(arch=args.arch, device=args.device, n_tokens=args.tokens,
          prompt_len=args.prompt_len, batch=args.batch,
          streams=args.streams, async_io=not args.sync_io,
          lossless_only=args.lossless_only)


if __name__ == "__main__":
    main()
