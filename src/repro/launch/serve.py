"""Serving driver: batched generation with TRACE-tiered KV offload.

Runs a (reduced or full) model with the ServeEngine, reporting tier traffic,
KV compression ratio, and the implied tok/s ceiling for each device kind —
the end-to-end integration of the paper's two mechanisms.  Spill readback
goes through the tier's queued async front-end by default (``--sync-io``
reverts to serialized submits); ``--streams N`` serves N sequences that
share one device queue; ``--num-requests N`` switches to the
continuous-batching scheduler (Poisson/bursty arrivals, capacity-aware
admission, retirement frees tier pages).

Usage (CPU demo):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --tokens 64 --device trace --streams 2
  PYTHONPATH=src python -m repro.launch.serve --num-requests 8 \
      --arrival-rate 0.5 --max-batch 2
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCHS, smoke_config
from ..core import make_device, synth
from ..core.precision import VIEWS
from ..models.model import init_params
from ..runtime import (
    MultiStreamEngine, PAPER_POLICY, ServeEngine, ServeScheduler,
)
from ..runtime.paging import DEFAULT_DEGRADE_LADDER, LOSSLESS_POLICY


def parse_degrade_ladder(spec: str):
    """CLI ladder spec → tuple of PrecisionViews.

    ``"none"``/empty disables reclamation, ``"default"`` is the paper's
    man4→man2→man0 progression, otherwise a comma-separated list of view
    names from ``repro.core.precision.VIEWS`` (e.g. ``man4,man0``).
    """
    spec = (spec or "none").strip().lower()
    if spec in ("none", ""):
        return ()
    if spec == "default":
        return DEFAULT_DEGRADE_LADDER
    try:
        return tuple(VIEWS[name.strip()] for name in spec.split(","))
    except KeyError as e:
        raise SystemExit(
            f"unknown precision view {e.args[0]!r} in --degrade-ladder "
            f"(known: {', '.join(sorted(VIEWS))})"
        )

EPILOG = """\
serving modes (and the benchmark figure each corresponds to):

  sync single-stream     --sync-io                 serialized spill readback;
                                                   the fig12 baseline every
                                                   overlap win is measured
                                                   against
  async single-stream    (default)                 readback tickets ride the
                                                   in-flight window across
                                                   the jitted decode step —
                                                   fig12's decode/fetch
                                                   overlap at long context
  multi-stream           --streams N               N sequences share ONE
                                                   device queue: cross-stream
                                                   coalesced slab decodes,
                                                   busy-clock queue delay —
                                                   fig12's async-vs-sync
                                                   multi-stream tok/s
  continuous batching    --num-requests N          request arrival/departure
                         [--arrival-rate R]        over the shared queue:
                         [--max-batch M]           FIFO + KV-capacity-aware
                         [--arrival-kind K]        admission, retire frees
                         [--kv-capacity B]         pages — fig12_14's
                         [--capacity-model M]      throughput + p50/p99
                         [--degrade-ladder L]      latency vs offered load
                         [--prefix-share]          shared-prefix KV reuse
                         [--share-prefix-len N]

  Every mode accepts --pnm-topk K: spill readback becomes a processing-
  near-memory gather — the device scores spilled pages on a reduced
  plane subset (sign + exponent + one guard mantissa plane) against the
  current query digest and ships full precision for only the top-K, so
  link bytes per boundary are O(K·page) instead of O(spilled·page).
  K >= spilled pages is bit-identical to the classic readback.
  --importance attention feeds measured attention mass into page
  ranking (residency, spill views) instead of commit recency.

  Every mode accepts --shards N (with --placement P): the tier becomes a
  ShardedTierStore fleet of N devices, each with its own LinkModel pipes
  and busy clock.  hash-stripe spreads each request's pages across the
  fleet, namespace pins whole request namespaces per shard, and
  replicate-weights copies TENSOR-kind writes to every shard with read
  fan-out to the least-busy replica.  Receipts carry the serving
  device_id; the continuous-batching report adds n_devices + fleet_skew.

  The physical capacity model admits against the device's residency
  ledger (projection / observed compression ratio) instead of logical
  BF16 bytes — trace devices admit a larger concurrent batch at the
  same --kv-capacity; a degrade ladder (e.g. "man4,man2,man0") lets a
  blocked admission reclaim stored bytes by shedding mantissa planes of
  cold pages in place before stalling — fig12_14's capacity sweep.

  --prefix-share stores identical completed prompt-prefix pages once
  under the content-addressed shared. namespace (refcounted in the
  residency ledger, copy-on-write past the divergence point) and
  charges each admission only its *novel* KV projection;
  --share-prefix-len makes the synthetic trace share its leading N
  prompt tokens (a common system prompt) so the reuse has something to
  bite on — fig12_14's prefix-share sweep.

All modes keep per-sequence outputs bit-identical to a solo run of the
same request; see docs/ARCHITECTURE.md for the dataflow.
"""


def serve(
    arch: str = "qwen2-0.5b",
    smoke: bool = True,
    device: str = "trace",
    prompt_len: int = 64,
    n_tokens: int = 32,
    batch: int = 2,
    hbm_kv_budget: int = 1 << 12,   # tiny on purpose → force KV spill to tier
    page_tokens: int = 16,
    lossless_only: bool = False,
    streams: int = 1,
    async_io: bool = True,
    seed: int = 0,
    sanitize: bool | None = None,
    shards: int | None = None,
    placement: str | None = None,
    pnm_topk: int | None = None,
    importance: str = "recency",
):
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    policy = LOSSLESS_POLICY if lossless_only else PAPER_POLICY
    kw = dict(
        max_seq=prompt_len + n_tokens + page_tokens,
        batch=batch,
        page_tokens=page_tokens,
        hbm_kv_budget=hbm_kv_budget,
        policy=policy,
        async_io=async_io,
        sanitize=sanitize,
        pnm_topk=pnm_topk,
        importance=importance,
    )
    # Build the (possibly sharded) device up front so the solo-engine
    # path honors --shards/--placement the same way MultiStreamEngine
    # does; `device` stays the kind name for reporting.
    dev = make_device(device, shards=shards, placement=placement,
                      sanitize=sanitize)
    rng = np.random.default_rng(seed)
    if streams > 1:
        eng = MultiStreamEngine(cfg, params, streams, device_kind=dev, **kw)
        prompts = [
            rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
            for _ in range(streams)
        ]
        toks = eng.generate(prompts, n_tokens)
        per = eng.stats()
        d = eng.device_stats()
        print(f"[serve] arch={arch} device={device} streams={streams} "
              f"async_io={async_io} generated {[t.shape for t in toks]}")
        print(f"[serve] shared tier: stored {d.dram_bytes_stored} B, "
              f"DRAM read {d.dram_bytes_read} B, link out {d.link_bytes_out} B")
        io_srv = sum(s.tier_io_service_s for s in per)
        io_qd = sum(s.tier_io_queue_delay_s for s in per)
        print(f"[serve] tier I/O: serialized {io_srv * 1e3:.3f} ms, "
              f"queue delay {io_qd * 1e3:.3f} ms")
        print(f"[serve] aggregate tok/s ceiling: {eng.throughput_ceiling():.1f}")
        return eng, toks
    eng = ServeEngine(cfg, params, device_kind=dev, **kw)
    if pnm_topk is not None:
        print(f"[serve] PNM read mode: device-side top-{pnm_topk} gather "
              f"per KV kind per boundary (importance={importance})")
    prompt = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    toks = eng.generate(prompt, n_tokens)
    s = eng.stats()
    print(f"[serve] arch={arch} device={device} async_io={async_io} "
          f"generated {toks.shape} tokens")
    print(f"[serve] spilled pages: {s.spilled_pages}, "
          f"tier stored {s.tier_dram_stored} B for {s.kv_logical_bytes} B logical "
          f"(ratio {s.kv_compression_ratio:.2f}x)")
    print(f"[serve] tier DRAM read {s.tier_dram_read} B, link out {s.tier_link_out} B")
    print(f"[serve] tier I/O: serialized {s.tier_io_service_s * 1e3:.3f} ms, "
          f"queue delay {s.tier_io_queue_delay_s * 1e3:.3f} ms")
    print(f"[serve] tok/s ceiling (tier-bound): {eng.throughput_ceiling():.1f}")
    return eng, toks


def serve_continuous(
    arch: str = "qwen2-0.5b",
    smoke: bool = True,
    device: str = "trace",
    num_requests: int = 8,
    arrival_rate: float = 0.5,
    arrival_kind: str = "poisson",
    max_batch: int = 2,
    prompt_len: int = 32,
    n_tokens: int = 8,
    batch: int = 1,
    hbm_kv_budget: int = 1 << 12,
    page_tokens: int = 16,
    kv_capacity_bytes: int | None = None,
    capacity_model: str = "logical",
    degrade_ladder=(),
    prefix_share: bool = False,
    share_prefix_len: int = 0,
    lossless_only: bool = False,
    async_io: bool = True,
    seed: int = 0,
    sanitize: bool | None = None,
    shards: int | None = None,
    placement: str | None = None,
    slo_ttft_s: float | None = None,
    slo_tpot_s: float | None = None,
    pnm_topk: int | None = None,
    importance: str = "recency",
):
    """Continuous-batching mode: run a synthetic arrival trace through the
    ServeScheduler and report throughput + latency percentiles."""
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    policy = LOSSLESS_POLICY if lossless_only else PAPER_POLICY
    trace = synth.request_trace(
        num_requests, cfg.vocab, rate=arrival_rate, kind=arrival_kind,
        prompt_len=prompt_len, new_tokens=n_tokens, batch=batch, seed=seed,
        share_prefix_len=share_prefix_len,
    )
    sched = ServeScheduler(
        cfg, params, max_batch=max_batch, device_kind=device, policy=policy,
        batch=batch, page_tokens=page_tokens, hbm_kv_budget=hbm_kv_budget,
        kv_capacity_bytes=kv_capacity_bytes, capacity_model=capacity_model,
        degrade_ladder=degrade_ladder, prefix_share=prefix_share,
        async_io=async_io, sanitize=sanitize,
        shards=shards, placement=placement,
        slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s,
        pnm_topk=pnm_topk, importance=importance,
    )
    rep = sched.run(trace)
    d = sched.device_stats()
    print(f"[serve] arch={arch} device={device} continuous batching: "
          f"{num_requests} requests, {arrival_kind} rate {arrival_rate}/round, "
          f"max_batch {max_batch}, capacity model {capacity_model}")
    print(f"[serve] {rep.steps} rounds, {rep.decode_tokens} decode tokens in "
          f"{rep.model_time_s * 1e3:.2f} modeled ms → {rep.tok_s:.1f} tok/s "
          f"(peak admitted batch {rep.peak_active})")
    print(f"[serve] latency p50 {rep.p50_latency_s * 1e3:.2f} ms, "
          f"p99 {rep.p99_latency_s * 1e3:.2f} ms, mean queue delay "
          f"{rep.mean_queue_delay_s * 1e3:.2f} ms")
    print(f"[serve] TTFT p50 {rep.p50_ttft_s * 1e3:.2f} ms, "
          f"p99 {rep.p99_ttft_s * 1e3:.2f} ms; "
          f"TPOT mean {rep.mean_tpot_s * 1e3:.2f} ms/tok")
    if slo_ttft_s is not None or slo_tpot_s is not None:
        targets = []
        if slo_ttft_s is not None:
            targets.append(f"TTFT <= {slo_ttft_s * 1e3:g} ms")
        if slo_tpot_s is not None:
            targets.append(f"TPOT <= {slo_tpot_s * 1e3:g} ms/tok")
        print(f"[serve] SLO attainment {rep.slo_attainment * 100:.1f}% "
              f"({' and '.join(targets)}, "
              f"{len(rep.records)} finished requests)")
    if capacity_model == "physical":
        print(f"[serve] admission ratio estimate "
              f"{rep.kv_ratio_estimate:.2f}x"
              + (f", reclaimed {rep.reclaimed_bytes} B via degrade ladder"
                 if rep.reclaimed_bytes else ""))
    if prefix_share:
        proj = sum(r.kv_projected_bytes for r in rep.records)
        novel = sum(r.kv_charged_bytes for r in rep.records)
        print(f"[serve] prefix share: admission charged {novel} of {proj} "
              f"projected KV bytes ({proj - novel} B already resident as "
              f"shared pages)")
    if rep.n_devices > 1:
        print(f"[serve] fleet: {rep.n_devices} devices "
              f"(placement {placement or 'hash-stripe'}), "
              f"skew {rep.fleet_skew:.2f}x max/mean moved bytes")
    print(f"[serve] tier after retirement: stored {d.dram_bytes_stored} B, "
          f"{d.blocks} blocks (retired requests freed their namespaces)")
    return sched, rep


def main():
    ap = argparse.ArgumentParser(
        epilog=EPILOG, formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--device", default="trace",
                    choices=["plain", "gcomp", "trace"])
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--streams", type=int, default=1,
                    help="sequences sharing one tier device queue")
    ap.add_argument("--sync-io", action="store_true",
                    help="serialize spill readback (disable the async queue)")
    ap.add_argument("--pnm-topk", type=int, default=None,
                    help="PNM read mode: device-side top-K gather replaces "
                         "full spill readback — the device scores spilled "
                         "pages on the reduced score_view plane subset and "
                         "ships only the K winners (K >= spilled pages is "
                         "bit-identical to the classic path); default off")
    ap.add_argument("--importance", default="recency",
                    choices=["recency", "attention"],
                    help="page-importance signal: commit recency (default) "
                         "or accumulated attention mass fed through "
                         "KVPagePool.update_importance each boundary")
    ap.add_argument("--lossless-only", action="store_true")
    ap.add_argument("--num-requests", type=int, default=0,
                    help="run the continuous-batching scheduler on a "
                         "synthetic trace of this many requests")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="offered load, requests per decode round")
    ap.add_argument("--arrival-kind", default="poisson",
                    choices=["poisson", "bursty"])
    ap.add_argument("--max-batch", type=int, default=2,
                    help="scheduler batch slots (active requests)")
    ap.add_argument("--kv-capacity", type=int, default=0,
                    help="KV admission capacity in bytes (0 = unlimited)")
    ap.add_argument("--capacity-model", default="logical",
                    choices=["logical", "physical"],
                    help="admit against logical BF16 bytes or the "
                         "residency ledger's physical (post-compression) "
                         "footprint")
    ap.add_argument("--degrade-ladder", default="none",
                    help="precision-elastic reclamation ladder: 'none', "
                         "'default' (man4,man2,man0) or a comma list of "
                         "view names; blocked admissions shed cold "
                         "pages' mantissa planes in place before "
                         "stalling (requires --capacity-model physical)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="store identical completed prompt-prefix KV "
                         "pages once (content-addressed shared. "
                         "namespace, refcounted ledger, copy-on-write "
                         "at divergence) and charge admission only the "
                         "novel-KV projection")
    ap.add_argument("--share-prefix-len", type=int, default=0,
                    help="leading prompt tokens shared verbatim by every "
                         "synthetic request (a common system prompt); "
                         "0 = fully independent prompts")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT SLO target in modeled ms; with either SLO "
                         "flag the continuous-batching report includes "
                         "the fraction of requests meeting every "
                         "configured target")
    ap.add_argument("--slo-tpot-ms", type=float, default=None,
                    help="TPOT SLO target in modeled ms per output token "
                         "(single-token requests have no inter-token gap "
                         "and can only miss on TTFT)")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve against a fleet of N tier devices behind "
                         "one ShardedTierStore front-end (each with its "
                         "own link pipes and busy clock); 0 defers to "
                         "the TRACE_SHARDS env var, 1 pins a single "
                         "device")
    ap.add_argument("--placement", default=None,
                    choices=["hash-stripe", "namespace",
                             "replicate-weights"],
                    help="fleet placement policy (with --shards > 1): "
                         "hash-stripe spreads pages by key hash, "
                         "namespace pins whole request namespaces per "
                         "shard, replicate-weights copies TENSOR writes "
                         "to every shard and fans reads out to the "
                         "least-busy replica")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the tier device with the accounting "
                         "sanitizer on: every commit boundary re-checks "
                         "the residency ledger, receipt conservation, "
                         "busy-clock monotonicity and retire cleanup "
                         "(same as TRACE_SANITIZE=1)")
    args = ap.parse_args()
    ladder = parse_degrade_ladder(args.degrade_ladder)
    if ladder and args.capacity_model != "physical":
        raise SystemExit(
            "--degrade-ladder requires --capacity-model physical: "
            "reclamation frees stored bytes, which the logical "
            "projection never looks at"
        )
    if args.share_prefix_len and not args.prefix_share:
        print("[serve] note: --share-prefix-len shapes the trace only; "
              "add --prefix-share to actually dedup the shared pages")
    if args.num_requests > 0:
        if args.streams > 1:
            print("[serve] note: --streams is ignored in continuous-"
                  "batching mode (concurrency comes from --max-batch)")
        serve_continuous(
            arch=args.arch, device=args.device,
            num_requests=args.num_requests, arrival_rate=args.arrival_rate,
            arrival_kind=args.arrival_kind, max_batch=args.max_batch,
            prompt_len=args.prompt_len, n_tokens=args.tokens,
            batch=args.batch, kv_capacity_bytes=args.kv_capacity or None,
            capacity_model=args.capacity_model,
            degrade_ladder=ladder,
            prefix_share=args.prefix_share,
            share_prefix_len=args.share_prefix_len,
            async_io=not args.sync_io, lossless_only=args.lossless_only,
            sanitize=args.sanitize or None,
            shards=args.shards or None, placement=args.placement,
            slo_ttft_s=(args.slo_ttft_ms / 1e3
                        if args.slo_ttft_ms is not None else None),
            slo_tpot_s=(args.slo_tpot_ms / 1e3
                        if args.slo_tpot_ms is not None else None),
            pnm_topk=args.pnm_topk, importance=args.importance,
        )
        return
    if args.slo_ttft_ms is not None or args.slo_tpot_ms is not None:
        print("[serve] note: --slo-ttft-ms/--slo-tpot-ms apply to "
              "continuous-batching mode (--num-requests N)")
    if args.prefix_share:
        print("[serve] note: --prefix-share applies to continuous-"
              "batching mode (--num-requests N); single/multi-stream "
              "runs have no cross-request reuse")
    serve(arch=args.arch, device=args.device, n_tokens=args.tokens,
          prompt_len=args.prompt_len, batch=args.batch,
          streams=args.streams, async_io=not args.sync_io,
          lossless_only=args.lossless_only,
          sanitize=args.sanitize or None,
          shards=args.shards or None, placement=args.placement,
          pnm_topk=args.pnm_topk, importance=args.importance)


if __name__ == "__main__":
    main()
