import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: pjit the
cell's step function (train_step / prefill_step / serve_step) against
ShapeDtypeStruct inputs on the production meshes:

  * single-pod: 16×16 = 256 chips, axes (data, model)
  * multi-pod : 2×16×16 = 512 chips, axes (pod, data, model)

For each cell we record ``memory_analysis()`` (fits/doesn't), and
``cost_analysis()`` FLOPs/bytes + the collective bytes parsed from the
post-SPMD HLO — the §Roofline inputs.

NOTE the XLA_FLAGS line above MUST precede any jax import (device count
locks at first init).  Do not import this module from tests.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, applicable_shapes
from ..configs.base import ArchConfig, ShapeConfig
from . import mesh as mesh_lib
from .steps import (
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind, from post-SPMD HLO text.

    We take each collective's RESULT shape(s) (tuples included) as the
    moved-bytes proxy: exact for all-reduce/permute/all-to-all, the
    gathered size for all-gather (upper bound on per-chip traffic), the
    input size is result×group for reduce-scatter (we use result — the
    per-chip output actually landing in memory).
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        # `%name = <shape-or-tuple> <op>(` — op must start the instruction
        m = re.search(r"=\s+(\(.*?\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")[\.\s(]", line)
        if not m:
            continue
        shapes, op = m.group(1), m.group(2)
        total = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shapes)
        )
        out[op] += total
        counts[op] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts, "total": sum(out[k] for k in _COLLECTIVES)}


# ---------------------------------------------------------------------------
# sharding trees for the cell inputs
# ---------------------------------------------------------------------------

def cell_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh, specs: dict):
    rules = mesh_lib.rules_for(cfg, shape, mesh)
    pshard = mesh_lib.param_shardings(cfg, rules)
    ba = mesh_lib.batch_axes(mesh)
    repl = NamedSharding(mesh, P())

    def batch_shard(name, leaf):
        if leaf.ndim == 0:
            return repl
        if shape.global_batch == 1:          # long_500k: batch unshardable
            return NamedSharding(mesh, P(*(None,) * leaf.ndim))
        return NamedSharding(mesh, P(ba, *(None,) * (leaf.ndim - 1)))

    out = {"params": pshard}
    if shape.kind == "train":
        out["opt_state"] = {
            "mu": pshard, "nu": pshard, "step": repl,
        }
        out["error_buf"] = pshard
    if shape.is_decode:
        out["cache"] = mesh_lib.cache_shardings(cfg, rules, specs["cache"])
    out["batch"] = {
        k: batch_shard(k, v) for k, v in specs["batch"].items()
    }
    return out, rules


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def scan_trip_count(cfg: ArchConfig) -> int:
    """Iterations of the layer scan (trip-count correction factor)."""
    if cfg.ssm == "mamba1" or cfg.family == "hybrid":
        return cfg.n_layers
    return cfg.n_layers - cfg.first_dense


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             collect_hlo: bool = True, correct_scan: bool = True,
             overrides: dict | None = None,
             mesh_shape: tuple | None = None) -> dict:
    """``overrides``: ArchConfig field replacements for §Perf variants,
    e.g. {"kv_dtype": "float8_e4m3fn"} or {"remat": False}.
    ``mesh_shape``: alternative (data, model) geometry at 256 chips —
    per-arch TP degree is a §Perf lever (e.g. (128, 2) for archs whose
    head count doesn't divide 16)."""
    import dataclasses

    cfg = ARCHS[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if mesh_shape is not None:
        assert not multi_pod
        mesh = mesh_lib.make_mesh(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape)
    shardings, rules = cell_shardings(cfg, shape, mesh, specs)

    def build_step():
        """Fresh closure each call — a reused function object would hit
        jit's C++ cache and silently ignore the scan_unroll context."""
        if shape.kind == "train":
            s, _ = make_train_step(cfg, rules=rules, grad_compression=True)
            return s
        if shape.is_decode:
            return make_serve_step(cfg, rules=rules)
        return make_prefill_step(cfg, rules=rules)

    step = build_step()
    if shape.kind == "train":
        args = (specs["params"], specs["opt_state"], specs["error_buf"],
                specs["batch"])
        in_sh = (shardings["params"], shardings["opt_state"],
                 shardings["error_buf"], shardings["batch"])
        out_sh = (shardings["params"], shardings["opt_state"],
                  shardings["error_buf"], None)
    elif shape.is_decode:
        args = (specs["params"], specs["batch"], specs["cache"])
        in_sh = (shardings["params"], shardings["batch"], shardings["cache"])
        out_sh = (None, shardings["cache"])
    else:
        args = (specs["params"], specs["batch"])
        in_sh = (shardings["params"], shardings["batch"])
        out_sh = None

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
    }
    # Donation matches the real launchers: train donates params/opt/err
    # (train.py), serving donates the KV cache (in-place update) — without
    # it the dry-run double-counts the cache in output+temp bytes.
    donate = (0, 1, 2) if shape.kind == "train" else (
        (2,) if shape.is_decode else ()
    )
    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                rec[k] = int(v)
        if "argument_size_in_bytes" in rec:
            rec["device_bytes_total"] = (
                rec.get("argument_size_in_bytes", 0)
                + rec.get("temp_size_in_bytes", 0)
            )
    # tracecheck: allow-broad-except(XLA memory_analysis is version-specific; the probe records the error and continues)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["hlo_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        rec["hlo_transcendentals"] = float(ca.get("transcendentals", 0.0))
    # tracecheck: allow-broad-except(XLA cost_analysis is version-specific; the probe records the error and continues)
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)

    if collect_hlo:
        try:
            txt = compiled.as_text()
            rec["collectives"] = collective_bytes(txt)
            rec["hlo_lines"] = txt.count("\n")
        # tracecheck: allow-broad-except(HLO text dump is best-effort diagnostics; record the error and continue)
        except Exception as e:  # pragma: no cover
            rec["collective_error"] = str(e)

    # --- scan trip-count correction (single-pod roofline cells only) --------
    # HloCostAnalysis counts a while body ONCE; re-lower with the layer
    # scan unrolled 2x — the delta is one extra body, so
    #   true = reported + (L - 1) * body.
    L = scan_trip_count(cfg)
    rec["scan_trip_count"] = L
    if correct_scan and not multi_pod and L > 1:
        from ..models.model import scan_unroll

        try:
            with mesh, scan_unroll(2):
                c2 = jax.jit(build_step(), in_shardings=in_sh,
                             out_shardings=out_sh,
                             donate_argnums=donate).lower(*args).compile()
            ca2 = c2.cost_analysis()
            if isinstance(ca2, list):
                ca2 = ca2[0]
            body_f = max(float(ca2.get("flops", 0.0)) - rec.get("hlo_flops", 0.0), 0.0)
            body_b = max(float(ca2.get("bytes accessed", 0.0)) - rec.get("hlo_bytes", 0.0), 0.0)
            rec["hlo_flops_corrected"] = rec.get("hlo_flops", 0.0) + (L - 1) * body_f
            rec["hlo_bytes_corrected"] = rec.get("hlo_bytes", 0.0) + (L - 1) * body_b
            if collect_hlo:
                coll2 = collective_bytes(c2.as_text())
                body_c = max(coll2["total"] - rec["collectives"]["total"], 0.0)
                rec["collective_bytes_corrected"] = (
                    rec["collectives"]["total"] + (L - 1) * body_c
                )
        # tracecheck: allow-broad-except(relowering for the scan correction is best-effort; record the error and continue)
        except Exception as e:  # pragma: no cover
            rec["scan_correction_error"] = str(e)
    return rec


def cells(archs=None):
    for name in sorted(archs or ARCHS):
        cfg = ARCHS[name]
        for shape in applicable_shapes(cfg):
            yield name, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value (perf variants)")
    ap.add_argument("--mesh-shape", default=None,
                    help="data,model geometry at 256 chips (perf variants)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        overrides[k] = v

    todo = []
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        for a, s in cells():
            for mp in meshes:
                todo.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    results = []
    for arch, shape, mp in todo:
        label = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
        print(f"[dryrun] {label} ...", flush=True)
        try:
            ms = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)
            rec = run_cell(arch, shape, mp, collect_hlo=not args.no_hlo,
                           overrides=overrides or None, mesh_shape=ms)
            rec["overrides"] = overrides
            if ms:
                rec["mesh"] = "x".join(str(x) for x in ms)
            rec["ok"] = True
            coll = rec.get("collectives", {}).get("total", 0)
            print(
                f"[dryrun]   ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                f"flops={rec.get('hlo_flops', 0):.3e} bytes={rec.get('hlo_bytes', 0):.3e} "
                f"coll={coll:.3e}",
                flush=True,
            )
        # tracecheck: allow-broad-except(sweep driver: one failing cell is recorded with its traceback, the rest still run)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun]   FAIL {type(e).__name__}: {e}", flush=True)
        results.append(rec)

    n_ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    if n_ok != len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
