"""Pipeline parallelism (GPipe-style) over a ``stage`` mesh axis.

The model zoo stacks per-layer parameters on a leading ``layers`` axis
(consumed by jax.lax.scan), which makes PP natural in JAX: shard THAT
axis over a ``stage`` mesh axis and run the microbatch rotation inside
shard_map — each device group owns n_layers/n_stages layers and passes
activations to the next stage with ``ppermute``.

Schedule: classic GPipe fill-drain.  T = n_micro + n_stages − 1 ticks;
at tick t, stage s processes microbatch (t − s) when 0 ≤ t−s < n_micro.
Stage 0 injects embeddings; the last stage applies the final norm + LM
head and collects logits.  Bubble fraction = (S−1)/T, amortized by
n_micro — the standard trade recorded in EXPERIMENTS.md §Perf-PP.

Scope: decoder-only dense/GQA families (the PP demo covers stablelm /
qwen / llava / nemotron configs); embedding + head weights are
replicated across stages (their layer placement is an orthogonal
optimization).  Forward-only here — jax.grad differentiates through
shard_map+ppermute, so the same structure trains; the train-step wiring
is left as the documented next step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from ..configs.base import ArchConfig
from ..models import layers as L
from ..models.model import DTYPE, cfg_layers


def _stage_forward(cfg: ArchConfig, p_layers, h, positions):
    """Run this stage's slice of the layer stack (same math as
    model._forward_transformer's scan body, attention cache-less)."""

    def body(carry, p_l):
        x = carry
        a, _ = L.gqa_block(x, p_l["attn"], cfg, positions=positions)
        x = x + a
        y = L.mlp_block(x, p_l["mlp"], cfg)
        return x + y, None

    h, _ = jax.lax.scan(body, h, p_layers)
    return h


def make_pp_prefill_step(cfg: ArchConfig, mesh, n_micro: int = 8):
    """Pipelined prefill: (B, S) tokens → (B, S, vocab) logits.

    Mesh must carry a ``stage`` axis; ``data`` (microbatch rows) and
    ``model`` axes compose as usual inside each stage.
    """
    assert not cfg.mla and not cfg.n_experts and cfg.ssm == "", \
        "PP demo covers the dense/GQA families"
    n_stages = mesh.shape["stage"]
    assert cfg_layers(cfg) % n_stages == 0

    def step(params, batch):
        tokens = batch["tokens"]                   # (B, S) global
        B, S = tokens.shape

        def body(p_layers, embed_w, head_w, fnorm, toks):
            Bl = toks.shape[0]                     # LOCAL batch shard
            assert Bl % n_micro == 0, (Bl, n_micro)
            mb = Bl // n_micro
            stage = jax.lax.axis_index("stage")
            last = n_stages - 1
            positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(mb, 0)
            T = n_micro + n_stages - 1
            d = cfg.d_model

            toks_mb = toks.reshape(n_micro, mb, S)
            out = jnp.zeros((n_micro, mb, S, cfg.vocab), DTYPE)
            cur = jnp.zeros((mb, S, d), DTYPE)     # incoming activation

            def tick(t, carry):
                cur, out = carry
                # stage 0 ingests microbatch t (if still filling)
                m_in = jnp.clip(t, 0, n_micro - 1)
                emb = jnp.take(embed_w, toks_mb[m_in], axis=0).astype(DTYPE)
                h_in = jnp.where(stage == 0, emb, cur)
                active = (t - stage >= 0) & (t - stage < n_micro)
                h = _stage_forward(cfg, p_layers, h_in, positions)
                h = jnp.where(active, h, cur)
                # last stage emits logits for microbatch t - last
                hn = L.norm(h, fnorm, cfg.norm)
                logits = (hn @ head_w).astype(DTYPE)
                m_out = jnp.clip(t - last, 0, n_micro - 1)
                emit = active & (stage == last)
                out = out.at[m_out].set(
                    jnp.where(emit, logits, out[m_out])
                )
                # rotate activations: stage s → s+1 (ring; wraps ignored)
                nxt = jax.lax.ppermute(
                    h, "stage",
                    [(s, (s + 1) % n_stages) for s in range(n_stages)],
                )
                return nxt, out

            cur, out = jax.lax.fori_loop(0, T, tick, (cur, out))
            # only the last stage holds real logits (zeros elsewhere):
            # reduce over the stage ring so every rank returns the result
            out = jax.lax.psum(out, "stage")
            return out.reshape(Bl, S, cfg.vocab)

        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        return shard_map(
            body, mesh=mesh,
            in_specs=(
                P("stage"),                        # layer stack → stages
                P(None, None),                     # embed (replicated)
                P(None, None),                     # head  (replicated)
                P(),                               # final norm
                P("data", None),                   # tokens over data
            ),
            out_specs=P("data", None, None),
            check_vma=False,
        )(params["layers"], params["embed"], head,
          params["final_norm"], tokens)

    return step
