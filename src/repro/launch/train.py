"""Production train loop: pjit'd step, sharded data, fault tolerance.

Fault-tolerance contract:
  * checkpoint every ``ckpt_every`` steps (async host-side serialization);
  * restart resumes from the latest committed manifest — params, optimizer
    moments, error-feedback buffers, AND the data-iterator step, so the
    token stream continues exactly where it stopped;
  * elastic restart: shardings are re-derived from logical axes on the
    *current* mesh, so the same checkpoint restores onto a different chip
    count (the checkpoint stores logical arrays, not layouts);
  * straggler mitigation: per-step wall-clock watchdog — steps exceeding
    ``straggler_factor`` × the trailing median are logged with the step
    index so an external orchestrator can replace the slow host.  (On real
    multi-host TPU the detection signal is the same; the replacement action
    is the scheduler's.)

XLA flags for overlap (recorded here; applied by the real launcher):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_enable_async_collective_permute=true
  --xla_tpu_overlap_compute_collective_tc=true

Usage (CPU demo sizes):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 20 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import ARCHS, SHAPES, smoke_config
from ..data import DataConfig, ShardedTokenStream
from ..models.model import abstract_params, init_params
from ..optim import AdamWConfig, init as opt_init
from ..optim.grad_compress import init_error_feedback
from . import mesh as mesh_lib
from .steps import make_train_step


def train(
    arch: str = "qwen2-0.5b",
    steps: int = 20,
    smoke: bool = True,
    seq_len: int = 128,
    global_batch: int = 8,
    ckpt_dir: str | None = None,
    ckpt_every: int = 10,
    grad_compression: bool = True,
    mesh=None,
    straggler_factor: float = 3.0,
    log_every: int = 1,
    seed: int = 0,
):
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_config(cfg)

    rules = None
    shardings = None
    if mesh is not None:
        from ..configs.base import ShapeConfig

        shape = ShapeConfig("train", seq_len, global_batch, "train")
        rules = mesh_lib.rules_for(cfg, shape, mesh)
        shardings = mesh_lib.param_shardings(cfg, rules)

    train_step, ocfg = make_train_step(
        cfg, rules=rules, grad_compression=grad_compression
    )
    jit_step = jax.jit(train_step, donate_argnums=(0, 1, 2))

    # --- state init or restore ------------------------------------------------
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    template = {
        "params": abstract_params(cfg),
        "opt": jax.eval_shape(
            lambda p: opt_init(ocfg, p), abstract_params(cfg)
        ),
        "err": jax.eval_shape(init_error_feedback, abstract_params(cfg)),
    }
    if mgr and mgr.latest_step() is not None:
        state, manifest = mgr.restore(template)
        params, opt_state, err = state["params"], state["opt"], state["err"]
        start_step = manifest["step"]
        print(f"[train] restored step {start_step} from {ckpt_dir}")
        if shardings is not None:
            params = jax.device_put(params, shardings)
    else:
        params = init_params(cfg, jax.random.PRNGKey(seed))
        if shardings is not None:
            params = jax.device_put(params, shardings)
        opt_state = opt_init(ocfg, params)
        err = init_error_feedback(params)

    data = ShardedTokenStream(
        DataConfig(cfg.vocab, seq_len, global_batch, seed=seed)
    )

    # --- loop -------------------------------------------------------------------
    losses, durations = [], []
    for step in range(start_step, steps):
        host = data.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in host.items()}
        if not cfg.uses_tokens:
            # frontend stub: deterministic embedding of the token ids
            emb_rng = jax.random.PRNGKey(step)
            batch["embeds"] = (
                jax.random.normal(
                    emb_rng, (global_batch, seq_len, cfg.d_model), jnp.bfloat16
                )
                + jnp.asarray(host["tokens"], jnp.bfloat16)[..., None] * 1e-3
            )
            del batch["tokens"]
        t0 = time.perf_counter()
        params, opt_state, err, metrics = jit_step(
            params, opt_state, err, batch
        )
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        durations.append(dt)
        if len(durations) >= 5:
            med = statistics.median(durations[-20:])
            if dt > straggler_factor * med:
                print(f"[straggler] step {step}: {dt:.3f}s vs median {med:.3f}s")
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(
                step + 1,
                {"params": params, "opt": opt_state, "err": err},
                blocking=False,
                extra={"arch": arch, "loss": loss},
            )
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state, "err": err},
                 extra={"arch": arch, "loss": losses[-1] if losses else None})
        mgr.wait()
    return {"losses": losses, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-grad-compression", action="store_true")
    args = ap.parse_args()
    out = train(
        arch=args.arch, steps=args.steps, smoke=args.smoke,
        seq_len=args.seq_len, global_batch=args.global_batch,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        grad_compression=not args.no_grad_compression,
    )
    print(f"[train] done; final loss {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
