"""Step functions + abstract input specs for every (arch × shape) cell.

``train_step``  — loss/grad + AdamW update (+ optional int8 gradient
                  compression with error feedback for the cross-pod
                  all-reduce).
``serve_step``  — one decode token against a populated KV cache of
                  ``seq_len`` (decode_* / long_* cells lower THIS, not
                  train_step).
``prefill_step``— full-prompt forward (prefill_* cells).

``input_specs`` returns ShapeDtypeStruct stand-ins for every input — the
dry-run lowers against these, so no memory is ever allocated for the full
configs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import decode_step, forward, init_cache, lm_loss
from ..models.model import abstract_params, DTYPE
from ..models.sharding import MeshRules, use_rules
from ..optim import AdamWConfig, init as opt_init, update as opt_update
from ..optim.grad_compress import compress_grads, init_error_feedback


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, ocfg: Optional[AdamWConfig] = None,
                    rules: Optional[MeshRules] = None,
                    grad_compression: bool = False):
    ocfg = ocfg or AdamWConfig(
        moment_dtype=jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16"
        else jnp.float32
    )

    act = rules.act() if rules is not None else None

    def train_step(params, opt_state, error_buf, batch):
        with use_rules(act):
            loss, grads = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, batch)
            )(params)
            if grad_compression:
                grads, error_buf = compress_grads(grads, error_buf)
            params, opt_state, metrics = opt_update(
                ocfg, grads, opt_state, params
            )
        return params, opt_state, error_buf, {"loss": loss, **metrics}

    return train_step, ocfg


def make_serve_step(cfg: ArchConfig, rules: Optional[MeshRules] = None):
    act = rules.act() if rules is not None else None

    def serve_step(params, batch, cache):
        with use_rules(act):
            return decode_step(cfg, params, batch, cache)

    return serve_step


def make_prefill_step(cfg: ArchConfig, rules: Optional[MeshRules] = None):
    act = rules.act() if rules is not None else None

    def prefill_step(params, batch):
        with use_rules(act):
            logits, _, _ = forward(cfg, params, batch)
        return logits

    return prefill_step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the host batch of one cell."""
    B = shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    sd = jax.ShapeDtypeStruct
    if cfg.uses_tokens:
        b = {"tokens": sd((B, S), jnp.int32)}
    else:
        # modality frontend stub: precomputed frame/patch embeddings
        b = {"embeds": sd((B, S, cfg.d_model), jnp.bfloat16)}
    if shape.kind == "train":
        b["labels"] = sd((B, S), jnp.int32)
    if shape.is_decode:
        b["cache_pos"] = sd((), jnp.int32)
    return b


def cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Abstract KV/SSM cache of ``seq_len`` capacity for decode cells."""
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    return cache


def opt_specs(cfg: ArchConfig, ocfg: AdamWConfig) -> dict:
    params = abstract_params(cfg)
    return jax.eval_shape(functools.partial(opt_init, ocfg), params)


def error_buf_specs(cfg: ArchConfig) -> dict:
    return jax.eval_shape(init_error_feedback, abstract_params(cfg))


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                ocfg: Optional[AdamWConfig] = None) -> dict:
    """All abstract inputs for the cell's step function, keyed by arg name."""
    params = abstract_params(cfg)
    if shape.kind == "train":
        ocfg = ocfg or AdamWConfig(
            moment_dtype=jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16"
            else jnp.float32
        )
        return {
            "params": params,
            "opt_state": opt_specs(cfg, ocfg),
            "error_buf": error_buf_specs(cfg),
            "batch": batch_specs(cfg, shape),
        }
    if shape.is_decode:
        return {
            "params": params,
            "batch": batch_specs(cfg, shape),
            "cache": cache_specs(cfg, shape),
        }
    return {"params": params, "batch": batch_specs(cfg, shape)}
