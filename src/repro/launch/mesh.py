"""Production mesh + per-cell logical sharding rules.

Mesh axes:
  * ``pod``   — data parallelism across pods (DCN-friendly: only gradient
    all-reduce crosses pods; FSDP all-gathers stay inside a pod's ICI).
  * ``data``  — in-pod data parallelism / FSDP (parameters' embed dim).
  * ``model`` — tensor parallelism (heads / mlp / experts / vocab).

``rules_for`` maps logical axis names used by the model code to mesh axes
per (arch × shape) cell:

  train/prefill: batch→(pod,data), embed→data (FSDP), heads/mlp/vocab→model
  decode:        batch→(pod,data), kv_seq→model (cache sequence sharding —
                 works for every kv-head count, incl. non-divisible ones)
  long-context:  batch=1 → sequence/state sharding over (data, model)
"""

from __future__ import annotations

import jax

try:  # jax ≥ 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax has Auto-only meshes
    AxisType = None

from ..configs.base import ArchConfig, ShapeConfig
from ..models.sharding import MeshRules


def _mk_mesh(shape: tuple, axes: tuple):
    """jax.make_mesh with ``axis_types`` only where the installed jax has
    it; older releases treat every axis as Auto already."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    return _mk_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def rules_for(cfg: ArchConfig, shape: ShapeConfig, mesh) -> MeshRules:
    """Logical→mesh axis rules for one (arch × shape × mesh) cell."""
    ba = batch_axes(mesh)
    model_size = mesh.shape["model"]

    # Expert parallelism only when experts divide the model axis cleanly;
    # otherwise experts stay replicated and the expert FFN is TP-sharded.
    ep = cfg.n_experts > 0 and cfg.n_experts % model_size == 0

    rules = {
        "batch": ba,
        "seq": None,
        # FSDP: params' d_model dim over data axis (cfg.fsdp=False → pure
        # TP: replicate over data, cutting the weight-grad all-gathers)
        "embed": "data" if cfg.fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model" if ep else None,
        "moe_mlp": None if ep else "model",
        "capacity": "data",      # MoE dispatch buffer's token-capacity dim
        "d_inner": "model",
        "layers": None,
        "kv_seq": None,
    }

    act_over: dict = {}
    if shape.is_decode:
        # Cache layout: (layers, batch, seq, kv_heads, hd) — shard the
        # sequence axis; uniform across kv-head counts.  The kv_heads
        # rule stays for PARAMS (wk/wv TP) but must not bind cache/attn
        # activations whose kv_seq dim already owns the model axis.
        rules["kv_seq"] = "model"
        act_over["kv_heads"] = None
        if cfg.decode_layout == "replicated" and shape.global_batch > 1:
            # batch-replicated decode: weights stay 2D-sharded (no per-step
            # FSDP gathers); the KV cache spreads over both axes.
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
        if shape.global_batch == 1:
            # long_500k: nothing to shard on batch → spread state/sequence
            # over both axes (cache + activations only; params keep the
            # FSDP embed→data rule).
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
            act_over["d_inner"] = ("data", "model")
    elif shape.seq_len * shape.global_batch >= 2**20 and shape.kind == "prefill":
        # long prefill: sequence parallelism on activations
        rules["seq"] = None

    return MeshRules(mesh, rules, act_over)


def param_shardings(cfg: ArchConfig, rules: MeshRules):
    """NamedSharding tree for the parameter pytree (shape-aware: mesh
    extents that don't divide a dim are dropped → replicated)."""
    from ..models.model import Spec, schema

    return jax.tree.map(
        lambda s: rules.sharding_for_shape(s.axes, s.shape),
        schema(cfg),
        is_leaf=lambda x: isinstance(x, Spec),
    )


def cache_shardings(cfg: ArchConfig, rules: MeshRules, cache_tree):
    """NamedSharding tree for a decode cache pytree (by array rank/kind).

    Uses the ACTIVATION view of the rules (cache tensors behave like
    activations: kv_seq owns the model axis, kv_heads/d_inner overrides
    apply) with divisibility guards per leaf shape.
    """
    rules = rules.act()

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim
        if name in ("k", "v", "c_kv", "k_rope"):
            # (L, B, S, ...) — batch then kv_seq
            axes = ("layers", "batch", "kv_seq") + (None,) * (nd - 3)
        elif name in ("conv", "ssm"):
            # (L, B, ..., d_inner-ish, ...): shard the widest inner dim
            axes = ("layers", "batch") + (None,) * (nd - 3) + ("d_inner",)
            if name == "ssm" and nd == 5:  # (L,B,Hm,P,N) mamba2
                axes = ("layers", "batch", "d_inner", None, None)
            if name == "conv":             # (L,B,K-1,C): channels last
                axes = ("layers", "batch", None, "d_inner")
        elif name in ("attn_k", "attn_v"):
            axes = ("layers", "batch", "kv_seq", None, None)
        elif name == "attn_pos":
            axes = ("layers", "batch", None)
        else:
            axes = (None,) * nd
        return rules.sharding_for_shape(axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)
