import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Verify the multi-pod communication contract from the compiled HLO.

Claim (DESIGN.md §4): on the 2×16×16 mesh the `pod` axis is pure data
parallelism — collectives that cross pods (replica groups containing
device ids from both pods, i.e. both <256 and ≥256) appear only in the
gradient-reduction path, never in the FSDP/TP all-gathers of the forward
pass.

Usage: PYTHONPATH=src python -m repro.launch.verify_multipod [arch]
"""

import re
import sys

import numpy as np


def group_crosses_pods(groups_txt: str, pod_size: int = 256) -> bool:
    """Decode HLO replica_groups (explicit {..} or iota v2 format
    ``[G,S]<=[dims]T(perm)``) and test whether any group spans pods."""
    for grp in re.findall(r"\{([\d,]+)\}", groups_txt):
        ids = [int(x) for x in grp.split(",") if x]
        if ids and min(ids) < pod_size <= max(ids):
            return True
    m = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                 groups_txt)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        groups = ids.ravel().reshape(g, s)
        pods = groups // pod_size
        return bool(np.any(pods.min(1) != pods.max(1)))
    return False


def analyse(hlo: str) -> dict:
    out = {"cross_pod": [], "in_pod": 0}
    for line in hlo.splitlines():
        m = re.search(
            r"= (?:\(?\S+\)?) (all-gather|all-reduce|reduce-scatter|"
            r"all-to-all|collective-permute)", line)
        g = re.search(r"replica_groups=(.+?)(?:, [a-z_]+=|$)", line)
        if not (m and g):
            continue
        op, groups = m.group(1), g.group(1)
        if group_crosses_pods(groups):
            meta = re.search(r'op_name="([^"]*)"', line)
            out["cross_pod"].append(
                (op, meta.group(1)[:110] if meta else "?"))
        else:
            out["in_pod"] += 1
    return out


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-0.5b"
    from .dryrun import cell_shardings, ARCHS, SHAPES
    from . import mesh as mesh_lib
    from .steps import input_specs, make_train_step

    import jax

    cfg = ARCHS[arch]
    shape = SHAPES["train_4k"]
    mesh = mesh_lib.make_production_mesh(multi_pod=True)
    specs = input_specs(cfg, shape)
    shardings, rules = cell_shardings(cfg, shape, mesh, specs)
    step, _ = make_train_step(cfg, rules=rules, grad_compression=True)
    with mesh:
        compiled = jax.jit(
            step,
            in_shardings=(shardings["params"], shardings["opt_state"],
                          shardings["error_buf"], shardings["batch"]),
            out_shardings=(shardings["params"], shardings["opt_state"],
                           shardings["error_buf"], None),
        ).lower(specs["params"], specs["opt_state"], specs["error_buf"],
                specs["batch"]).compile()
    res = analyse(compiled.as_text())
    print(f"[multipod] {arch}: {res['in_pod']} in-pod collectives, "
          f"{len(res['cross_pod'])} cross-pod")
    grad_like = 0
    for op, name in res["cross_pod"]:
        tag = "GRAD/OPT" if any(
            s in name.lower() for s in
            ("transpose", "grad", "add_any", "opt", "update")
        ) else "forward?"
        if tag == "GRAD/OPT":
            grad_like += 1
        print(f"  cross-pod {op:20s} [{tag}] {name}")
    if res["cross_pod"] and grad_like == len(res["cross_pod"]):
        print("[multipod] OK: all cross-pod collectives are in the "
              "gradient/optimizer path")
    elif not res["cross_pod"]:
        print("[multipod] no cross-pod collectives found (check parsing)")
    else:
        print("[multipod] WARNING: forward-path cross-pod collectives above")


if __name__ == "__main__":
    main()
