"""Logical-axis sharding (MaxText-style).

Model code annotates tensors with *logical* axis names; the launcher
installs a rule set mapping logical names → mesh axes for the current
(arch × shape × mesh) cell.  On CPU smoke tests no rules are installed and
annotations are no-ops, so the same model code runs everywhere.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: list["MeshRules"] = []


class MeshRules:
    """Mapping of logical axis name → mesh axis (or tuple, or None).

    ``act_overrides`` is a per-cell patch applied by :meth:`act` — the
    activation/cache view of the rules.  Canonical uses: params' FSDP
    ``embed``→data rule must not bind activations (their batch dim owns
    the data axis), and decode cells shard the KV cache's sequence axis
    on the mesh axis that params use for kv_heads.
    """

    def __init__(self, mesh: Mesh, rules: dict, act_overrides: dict | None = None):
        self.mesh = mesh
        self.rules = dict(rules)
        self.act_overrides = {"embed": None, **(act_overrides or {})}

    def spec(self, axes: tuple) -> P:
        out = []
        for a in axes:
            r = self.rules.get(a) if a is not None else None
            out.append(r)
        return P(*out)

    def axis_size(self, rule) -> int:
        """Product of mesh axis sizes a rule entry maps to."""
        if rule is None:
            return 1
        names = rule if isinstance(rule, tuple) else (rule,)
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n

    def spec_for_shape(self, axes: tuple, shape: tuple) -> P:
        """Spec with non-dividing entries degraded: a tuple rule falls back
        to its longest dividing prefix, a scalar rule to None (e.g. a
        504-way vocab on a 16-way model axis stays replicated)."""
        out = []
        for a, d in zip(axes, shape):
            r = self.rules.get(a) if a is not None else None
            if r is not None:
                cand = r if isinstance(r, tuple) else (r,)
                while cand and d % self.axis_size(cand) != 0:
                    cand = cand[:-1]
                r = (cand if len(cand) > 1 else (cand[0] if cand else None))
            out.append(r)
        return P(*out)

    def sharding(self, axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))

    def sharding_for_shape(self, axes: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for_shape(axes, shape))

    def act(self) -> "MeshRules":
        r = dict(self.rules)
        r.update(self.act_overrides)
        return MeshRules(self.mesh, r, {})


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    if rules is None:
        yield
        return
    _ACTIVE.append(rules)
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_rules() -> Optional[MeshRules]:
    return _ACTIVE[-1] if _ACTIVE else None


def logical(x, *axes):
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    No-op when no rules are installed (CPU smoke tests) or when the rank
    doesn't match (defensive: lets layers be reused across cache layouts).
    Axes whose mesh extent doesn't divide the dim are dropped (replicated)
    rather than erroring — e.g. a 504-way vocab on a 16-way model axis.
    """
    r = current_rules()
    if r is None or x.ndim != len(axes):
        return x
    return jax.lax.with_sharding_constraint(
        x, r.sharding_for_shape(axes, x.shape)
    )
