"""Model assembly: parameter schema (shapes + logical sharding axes),
init, forward for train/prefill/decode, and KV/SSM cache construction.

One source of truth: ``schema(cfg)`` returns a nested dict of ``Spec``
leaves; ``init_params`` / ``abstract_params`` / ``param_axes`` all traverse
it, so parameter trees and sharding trees can never drift apart.

Layer parameters are stacked with a leading ``layers`` axis and consumed by
``jax.lax.scan`` — essential to keep HLO size O(1) in depth for the 96-layer
/ 340 B dry-run cells.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import layers as L
from .sharding import logical


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple                 # logical axis names (len == rank)
    init: str = "normal"        # normal | zeros | ones | a_log | a_log2 | dt_bias

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


DTYPE = jnp.bfloat16

# Scan unroll factor for the layer loop.  The dry-run lowers each cell
# twice (unroll=1 and unroll=2): XLA's HloCostAnalysis counts a while-loop
# body ONCE regardless of trip count, so the delta between the two
# lowerings isolates the per-layer body cost for trip-count correction
# (benchmarks/roofline.py).
_SCAN_UNROLL = [1]


@contextlib.contextmanager
def scan_unroll(n: int):
    _SCAN_UNROLL.append(n)
    try:
        yield
    finally:
        _SCAN_UNROLL.pop()


def _unroll() -> int:
    return _SCAN_UNROLL[-1]


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

def _norm_spec(cfg, d, stacked: bool):
    lead = ((cfg_layers(cfg),), ("layers",)) if stacked else ((), ())
    s = {"scale": Spec(lead[0] + (d,), lead[1] + (None,), "zeros")}
    if cfg.norm == "layernorm":
        s["bias"] = Spec(lead[0] + (d,), lead[1] + (None,), "zeros")
    return s


def cfg_layers(cfg):  # stacked-layer count (excludes leading dense layers)
    return cfg.n_layers - cfg.first_dense


def _attn_specs(cfg, n_layers_key="layers"):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    Lk = (cfg_layers(cfg),)
    A = (n_layers_key,)
    s = {
        "norm": {"scale": Spec(Lk + (d,), A + (None,), "zeros")},
        "wq": Spec(Lk + (d, H * hd), A + ("embed", "heads")),
        "wk": Spec(Lk + (d, KV * hd), A + ("embed", "kv_heads")),
        "wv": Spec(Lk + (d, KV * hd), A + ("embed", "kv_heads")),
        "wo": Spec(Lk + (H * hd, d), A + ("heads", "embed")),
    }
    if cfg.norm == "layernorm":
        s["norm"]["bias"] = Spec(Lk + (d,), A + (None,), "zeros")
    if cfg.qkv_bias:
        s["bq"] = Spec(Lk + (H * hd,), A + ("heads",), "zeros")
        s["bk"] = Spec(Lk + (KV * hd,), A + ("kv_heads",), "zeros")
        s["bv"] = Spec(Lk + (KV * hd,), A + ("kv_heads",), "zeros")
    return s


def _mla_specs(cfg):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    vh = cfg.v_head_dim or hd
    Lk = (cfg_layers(cfg),)
    A = ("layers",)
    return {
        "norm": {"scale": Spec(Lk + (d,), A + (None,), "zeros")},
        "wq": Spec(Lk + (d, H * (hd + rd)), A + ("embed", "heads")),
        "w_dkv": Spec(Lk + (d, r + rd), A + ("embed", None)),
        "kv_norm": {"scale": Spec(Lk + (r,), A + (None,), "zeros")},
        "w_uk": Spec(Lk + (r, H * hd), A + (None, "heads")),
        "w_uv": Spec(Lk + (r, H * vh), A + (None, "heads")),
        "wo": Spec(Lk + (H * vh, d), A + ("heads", "embed")),
    }


def _mlp_specs(cfg, f=None, stacked=True):
    d = cfg.d_model
    f = f or cfg.d_ff
    Lk = (cfg_layers(cfg),) if stacked else ()
    A = ("layers",) if stacked else ()
    s = {"norm": {"scale": Spec(Lk + (d,), A + (None,), "zeros")},
         "w1": Spec(Lk + (d, f), A + ("embed", "mlp")),
         "w2": Spec(Lk + (f, d), A + ("mlp", "embed"))}
    if cfg.norm == "layernorm":
        s["norm"]["bias"] = Spec(Lk + (d,), A + (None,), "zeros")
    if cfg.mlp == "swiglu":
        s["w3"] = Spec(Lk + (d, f), A + ("embed", "mlp"))
    return s


def _moe_specs(cfg):
    d, E = cfg.d_model, cfg.n_experts
    fe = cfg.moe_d_ff or cfg.d_ff
    Lk = (cfg_layers(cfg),)
    A = ("layers",)
    s = {
        "norm": {"scale": Spec(Lk + (d,), A + (None,), "zeros")},
        "router": Spec(Lk + (d, E), A + ("embed", None)),
        "w1": Spec(Lk + (E, d, fe), A + ("experts", "embed", "moe_mlp")),
        "w2": Spec(Lk + (E, fe, d), A + ("experts", "moe_mlp", "embed")),
    }
    if cfg.norm == "layernorm":
        s["norm"]["bias"] = Spec(Lk + (d,), A + (None,), "zeros")
    if cfg.mlp == "swiglu":
        s["w3"] = Spec(Lk + (E, d, fe), A + ("experts", "embed", "moe_mlp"))
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        s["shared"] = {
            "w1": Spec(Lk + (d, fs), A + ("embed", "mlp")),
            "w2": Spec(Lk + (fs, d), A + ("mlp", "embed")),
        }
        if cfg.mlp == "swiglu":
            s["shared"]["w3"] = Spec(Lk + (d, fs), A + ("embed", "mlp"))
    return s


def _mamba1_specs(cfg):
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    dt_rank = max(d // 16, 1)
    Lk = (cfg.n_layers,)
    A = ("layers",)
    return {
        "norm": {"scale": Spec(Lk + (d,), A + (None,), "zeros")},
        "in_proj": Spec(Lk + (d, 2 * di), A + ("embed", "d_inner")),
        "conv_w": Spec(Lk + (di, K), A + ("d_inner", None)),
        "conv_b": Spec(Lk + (di,), A + ("d_inner",), "zeros"),
        "x_proj": Spec(Lk + (di, dt_rank + 2 * N), A + ("d_inner", None)),
        "dt_proj": Spec(Lk + (dt_rank, di), A + (None, "d_inner")),
        "dt_bias": Spec(Lk + (di,), A + ("d_inner",), "dt_bias"),
        "A_log": Spec(Lk + (di, N), A + ("d_inner", None), "a_log"),
        "D": Spec(Lk + (di,), A + ("d_inner",), "ones"),
        "out_proj": Spec(Lk + (di, d), A + ("d_inner", "embed")),
    }


def _mamba2_specs(cfg):
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    Hm = cfg.ssm_heads or max(di // 64, 1)
    Lk = (cfg.n_layers,)
    A = ("layers",)
    return {
        "norm": {"scale": Spec(Lk + (d,), A + (None,), "zeros")},
        "in_proj": Spec(Lk + (d, 2 * di + 2 * N + Hm), A + ("embed", "d_inner")),
        "conv_w": Spec(Lk + (di + 2 * N, K), A + ("d_inner", None)),
        "conv_b": Spec(Lk + (di + 2 * N,), A + ("d_inner",), "zeros"),
        "dt_bias": Spec(Lk + (Hm,), A + (None,), "dt_bias"),
        "A_log": Spec(Lk + (Hm,), A + (None,), "a_log2"),
        "D": Spec(Lk + (Hm,), A + (None,), "ones"),
        "norm_gated": {"scale": Spec(Lk + (di,), A + ("d_inner",), "zeros")},
        "out_proj": Spec(Lk + (di, d), A + ("d_inner", "embed")),
    }


def _shared_block_specs(cfg):
    """zamba2's single shared attention+MLP block (unstacked)."""
    d, H, KV, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    s = {
        "attn": {
            "norm": {"scale": Spec((d,), (None,), "zeros")},
            "wq": Spec((d, H * hd), ("embed", "heads")),
            "wk": Spec((d, KV * hd), ("embed", "kv_heads")),
            "wv": Spec((d, KV * hd), ("embed", "kv_heads")),
            "wo": Spec((H * hd, d), ("heads", "embed")),
        },
        "mlp": {
            "norm": {"scale": Spec((d,), (None,), "zeros")},
            "w1": Spec((d, f), ("embed", "mlp")),
            "w2": Spec((f, d), ("mlp", "embed")),
        },
    }
    if cfg.mlp == "swiglu":
        s["mlp"]["w3"] = Spec((d, f), ("embed", "mlp"))
    return s


def _dense0_specs(cfg):
    """Leading dense layers (deepseek ``first_dense``), stacked separately."""
    n = cfg.first_dense
    base_attn = _mla_specs(cfg) if cfg.mla else _attn_specs(cfg)
    base_mlp = _mlp_specs(cfg)

    def restack(tree):
        return jax.tree.map(
            lambda s: Spec((n,) + s.shape[1:], s.axes, s.init), tree,
            is_leaf=lambda x: isinstance(x, Spec),
        )

    return {"attn": restack(base_attn), "mlp": restack(base_mlp)}


def schema(cfg: ArchConfig) -> dict:
    s: dict[str, Any] = {}
    d, V = cfg.d_model, cfg.vocab
    if cfg.uses_tokens:
        v_ax = "vocab" if cfg.embed_vocab_shard else None
        s["embed"] = Spec((V, d), (v_ax, "embed"))
    if cfg.ssm == "mamba1":
        s["layers"] = _mamba1_specs(cfg)
    elif cfg.family == "hybrid":
        s["layers"] = _mamba2_specs(cfg)
        s["shared"] = _shared_block_specs(cfg)
    else:
        block = {"attn": _mla_specs(cfg) if cfg.mla else _attn_specs(cfg)}
        block["moe" if cfg.n_experts else "mlp"] = (
            _moe_specs(cfg) if cfg.n_experts else _mlp_specs(cfg)
        )
        s["layers"] = block
        if cfg.first_dense:
            s["dense0"] = _dense0_specs(cfg)
    s["final_norm"] = {"scale": Spec((d,), (None,), "zeros")}
    if cfg.norm == "layernorm":
        s["final_norm"]["bias"] = Spec((d,), (None,), "zeros")
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((d, V), ("embed", "vocab"))
    return s


# ---------------------------------------------------------------------------
# init / abstract / axes from schema
# ---------------------------------------------------------------------------

_IS_SPEC = lambda x: isinstance(x, Spec)


def _init_leaf(spec: Spec, key, dtype=DTYPE):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "a_log":           # mamba1: A = -(1..N) per channel
        N = spec.shape[-1]
        a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), spec.shape[:-1] + (1,))
        return jnp.log(a)
    if spec.init == "a_log2":          # mamba2: A scalar per head in [1, 16]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u)
    if spec.init == "dt_bias":
        dt = jnp.exp(
            jax.random.uniform(key, spec.shape, jnp.float32)
            * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3)
        )
        return jnp.log(jnp.expm1(dt))
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    return (jax.random.normal(key, spec.shape, jnp.float32)
            * (1.0 / np.sqrt(fan_in))).astype(dtype)


def init_params(cfg: ArchConfig, key) -> dict:
    leaves, treedef = jax.tree.flatten(schema(cfg), is_leaf=_IS_SPEC)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    )


def abstract_params(cfg: ArchConfig) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            jnp.float32 if s.init in ("a_log", "a_log2", "dt_bias") else DTYPE,
        ),
        schema(cfg), is_leaf=_IS_SPEC,
    )


def param_axes(cfg: ArchConfig) -> dict:
    return jax.tree.map(lambda s: s.axes, schema(cfg), is_leaf=_IS_SPEC)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_in(cfg, params, batch):
    if cfg.uses_tokens:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(DTYPE)
    else:
        x = batch["embeds"].astype(DTYPE)
    return logical(x, "batch", "seq", "embed")


def _logits_out(cfg, params, x):
    x = L.norm(x, params["final_norm"], cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    return logical(logits, "batch", "seq", "vocab")


def _transformer_block(cfg, p, x, *, positions, cache=None, cache_pos=None,
                       window=0):
    if cfg.mla:
        a, new_c = L.mla_block(x, p["attn"], cfg, positions=positions,
                               cache=cache, cache_pos=cache_pos)
    else:
        a, new_c = L.gqa_block(x, p["attn"], cfg, positions=positions,
                               cache=cache, cache_pos=cache_pos,
                               window=window)
    x = x + a
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = L.moe_block(x, p["moe"], cfg)
    else:
        y = L.mlp_block(x, p["mlp"], cfg)
    x = logical(x + y, "batch", "seq", "embed")
    return x, new_c, aux


def forward(cfg: ArchConfig, params, batch, cache=None):
    """Returns (logits, new_cache, aux_loss).

    train/prefill: ``cache is None``; decode: ``cache`` is the stacked
    cache pytree and ``batch['cache_pos']`` the write position.
    """
    x = _embed_in(cfg, params, batch)
    B, S = x.shape[:2]
    decode = cache is not None
    cache_pos = batch.get("cache_pos") if decode else None
    if decode:
        # works for both decode (S=1) and prefill-into-cache (S=prompt)
        positions = jnp.broadcast_to(
            cache_pos + jnp.arange(S, dtype=jnp.int32)[None], (B, S)
        )
    else:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S)
        )

    if cfg.ssm == "mamba1":
        return _forward_mamba(cfg, params, x, cache)
    if cfg.family == "hybrid":
        return _forward_hybrid(cfg, params, x, positions, cache, cache_pos)
    return _forward_transformer(cfg, params, x, positions, cache, cache_pos)


def _forward_transformer(cfg, params, x, positions, cache, cache_pos):
    decode = cache is not None

    if cfg.first_dense:
        for i in range(cfg.first_dense):
            p_i = jax.tree.map(lambda a: a[i], params["dense0"])
            c_i = (jax.tree.map(lambda a: a[i], cache["dense0"])
                   if decode else None)
            x, new_c, _ = _transformer_block(
                cfg, p_i, x, positions=positions, cache=c_i,
                cache_pos=cache_pos,
            )
            if decode:
                cache["dense0"] = jax.tree.map(
                    lambda full, new: full.at[i].set(new),
                    cache["dense0"], new_c,
                )

    def body(carry, xs):
        h, aux_acc = carry
        if decode:
            p_l, c_l = xs
        else:
            p_l, c_l = xs, None
        h, new_c, aux = _transformer_block(
            cfg, p_l, h, positions=positions, cache=c_l, cache_pos=cache_pos,
        )
        return (h, aux_acc + aux), new_c

    body_fn = jax.checkpoint(body) if (cfg.remat and not decode) else body
    xs = (params["layers"], cache["layers"]) if decode else params["layers"]
    (x, aux), new_layer_cache = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), xs, unroll=_unroll()
    )

    logits = _logits_out(cfg, params, x)
    new_cache = None
    if decode:
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_cache
    return logits, new_cache, aux


def _forward_mamba(cfg, params, x, cache):
    decode = cache is not None

    def body(carry, xs):
        h = carry
        if decode:
            p_l, st = xs
        else:
            p_l, st = xs, None
        hin = L.norm(h, p_l["norm"], cfg.norm)
        y, new_st = L.mamba1_mix(hin, p_l, cfg, state=st)
        return h + y, new_st

    body_fn = jax.checkpoint(body) if (cfg.remat and not decode) else body
    xs = (params["layers"], cache["layers"]) if decode else params["layers"]
    x, new_states = jax.lax.scan(body_fn, x, xs, unroll=_unroll())
    logits = _logits_out(cfg, params, x)
    new_cache = {"layers": new_states} if decode else None
    return logits, new_cache, jnp.zeros((), jnp.float32)


def _forward_hybrid(cfg, params, x, positions, cache, cache_pos):
    """zamba2: scan over mamba2 blocks; one SHARED attention+MLP block is
    applied (with its own per-application KV cache) every ``attn_every``
    blocks.  Sliding-window attention bounds the cache for long contexts."""
    decode = cache is not None
    shared = params["shared"]
    every = cfg.attn_every
    n_apps = cfg.n_layers // every

    def apply_shared(h, app_cache, app_pos):
        a, new_c = L.gqa_block(
            h, shared["attn"], cfg, positions=positions,
            cache=app_cache, cache_pos=app_pos, window=cfg.window,
        )
        h = h + a
        h = h + L.mlp_block(h, shared["mlp"], cfg)
        return h, new_c

    def body(carry, xs):
        if decode:
            (h, i, ak, av, apos) = carry
            p_l, st = xs
        else:
            (h, i) = carry
            p_l, st = xs, None

        hin = L.norm(h, p_l["norm"], cfg.norm)
        y, new_st = L.mamba2_mix(hin, p_l, cfg, state=st)
        h = h + y

        is_app = ((i % every) == 0) & ((i // every) < n_apps)
        app_idx = jnp.minimum(i // every, n_apps - 1)
        if decode:
            write_pos = cache_pos % cfg.window
            k_cur = jax.lax.dynamic_index_in_dim(ak, app_idx, 0, False)
            v_cur = jax.lax.dynamic_index_in_dim(av, app_idx, 0, False)
            pos_cur = jax.lax.dynamic_index_in_dim(apos, app_idx, 0, False)

            def do_attn(h):
                # attention over this application's rolling-window cache;
                # per-slot absolute positions drive the window mask.
                h2, new_c = L.gqa_block(
                    h, shared["attn"], cfg, positions=positions,
                    cache={"k": k_cur, "v": v_cur, "kpos": pos_cur},
                    cache_pos=write_pos, window=cfg.window,
                )
                pos_new = jax.lax.dynamic_update_slice_in_dim(
                    pos_cur,
                    jnp.broadcast_to(positions[:, :1], pos_cur[:, :1].shape),
                    write_pos, 1,
                )
                h3 = h + h2
                out = h3 + L.mlp_block(h3, shared["mlp"], cfg)
                return out, new_c["k"], new_c["v"], pos_new

            def no_attn(h):
                return h, k_cur, v_cur, pos_cur

            h, k_new, v_new, pos_new = jax.lax.cond(is_app, do_attn, no_attn, h)
            ak = jax.lax.dynamic_update_index_in_dim(ak, k_new, app_idx, 0)
            av = jax.lax.dynamic_update_index_in_dim(av, v_new, app_idx, 0)
            apos = jax.lax.dynamic_update_index_in_dim(apos, pos_new, app_idx, 0)
            return (h, i + 1, ak, av, apos), new_st

        def do_attn_t(h):
            a, _ = L.gqa_block(h, shared["attn"], cfg, positions=positions,
                               window=cfg.window)
            h = h + a
            return h + L.mlp_block(h, shared["mlp"], cfg)

        h = jax.lax.cond(is_app & (app_idx < n_apps), do_attn_t, lambda h: h, h)
        return (h, i + 1), new_st

    body_fn = jax.checkpoint(body) if (cfg.remat and not decode) else body
    if decode:
        carry0 = (x, jnp.int32(0), cache["attn_k"], cache["attn_v"],
                  cache["attn_pos"])
        (x, _, ak, av, apos), new_states = jax.lax.scan(
            body_fn, carry0, (params["layers"], cache["layers"]),
            unroll=_unroll(),
        )
        new_cache = {"layers": new_states, "attn_k": ak, "attn_v": av,
                     "attn_pos": apos}
    else:
        (x, _), _ = jax.lax.scan(
            body_fn, (x, jnp.int32(0)), params["layers"], unroll=_unroll()
        )
        new_cache = None
    logits = _logits_out(cfg, params, x)
    return logits, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> dict:
    """Decode-time state for one request batch.

    KV leaves use ``cfg.kv_dtype`` (fp8 halves decode HBM traffic — the
    on-chip analogue of plane-proportional fetch); SSM/conv recurrent
    state stays at full precision (it is rewritten, not appended).
    """
    dtype = dtype or jnp.dtype(cfg.kv_dtype)
    n = cfg_layers(cfg)
    if cfg.ssm == "mamba1":
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
        return {"layers": {
            "conv": jnp.zeros((cfg.n_layers, batch, K - 1, di), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, di, N), jnp.float32),
        }}
    if cfg.family == "hybrid":
        di, N, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
        Hm = cfg.ssm_heads or max(di // 64, 1)
        P_ = di // Hm
        W = min(cfg.window or max_seq, max_seq)
        n_apps = cfg.n_layers // cfg.attn_every
        KV, hd = cfg.n_kv_heads, cfg.hd
        return {
            "layers": {
                "conv": jnp.zeros((cfg.n_layers, batch, K - 1, di + 2 * N), dtype),
                "ssm": jnp.zeros((cfg.n_layers, batch, Hm, P_, N), jnp.float32),
            },
            "attn_k": jnp.zeros((n_apps, batch, W, KV, hd), dtype),
            "attn_v": jnp.zeros((n_apps, batch, W, KV, hd), dtype),
            "attn_pos": jnp.full((n_apps, batch, W), -2 * (cfg.window or 1),
                                 jnp.int32),
        }
    if cfg.mla:
        r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
        out = {"layers": {
            "c_kv": jnp.zeros((n, batch, max_seq, r), dtype),
            "k_rope": jnp.zeros((n, batch, max_seq, 1, rd), dtype),
        }}
    else:
        KV, hd = cfg.n_kv_heads, cfg.hd
        out = {"layers": {
            "k": jnp.zeros((n, batch, max_seq, KV, hd), dtype),
            "v": jnp.zeros((n, batch, max_seq, KV, hd), dtype),
        }}
    if cfg.first_dense:
        out["dense0"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.first_dense,) + a.shape[1:], a.dtype),
            out["layers"],
        )
    return out


# ---------------------------------------------------------------------------
# losses / step functions
# ---------------------------------------------------------------------------

def lm_loss(cfg: ArchConfig, params, batch):
    """Next-token CE for decoders; masked-frame CE for encoder-only."""
    logits, _, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss + 0.01 * aux


def prefill(cfg: ArchConfig, params, batch, max_seq: int):
    """Run the full prompt, returning (logits, populated cache).

    Attention caches are filled by recomputing K/V into the cache buffer;
    for SSM/hybrid the final state is produced by the scan itself.  For
    dry-run purposes prefill = forward (cache population is fused)."""
    logits, _, aux = forward(cfg, params, batch)
    return logits


def decode_step(cfg: ArchConfig, params, batch, cache):
    """One token across the batch with a populated cache."""
    logits, new_cache, _ = forward(cfg, params, batch, cache=cache)
    return logits, new_cache
