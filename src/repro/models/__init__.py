from .model import (
    schema, init_params, abstract_params, param_axes,
    forward, lm_loss, prefill, decode_step, init_cache,
)
from . import layers, sharding
