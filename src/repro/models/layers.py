"""Model building blocks: norms, RoPE, (chunked/windowed) GQA attention,
MLA attention, MLP variants, MoE dispatch, Mamba1/Mamba2 blocks.

All functions are pure; parameters arrive as dicts of jnp arrays.  Heavy
attention paths avoid materialising the full (Sq, Sk) score matrix across
the whole sequence by scanning query chunks (online per-chunk softmax over
the full key range), which keeps peak activation memory ∝ chunk * Sk.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .sharding import current_rules, logical


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale) + bias).astype(x.dtype)


def norm(x, p, kind: str):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _masked_softmax(scores, mask):
    scores = jnp.where(mask, scores, -1e30)
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


def attention(
    q, k, v,
    *,
    causal: bool = True,
    window: int = 0,
    q_positions=None,
    k_positions=None,
    kv_valid_len=None,
    q_chunk: int = 0,
):
    """GQA attention.

    q: (B, Sq, H, hd);  k, v: (B, Sk, KV, hd) with H % KV == 0.
    ``window`` > 0 applies sliding-window attention (zamba2 long-context).
    ``kv_valid_len``: (B,) or scalar — mask out cache slots beyond it.
    ``q_chunk`` > 0 scans query chunks to bound score memory.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = hd ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :]
    if k_positions is None:
        k_positions = jnp.arange(Sk)[None, :]

    qg = q.reshape(B, Sq, KV, rep, hd) * scale

    def block(qb, qpos):
        # qb: (B, C, KV, rep, hd) → scores (B, KV, rep, C, Sk)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qb.astype(jnp.float32),
                       k.astype(jnp.float32))
        m = jnp.ones((B, 1, 1, qb.shape[1], Sk), dtype=bool)
        if causal:
            m &= (k_positions[:, None, None, None, :]
                  <= qpos[:, None, None, :, None])
        if window:
            m &= (k_positions[:, None, None, None, :]
                  > qpos[:, None, None, :, None] - window)
        if kv_valid_len is not None:
            lim = jnp.asarray(kv_valid_len).reshape(-1, 1, 1, 1, 1)
            m &= k_positions[:, None, None, None, :] < lim
        p = _masked_softmax(s, m)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
        return o.astype(q.dtype)

    dv = v.shape[-1]  # may differ from hd (MLA)
    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        nc = Sq // q_chunk
        qc = qg.reshape(B, nc, q_chunk, KV, rep, hd).transpose(1, 0, 2, 3, 4, 5)
        pc = jnp.broadcast_to(q_positions, (B, Sq))
        pc = pc.reshape(B, nc, q_chunk).transpose(1, 0, 2)
        out = jax.lax.map(lambda args: block(*args), (qc, pc))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, dv)
    else:
        out = block(qg, jnp.broadcast_to(q_positions, (B, Sq)))
        out = out.reshape(B, Sq, H, dv)
    return out


def gqa_block(x, p, cfg, *, positions, cache=None, cache_pos=None,
              window: int = 0):
    """Full attention sub-block: norm → qkv (+rope) → attention → out proj.

    ``cache``: optional dict {k: (B, Smax, KV, hd), v: ...} for decoding —
    the new token's K/V is written at ``cache_pos`` and attention runs over
    the cache.  Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, KVh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = norm(x, p["norm"], cfg.norm)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KVh, hd)
    v = v.reshape(B, S, KVh, hd)
    if cfg.causal:  # RoPE only for decoder families
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        q = logical(q, "batch", "seq", "heads", None)
        k = logical(k, "batch", "seq", "kv_heads", None)
        out = attention(
            q, k, v, causal=cfg.causal, window=window,
            q_positions=positions, q_chunk=256 if S > 1024 else 0,
        )
        new_cache = None
    else:
        cdt = cache["k"].dtype           # may be fp8 (elastic KV storage)
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cdt), cache_pos, 1
        )
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cdt), cache_pos, 1
        )
        kc = logical(kc, "batch", "kv_seq", "kv_heads", None)
        vc = logical(vc, "batch", "kv_seq", "kv_heads", None)
        if "kpos" in cache:
            # rolling-window cache: per-slot absolute positions; the window
            # + causal tests against kpos do all masking (stale slots hold
            # kpos = -2*window → always excluded).
            kpos = jax.lax.dynamic_update_slice_in_dim(
                cache["kpos"],
                jnp.broadcast_to(positions[:, :1], cache["kpos"][:, :1].shape),
                cache_pos, 1,
            )
            valid = None
        else:
            kpos = jnp.arange(kc.shape[1])[None, :]
            valid = cache_pos + S
        out = attention(
            q, kc.astype(k.dtype), vc.astype(v.dtype), causal=True,
            window=window,
            q_positions=positions, k_positions=kpos,
            kv_valid_len=valid,
            q_chunk=256 if S > 1024 else 0,
        )
        new_cache = {"k": kc, "v": vc}
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


def mla_block(x, p, cfg, *, positions, cache=None, cache_pos=None):
    """DeepSeek-V2 Multi-head Latent Attention.

    KV is compressed into a per-token latent c_kv (kv_lora_rank) plus a
    shared RoPE key (qk_rope_dim); the cache stores only these (the MLA
    memory win).  Decode re-expands K/V from the latent.
    """
    B, S, d = x.shape
    H, hd, r = cfg.n_heads, cfg.hd, cfg.kv_lora_rank
    rd, vh = cfg.qk_rope_dim, cfg.v_head_dim or cfg.hd
    h = norm(x, p["norm"], cfg.norm)

    q = (h @ p["wq"]).reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv_full = h @ p["w_dkv"]                       # (B, S, r + rd)
    c_kv = rmsnorm(ckv_full[..., :r], p["kv_norm"]["scale"])
    k_rope = rope(ckv_full[..., None, r:], positions, cfg.rope_theta)  # (B,S,1,rd)

    if cache is not None:
        cdt = cache["c_kv"].dtype        # may be fp8 (elastic KV storage)
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cdt), cache_pos, 1
        )
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cdt), cache_pos, 1
        )
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        c_kv = c_kv.astype(h.dtype)
        k_rope = k_rope.astype(h.dtype)
        Sk = c_kv.shape[1]
        kv_valid = cache_pos + S
        k_positions = jnp.arange(Sk)[None, :]
    else:
        new_cache = None
        Sk = S
        kv_valid = None
        k_positions = positions

    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"].reshape(r, H, hd))
    v = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"].reshape(r, H, vh))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, Sk, H, rd))], axis=-1
    )
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(
        qfull, k, v, causal=True,
        q_positions=positions, k_positions=k_positions,
        kv_valid_len=kv_valid, q_chunk=256 if S > 1024 else 0,
    )
    out = out.reshape(B, S, H * vh) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(h, p, kind: str):
    if kind == "swiglu":
        a = h @ p["w1"]
        g = h @ p["w3"]
        z = jax.nn.silu(a) * g
    elif kind == "squared_relu":
        z = jnp.square(jax.nn.relu(h @ p["w1"]))
    else:  # gelu
        z = jax.nn.gelu(h @ p["w1"])
    z = logical(z, "batch", "seq", "mlp")
    return z @ p["w2"]


def mlp_block(x, p, cfg):
    h = norm(x, p["norm"], cfg.norm)
    return mlp_apply(h, p, cfg.mlp)


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-bounded batched expert matmul)
# ---------------------------------------------------------------------------

def _moe_dense_decode(flat, p, cfg, gate_vals, expert_idx):
    """Decode path: activate EVERY expert for the (few) tokens and mask.

    For T = batch tokens this is exact (no capacity drops) and turns the
    dispatch into one batched einsum — the right trade at decode batch
    sizes, and it matches the training math wherever no drop occurred.
    """
    E, k = cfg.n_experts, cfg.top_k
    T, d = flat.shape
    if cfg.mlp == "swiglu":
        a = jnp.einsum("td,edf->etf", flat, p["w1"])
        g = jnp.einsum("td,edf->etf", flat, p["w3"])
        z = jax.nn.silu(a) * g
    else:
        z = jax.nn.gelu(jnp.einsum("td,edf->etf", flat, p["w1"]))
    y_all = jnp.einsum("etf,efd->etd", z, p["w2"])           # (E, T, d)
    weight = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], expert_idx
    ].add(gate_vals)
    return jnp.einsum("etd,te->td", y_all, weight.astype(flat.dtype))


def _local_dispatch(flat, cfg, cap, router_w):
    """Top-k routing + capacity-bounded scatter on LOCAL tokens.

    Returns (buf (E, cap, d), e_flat, p_flat, keep, gate_vals, probs)."""
    E, k = cfg.n_experts, cfg.top_k
    T, d = flat.shape
    logits = (flat @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)
    pos_in_expert = (jnp.cumsum(onehot.reshape(T * k, E), axis=0) - 1)
    pos_in_expert = jnp.take_along_axis(
        pos_in_expert.reshape(T, k, E), expert_idx[..., None], axis=-1
    )[..., 0]
    keep = pos_in_expert < cap
    e_flat = jnp.where(keep, expert_idx, E)
    p_flat = jnp.where(keep, pos_in_expert, cap)
    buf = jnp.zeros((E, cap, d), dtype=flat.dtype)
    buf = buf.at[e_flat.reshape(-1), p_flat.reshape(-1)].set(
        jnp.repeat(flat, k, axis=0), mode="drop"
    )
    return buf, e_flat, p_flat, keep, gate_vals, expert_idx, probs


def _expert_ffn(buf, p, cfg, w1, w2, w3):
    if cfg.mlp == "swiglu":
        a = jnp.einsum("ecd,edf->ecf", buf, w1)
        g = jnp.einsum("ecd,edf->ecf", buf, w3)
        z = jax.nn.silu(a) * g
    else:
        z = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, w1))
    return jnp.einsum("ecf,efd->ecd", z, w2)


def _combine(y_buf, e_flat, p_flat, keep, gate_vals, E, cap, d, dtype):
    gathered = y_buf[e_flat.reshape(-1) % E, p_flat.reshape(-1) % cap]
    gathered = jnp.where(keep.reshape(-1, 1), gathered, 0.0)
    T, k = keep.shape
    return (gathered.reshape(T, k, d)
            * gate_vals[..., None].astype(dtype)).sum(axis=1)


def moe_block_ep(x, p, cfg, mesh, token_axes):
    """Expert-parallel MoE via shard_map + all_to_all (§Perf deepseek
    iteration 2 — the production dispatch).

    The jnp scatter in the SPMD path is unpartitionable: XLA replicates
    the GLOBAL (E, cap, d) buffer on every device and all-gathers the
    (T·k, d) token copies (measured: 26 GB/layer/device on the deepseek
    train cell).  Under shard_map the dispatch scatter touches only LOCAL
    tokens; the only cross-device traffic is the (E, C_l, d) all_to_all
    that moves each expert group to its owner — bytes = buf size, not
    tokens × k, and the FFN einsums run at (E/M, M·C_l, d) per device
    with zero redundancy.

    Layout: tokens sharded over ``token_axes`` (= batch axes + 'model');
    experts over 'model'; expert weights FSDP-gathered over 'data' inside
    (standard FSDP all-gather, same as the dense layers).
    """
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    M = mesh.shape["model"]
    h = norm(x, p["norm"], cfg.norm)
    flat = h.reshape(B * S, d)
    T = B * S
    nshards = 1
    for a in token_axes:
        nshards *= mesh.shape[a]
    T_l = T // nshards
    cap_l = int(max(1, cfg.capacity_factor * k * T_l / E))

    def body(flat_l, router_l, w1_l, w2_l, *w3_rest):
        if cfg.fsdp:
            # FSDP: gather the d_model shards of this device's expert group
            router_g = jax.lax.all_gather(router_l, "data", axis=0, tiled=True)
            w1_g = jax.lax.all_gather(w1_l, "data", axis=1, tiled=True)
            w2_g = jax.lax.all_gather(w2_l, "data", axis=2, tiled=True)
            w3_g = (jax.lax.all_gather(w3_rest[0], "data", axis=1, tiled=True)
                    if w3_rest else None)
        else:
            router_g, w1_g, w2_g = router_l, w1_l, w2_l
            w3_g = w3_rest[0] if w3_rest else None

        buf, e_flat, p_flat, keep, gates, expert_idx, probs = _local_dispatch(
            flat_l, cfg, cap_l, router_g
        )
        # exchange expert groups: (E, C_l, d) → (E/M, M*C_l, d)
        buf = jax.lax.all_to_all(
            buf, "model", split_axis=0, concat_axis=1, tiled=True
        )
        y = _expert_ffn(buf, p, cfg, w1_g, w2_g, w3_g)
        # return results to token owners: (E/M, M*C_l, d) → (E, C_l, d)
        y = jax.lax.all_to_all(
            y, "model", split_axis=1, concat_axis=0, tiled=True
        )
        out_l = _combine(y, e_flat, p_flat, keep, gates, E, cap_l, d, x.dtype)

        frac_tokens = jnp.mean(
            jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0
        )
        frac_probs = jnp.mean(probs, axis=0)
        for a in token_axes:
            frac_tokens = jax.lax.pmean(frac_tokens, a)
            frac_probs = jax.lax.pmean(frac_probs, a)
        aux = E * jnp.sum(frac_tokens * frac_probs)
        return out_l, aux

    w3 = p.get("w3")
    dd = "data" if cfg.fsdp else None
    in_specs = [
        P(token_axes, None),              # tokens
        P(dd, None),                      # router (d, E): FSDP on d
        P("model", dd, None),             # w1 (E, d, fe)
        P("model", None, dd),             # w2 (E, fe, d)
    ]
    args = [flat, p["router"], p["w1"], p["w2"]]
    if w3 is not None:
        in_specs.append(P("model", dd, None))
        args.append(w3)
    out_flat, aux = shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(token_axes, None), P()),
        check_vma=False,
    )(*args)

    out = out_flat.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + mlp_apply(h, p["shared"], cfg.mlp)
    return out, aux


def moe_block(x, p, cfg):
    """Token-choice top-k MoE with sort-free one-hot dispatch.

    Tokens are flattened, routed to ``top_k`` experts, packed into a
    per-expert capacity buffer via scatter, processed with one batched
    einsum over experts (MXU-friendly), and combined weighted by gates.
    Overflowing tokens are dropped (standard capacity semantics); a
    load-balancing auxiliary loss is returned for training.  Single-token
    steps (decode) use the exact dense-activation path instead.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    capacity_factor = getattr(cfg, "capacity_factor", 1.25)

    # Distributed training/prefill: use the shard_map EP dispatch whenever
    # the mesh can own experts (E % model == 0) — the SPMD scatter path
    # below replicates the global dispatch buffer on every device.
    r = current_rules()
    if (r is not None and S > 1 and hasattr(r.mesh, "axis_names")
            and "model" in r.mesh.axis_names
            and cfg.n_experts % r.mesh.shape["model"] == 0):
        ba = tuple(a for a in ("pod", "data") if a in r.mesh.axis_names)
        token_axes = ba + ("model",)
        nshards = 1
        for a in token_axes:
            nshards *= r.mesh.shape[a]
        if (B * S) % nshards == 0:
            return moe_block_ep(x, p, cfg, r.mesh, token_axes)

    h = norm(x, p["norm"], cfg.norm)
    flat = h.reshape(B * S, d)
    T = B * S

    logits = (flat @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    if S == 1:
        combined = _moe_dense_decode(flat, p, cfg, gate_vals, expert_idx)
        out = combined.reshape(B, S, d)
        if cfg.n_shared_experts:
            out = out + mlp_apply(h, p["shared"], cfg.mlp)
        return out, jnp.zeros((), jnp.float32)

    cap = int(max(1, capacity_factor * k * T / E))
    # position of each (token, choice) within its expert's buffer
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (T, k, E)
    pos_in_expert = (jnp.cumsum(onehot.reshape(T * k, E), axis=0) - 1)
    pos_in_expert = jnp.take_along_axis(
        pos_in_expert.reshape(T, k, E), expert_idx[..., None], axis=-1
    )[..., 0]                                                # (T, k)
    keep = pos_in_expert < cap

    buf = jnp.zeros((E, cap, d), dtype=x.dtype)
    e_flat = jnp.where(keep, expert_idx, E)                  # drop → OOB
    p_flat = jnp.where(keep, pos_in_expert, cap)
    buf = buf.at[e_flat.reshape(-1), p_flat.reshape(-1)].set(
        jnp.repeat(flat, k, axis=0), mode="drop"
    )
    # capacity must shard over the data axis: with only experts sharded,
    # every data-shard would redundantly compute the FULL global capacity
    # (16x wasted MXU flops at mesh 16x16 — §Perf deepseek iteration 1)
    buf = logical(buf, "experts", "capacity", "embed")

    if cfg.mlp == "swiglu":
        a = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
        g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
        z = jax.nn.silu(a) * g
    else:
        z = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    z = logical(z, "experts", "capacity", "moe_mlp")
    y_buf = jnp.einsum("ecf,efd->ecd", z, p["w2"])           # (E, cap, d)

    gathered = y_buf[e_flat.reshape(-1) % E, p_flat.reshape(-1) % cap]
    gathered = jnp.where(keep.reshape(-1, 1), gathered, 0.0)
    combined = (gathered.reshape(T, k, d)
                * gate_vals[..., None].astype(x.dtype)).sum(axis=1)

    out = combined.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + mlp_apply(h, p["shared"], cfg.mlp)

    # load-balance aux loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b, state=None):
    """x: (B, S, C), depthwise kernel w: (C, K).  If ``state`` (B, K-1, C)
    is given, run incrementally (decode) and return (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[-1]
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)           # (B, K-1+S, C)
        new_state = xin[:, -(K - 1):, :]
    else:
        xin = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    y = jax.lax.conv_general_dilated(
        xin, w.T[:, None, :],                                # (K, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return y + b, new_state


def mamba1_mix(x, p, cfg, state=None):
    """Mamba1 mixer.  x: (B, S, d).  ``state``: dict(conv, ssm) for decode.
    Returns (y, new_state)."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    dt_rank = max(d // 16, 1)

    xz = x @ p["in_proj"]                                   # (B, S, 2*di)
    xs, z = xz[..., :di], xz[..., di:]
    xs = logical(xs, "batch", "seq", "d_inner")

    conv_state = state["conv"] if state is not None else None
    xs, new_conv = causal_conv1d(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    xdbc = xs @ p["x_proj"]                                  # (B,S,dt_rank+2N)
    dt = jax.nn.softplus(
        xdbc[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"]
    )                                                        # (B, S, di)
    Bm = xdbc[..., dt_rank : dt_rank + N]                    # (B, S, N)
    Cm = xdbc[..., dt_rank + N :]                            # (B, S, N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di, N)

    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)      # (B,S,di,N)
    dBx = (dt * xs)[..., None].astype(jnp.float32) * Bm[:, :, None, :]

    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, di, N), jnp.float32))

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    hT, ys = jax.lax.scan(
        step,
        h0,
        (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
         Cm.astype(jnp.float32).transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2).astype(x.dtype)                # (B, S, di)
    y = y + xs * p["D"]
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": hT.astype(state["ssm"].dtype)}
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba2 (zamba2 backbone)
# ---------------------------------------------------------------------------

def mamba2_mix(x, p, cfg, state=None):
    """Mamba2 (SSD recurrence, scan form — the chunked-parallel SSD kernel
    is a TPU adaptation noted in DESIGN.md).  x: (B, S, d)."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    Hm = cfg.ssm_heads or max(di // 64, 1)
    P_ = di // Hm

    proj = x @ p["in_proj"]                  # (B,S, 2*di + 2N + Hm)
    z, xs = proj[..., :di], proj[..., di : 2 * di]
    Bm = proj[..., 2 * di : 2 * di + N]
    Cm = proj[..., 2 * di + N : 2 * di + 2 * N]
    dt = jax.nn.softplus(proj[..., 2 * di + 2 * N :] + p["dt_bias"])  # (B,S,Hm)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = causal_conv1d(conv_in, p["conv_w"], p["conv_b"],
                                       conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :di].reshape(B, S, Hm, P_)
    Bm = conv_out[..., di : di + N]
    Cm = conv_out[..., di + N :]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (Hm,)
    dA = jnp.exp(dt.astype(jnp.float32) * A)                  # (B,S,Hm)
    dBx = (dt[..., None] * xs)[..., None].astype(jnp.float32) \
        * Bm[:, :, None, None, :].astype(jnp.float32)         # (B,S,Hm,P,N)

    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, Hm, P_, N), jnp.float32))

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t[..., None, None] * h + dBx_t
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    hT, ys = jax.lax.scan(
        step,
        h0,
        (dA.transpose(1, 0, 2), dBx.transpose(1, 0, 2, 3, 4),
         Cm.astype(jnp.float32).transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)              # (B,S,Hm,P)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_gated"]["scale"])
    out = y @ p["out_proj"]
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": hT.astype(state["ssm"].dtype)}
    return out, new_state
