"""Gradient compression with error feedback (distributed-optimization trick).

Cross-pod gradient all-reduce is the dominant DCN traffic in multi-pod
data parallelism.  We compress gradients to int8 (per-tensor max-scale)
before the reduction and keep the quantisation residual in an error-
feedback buffer so the compression is unbiased over time (Seide et al.,
1-bit SGD lineage).  Under pjit the reduction itself is inserted by SPMD;
quantise→dequantise around the loss-gradient boundary models the wire
format while keeping the math explicit and testable.  Wire-byte savings
(4x vs f32 / 2x vs bf16) are accounted in the roofline collective term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def _quantize(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, error_buf):
    """Apply error feedback, quantise to int8, return (dequantised grads,
    new error buffer).  The dequantised grads are what the (SPMD-inserted)
    all-reduce sees; the residual stays local."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq).astype(jnp.bfloat16)

    pairs = jax.tree.map(one, grads, error_buf)
    deq = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def compressed_bytes(params) -> int:
    """Wire bytes per all-reduce under int8 compression (+ scales)."""
    return sum(p.size + 4 for p in jax.tree.leaves(params))
