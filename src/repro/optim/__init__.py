from .adamw import AdamWConfig, init, update, schedule, global_norm
from . import grad_compress
