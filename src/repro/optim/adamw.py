"""AdamW with global-norm clipping, warmup-cosine schedule, and optional
low-precision moments (required to fit 100B+ optimizer state on v5e).

Functional API (init/update) so the whole optimizer state is a pytree the
checkpointer and pjit can handle directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(cfg: AdamWConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        upd_ = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        return newp, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
