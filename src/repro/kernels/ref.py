"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose (bit-exact for
the integer transforms) against these references.  The references are also
the fallback path on backends without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.bitplane import BF16_BITS, EXP_BITS, MAN_BITS, MAN_HI, SIGN_BIT

_EXP_ALL_ONES = jnp.uint16(((1 << EXP_BITS) - 1) << (MAN_HI + 1))


# ---------------------------------------------------------------------------
# bit-plane pack / unpack, minor-axis packing: (R, C) u16 <-> (16, R, C//8) u8
# ---------------------------------------------------------------------------

def pack_planes_2d(x_u16: jnp.ndarray, bits: int = BF16_BITS) -> jnp.ndarray:
    """(R, C) uint16 → (bits, R, C//8) uint8; bit i of each element goes to
    plane i; 8 consecutive minor-axis elements pack MSB-first per byte."""
    R, C = x_u16.shape
    shifts = jnp.arange(bits, dtype=jnp.uint16).reshape(bits, 1, 1)
    bitmat = ((x_u16[None] >> shifts) & jnp.uint16(1)).astype(jnp.uint8)
    grouped = bitmat.reshape(bits, R, C // 8, 8)
    weights = jnp.uint8(1) << jnp.arange(7, -1, -1, dtype=jnp.uint8)
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint8)


def unpack_planes_2d(planes: jnp.ndarray, bits: int = BF16_BITS) -> jnp.ndarray:
    """Inverse of :func:`pack_planes_2d` → (R, C) uint16."""
    _, R, Cb = planes.shape
    shifts_in = jnp.arange(7, -1, -1, dtype=jnp.uint8)
    bitmat = (planes[..., None] >> shifts_in) & jnp.uint8(1)
    bitmat = bitmat.reshape(bits, R, Cb * 8).astype(jnp.uint16)
    shifts = jnp.arange(bits, dtype=jnp.uint16).reshape(bits, 1, 1)
    return jnp.sum(bitmat << shifts, axis=0).astype(jnp.uint16)


# ---------------------------------------------------------------------------
# elastic reconstruction (R operator, Eq. 7) on uint16 bit patterns
# ---------------------------------------------------------------------------

def reconstruct_u16_jnp(fetched: jnp.ndarray, r_e: int, r_m: int,
                        d_m: int) -> jnp.ndarray:
    """jnp port of core.precision.reconstruct_u16 (round-to-nearest-even at
    the mantissa cut using guard planes, Inf/NaN preserved, LSB zero-pad)."""
    x = fetched.astype(jnp.uint16)
    if r_e == EXP_BITS and r_m == MAN_BITS:
        return x

    keep = jnp.uint16(
        (1 << SIGN_BIT)
        | (((1 << r_e) - 1) << (MAN_HI + 1 + EXP_BITS - r_e))
        | (((1 << r_m) - 1) << (MAN_HI + 1 - r_m))
    )
    cut = MAN_HI - r_m + 1

    if d_m == 0 or r_e != EXP_BITS:
        return x & keep

    sign = x & jnp.uint16(1 << SIGN_BIT)
    mag = x & jnp.uint16((1 << SIGN_BIT) - 1)
    is_special = (x & _EXP_ALL_ONES) == _EXP_ALL_ONES

    half = jnp.uint16(1 << (cut - 1))
    guard_mask = jnp.uint16((1 << cut) - 1)
    guard = mag & guard_mask
    lsb = (mag >> jnp.uint16(cut)) & jnp.uint16(1)
    round_up = (guard > half) | ((guard == half) & (lsb == 1))
    mag_r = (mag & ~guard_mask) + (
        round_up.astype(jnp.uint16) << jnp.uint16(cut)
    )
    mag_r = jnp.minimum(mag_r, _EXP_ALL_ONES)

    special_out = x & keep
    if r_m > 0:
        man_mask = jnp.uint16((1 << MAN_BITS) - 1)
        nan_lost = (
            is_special & ((x & man_mask) != 0) & ((special_out & man_mask) == 0)
        )
        special_out = jnp.where(
            nan_lost, special_out | jnp.uint16(1 << MAN_HI), special_out
        )
    out = jnp.where(is_special, special_out, sign | mag_r)
    return (out & keep).astype(jnp.uint16)


def elastic_unpack_ref(planes: jnp.ndarray, r_e: int, r_m: int,
                       d_m: int) -> jnp.ndarray:
    """Plane-masked fetch + reconstruction: zero unfetched planes of a full
    (16, R, C//8) stack, unpack, round.  Returns (R, C) uint16."""
    fetch = [SIGN_BIT]
    fetch += list(range(14, 14 - r_e, -1))
    fetch += list(range(MAN_HI, MAN_HI - min(r_m + d_m, MAN_BITS), -1))
    mask = jnp.zeros((BF16_BITS, 1, 1), jnp.uint8)
    mask = mask.at[jnp.array(fetch)].set(0xFF)
    u16 = unpack_planes_2d(planes & mask)
    return reconstruct_u16_jnp(u16, r_e, r_m, d_m)


# ---------------------------------------------------------------------------
# KV exponent-delta transform (Mechanism I, Eq. 3-5)
# ---------------------------------------------------------------------------

def kv_delta_ref(block_u16: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """(n, C) u16 token-major + (C,) u8 base exponents → (C, n) u16
    channel-major with zigzag exponent deltas (bit-exact vs numpy path)."""
    cm = block_u16.T.astype(jnp.uint16)
    exp = ((cm & jnp.uint16(0x7F80)) >> 7).astype(jnp.int32)
    d = (exp - beta[:, None].astype(jnp.int32)) % 256
    s = jnp.where(d >= 128, d - 256, d)
    z = jnp.where(s >= 0, 2 * s, -2 * s - 1).astype(jnp.uint16)
    return (cm & jnp.uint16(0x807F)) | (z << jnp.uint16(7))


def kv_delta_inv_ref(cm_u16: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """(C, n) transformed channel-major → (n, C) token-major original."""
    z = ((cm_u16 & jnp.uint16(0x7F80)) >> 7).astype(jnp.int32)
    s = jnp.where(z % 2 == 0, z // 2, -(z + 1) // 2)
    exp = ((s + beta[:, None].astype(jnp.int32)) % 256).astype(jnp.uint16)
    out = (cm_u16 & jnp.uint16(0x807F)) | (exp << jnp.uint16(7))
    return out.T


# ---------------------------------------------------------------------------
# elastic dequant matmul (Mechanism II consumer)
# ---------------------------------------------------------------------------

def elastic_matmul_ref(x: jnp.ndarray, w_planes: jnp.ndarray, r_m: int,
                       d_m: int = 1) -> jnp.ndarray:
    """x (M, K) bf16 @ dequant(w_planes) → (M, N) f32.

    ``w_planes``: (16, K//8, N) uint8 — K-axis packed bit-planes of a
    (K, N) BF16 weight matrix.  Only sign+exponent+(r_m+d_m) mantissa
    planes participate (the rest are treated as unfetched/zero).
    """
    P, K8, N = w_planes.shape
    fetch = [SIGN_BIT] + list(range(14, 6, -1)) + list(
        range(MAN_HI, MAN_HI - min(r_m + d_m, MAN_BITS), -1)
    )
    mask = jnp.zeros((BF16_BITS, 1, 1), jnp.uint8).at[jnp.array(fetch)].set(0xFF)
    planes = w_planes & mask
    # unpack along K: (16, K//8, N) → (K, N) u16
    shifts_in = jnp.arange(7, -1, -1, dtype=jnp.uint8).reshape(1, 1, 8, 1)
    bits = (planes[:, :, None, :] >> shifts_in) & jnp.uint8(1)
    bits = bits.reshape(P, K8 * 8, N).astype(jnp.uint16)
    shifts = jnp.arange(P, dtype=jnp.uint16).reshape(P, 1, 1)
    u16 = jnp.sum(bits << shifts, axis=0).astype(jnp.uint16)
    u16 = reconstruct_u16_jnp(u16, EXP_BITS, r_m, d_m)
    w = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid_len: int) -> jnp.ndarray:
    """Oracle for the fp8-KV decode attention kernel.

    q (B,H,hd) bf16; k/v (B,S,KV,hd) any float dtype; softmax over the
    first ``valid_len`` slots; GQA via KV-head repetition → (B,H,hd) f32.
    """
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    groups = H // KV
    kx = jnp.repeat(k.astype(jnp.float32), groups, axis=2)
    vx = jnp.repeat(v.astype(jnp.float32), groups, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), kx) / (hd ** 0.5)
    mask = jnp.arange(S)[None, None, :] < valid_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bthd->bhd", p, vx)


def pack_weights_kmajor(w: jnp.ndarray) -> jnp.ndarray:
    """(K, N) bf16 → (16, K//8, N) uint8 K-axis-packed planes (host-side
    prep for :func:`elastic_matmul_ref` and the Pallas kernel)."""
    u16 = jax.lax.bitcast_convert_type(w.astype(jnp.bfloat16), jnp.uint16)
    K, N = u16.shape
    shifts = jnp.arange(BF16_BITS, dtype=jnp.uint16).reshape(-1, 1, 1)
    bitmat = ((u16[None] >> shifts) & jnp.uint16(1)).astype(jnp.uint8)
    grouped = bitmat.reshape(BF16_BITS, K // 8, 8, N)
    weights = (jnp.uint8(1) << jnp.arange(7, -1, -1, dtype=jnp.uint8)).reshape(
        1, 1, 8, 1
    )
    return jnp.sum(grouped * weights, axis=2, dtype=jnp.uint8)
