"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; interpret mode
executes the kernel body in Python for correctness).  On TPU, pass
``interpret=False`` — the BlockSpecs are written for VMEM tiling there.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.bitplane import BF16_BITS, SIGN_BIT, MAN_HI
from .bitplane import pack_planes_pallas, unpack_planes_pallas
from .elastic_matmul import elastic_matmul_pallas
from .kv_delta import kv_delta_inv_pallas, kv_delta_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitplane_pack(x_u16: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """(R, C) uint16 → (16, R, C//8) uint8 plane stack."""
    return pack_planes_pallas(x_u16, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("r_e", "r_m", "d_m", "interpret")
)
def elastic_unpack(
    planes: jnp.ndarray, r_e: int = 8, r_m: int = 7, d_m: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Plane stack → (R, C) uint16 at a precision view.

    Zeroes unfetched planes first (the bytes-scaling slice happens at the
    storage layer; this wrapper keeps the full-stack signature so tests
    can diff views cheaply), then runs the fused unpack+round kernel.
    """
    fetch = [SIGN_BIT] + list(range(14, 14 - r_e, -1)) + list(
        range(MAN_HI, MAN_HI - min(r_m + d_m, 7), -1)
    )
    mask = jnp.zeros((BF16_BITS, 1, 1), jnp.uint8).at[jnp.array(fetch)].set(0xFF)
    return unpack_planes_pallas(
        planes & mask, r_e=r_e, r_m=r_m, d_m=d_m, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_transform(block_u16: jnp.ndarray, beta: jnp.ndarray,
                 interpret: bool = True) -> jnp.ndarray:
    """Token-major (n, C) → channel-major exponent-delta (C, n)."""
    return kv_delta_pallas(block_u16, beta, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_transform_inv(cm_u16: jnp.ndarray, beta: jnp.ndarray,
                     interpret: bool = True) -> jnp.ndarray:
    return kv_delta_inv_pallas(cm_u16, beta, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("r_m", "d_m", "interpret")
)
def elastic_matmul(x: jnp.ndarray, w_planes: jnp.ndarray, r_m: int = 7,
                   d_m: int = 0, interpret: bool = True) -> jnp.ndarray:
    """x @ dequant(planes) with weight bytes ∝ (9 + r_m + d_m)/16."""
    return elastic_matmul_pallas(x, w_planes, r_m, d_m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("valid_len", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid_len: int, interpret: bool = True) -> jnp.ndarray:
    """One-token GQA attention streaming an fp8-stored KV cache: HBM
    traffic = stored (fp8) bytes; upcast + online softmax fused in VMEM."""
    from .decode_attn import decode_attention_pallas

    return decode_attention_pallas(q, k, v, valid_len, interpret=interpret)
