"""PNM page-scoring kernel: device-side top-k candidate ranking.

The processing-near-memory read mode (``core.tier.GatherReq``) scores
every spilled KV page against a host-supplied query digest ON the
device, then ships full precision for only the top-k winners.  Scoring
runs on a *plane subset* — the gather's ``score_view`` defaults to
``MAN0`` (sign + full exponent + one guard mantissa plane), so the
score fetch touches a fraction of each page's stored planes — and this
module turns those reduced-precision rows into one float32 score per
page:

    score(page) = max over valid token rows t of  <row_t, digest>

(the max-dot proxy for the page's attention mass against the digest —
the dynamic-placement literature's top-k page selection signal).

Twin implementations, mirroring ``kernels/lz4.py`` / ``bitplane.py``:

* ``page_scores_pallas`` — a pallas kernel (one grid step per page; the
  masked dot+max reduction stays in VMEM), compiled on TPU/GPU and run
  in interpret mode for the CPU parity tests;
* the vectorized-numpy twin inside :func:`page_scores` — the CPU
  production path the tier device calls.

Determinism: winner selection must be bit-stable across sync/async
submission and shard counts, so :func:`topk_select` ranks by
(-score, candidate position) — equal scores break toward the earlier
candidate in the host-chosen key order, never by float reduction
accident.  The tie-break is exercised by the determinism tests with
byte-identical duplicate pages.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _accel_backend() -> str:
    try:
        return jax.default_backend()
    except RuntimeError:  # pragma: no cover - no runtime available
        return "cpu"


def u16_rows_to_f32(u16: np.ndarray, channels: int) -> np.ndarray:
    """Reinterpret a device payload (uint16 bf16 bit patterns) as
    ``(tokens, channels)`` float32 rows for scoring."""
    import ml_dtypes

    flat = np.ascontiguousarray(np.asarray(u16, dtype=np.uint16)).ravel()
    if flat.size % channels:
        raise ValueError(
            f"page of {flat.size} elems does not factor into "
            f"{channels}-channel rows"
        )
    return (flat.view(ml_dtypes.bfloat16)
            .astype(np.float32)
            .reshape(-1, channels))


def _score_kernel(valid_ref, digest_ref, page_ref, out_ref):
    """One grid step scores one page: masked row-dot + max in VMEM."""
    page = page_ref[0]                    # (T, C) f32
    digest = digest_ref[0]                # (C,) f32
    v = valid_ref[0, 0]                   # valid token rows
    dots = jnp.sum(page * digest[None, :], axis=-1)       # (T,)
    t_ix = jax.lax.broadcasted_iota(jnp.int32, dots.shape, 0)
    out_ref[0] = jnp.max(jnp.where(t_ix < v, dots, -jnp.inf))


def page_scores_pallas(padded: jnp.ndarray, valid: jnp.ndarray,
                       digest: jnp.ndarray,
                       interpret: bool = True) -> jnp.ndarray:
    """(P, T, C) f32 pages + (P,) valid lens + (C,) digest → (P,) f32."""
    P, T, C = padded.shape
    return pl.pallas_call(
        _score_kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((1, T, C), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), jnp.float32),
        interpret=interpret,
    )(valid.reshape(P, 1).astype(jnp.int32),
      digest.reshape(1, C).astype(jnp.float32),
      padded.astype(jnp.float32))


def page_scores(padded: np.ndarray, valid: np.ndarray, digest: np.ndarray,
                force: str | None = None) -> np.ndarray:
    """Score a padded page stack: ``(P, T, C)`` f32 rows (rows past
    ``valid[p]`` ignored) against a ``(C,)`` digest → ``(P,)`` f32.

    Pages with zero valid rows score ``-inf`` (they rank last, ties by
    candidate position).  ``force``: ``"numpy"`` pins the vectorized
    twin, ``"pallas"`` pins the kernel (interpret mode off-accelerator).
    """
    padded = np.asarray(padded, dtype=np.float32)
    valid = np.asarray(valid, dtype=np.int64)
    digest = np.asarray(digest, dtype=np.float32)
    P, T, C = padded.shape
    if P == 0 or T == 0:
        return np.full((P,), -np.inf, dtype=np.float32)
    backend = _accel_backend()
    use_pallas = (force == "pallas"
                  or (force is None and backend in ("tpu", "gpu")))
    if use_pallas:
        out = page_scores_pallas(
            jnp.asarray(padded), jnp.asarray(valid), jnp.asarray(digest),
            interpret=backend not in ("tpu", "gpu"),
        )
        return np.asarray(out, dtype=np.float32)
    dots = padded @ digest                                # (P, T)
    mask = np.arange(T)[None, :] < valid[:, None]
    return np.where(mask, dots, -np.inf).max(axis=1).astype(np.float32)


def page_scores_u16(pages: Sequence[np.ndarray], digest: np.ndarray,
                    force: str | None = None) -> np.ndarray:
    """Score raw device payloads: each page is a uint16 (bf16-pattern)
    array whose elements factor into ``digest.size``-channel rows.
    Ragged pages are padded to the longest and masked."""
    digest = np.asarray(digest, dtype=np.float32)
    if not pages:
        return np.zeros((0,), dtype=np.float32)
    rows = [u16_rows_to_f32(p, digest.size) for p in pages]
    valid = np.array([r.shape[0] for r in rows], dtype=np.int64)
    T = max(1, int(valid.max()))
    padded = np.zeros((len(rows), T, digest.size), dtype=np.float32)
    for i, r in enumerate(rows):
        padded[i, : r.shape[0]] = r
    return page_scores(padded, valid, digest, force=force)


def topk_select(scores: np.ndarray, k: int) -> List[int]:
    """Deterministic top-k: descending score, ties broken by candidate
    position (stable across shard counts and sync/async paths).  ``k``
    past the candidate count clamps; ``k=0`` selects nothing."""
    scores = np.asarray(scores)
    n = scores.size
    if n == 0 or k <= 0:
        return []
    order = np.lexsort((np.arange(n), -scores))
    return [int(i) for i in order[: min(k, n)]]
