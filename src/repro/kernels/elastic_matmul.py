"""Pallas TPU kernel: elastic-precision dequant matmul (Mechanism II).

The consumer side of plane-aligned fetch on TPU: weights live in HBM as
K-axis-packed bit-planes ``(16, K//8, N) uint8``; the runtime picks a
precision view and passes ONLY the fetched planes — HBM→VMEM weight bytes
scale as ``(9 + r_m + d_m)/16`` of BF16, the TPU analogue of the paper's
"DRAM activations scale with requested precision".  Reconstruction
(plane combine + guard round-to-nearest-even + bitcast to BF16) runs in
VMEM, fused immediately ahead of the MXU dot.

Hardware-codesign choices (guides: VMEM ~16 MiB/core, MXU 128×128):
  * N stays the minor axis of every weight tile (lane-dim 128-aligned);
    K-axis packing keeps unpack shifts on the sublane axis.
  * Block (Bm, Bk, Bn) = (128, 512, 256) default: x tile 128·512·2 =
    128 KiB, plane tile ≤ 16·64·256 = 256 KiB, acc 128·256·4 = 128 KiB —
    well under VMEM with double-buffering.
  * K-grid is the innermost loop; the f32 accumulator lives in the output
    block across K steps (revisiting out[i,j] per k), standard Pallas
    matmul pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, p_ref, o_ref, *, plane_ids: tuple, keep_mask: int,
            cut: int, do_round: bool, n_k: int):
    """x: (Bm, Bk) bf16; p: (P_f, Bk//8, Bn) u8; o: (Bm, Bn) f32."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[...].astype(jnp.int32)
    pf, bk8, bn = p.shape
    # unpack bytes → bits on the K (sublane) axis, MSB-first
    shifts_in = 7 - jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8, 1), 2)
    bits = (p[:, :, None, :] >> shifts_in) & 1          # (P_f, Bk//8, 8, Bn)
    bits = bits.reshape(pf, bk8 * 8, bn)
    # combine planes at their true bit positions (compile-time constants)
    u = jnp.zeros((bk8 * 8, bn), jnp.int32)
    for slot, bitpos in enumerate(plane_ids):
        u |= bits[slot] << bitpos

    if do_round:
        sign = u & 0x8000
        mag = u & 0x7FFF
        is_special = (u & 0x7F80) == 0x7F80
        half = 1 << (cut - 1)
        gmask = (1 << cut) - 1
        guard = mag & gmask
        lsb = (mag >> cut) & 1
        round_up = (guard > half) | ((guard == half) & (lsb == 1))
        mag_r = (mag & ~gmask) + (round_up.astype(jnp.int32) << cut)
        mag_r = jnp.minimum(mag_r, 0x7F80)
        special_out = u & keep_mask
        nan_lost = is_special & ((u & 0x7F) != 0) & ((special_out & 0x7F) == 0)
        special_out = jnp.where(nan_lost, special_out | 0x40, special_out)
        u = jnp.where(is_special, special_out, sign | mag_r)
    u = (u & keep_mask).astype(jnp.uint16)
    w = jax.lax.bitcast_convert_type(u, jnp.bfloat16)   # (Bk, Bn)

    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )


def elastic_matmul_pallas(
    x: jnp.ndarray,
    w_planes: jnp.ndarray,
    r_m: int,
    d_m: int = 1,
    *,
    block_m: int = 128,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """x (M, K) bf16 × plane-packed W (16, K//8, N) → (M, N) f32 at the
    (r_e=8, r_m, d_m) view.  Slices the fetched planes BEFORE the kernel —
    the pallas_call never sees (nor moves) unfetched planes."""
    M, K = x.shape
    P, K8, N = w_planes.shape
    assert K8 * 8 == K and P == 16
    fetch = [15] + list(range(14, 6, -1)) + list(
        range(6, 6 - min(r_m + d_m, 7), -1)
    )
    planes = w_planes[jnp.array(fetch)]       # (P_f, K//8, N) — bytes scale
    pf = len(fetch)

    bm, bk, bn = min(block_m, M), min(block_k, K), min(block_n, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % 8 == 0

    keep = 0x8000 | 0x7F80 | (((1 << r_m) - 1) << (7 - r_m))
    cut = 7 - r_m
    do_round = bool(d_m > 0 and r_m < 7 and cut > 0)

    kern = functools.partial(
        _kernel, plane_ids=tuple(fetch), keep_mask=keep,
        cut=max(cut, 1), do_round=do_round, n_k=K // bk,
    )
    return pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((pf, bk // 8, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, planes)
