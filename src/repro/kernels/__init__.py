"""Pallas TPU kernels for TRACE's compute hot-spots.

The paper's controller performs three line-rate transforms that map to
on-chip kernels on a TPU system (DESIGN.md §2):

* bit-plane pack / elastic unpack+round  (bitplane.py)
* cross-token KV exponent-delta          (kv_delta.py)
* plane-fetch dequant matmul             (elastic_matmul.py)

Wrappers in ops.py; pure-jnp oracles in ref.py.
"""

from .ops import (
    bitplane_pack,
    decode_attention,
    elastic_matmul,
    elastic_unpack,
    kv_transform,
    kv_transform_inv,
)

__all__ = [
    "bitplane_pack",
    "decode_attention",
    "elastic_matmul",
    "elastic_unpack",
    "kv_transform",
    "kv_transform_inv",
]
