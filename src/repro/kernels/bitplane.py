"""Pallas TPU kernels: bit-plane pack / elastic unpack (paper §III-A/C).

TPU adaptation (DESIGN.md §2): the paper's transpose runs in a CXL
controller; on a TPU system the bit-plane layout lives in the offload
tier / HBM and the transpose+reconstruction run on-chip, next to the
consumer.  These kernels stream (R, C) uint16 tiles through VMEM:

* ``pack_kernel``    — (R, C) u16 → (16, R, C//8) u8 plane stack.  One
  grid step owns a (Br, C) row stripe; all 16 output planes of that
  stripe are produced in-register (the bit-matrix transpose never touches
  HBM, mirroring the paper's "transposition fully overlapped" claim).
* ``unpack_kernel``  — inverse, with *elastic* plane masking + guard-plane
  round-to-nearest-even fused in (Eq. 6/7): unfetched planes are never
  read (their BlockSpec rows are masked out by zeroing — on real TPU the
  fetched-plane subset is sliced by the caller, so HBM→VMEM bytes scale
  with the view; see ops.elastic_unpack).

Block shapes: C is kept whole per grid step (plane bytes stay contiguous
along the minor axis — lane-dim friendly, multiples of 128 bytes when
C ≥ 1024); Br rows per step bound VMEM: Br·C·2 B in + 16·Br·C/8 B out =
4·Br·C bytes ≈ 1 MiB at the default (64, 4096).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.bitplane import BF16_BITS

DEFAULT_BLOCK_R = 64


def _pack_kernel(x_ref, out_ref):
    """x: (Br, C) u16 → out: (16, Br, C//8) u8."""
    x = x_ref[...].astype(jnp.int32)
    br, c = x.shape
    # bit i of every element, for all 16 planes: (16, Br, C)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (BF16_BITS, 1, 1), 0)
    bits = (x[None] >> shifts) & 1
    # pack groups of 8 along C, MSB-first: weights 128..1
    grouped = bits.reshape(BF16_BITS, br, c // 8, 8)
    w = (128 >> jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 8), 3))
    out_ref[...] = jnp.sum(grouped * w, axis=-1).astype(jnp.uint8)


def _unpack_kernel(planes_ref, out_ref, *, keep_mask: int, cut: int,
                   do_round: bool):
    """planes: (16, Br, C//8) u8 → out: (Br, C) u16, masked + rounded.

    ``keep_mask``/``cut``/``do_round`` are compile-time view constants —
    the alias decides the planes, never per-element values (paper §III-C).
    """
    p = planes_ref[...].astype(jnp.int32)
    nb, br, c8 = p.shape
    # unpack bytes → bits along the minor axis (MSB-first)
    shifts_in = 7 - jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 8), 3)
    bits = (p[..., None] >> shifts_in) & 1        # (16, Br, C//8, 8)
    bits = bits.reshape(nb, br, c8 * 8)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (BF16_BITS, 1, 1), 0)
    u = jnp.sum(bits << shifts, axis=0)           # (Br, C) int32 patterns

    if do_round:
        sign = u & 0x8000
        mag = u & 0x7FFF
        is_special = (u & 0x7F80) == 0x7F80
        half = 1 << (cut - 1)
        gmask = (1 << cut) - 1
        guard = mag & gmask
        lsb = (mag >> cut) & 1
        round_up = (guard > half) | ((guard == half) & (lsb == 1))
        mag_r = (mag & ~gmask) + (round_up.astype(jnp.int32) << cut)
        mag_r = jnp.minimum(mag_r, 0x7F80)
        special_out = u & keep_mask
        man = u & 0x7F
        nan_lost = is_special & (man != 0) & ((special_out & 0x7F) == 0)
        special_out = jnp.where(nan_lost, special_out | 0x40, special_out)
        u = jnp.where(is_special, special_out, sign | mag_r)
    out_ref[...] = (u & keep_mask).astype(jnp.uint16)


def _accel_backend() -> str:
    try:
        return jax.default_backend()
    except RuntimeError:  # pragma: no cover - no runtime available
        return "cpu"


def pack_planes_slab(flat_u16, force: str | None = None):
    """Pack a flat ``(n,)`` uint16 encode slab to ``(16, n // 8)`` uint8
    planes — the write-side pack primitive of the batched encode pipeline.

    Dispatch: on an accelerator backend (TPU/GPU) the slab is reshaped to
    a 2-D tile and packed by :func:`pack_planes_pallas` (compiled; the
    bit-matrix transpose never leaves VMEM); anywhere else the numpy
    :func:`~repro.core.bitplane.pack_planes` path runs.  Both produce the
    same bytes — plane streams are element-order packed, so a row-major
    ``(R, C)`` reshape concatenates back to the flat stream exactly.

    ``force``: ``"numpy"`` pins the fallback; ``"pallas"`` pins the kernel
    (interpret mode off-accelerator — used by the equivalence tests).
    """
    from ..core.bitplane import pack_planes

    flat = np.asarray(flat_u16, dtype=np.uint16).ravel()
    n = flat.size
    if n % 8:
        raise ValueError(f"slab length {n} not a multiple of 8")
    backend = _accel_backend()
    use_pallas = (force == "pallas"
                  or (force is None and backend in ("tpu", "gpu")))
    if not use_pallas or n == 0:
        return pack_planes(flat)
    # factor n into (R, C) with C % 8 == 0; fall back if n is too ragged
    for C in (4096, 2048, 1024, 512, 256, 128, 64, 32, 16, 8):
        if n % C == 0:
            break
    else:
        return pack_planes(flat)
    R = n // C
    for br in (DEFAULT_BLOCK_R, 32, 16, 8, 4, 2, 1):
        if R % br == 0:
            break
    planes = pack_planes_pallas(
        jnp.asarray(flat.reshape(R, C)), block_r=br,
        interpret=backend not in ("tpu", "gpu"),
    )
    return np.asarray(planes).reshape(BF16_BITS, n // 8)


def pack_planes_pallas(x_u16: jnp.ndarray, block_r: int = DEFAULT_BLOCK_R,
                       interpret: bool = True) -> jnp.ndarray:
    """(R, C) uint16 → (16, R, C//8) uint8 (C % 8 == 0, R % block_r == 0)."""
    R, C = x_u16.shape
    br = min(block_r, R)
    assert R % br == 0 and C % 8 == 0
    return pl.pallas_call(
        _pack_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BF16_BITS, br, C // 8), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BF16_BITS, R, C // 8), jnp.uint8),
        interpret=interpret,
    )(x_u16)


def unpack_planes_pallas(planes: jnp.ndarray, *, r_e: int = 8, r_m: int = 7,
                         d_m: int = 0, block_r: int = DEFAULT_BLOCK_R,
                         interpret: bool = True) -> jnp.ndarray:
    """(16, R, C//8) uint8 → (R, C) uint16 at view (r_e, r_m, d_m).

    The full plane stack is accepted; unfetched planes are zeroed before
    the call by ops.elastic_unpack (bytes-scaling happens there — the
    kernel itself is the fused reconstruct).
    """
    _, R, C8 = planes.shape
    br = min(block_r, R)
    assert R % br == 0
    keep = (
        0x8000
        | (((1 << r_e) - 1) << (15 - r_e))
        | (((1 << r_m) - 1) << (7 - r_m))
    )
    cut = 7 - r_m
    do_round = bool(d_m > 0 and r_e == 8 and (r_m, d_m) != (7, 0) and cut > 0)
    kern = functools.partial(
        _unpack_kernel, keep_mask=keep, cut=max(cut, 1), do_round=do_round
    )
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((BF16_BITS, br, C8), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((br, C8 * 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C8 * 8), jnp.uint16),
        interpret=interpret,
    )(planes)
