"""Pallas TPU kernel: cross-token KV transform (Mechanism I, Fig. 8).

Fuses the paper's Step 1+2 — token-major → channel-major transposition and
per-channel exponent-delta (zigzag) — in one VMEM pass, so the staged KV
window never round-trips to HBM between steps.  The inverse kernel restores
token-major BF16 containers on the read path (part of T⁻¹∘R of Eq. 7).

Tiling: one grid step owns a (n, Cb) token-window × channel-block tile and
writes the (Cb, n) transposed tile.  ``n`` is the KV staging window (64-256
tokens, Eq. 4 sizes the SRAM analogue) and fits VMEM alongside the channel
block: 2·n·Cb·2 B ≈ 256 KiB at (256, 128).  The transpose happens in
registers/VMEM (the paper's SRAM staging buffer).

beta (per-channel base exponent) is a separate (C,) input computed by the
host/stats pass — the modal exponent needs a histogram, which is cheap on
the write path and constant-size metadata (§III-D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_C = 128


def _fwd_kernel(x_ref, beta_ref, out_ref):
    """x: (n, Cb) u16 token-major; beta: (Cb,) i32 → out: (Cb, n) u16."""
    x = x_ref[...].astype(jnp.int32)
    beta = beta_ref[...].astype(jnp.int32)
    cm = x.T                                     # (Cb, n) — in-VMEM transpose
    exp = (cm & 0x7F80) >> 7
    d = jnp.remainder(exp - beta[:, None], 256)
    s = jnp.where(d >= 128, d - 256, d)
    z = jnp.where(s >= 0, 2 * s, -2 * s - 1)
    out_ref[...] = ((cm & 0x807F) | (z << 7)).astype(jnp.uint16)


def _inv_kernel(cm_ref, beta_ref, out_ref):
    """cm: (Cb, n) u16 transformed; beta: (Cb,) i32 → out: (n, Cb) u16."""
    cm = cm_ref[...].astype(jnp.int32)
    beta = beta_ref[...].astype(jnp.int32)
    z = (cm & 0x7F80) >> 7
    s = jnp.where(z % 2 == 0, z // 2, -(z + 1) // 2)
    exp = jnp.remainder(s + beta[:, None], 256)
    out = (cm & 0x807F) | (exp << 7)
    out_ref[...] = out.T.astype(jnp.uint16)


def kv_delta_pallas(block_u16: jnp.ndarray, beta: jnp.ndarray,
                    block_c: int = DEFAULT_BLOCK_C,
                    interpret: bool = True) -> jnp.ndarray:
    """(n, C) u16 + (C,) u8/i32 beta → (C, n) u16 transformed."""
    n, C = block_u16.shape
    bc = min(block_c, C)
    assert C % bc == 0
    return pl.pallas_call(
        _fwd_kernel,
        grid=(C // bc,),
        in_specs=[
            pl.BlockSpec((n, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((bc, n), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((C, n), jnp.uint16),
        interpret=interpret,
    )(block_u16, beta.astype(jnp.int32))


def kv_delta_inv_pallas(cm_u16: jnp.ndarray, beta: jnp.ndarray,
                        block_c: int = DEFAULT_BLOCK_C,
                        interpret: bool = True) -> jnp.ndarray:
    """(C, n) u16 transformed + (C,) beta → (n, C) u16 token-major."""
    C, n = cm_u16.shape
    bc = min(block_c, C)
    assert C % bc == 0
    return pl.pallas_call(
        _inv_kernel,
        grid=(C // bc,),
        in_specs=[
            pl.BlockSpec((bc, n), lambda j: (j, 0)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((n, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, C), jnp.uint16),
        interpret=interpret,
    )(cm_u16, beta.astype(jnp.int32))
