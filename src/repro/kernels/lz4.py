"""Device-resident LZ4 match kernel (paper §IV-E's 32-lane engine).

The encoder's hot path — match-table build, previous-occurrence
resolution, LCP extension and greedy selection — restated as array
programs over a whole flush group's concatenated (plane, block) streams,
so the scalar python match loop the codec shipped with is no longer on
the write path.  Four passes:

1. **prep** — 4-byte little-endian words, multiplicative hashes and
   byte-run boundaries for every position.  On accelerator backends this
   is a pallas kernel (`_prep_kernel`, elementwise over shifted views of
   the slab — the packed planes never leave the device for it);
   elsewhere one vectorized numpy pass.
2. **previous occurrence** — the per-stream last-occurrence hash table,
   for all positions at once: one stable sort of stream-namespaced hash
   keys, then same-key adjacency.  Candidates can never cross a stream
   boundary, exactly like the reference scan's per-block table.
3. **candidate filter** — window / end-of-block / run-stride rules as
   boolean masks (the reference rules in ``codec._lz4_events_scalar``).
4. **greedy select** — every stream keeps a cursor; one round advances
   ALL live streams by their next selected match (LCP resolved lazily:
   run-boundary table for offset-1 byte runs, word-gallop otherwise —
   selected matches never overlap, so total extension work is bounded by
   the slab).  Rounds are vectorized across streams; the loop runs
   max-matches-per-stream times, not once per candidate.

The result is a compact ``(pos, dist, mlen)`` event tensor — selected
matches in stream order.  Only the final byte-level token serialization
(``codec.lz4_emit_events``) stays host-side.

Dispatch mirrors ``bitplane.pack_planes_slab``: the device path (pallas
prep + jnp passes under one jit) runs on TPU/GPU backends or under
``force="device"`` (interpret-mode pallas off-accelerator — the
equivalence tests); the numpy path runs anywhere and is the CPU
production encoder.  Both are byte-identical to the scalar reference —
``codec.lz4_compress_batch`` differential-tests them against
``TRACE_SCALAR_LZ4=1``.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

# LZ4 block-format constants + repo match-policy knobs.  core.codec's
# scalar reference mirrors these (asserted at dispatch time there): a
# drift would silently break kernel-vs-oracle byte identity.
HASH_LOG = 13
HASH_SIZE = 1 << HASH_LOG
MIN_MATCH = 4
MFLIMIT = 12          # a match must not start within the last 12 bytes
LAST_LITERALS = 5     # the last 5 bytes of a stream are always literals
RUN_STRIDE = 4        # interior byte-run positions keep a candidate only
                      # every RUN_STRIDE bytes (re-anchor bound)

_EMPTY = (np.empty(0, np.int64),) * 3


def _accel_backend() -> str:
    try:
        import jax

        return jax.default_backend()
    except (ImportError, RuntimeError):  # pragma: no cover - no runtime
        return "cpu"


def match_events_slab(slab, starts, ends,
                      force: str | None = None
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy LZ4 match events for every stream of a concatenated slab.

    ``slab`` is a flat uint8 buffer (numpy, or a device array on the
    accelerator path — e.g. the ravelled output of ``pack_planes_slab``);
    ``starts``/``ends`` bound each stream's half-open byte range, disjoint
    and ascending (gaps — bypassed streams — are allowed and never
    touched).  Returns ``(pos, dist, mlen)`` int64 arrays sorted by
    global position: the matches a per-stream scalar
    ``codec._lz4_events_scalar`` scan would select, bit for bit.

    ``force``: ``"numpy"`` pins the vectorized-numpy fallback,
    ``"device"`` pins the pallas+jnp path (interpret mode off
    accelerator); default dispatches on the jax backend.
    """
    starts = np.asarray(starts, dtype=np.int64).ravel()
    ends = np.asarray(ends, dtype=np.int64).ravel()
    if starts.size == 0:
        return _EMPTY
    backend = _accel_backend()
    use_device = (force == "device"
                  or (force is None and backend in ("tpu", "gpu")))
    if use_device:
        return _match_events_device(
            slab, starts, ends, interpret=backend not in ("tpu", "gpu"))
    buf = np.asarray(slab, dtype=np.uint8).ravel()
    return _match_events_numpy(buf, starts, ends)


# ---------------------------------------------------------------------------
# vectorized-numpy path (CPU production encoder)
# ---------------------------------------------------------------------------

def _words_hashes(buf: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """4-byte LE words + hashes for positions 0..N-4.

    Built in place (one uint32 accumulator, no shift temporaries); the
    hash fits HASH_LOG ≤ 16 bits so it is returned as uint16, which is
    what lets the table sort use numpy's radix path downstream."""
    w = buf[3:].astype(np.uint32)
    np.left_shift(w, np.uint32(8), out=w)
    np.bitwise_or(w, buf[2:-1], out=w)
    np.left_shift(w, np.uint32(8), out=w)
    np.bitwise_or(w, buf[1:-2], out=w)
    np.left_shift(w, np.uint32(8), out=w)
    np.bitwise_or(w, buf[:-3], out=w)
    h = w * np.uint32(2654435761)
    np.right_shift(h, np.uint32(32 - HASH_LOG), out=h)
    return w, h.astype(np.uint16)


def _stream_ids(n_pos: int, starts: np.ndarray,
                ends: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Dense (sid, covered) maps for positions 0..n_pos-1 — O(N), no
    per-position searchsorted.  ``sid`` is meaningful only where
    ``covered``."""
    s = starts[starts < n_pos]
    e = np.minimum(ends, n_pos)
    marks = np.zeros(n_pos + 1, dtype=np.int64)
    np.add.at(marks, s, 1)
    sid = np.cumsum(marks[:-1]) - 1
    cover = np.zeros(n_pos + 1, dtype=np.int64)
    np.add.at(cover, s, 1)
    np.subtract.at(cover, e, 1)
    covered = np.cumsum(cover[:-1]) > 0
    return sid, covered


_SWEEP_CAP = 32        # capped-LCP sweep bound for offsets > 1; NOT an
                       # output cap — selected matches that hit it are
                       # galloped to the true LCP during selection
_GALLOP_EAGER = 128    # ≤ this many sweep-capped candidates → gallop
                       # them all up front so selection runs flag-free;
                       # above it (long periodic data) gallop lazily on
                       # selection only, keeping worst-case work bounded


def _match_events_numpy(buf: np.ndarray, starts: np.ndarray,
                        ends: np.ndarray):
    """The numpy match path — the CPU production encoder.

    The expensive part of the hash table is the sort; this path
    *run-collapses* it first: a position whose word equals its left
    neighbour's word sits inside a byte run, so its previous occurrence
    is trivially ``pos - 1`` (same word, one back) and never needs the
    table.  Interior run positions are also redundant as table SOURCES —
    any later lookup that would land on one resolves to the run's last
    word-position instead — so only run-last and non-run positions enter
    the sort.  The run side itself never materializes per-position
    state: maximal byte runs are intersected with streams into
    *segments*, and the kept stride candidates (plus their exact match
    lengths, read off the run end) are generated segment-wise by ragged
    arithmetic.  On zero-heavy bitplane slabs that collapses most of the
    slab out of every O(n)-per-position stage; the remaining passes
    (lookup filter, capped-sweep LCP, pointer-jump greedy rounds) are
    O(candidates) each.
    """
    N = int(buf.size)
    if N < MIN_MATCH:
        return _EMPTY
    w, h = _words_hashes(buf)
    S = int(starts.size)
    sizes = ends - starts
    # word-valid positions per stream, contiguous per stream in j-domain
    cnt = np.maximum(sizes - 3, 0)
    ccum = np.cumsum(cnt)
    W = int(ccum[-1])
    if W == 0:
        return _EMPTY
    cbase = ccum - cnt
    sid_dt = np.uint16 if S <= 0xFFFF else np.int64
    sid_w = np.repeat(np.arange(S, dtype=sid_dt), cnt)
    # j-domain index ``j`` maps to position ``j + adj[sid]`` — kept as a
    # per-stream adjustment so no W-sized position array is ever built
    adj = (starts - cbase).astype(np.int32)

    # --- byte-run segmentation ----------------------------------------
    # maximal equal-byte runs [a, b]; a run holds word-run positions
    # (word == left neighbour's word) at a+1..b-3, so only runs of
    # length ≥ 5 matter.  Intersecting those with the streams gives
    # segments [lo, hi] of run positions — everything the run side
    # needs (table exclusion, stride candidates, match lengths) is
    # derived per segment, never per position.
    bnd = np.flatnonzero(buf[1:] != buf[:-1])    # last index of each run
    if bnd.size:
        # interior long runs: gap ≥ 5 between consecutive boundaries;
        # the first and last runs are handled as explicit edge cases
        li = np.flatnonzero(np.diff(bnd) >= MIN_MATCH + 1)
        ra = bnd[li] + 1
        rb = bnd[li + 1]
        if int(bnd[0]) >= MIN_MATCH:                # run before first bnd
            ra = np.concatenate(([0], ra))
            rb = np.concatenate(([bnd[0]], rb))
        if N - 1 - int(bnd[-1]) >= MIN_MATCH + 1:   # run after last bnd
            ra = np.concatenate((ra, [bnd[-1] + 1]))
            rb = np.concatenate((rb, [N - 1]))
    elif N >= MIN_MATCH + 1:                        # whole buf one run
        ra = np.asarray([0], dtype=np.int64)
        rb = np.asarray([N - 1], dtype=np.int64)
    else:
        ra = rb = np.empty(0, dtype=np.int64)
    if ra.size:
        s0 = np.minimum(np.searchsorted(ends, ra + 1, side="right"), S - 1)
        s1 = np.minimum(np.searchsorted(ends, rb - 3, side="right"), S - 1)
        nspan = s1 - s0 + 1
        segc = np.cumsum(nspan)
        nseg0 = int(segc[-1])
        segrun = np.repeat(np.arange(ra.size, dtype=np.int64), nspan)
        segsid = (np.arange(nseg0, dtype=np.int64)
                  - np.repeat(segc - nspan - s0, nspan))
        lo = np.maximum(ra[segrun] + 1, starts[segsid] + 1)
        hi = np.minimum(rb[segrun] - 3, ends[segsid] - 4)
        keep = lo <= hi
        segrun, segsid = segrun[keep], segsid[keep]
        lo, hi = lo[keep], hi[keep]
    else:
        segrun = segsid = lo = hi = np.empty(0, dtype=np.int64)

    # --- hash-table sort over the run-collapsed subset -----------------
    # run-interior positions ([lo, hi-1] per segment) leave the table;
    # run-LAST positions (hi) stay as sources but never look up
    jlo = cbase[segsid] + (lo - starts[segsid])
    jhi = jlo + (hi - lo)
    # subset = complement of the excluded [jlo, jhi-1] ranges, built
    # directly as ragged keep-ranges (segments are disjoint ascending in
    # j, with ≥ 2 positions between consecutive excluded ranges)
    klo = np.concatenate(([0], jhi))
    khi = np.concatenate((jlo, [W]))
    klen = khi - klo
    kcum = np.cumsum(klen)
    subset = (np.arange(int(kcum[-1]), dtype=np.int32)
              + np.repeat((klo - (kcum - klen)).astype(np.int32), klen))
    # run-last flags in the SUBSET domain (every jhi survives the cut,
    # so its subset index is exact)
    irl_sub = np.zeros(subset.size, dtype=bool)
    irl_sub[np.searchsorted(subset, jhi.astype(np.int32))] = True
    ssub = sid_w[subset]
    psub = subset + adj[ssub]
    hsub = h[psub]
    if S <= 0xFFFF:
        # ONE stable uint16 radix pass on the WRAPPED key: numpy only
        # radix-sorts ≤ 16-bit ints, and a full lexicographic sort isn't
        # needed — the subset is already sid-ascending, so groups whose
        # keys alias mod 2^16 (sids differing by a multiple of
        # 2^(16-HASH_LOG)) land concatenated in j order, never
        # interleaved, and the `same` test below cuts the seam between
        # them.  Adjacency is exact on (key16, sid): with equal sids,
        # equal wrapped keys force equal hashes (hash < 2^HASH_LOG) — no
        # widened key is ever materialized
        key16 = ((ssub.astype(np.uint16) << np.uint16(HASH_LOG))
                 + hsub)
        order = np.argsort(key16, kind="stable")
        k16o = key16[order]
        so = ssub[order]
        same = (k16o[1:] == k16o[:-1]) & (so[1:] == so[:-1])
    else:  # pragma: no cover - >65535 streams per flush group
        skeys = (ssub.astype(np.int64) << np.int64(HASH_LOG)) | hsub
        order = np.argsort(skeys, kind="stable")
        ks = skeys[order]
        same = ks[1:] == ks[:-1]
    # lookups: later element of a same-key pair, unless it is a run
    # position (their prev is pos-1, handled without the table).
    # prev_sub stores SUBSET indices, so position resolution is a psub
    # gather, never a W-sized one
    cand_idx = np.flatnonzero(same & ~irl_sub[order[1:]])
    prev_sub = np.full(subset.size, -1, dtype=np.int32)
    prev_sub[order[cand_idx + 1]] = order[cand_idx]

    # --- general candidates: window + word + end-of-stream rules -------
    gsel = np.flatnonzero(prev_sub >= 0)     # ascending j → ascending pos
    pj = psub[gsel]
    cj = psub[prev_sub[gsel]]
    okg = (pj - cj <= 0xFFFF) & (w[pj] == w[cj])
    pj, cj = pj[okg], cj[okg]
    sid_g = ssub[gsel[okg]]
    # a collision-induced dist-1 pair of unequal words is gone already
    # (word equality); true dist-1 equal-word pairs are run positions and
    # never reach the lookup set, so no run-stride test is needed here
    okg = pj < ends[sid_g] - MFLIMIT         # local < size - MFLIMIT
    pj, cj, sid_g = pj[okg], cj[okg], sid_g[okg]

    # --- run candidates + exact match lengths, straight off segments ---
    # kept positions per segment: the first run position ``lo`` (always
    # special: either local < 2 or the first interior of its byte run),
    # plus every RUN_STRIDE-aligned local.  Match length is read off the
    # byte-run end — no LCP pass for offset-1 matches.
    if lo.size:
        ends_seg = ends[segsid]
        hi2 = np.minimum(hi, ends_seg - (MFLIMIT + 1))
        f0 = lo + ((starts[segsid] - lo) % RUN_STRIDE)
        has = lo <= hi2
        nstr = np.where(has & (f0 <= hi2),
                        (hi2 - f0) // RUN_STRIDE + 1, 0)
        extra = (has & (f0 != lo)).astype(np.int64)
        tc = nstr + extra
        tcum = np.cumsum(tc)
        segi = np.repeat(np.arange(tc.size, dtype=np.int64), tc)
        within = (np.arange(int(tcum[-1]), dtype=np.int64)
                  - np.repeat(tcum - tc, tc))
        ex_i = extra[segi]
        pos_r = np.where(ex_i > within, lo[segi],
                         f0[segi] + RUN_STRIDE * (within - ex_i))
        sid_r = segsid[segi]
        mlen_r = np.minimum(rb[segrun][segi] + 1 - pos_r,
                            ends_seg[segi] - LAST_LITERALS - pos_r)
    else:
        pos_r = sid_r = mlen_r = np.empty(0, dtype=np.int64)

    if pos_r.size == 0 and pj.size == 0:
        return _EMPTY

    # --- LCP for general candidates: capped word sweep -----------------
    cap_full = ends[sid_g] - LAST_LITERALS - pj
    cap_g = np.minimum(cap_full, _SWEEP_CAP)
    mlen_g = np.full(pj.size, MIN_MATCH, dtype=np.int64)
    alive = np.arange(pj.size)
    k = MIN_MATCH
    while alive.size:
        word_ok = cap_g[alive] >= k + 4
        alive = alive[word_ok]
        if alive.size == 0:
            break
        eqw = w[pj[alive] + k] == w[cj[alive] + k]
        fail = alive[~eqw]
        if fail.size:
            b0 = (buf[pj[fail] + k] == buf[cj[fail] + k]).astype(np.int64)
            b1 = b0 & (buf[pj[fail] + k + 1] == buf[cj[fail] + k + 1])
            b2 = b1 & (buf[pj[fail] + k + 2] == buf[cj[fail] + k + 2])
            mlen_g[fail] = k + b0 + b1 + b2
        alive = alive[eqw]
        k += 4
        mlen_g[alive] = k
    arr = np.flatnonzero(mlen_g < cap_g)
    for _ in range(3):      # ≤3-byte exact tail (word room ran out)
        if arr.size == 0:
            break
        eq = buf[pj[arr] + mlen_g[arr]] == buf[cj[arr] + mlen_g[arr]]
        arr = arr[eq]
        mlen_g[arr] += 1
        arr = arr[mlen_g[arr] < cap_g[arr]]
    # sweep-capped candidates carry their TRUE LCP lazily: flagged, and
    # galloped out only if the greedy walk actually selects them
    flag_g = (mlen_g == _SWEEP_CAP) & (cap_full > _SWEEP_CAP)

    # --- merge run + general candidates in position order --------------
    C = int(pos_r.size + pj.size)
    pos_c = np.empty(C, dtype=np.int64)
    dist_c = np.empty(C, dtype=np.int64)
    mlen_c = np.empty(C, dtype=np.int64)
    flag_c = np.zeros(C + 1, dtype=bool)
    cap_c = np.empty(C, dtype=np.int64)
    # merge ranks: binary-search only the SMALLER side into the larger
    # (positions are disjoint across the two sides), then read the other
    # side's slots off the boolean complement — one searchsorted, not two
    if pj.size <= pos_r.size:
        at_g = np.arange(pj.size) + np.searchsorted(pos_r, pj)
        other = np.ones(C, dtype=bool)
        other[at_g] = False
        at_r = np.flatnonzero(other)
    else:
        at_r = np.arange(pos_r.size) + np.searchsorted(pj, pos_r)
        other = np.ones(C, dtype=bool)
        other[at_r] = False
        at_g = np.flatnonzero(other)
    pos_c[at_r] = pos_r
    pos_c[at_g] = pj
    dist_c[at_r] = 1
    dist_c[at_g] = pj - cj
    mlen_c[at_r] = mlen_r
    mlen_c[at_g] = mlen_g
    flag_c[at_g] = flag_g
    cap_c[at_g] = cap_full    # only flagged (general) slots are read
    # streams are contiguous ascending byte ranges, so the pos-sorted
    # candidate array groups by stream — per-stream bounds via bincount
    scnt = (np.bincount(sid_r, minlength=S)
            + np.bincount(sid_g, minlength=S))
    b_hi = np.cumsum(scnt)
    b_lo = b_hi - scnt
    bhi_c = np.repeat(b_hi, scnt)     # owning stream's bound, per slot

    # next-candidate resolution as a dense rank map: cs[q] = #candidates
    # with pos < q ≡ searchsorted(pos_c, q, "left").  pos_c is strictly
    # increasing, so the map is a step function materialized by ONE
    # ragged repeat of the inter-candidate widths — cheaper than a
    # bincount+cumsum and far cheaper than per-query binary search, here
    # and in the gallop paths below
    widths = np.diff(np.concatenate(([-1], pos_c, [N])))
    cs = np.repeat(np.arange(C + 1, dtype=np.int64), widths)
    nxt_c = cs[pos_c + mlen_c]
    fl = np.flatnonzero(flag_c[:C])
    if 0 < fl.size <= _GALLOP_EAGER:
        # few sweep-capped candidates: gallop them ALL to the true LCP
        # up front so selection runs flag-free.  Extending a node that
        # is never selected is harmless — match length only matters on
        # the selected path — so eager == lazy semantically.
        bb = buf.tobytes()
        for node in fl:
            node = int(node)
            p = int(pos_c[node])
            c = p - int(dist_c[node])
            m = int(mlen_c[node])
            mx = int(cap_c[node])
            while (m + 32 <= mx
                   and bb[c + m : c + m + 32] == bb[p + m : p + m + 32]):
                m += 32
            while m < mx and bb[c + m] == bb[p + m]:
                m += 1
            mlen_c[node] = m
        nxt_c[fl] = cs[pos_c[fl] + mlen_c[fl]]
        flag_c[:] = False

    # --- greedy selection: pointer-jump rounds across all streams ------
    # every live stream holds a cursor into the pos-sorted candidate
    # array; one round selects the cursor's match everywhere at once and
    # jumps past it.  Rounds run max-matches-per-stream times with ~2
    # small array ops each — no per-candidate python.
    # next-pointer per candidate: first candidate at or after the match
    # end, dead-ended (sentinel C) at the owning stream's boundary — so a
    # selection round is ONE gather, not a searchsorted
    nxt_ext = np.append(np.where(nxt_c < bhi_c, nxt_c, C), C)
    cur = np.where(b_lo < b_hi, b_lo, C)
    rounds = []
    if not flag_c.any():
        # flag-free: tight loop, liveness checked every 8 rounds
        # (overshoot rows are all-sentinel and filter out)
        live = True
        while live:
            for _ in range(8):
                rounds.append(cur)
                cur = nxt_ext[cur]
            live = bool((cur < C).any())
    else:  # lazy fallback: many capped candidates (long periodic data)
        bb = None
        while (cur < C).any():
            if flag_c[cur].any():
                # selected a sweep-capped match: gallop to the true LCP
                # now (selected matches never overlap → total work is
                # bounded) and repoint its next-jump past the full match
                if bb is None:
                    bb = buf.tobytes()
                for ci in np.flatnonzero(flag_c[cur]):
                    node = int(cur[ci])
                    p = int(pos_c[node])
                    c = p - int(dist_c[node])
                    m = int(mlen_c[node])
                    mx = int(cap_c[node])
                    while (m + 32 <= mx
                           and bb[c + m : c + m + 32]
                           == bb[p + m : p + m + 32]):
                        m += 32
                    while m < mx and bb[c + m] == bb[p + m]:
                        m += 1
                    mlen_c[node] = m
                    flag_c[node] = False
                    nj = int(cs[p + m])
                    nxt_ext[node] = nj if nj < bhi_c[node] else C
            rounds.append(cur)
            cur = nxt_ext[cur]
    if not rounds:
        return _EMPTY
    # column-major flatten: ascending within each stream, streams in
    # ascending byte order → globally ascending positions, no final sort
    sel = np.stack(rounds).ravel(order="F")
    sel = sel[sel < C]
    return pos_c[sel], dist_c[sel], mlen_c[sel]


# ---------------------------------------------------------------------------
# device path: pallas prep kernel + jnp passes under one jit
# ---------------------------------------------------------------------------

_PREP_BLOCK = 256     # rows per grid step; 128-byte minor axis (lane dim)
_PREP_C = 128


def _prep_kernel(b0_ref, b1_ref, b2_ref, b3_ref, w_ref, h_ref, run_ref):
    """Elementwise prep over shifted slab views: 4-byte LE word, hash,
    and run-boundary flag per position.  Pure array ops — the R6 lint
    holds this body host-sync-free."""
    import jax.numpy as jnp

    b0 = b0_ref[...].astype(jnp.uint32)
    b1 = b1_ref[...].astype(jnp.uint32)
    b2 = b2_ref[...].astype(jnp.uint32)
    b3 = b3_ref[...].astype(jnp.uint32)
    w = b0 | (b1 << 8) | (b2 << 16) | (b3 << 24)
    w_ref[...] = w
    h_ref[...] = ((w * jnp.uint32(2654435761))
                  >> jnp.uint32(32 - HASH_LOG)).astype(jnp.int32)
    run_ref[...] = (b0 != b1).astype(jnp.int32)


def _prep_pallas(buf, interpret: bool):
    """(N,) uint8 device slab → (w, h, runb) arrays of length N (tail
    entries are garbage the downstream masks never read)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    n = buf.shape[0]
    tile = _PREP_BLOCK * _PREP_C
    pad = (-n - 3) % tile + 3          # room for the +3 shifted views
    bp = jnp.pad(buf, (0, pad))
    rows = (n + pad - 3) // _PREP_C
    shifted = [bp[i : i + rows * _PREP_C].reshape(rows, _PREP_C)
               for i in range(4)]
    br = min(_PREP_BLOCK, rows)
    grid = (rows // br,)
    spec = pl.BlockSpec((br, _PREP_C), lambda i: (i, 0))
    w, h, runb = pl.pallas_call(
        _prep_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((rows, _PREP_C), jnp.uint32),
            jax.ShapeDtypeStruct((rows, _PREP_C), jnp.int32),
            jax.ShapeDtypeStruct((rows, _PREP_C), jnp.int32),
        ],
        interpret=interpret,
    )(*shifted)
    return (w.reshape(-1)[:n], h.reshape(-1)[:n], runb.reshape(-1)[:n])


def _match_events_device(slab, starts, ends, interpret: bool):
    """Pallas prep + jnp match pipeline in one device launch; only the
    compacted event tensor returns to the host."""
    import jax.numpy as jnp

    buf_np = None
    if isinstance(slab, np.ndarray):
        buf_np = slab.astype(np.uint8, copy=False).ravel()
        N = int(buf_np.size)
    else:
        N = int(np.prod(slab.shape))
    if N < MIN_MATCH:
        return _EMPTY
    # static geometry → dense masks (host-computed constants, passed as
    # device operands so the jitted pipeline stays pure array code)
    npos = N - 3
    sid, covered = _stream_ids(npos, starts, ends)
    valid = covered & (np.arange(npos) + MIN_MATCH <= ends[sid])
    local = np.arange(npos) - starts[np.minimum(sid, starts.size - 1)]
    nb = (ends - starts)[np.minimum(sid, starts.size - 1)]
    start_ok = valid & (local < nb - MFLIMIT)
    stride_ok = (local >= 2) & (local % RUN_STRIDE != 0)
    # per-stream event bound: matches never overlap and are ≥ MIN_MATCH
    sizes = ends - starts
    row_start = np.concatenate(
        ([0], np.cumsum(sizes // MIN_MATCH + 1)))
    E = int(row_start[-1])
    S = starts.size

    dev = jnp.asarray(slab, dtype=jnp.uint8).reshape(-1)
    pos, dist, mlen, count = _device_match(
        dev, jnp.asarray(sid), jnp.asarray(valid), jnp.asarray(start_ok),
        jnp.asarray(stride_ok), jnp.asarray(starts), jnp.asarray(ends),
        jnp.asarray(row_start[:-1]), E, interpret)
    pos = np.asarray(pos)
    dist = np.asarray(dist)
    mlen = np.asarray(mlen)
    count = np.asarray(count)
    keep = np.concatenate([
        np.arange(row_start[s], row_start[s] + count[s]) for s in range(S)
    ]) if S else np.empty(0, np.int64)
    pos, dist, mlen = (pos[keep].astype(np.int64),
                       dist[keep].astype(np.int64),
                       mlen[keep].astype(np.int64))
    order = np.argsort(pos, kind="stable")
    return pos[order], dist[order], mlen[order]


def _device_match(buf, sid, valid, start_ok, stride_ok, starts, ends,
                  row_start, E: int, interpret: bool):
    import jax

    fn = jax.jit(_device_match_impl,
                 static_argnames=("E", "interpret"))
    return fn(buf, sid, valid, start_ok, stride_ok, starts, ends,
              row_start, E=E, interpret=interpret)


def _device_match_impl(buf, sid, valid, start_ok, stride_ok, starts, ends,
                       row_start, *, E: int, interpret: bool):
    import jax
    import jax.numpy as jnp
    from jax import lax

    N = buf.shape[0]
    npos = N - 3
    S = starts.shape[0]
    w, h, runb = _prep_pallas(buf, interpret)
    w, h = w[:npos], h[:npos]
    iota = jnp.arange(npos, dtype=jnp.int32)
    BIG = jnp.int32(S) * HASH_SIZE + HASH_SIZE
    keys = jnp.where(valid, sid.astype(jnp.int32) * HASH_SIZE
                     + h.astype(jnp.int32), BIG)
    # stable sort of (key, position): previous same-key occurrence is the
    # sorted neighbour — the whole hash table in one pass
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    same = (sk[1:] == sk[:-1]) & (sk[1:] < BIG)
    prev = jnp.full(npos, -1, dtype=jnp.int32)
    prev = prev.at[order[1:]].set(jnp.where(same, order[:-1], -1))
    dist = iota - prev
    bufm2 = jnp.concatenate([jnp.zeros(2, buf.dtype), buf[:-2]])[:npos]
    bufm1 = jnp.concatenate([jnp.zeros(1, buf.dtype), buf[:-1]])[:npos]
    ok = (start_ok & (prev >= 0) & (dist <= 0xFFFF)
          & (w == w[jnp.clip(prev, 0, npos - 1)]))
    ok &= ~((dist == 1) & stride_ok & (bufm2 == bufm1))
    # next-candidate-at-or-after + run-end tables: reverse cumulative mins
    ncand = lax.cummin(jnp.where(ok, iota, npos), reverse=True)
    run_last = lax.cummin(
        jnp.where(runb[: N - 1] > 0, jnp.arange(N - 1, dtype=jnp.int32),
                  N - 1),
        reverse=True)
    run_last = jnp.concatenate([run_last, jnp.full(1, N - 1, jnp.int32)])

    sids = jnp.arange(S, dtype=jnp.int32)
    max_end = ends - LAST_LITERALS

    def cursor_of(p):
        c = ncand[jnp.clip(p, 0, npos - 1)]
        live = (p < npos) & (c < npos) & (sid[jnp.clip(c, 0, npos - 1)]
                                          == sids)
        return jnp.where(live, c, npos), live

    cur0, live0 = cursor_of(starts)

    def lcp_round(p, d, live):
        cap = max_end - p
        c = p - d
        run = d == 1
        m_run = jnp.minimum(run_last[jnp.clip(p, 0, N - 1)] - p + 1, cap)
        m = jnp.full((S,), MIN_MATCH, dtype=jnp.int32)

        def gallop_cond(st):
            m_, adv = st
            return jnp.any(adv)

        def gallop_body(st):
            m_, _ = st
            gi = jnp.clip(p + m_, 0, npos - 1)
            ci = jnp.clip(c + m_, 0, npos - 1)
            adv = live & ~run & (m_ + 4 <= cap) & (w[gi] == w[ci])
            return m_ + 4 * adv, adv

        m, _ = lax.while_loop(gallop_cond, gallop_body,
                              (m, jnp.ones((S,), bool)))
        for _ in range(3):      # exact ≤3-byte tail
            gi = jnp.clip(p + m, 0, N - 1)
            ci = jnp.clip(c + m, 0, N - 1)
            adv = live & ~run & (m < cap) & (buf[gi] == buf[ci])
            m = m + adv
        return jnp.where(run, m_run, jnp.where(live, m, MIN_MATCH))

    def cond(state):
        _, _, _, _, live, _ = state
        return jnp.any(live)

    def body(state):
        cur, count, out, nxt_unused, live, _ = state
        ci = jnp.clip(cur, 0, npos - 1)
        p = iota[ci]
        d = dist[ci]
        m = lcp_round(p, d, live)
        slot = jnp.where(live, row_start + count, E)
        out = (out[0].at[slot].set(jnp.where(live, p, 0), mode="drop"),
               out[1].at[slot].set(jnp.where(live, d, 0), mode="drop"),
               out[2].at[slot].set(jnp.where(live, m, 0), mode="drop"))
        count = count + live
        ncur, nlive = cursor_of(jnp.where(live, p + m, npos))
        nlive &= live
        # a cursor that jumped into another stream's range is dead
        return (ncur, count, out, nxt_unused, nlive, 0)

    out0 = tuple(jnp.zeros(E + 1, jnp.int32) for _ in range(3))
    cur, count, out, _, _, _ = lax.while_loop(
        cond, body, (cur0, jnp.zeros(S, jnp.int32), out0, 0, live0, 0))
    return out[0][:E], out[1][:E], out[2][:E], count
