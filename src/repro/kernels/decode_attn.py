"""Pallas TPU kernel: decode attention over an fp8-stored KV cache.

§Perf cell 2 residual: with ``kv_dtype=float8_e4m3fn`` the XLA path
materializes a bf16 upcast of the WHOLE cache before the attention dots
(≈2× cache bytes of temp on the CPU lowering; an extra HBM round-trip if
unfused on TPU).  This kernel streams fp8 K/V tiles HBM→VMEM, upcasts
in-register, and runs an online-softmax accumulation — HBM traffic is
exactly the fp8 cache bytes, the TPU-side completion of the paper's
"bytes move at stored precision" principle.

Shapes (one decode step, GQA):
    q: (B, H, hd) bf16          — current token's queries
    k: (B, S, KV, hd) fp8/bf16  — cache keys
    v: (B, S, KV, hd) fp8/bf16  — cache values
    valid_len: int              — #valid cache slots (static per call)
    out: (B, H, hd) f32

Grid: (B, S // block_s); each step processes one (batch, key-block):
online max/sum/accumulator carried in VMEM scratch across the S-grid
(standard flash-decoding shape).  hd and KV·hd stay lane-aligned
(multiples of 128 for the assigned archs).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            valid_len: int, block_s: int, groups: int, scale: float):
    si = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (H, hd)
    k = k_ref[0].astype(jnp.float32)              # (block_s, KV, hd)
    v = v_ref[0].astype(jnp.float32)

    H, hd = q.shape
    KV = k.shape[1]
    # GQA: repeat KV heads across groups in-register
    kx = jnp.repeat(k, groups, axis=1)            # (block_s, H, hd)
    vx = jnp.repeat(v, groups, axis=1)

    s = jnp.einsum("hd,thd->ht", q, kx) * scale   # (H, block_s)
    # mask slots beyond valid_len
    pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1
    )
    s = jnp.where(pos < valid_len, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=1)                    # (H,)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])               # (H, block_s)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jnp.einsum("ht,thd->hd", p, vx)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(si == n_s - 1)
    def _finish():
        o_ref[0] = acc_ref[...] / l_ref[...][:, None]


def decode_attention_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, valid_len: int,
    *, block_s: int = 512, interpret: bool = True,
) -> jnp.ndarray:
    """(B,H,hd) × (B,S,KV,hd) fp8/bf16 cache → (B,H,hd) f32, one token."""
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    assert H % KV == 0
    groups = H // KV
    bs = min(block_s, S)
    assert S % bs == 0
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(
        _kernel, valid_len=valid_len, block_s=bs, groups=groups, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=(B, S // bs),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, s: (b, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),        # running max
            pltpu.VMEM((H,), jnp.float32),        # running denom
            pltpu.VMEM((H, hd), jnp.float32),     # running accumulator
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Partial attention sums — the PNM "ship statistics, not pages" algebra
# ---------------------------------------------------------------------------
# A PNM device holding a KV chunk can return the chunk's online-softmax
# statistics (m, l, acc) instead of the pages themselves; the host merges
# any number of such triples into the exact full-context attention output.
# These are the host-side reference halves of that protocol: the same
# (max, denominator, accumulator) carry the pallas kernel above keeps in
# VMEM scratch, exposed as a pure-numpy pair so chunk splits are testable
# against the monolithic kernel.

AttnPartial = Tuple[np.ndarray, np.ndarray, np.ndarray]   # (m, l, acc)

_MASKED = -1e30     # matches the kernel's out-of-range fill (never NaNs)


def attention_partial(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                      valid_len: Optional[int] = None,
                      scale: Optional[float] = None) -> AttnPartial:
    """Online-softmax statistics of ONE KV chunk for one decode step.

    ``q``: (B, H, hd); ``k``/``v``: (B, S, KV, hd) (any dtype castable
    to f32; GQA repeat handled like the kernel).  Returns ``(m, l,
    acc)`` — running max (B, H), denominator (B, H) and unnormalized
    accumulator (B, H, hd) — such that ``acc / l`` is the chunk-local
    attention output and chunks merge EXACTLY via
    :func:`combine_partials`.  ``valid_len`` masks slots past it with
    the kernel's finite fill."""
    q = np.asarray(q, np.float32)
    k = np.asarray(k).astype(np.float32)
    v = np.asarray(v).astype(np.float32)
    B, H, hd = q.shape
    groups = H // k.shape[2]
    kx = np.repeat(k, groups, axis=2)             # (B, S, H, hd)
    vx = np.repeat(v, groups, axis=2)
    s = np.einsum("bhd,bshd->bhs", q, kx) * (
        (1.0 / hd ** 0.5) if scale is None else scale)
    if valid_len is not None:
        pos = np.arange(k.shape[1])
        s = np.where(pos[None, None, :] < valid_len, s, _MASKED)
    m = s.max(axis=-1)                            # (B, H)
    p = np.exp(s - m[..., None])
    l = p.sum(axis=-1)                            # noqa: E741 — flash notation
    acc = np.einsum("bhs,bshd->bhd", p, vx)
    return m, l, acc


def combine_partials(parts: Sequence[AttnPartial]) -> np.ndarray:
    """Merge per-chunk ``(m, l, acc)`` triples into the full-context
    attention output (B, H, hd) f32 — the associative online-softmax
    merge (rescale both sides to the joint max, add).  Splitting a
    context into ANY chunking and combining reproduces the monolithic
    result exactly up to f32 rounding (tested against
    :func:`decode_attention_pallas`)."""
    m, l, acc = parts[0]
    for m2, l2, acc2 in parts[1:]:
        m_new = np.maximum(m, m2)
        c1 = np.exp(m - m_new)
        c2 = np.exp(m2 - m_new)
        l = l * c1 + l2 * c2
        acc = acc * c1[..., None] + acc2 * c2[..., None]
        m = m_new
    return acc / l[..., None]
