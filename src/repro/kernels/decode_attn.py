"""Pallas TPU kernel: decode attention over an fp8-stored KV cache.

§Perf cell 2 residual: with ``kv_dtype=float8_e4m3fn`` the XLA path
materializes a bf16 upcast of the WHOLE cache before the attention dots
(≈2× cache bytes of temp on the CPU lowering; an extra HBM round-trip if
unfused on TPU).  This kernel streams fp8 K/V tiles HBM→VMEM, upcasts
in-register, and runs an online-softmax accumulation — HBM traffic is
exactly the fp8 cache bytes, the TPU-side completion of the paper's
"bytes move at stored precision" principle.

Shapes (one decode step, GQA):
    q: (B, H, hd) bf16          — current token's queries
    k: (B, S, KV, hd) fp8/bf16  — cache keys
    v: (B, S, KV, hd) fp8/bf16  — cache values
    valid_len: int              — #valid cache slots (static per call)
    out: (B, H, hd) f32

Grid: (B, S // block_s); each step processes one (batch, key-block):
online max/sum/accumulator carried in VMEM scratch across the S-grid
(standard flash-decoding shape).  hd and KV·hd stay lane-aligned
(multiples of 128 for the assigned archs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            valid_len: int, block_s: int, groups: int, scale: float):
    si = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (H, hd)
    k = k_ref[0].astype(jnp.float32)              # (block_s, KV, hd)
    v = v_ref[0].astype(jnp.float32)

    H, hd = q.shape
    KV = k.shape[1]
    # GQA: repeat KV heads across groups in-register
    kx = jnp.repeat(k, groups, axis=1)            # (block_s, H, hd)
    vx = jnp.repeat(v, groups, axis=1)

    s = jnp.einsum("hd,thd->ht", q, kx) * scale   # (H, block_s)
    # mask slots beyond valid_len
    pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1
    )
    s = jnp.where(pos < valid_len, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=1)                    # (H,)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])               # (H, block_s)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jnp.einsum("ht,thd->hd", p, vx)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(si == n_s - 1)
    def _finish():
        o_ref[0] = acc_ref[...] / l_ref[...][:, None]


def decode_attention_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, valid_len: int,
    *, block_s: int = 512, interpret: bool = True,
) -> jnp.ndarray:
    """(B,H,hd) × (B,S,KV,hd) fp8/bf16 cache → (B,H,hd) f32, one token."""
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    assert H % KV == 0
    groups = H // KV
    bs = min(block_s, S)
    assert S % bs == 0
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(
        _kernel, valid_len=valid_len, block_s=bs, groups=groups, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=(B, S // bs),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, s: (b, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),        # running max
            pltpu.VMEM((H,), jnp.float32),        # running denom
            pltpu.VMEM((H, hd), jnp.float32),     # running accumulator
        ],
        interpret=interpret,
    )(q, k, v)
