"""Deterministic, sharded, checkpointable data pipeline.

No datasets ship offline, so the source is a synthetic token stream with
LLM-like statistics (Zipfian unigram + a repeated-ngram process so the loss
actually decreases during the example training runs).  What matters for the
framework is the *contract*, which is the same one a production corpus
loader honours:

* **Sharded**: each data-parallel rank reads a disjoint slice, derived from
  (step, rank) alone — no coordination traffic.
* **Deterministic + checkpointable**: the iterator is a pure function of
  ``(seed, step)``; its state is the integer ``step``, stored in the train
  checkpoint, so restart resumes the exact sample sequence (fault
  tolerance) even on a different mesh (elastic restart re-slices by the new
  rank count).
* **Host-sharded arrays**: ``make_train_iterator`` places each global batch
  with ``jax.make_array_from_process_local_data`` semantics (single-process
  here: ``jax.device_put`` with the batch sharding).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic-structure knobs
    zipf_a: float = 1.2
    ngram: int = 8            # repeated-phrase length (gives learnable signal)
    repeat_p: float = 0.5     # probability a position continues a phrase


class ShardedTokenStream:
    """Stateless-per-step token source: ``batch_at(step, rank, world)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # A fixed bank of "phrases" — repeated n-grams the model can learn.
        ranks = base.zipf(cfg.zipf_a, size=(1024, cfg.ngram)).astype(np.int64)
        self._phrases = (ranks - 1) % cfg.vocab

    def _sequence(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, dtype=np.int32)
        i = 0
        while i < out.size:
            if rng.random() < cfg.repeat_p:
                ph = self._phrases[rng.integers(len(self._phrases))]
                take = min(len(ph), out.size - i)
                out[i : i + take] = ph[:take]
                i += take
            else:
                out[i] = (rng.zipf(cfg.zipf_a) - 1) % cfg.vocab
                i += 1
        return out

    def batch_at(self, step: int, rank: int = 0, world: int = 1) -> dict:
        """Per-rank slice of the global batch for ``step`` (token/label)."""
        cfg = self.cfg
        per_rank = cfg.global_batch // world
        assert per_rank * world == cfg.global_batch, (
            f"global_batch {cfg.global_batch} not divisible by world {world}"
        )
        rows = []
        for b in range(per_rank):
            # deterministic stream id: (step, global row index)
            g = rank * per_rank + b
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, g])
            )
            rows.append(self._sequence(rng))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_train_iterator(cfg: DataConfig, batch_sharding=None, start_step: int = 0):
    """Yield (step, device batch) forever from ``start_step``.

    ``batch_sharding``: NamedSharding for the (B, S) arrays; None → default
    device placement (CPU smoke path).
    """
    stream = ShardedTokenStream(cfg)

    def put(x):
        if batch_sharding is None:
            return x
        return jax.device_put(x, batch_sharding)

    step = start_step
    while True:
        host = stream.batch_at(step)
        yield step, {k: put(v) for k, v in host.items()}
        step += 1
