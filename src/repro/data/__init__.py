from .pipeline import DataConfig, ShardedTokenStream, make_train_iterator

__all__ = ["DataConfig", "ShardedTokenStream", "make_train_iterator"]
