"""Batched serving engine with TRACE-tiered KV offload.

End-to-end path (paper Fig. 1/6 mapped onto a TPU host):

  prefill  — jit'd full-prompt forward fills a jnp KV cache; completed
             pages (window of ``page_tokens``) are committed to the
             ``KVPagePool`` as BF16 token-major streams (the CXL.mem write
             stream of the paper).
  decode   — jit'd single-token step reads the *reconstructed* KV
             (HBM-resident pages exact; spilled pages served by the tier
             device at their policy precision) and appends new tokens.
  accounting — every step tallies bytes on HBM / CXL link / device DRAM
             from the pool's device stats; ``throughput_model()`` converts
             them to a tok/s ceiling with the paper's first-order model.

This engine is intentionally *functional* about the device: KV numerics
flow through the actual bit-plane + codec + precision pipeline, so serving
quality under a policy is measurable, not assumed.

I/O overlap (``async_io``, default on): spill readback goes through the
tier's queued front-end — tickets are issued at the commit boundary and
drained at the *next* one, so they are in flight across the jitted decode
step in between and their receipts carry overlap-adjusted latency instead
of serialized sync latency.  Tier reads are byte-identical either way
(the async queue preserves per-key program order), and under a lossless
policy generation is bit-identical to ``async_io=False`` (tested).  Under
a *lossy* policy the one-boundary deferral is visible: the decode steps
between issue and drain still attend over the pristine HBM values, so
tokens can differ from the serialized engine (freshly spilled pages serve
one extra boundary at full precision — the overlap hides, never adds,
degradation).  Total traffic is identical in all modes.

Multi-stream serving: :class:`MultiStreamEngine` runs N independent
sequences whose page pools share ONE tier device queue (per-stream key
namespaces).  In round-robin steady state every stream's boundary-issued
tickets accumulate in the shared window and the first stream to reach
its next commit boundary drains them as one coalesced cross-stream flush
group (see :meth:`KVPagePool.drain_reads`) — the many-stream sharing the
ROADMAP calls for.

Continuous batching: :class:`ServeScheduler` adds request
arrival/departure on top of that sharing — requests from a synthetic
trace (:func:`repro.core.synth.request_trace`) wait FIFO for a batch
slot, prefill on admission (gated on projected KV capacity), decode
round-robin with whoever else is active, and retire at the commit
boundary of their last token, freeing their pages and tier namespace
(:meth:`ServeEngine.retire`) for the next queued request.  Per-sequence
outputs stay bit-identical to solo runs under dynamic membership — the
contract every piece of this module preserves.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.system_model import SystemSpec
from ..core.tier import Ticket, TierStore, make_device
from ..models import decode_step, forward, init_cache
from .paging import (
    KVPagePool, PagePolicy, PAPER_POLICY, PrefixShareIndex, _Page,
    prefix_chain_hashes, shared_page_key,
)

# One jitted step per distinct (frozen, hashable) ArchConfig, shared by
# every engine — N streams of the same model trace and compile once, not
# N times.
_jit_step = jax.jit(decode_step, static_argnums=0)


def _sample_next(logits: np.ndarray, rng: np.random.Generator,
                 greedy: bool) -> np.ndarray:
    """Next-token ids from last-position logits (one sampling path for
    single- and multi-stream generation)."""
    if greedy:
        return logits.argmax(-1).astype(np.int32)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.array([rng.choice(p.shape[-1], p=row) for row in p], np.int32)


@dataclasses.dataclass
class ServeStats:
    tokens_generated: int = 0
    prefill_tokens: int = 0
    hbm_page_bytes: int = 0
    tier_dram_read: int = 0
    tier_dram_stored: int = 0
    tier_link_out: int = 0
    spilled_pages: int = 0
    kv_logical_bytes: int = 0
    tier_io_service_s: float = 0.0      # serialized service time of all I/O
    tier_io_queue_delay_s: float = 0.0  # queueing on the shared DDR/link pipes
    tier_device_compute_s: float = 0.0  # PNM scoring time on the device

    @property
    def kv_compression_ratio(self) -> float:
        return self.kv_logical_bytes / max(
            self.tier_dram_stored + self.hbm_page_bytes, 1
        )


class ServeEngine:
    """Single-host serving of one model with paged, tiered KV."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_seq: int = 512,
        batch: int = 1,
        page_tokens: int = 64,
        hbm_kv_budget: int = 1 << 22,
        device_kind: Union[str, TierStore] = "trace",
        policy: PagePolicy = PAPER_POLICY,
        key_prefix: str = "",
        async_io: bool = True,
        sanitize: Optional[bool] = None,
        prefix_index: Optional[PrefixShareIndex] = None,
        pnm_topk: Optional[int] = None,
        importance: str = "recency",
    ):
        assert not cfg.is_encoder_only, "serving needs a decoder"
        if importance not in ("recency", "attention"):
            raise ValueError(f"unknown importance mode {importance!r}")
        if pnm_topk is not None and pnm_topk < 0:
            raise ValueError("pnm_topk must be >= 0 (or None to disable)")
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.page_tokens = page_tokens
        self.async_io = async_io
        # PNM read mode: spill readback becomes a device-side top-k
        # gather (one GatherReq per KV kind per boundary) — only the k
        # highest-scoring spilled pages ship back.  k >= spilled pages
        # degenerates to the full readback bit-for-bit.
        self.pnm_topk = pnm_topk
        # "recency" keeps the pre-existing commit-order ranking;
        # "attention" accumulates digest-proxy attention mass per page
        # each commit boundary and feeds pool.update_importance.
        self.importance = importance
        self._imp_acc: Dict[str, float] = {}
        self.pool = KVPagePool(
            device_kind, page_tokens, hbm_kv_budget, policy,
            key_prefix=key_prefix, sanitize=sanitize,
            prefix_index=prefix_index,
        )
        self.cache = init_cache(cfg, batch, max_seq)
        self.pos = 0
        # Prompt-prefix chain hashes (share-tagging completed prompt
        # windows); filled by the first prefill when the pool is wired to
        # a PrefixShareIndex, empty otherwise.
        self._share_hashes: List[str] = []
        self._prompt_len = 0
        self._inflight: List[Tuple[_Page, Ticket]] = []
        self._inflight_gathers: List[Tuple[List[_Page], Ticket]] = []
        self._decode = lambda p, b, c: _jit_step(cfg, p, b, c)
        self._prefill = self._decode

    # -- helpers ---------------------------------------------------------------
    def _commit_pages(self, lo: int, hi: int):
        """Push completed KV windows [lo, hi) into the page pool."""
        # Tickets issued at the previous boundary were in flight across the
        # decode step that just ran — apply their data before committing.
        self.flush_io()
        layers = self.cache.get("layers", {})
        kv_keys = [k for k in ("k", "v", "c_kv") if k in layers]
        if not kv_keys:
            return  # SSM/hybrid: constant-size state, nothing paged
        # Gather every completed window across layers and kinds into one
        # batched admission: the spill this triggers goes to the device as
        # one write batch → one vectorized encode slab, instead of a
        # per-page pack+codec pipeline.
        batch_pages = []
        for start in range(lo - lo % self.page_tokens, hi, self.page_tokens):
            if start + self.page_tokens > hi:
                break
            for kind in kv_keys:
                buf = np.asarray(layers[kind])  # (L, B, S, ...) bf16
                n_layers = buf.shape[0]
                # Windows fully inside the prompt carry their prefix
                # chain hash: identical prompt prefixes produce identical
                # KV there (causal attention), so these pages are the
                # shareable ones.  Windows touching generated tokens stay
                # private — that is the copy-on-write divergence point.
                share = None
                if (self._share_hashes
                        and start + self.page_tokens <= self._prompt_len):
                    share = self._share_hashes[start // self.page_tokens]
                for layer in range(n_layers):
                    page = buf[layer, :, start : start + self.page_tokens]
                    tok = page.reshape(self.page_tokens * self.batch, -1)
                    u16 = np.ascontiguousarray(tok).view(np.uint16)
                    # recency as default importance; importance="attention"
                    # replaces it below with accumulated attention mass and
                    # keeps re-ranking live pages via
                    # pool.update_importance every boundary
                    batch_pages.append(
                        (layer, kind, start, u16, float(start), share)
                    )
        if batch_pages:
            if self.importance == "attention":
                batch_pages = self._apply_attention_importance(batch_pages)
            self.pool.append_pages(batch_pages)
        self._issue_readback()

    def _issue_readback(self):
        """Start spill readback for this boundary's evictions.

        Sync mode reads and applies immediately (the pre-async behavior).
        Async mode only issues tickets: they ride the device's in-flight
        window across the next jitted decode step and are drained/applied
        by :meth:`flush_io` at the next commit boundary — decode and tier
        fetch overlap instead of serializing.
        """
        events, self.pool.spill_events = self.pool.spill_events, []
        if not events:
            return
        if self.pnm_topk is not None:
            self._issue_gather(events)
            return
        if self.async_io:
            self._inflight.extend(
                zip(events, self.pool.read_pages_async(events))
            )
        else:
            self._apply_readback(events, self.pool.read_pages(events))

    def _issue_gather(self, events: Sequence[_Page]):
        """PNM read mode: replace the boundary's full spill readback with
        one device-side top-k gather per KV kind.

        The device scores every candidate page on the reduced
        ``score_view`` plane subset against this step's query digest and
        ships full precision for only the ``pnm_topk`` winners; losers
        keep their pristine HBM values in the jnp cache (the overlap
        contract: PNM hides degradation, never adds it).  With
        ``pnm_topk >= len(events)`` every candidate wins and the applied
        bytes are identical to the classic readback."""
        by_kind: Dict[str, List[_Page]] = {}
        for p in events:
            by_kind.setdefault(p.kind, []).append(p)
        for kind, pages in by_kind.items():
            digest = self._query_digest(kind)
            if self.async_io:
                cands, ticket = self.pool.gather_topk_async(
                    digest, self.pnm_topk, pages)
                if ticket is not None:
                    self._inflight_gathers.append((cands, ticket))
            else:
                winners, data = self.pool.gather_topk(
                    digest, self.pnm_topk, pages)
                self._apply_readback(winners, data)

    def flush_io(self):
        """Drain in-flight readback tickets and fold them into the cache."""
        if not self._inflight and not self._inflight_gathers:
            return
        inflight, self._inflight = self._inflight, []
        gathers, self._inflight_gathers = self._inflight_gathers, []
        if inflight:
            pages = [p for p, _ in inflight]
            data = self.pool.drain_reads([t for _, t in inflight])
            self._apply_readback(pages, data)
        for cands, ticket in gathers:
            winners, data = self.pool.drain_gather(cands, ticket)
            self._apply_readback(winners, data)

    def _query_digest(self, kind: str) -> np.ndarray:
        """f32 mean of the last committed window's rows for ``kind`` —
        the host-side stand-in for the current query direction that both
        the PNM gather and attention-mass importance score against."""
        buf = np.asarray(self.cache["layers"][kind])
        channels = int(np.prod(buf.shape[3:])) if buf.ndim > 3 else 1
        lo = max(0, self.pos - self.page_tokens)
        win = buf[:, :, lo:self.pos]
        if win.size == 0:
            return np.zeros((channels,), np.float32)
        return win.astype(np.float32).reshape(-1, channels).mean(axis=0)

    def _attention_masses(self) -> Dict[Tuple[str, int, int], float]:
        """Digest-proxy attention mass per committed page window.

        For each key-bearing kind (``k`` / ``c_kv``), every committed
        token row is scored ``<row, digest>`` and softmaxed across the
        layer's whole committed context; a window's mass is the sum of
        its rows' probabilities — the share of attention the current
        query direction would spend on that page.  Keyed by
        ``(kind, layer, start)``; V pages inherit their K twin's mass
        (values move under the weights keys produce)."""
        layers = self.cache.get("layers", {})
        paged = (self.pos // self.page_tokens) * self.page_tokens
        masses: Dict[Tuple[str, int, int], float] = {}
        if paged <= 0:
            return masses
        for kind in ("k", "c_kv"):
            if kind not in layers:
                continue
            buf = np.asarray(layers[kind])
            digest = self._query_digest(kind)
            n_layers = buf.shape[0]
            for layer in range(n_layers):
                rows = (buf[layer][:, :paged].astype(np.float32)
                        .reshape(self.batch, paged, -1))
                dots = rows @ digest                      # (B, paged)
                p = np.exp(dots - dots.max())
                p /= p.sum()
                for start in range(0, paged, self.page_tokens):
                    masses[(kind, layer, start)] = float(
                        p[:, start : start + self.page_tokens].sum())
        return masses

    def _apply_attention_importance(self, batch_pages: List[tuple]) -> List[tuple]:
        """Satellite of the PNM PR: make ``pool.update_importance`` have
        a real caller.  Accumulates this boundary's attention masses into
        the per-key running totals, re-ranks the pool's live pages, and
        rewrites the fresh commit batch so new pages are admitted at
        their measured mass instead of recency."""
        masses = self._attention_masses()
        if not masses:
            return batch_pages

        def _mass(kind: str, layer: int, start: int) -> Optional[float]:
            src = "k" if kind in ("k", "v") else kind
            return masses.get((src, layer, start))

        for p in self.pool.iter_pages():
            m = _mass(p.kind, p.layer, p.start)
            if m is not None:
                self._imp_acc[p.key] = self._imp_acc.get(p.key, 0.0) + m
        known = {p.key for p in self.pool.iter_pages()}
        scores = {k: v for k, v in self._imp_acc.items() if k in known}
        if scores:
            self.pool.update_importance(scores)
        out = []
        for entry in batch_pages:
            layer, kind, start, u16, imp = entry[:5]
            share = entry[5] if len(entry) > 5 else None
            if share is not None and self.pool.prefix_index is not None:
                key = shared_page_key(share, layer, kind)
            else:
                key = f"{self.pool.key_prefix}L{layer}.{kind}.{start}"
            m = _mass(kind, layer, start)
            if m is not None:
                self._imp_acc[key] = self._imp_acc.get(key, 0.0) + m
                imp = self._imp_acc[key]
            out.append((layer, kind, start, u16, imp, share))
        return out

    def _apply_readback(self, pages: Sequence[_Page],
                        data: Sequence[np.ndarray]):
        """Replace spilled pages' jnp-cache content with the tier-served
        values at their policy precision, so generation quality actually
        reflects the device pipeline (and DRAM reads are tallied).  All
        spilled pages of one boundary reach the device as a single request
        batch (vectorized plane decode on the device side)."""
        import ml_dtypes

        layers = dict(self.cache["layers"])
        touched = False
        for page, u16 in zip(pages, data):
            buf = np.asarray(layers[page.kind])
            target = buf[page.layer][:, page.start : page.start + self.page_tokens]
            vals = u16.view(ml_dtypes.bfloat16).reshape(target.shape)
            buf = buf.copy()
            buf[page.layer][:, page.start : page.start + self.page_tokens] = vals
            layers[page.kind] = buf
            touched = True
        if touched:
            self.cache = dict(self.cache)
            self.cache["layers"] = {
                k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
                for k, v in layers.items()
            }

    # -- API ---------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (batch, prompt_len) → last-token logits."""
        B, S = tokens.shape
        assert B == self.batch
        batch = {
            "tokens": jnp.asarray(tokens),
            "cache_pos": jnp.int32(self.pos),
        }
        logits, self.cache = self._prefill(self.params, batch, self.cache)
        old = self.pos
        self.pos += S
        if old == 0 and self.pool.prefix_index is not None:
            self._share_hashes = prefix_chain_hashes(tokens, self.page_tokens)
            self._prompt_len = S
        self._commit_pages(old, self.pos)
        return np.asarray(logits[:, -1])

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (batch, 1) current token → next-token logits."""
        batch = {
            "tokens": jnp.asarray(tokens.reshape(self.batch, 1)),
            "cache_pos": jnp.int32(self.pos),
        }
        logits, self.cache = self._decode(self.params, batch, self.cache)
        old = self.pos
        self.pos += 1
        self._commit_pages(old, self.pos)
        return np.asarray(logits[:, -1])

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        logits = self.prefill(prompt)
        out = []
        for _ in range(n_tokens):
            nxt = _sample_next(logits, rng, greedy)
            out.append(nxt)
            logits = self.decode(nxt.reshape(-1, 1))
        return np.stack(out, axis=1)

    # -- KV readback through the tier (quality measurement path) ---------------
    def kv_through_tier(self, layer: int, kind: str = "k") -> np.ndarray:
        """Token-major KV for (layer, kind) as the host would see it after a
        round-trip through the tier at the current policy."""
        self.flush_io()
        return self.pool.read_layer(layer, kind)

    def layer_traffic(self):
        """Per-layer tier traffic, attributed from the pool's receipts."""
        self.flush_io()
        return self.pool.traffic_by_layer()

    def stats(self) -> ServeStats:
        self.flush_io()
        d = self.pool.stats()
        return ServeStats(
            tokens_generated=self.pos,
            hbm_page_bytes=self.pool.hbm_bytes,
            tier_dram_read=d.dram_bytes_read,
            tier_dram_stored=d.dram_bytes_stored,
            tier_link_out=d.link_bytes_out,
            spilled_pages=self.pool.spilled_pages,
            kv_logical_bytes=d.raw_bytes_stored + self.pool.hbm_bytes,
            tier_io_service_s=self.pool.io_service_s,
            tier_io_queue_delay_s=self.pool.io_queue_delay_s,
            tier_device_compute_s=d.device_compute_s,
        )

    def throughput_ceiling(self, sys: SystemSpec = SystemSpec()) -> float:
        """tok/s ceiling implied by current per-step tier traffic."""
        d = self.pool.stats()
        steps = max(self.pos, 1)
        ddr_per_step = d.dram_bytes_read / steps
        link_per_step = d.link_bytes_out / steps
        t = max(ddr_per_step / sys.cxl_ddr_bw,
                link_per_step / sys.cxl_link_bw, 1e-12)
        return min(1.0 / t, sys.cap_tok_s)

    def retire(self) -> int:
        """Finish this sequence: drain in-flight readback, then free every
        page — HBM residents and the tier's per-stream key namespace — so
        the capacity serves the next admitted request (continuous
        batching's leave-at-commit-boundary).  Returns the number of tier
        keys freed.  The engine must not decode after retirement."""
        self.flush_io()
        return self.pool.release()


class MultiStreamEngine:
    """N independent sequences sharing one tier device queue.

    Each stream is a full :class:`ServeEngine` (own jnp cache, own page
    pool, own HBM budget) but all pools write/read through a single
    :class:`TierStore`, namespaced by a per-stream key prefix.  Decode
    proceeds round-robin one token at a time: each round's readback
    tickets accumulate in the shared in-flight window, and the first
    stream whose commit boundary finds its tickets still queued drains
    the whole window — the device coalesces reads *across* streams into
    one vectorized slab decode, and receipts price the queueing on the
    shared DDR + link pipes.  The async queue preserves per-key program
    order, so stream results are bit-identical to running each stream
    alone.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        n_streams: int,
        *,
        device_kind: Union[str, TierStore] = "trace",
        async_io: bool = True,
        sanitize: Optional[bool] = None,
        shards: Optional[int] = None,
        placement: Optional[str] = None,
        **engine_kw,
    ):
        # shards=None defers to the TRACE_SHARDS env var (make_device);
        # >1 stripes every stream's pages across a device fleet.
        self.device = (make_device(device_kind, shards=shards,
                                   placement=placement, sanitize=sanitize)
                       if isinstance(device_kind, str) else device_kind)
        self.streams = [
            ServeEngine(cfg, params, device_kind=self.device,
                        key_prefix=f"s{i}.", async_io=async_io, **engine_kw)
            for i in range(n_streams)
        ]

    def generate(self, prompts: Sequence[np.ndarray], n_tokens: int,
                 greedy: bool = True, seed: int = 0) -> List[np.ndarray]:
        """Round-robin generation; ``prompts[i]`` is stream *i*'s (batch,
        prompt_len) tokens.  Returns per-stream (batch, n_tokens) arrays."""
        assert len(prompts) == len(self.streams)
        rngs = [np.random.default_rng(seed + i) for i in range(len(prompts))]
        logits = [eng.prefill(p) for eng, p in zip(self.streams, prompts)]
        outs: List[List[np.ndarray]] = [[] for _ in self.streams]
        for _ in range(n_tokens):
            for i, eng in enumerate(self.streams):
                nxt = _sample_next(logits[i], rngs[i], greedy)
                outs[i].append(nxt)
                logits[i] = eng.decode(nxt.reshape(-1, 1))
        return [np.stack(o, axis=1) for o in outs]

    def flush_io(self):
        for eng in self.streams:
            eng.flush_io()

    def stats(self) -> List[ServeStats]:
        """Per-stream stats (shared-device aggregates are identical)."""
        return [eng.stats() for eng in self.streams]

    def device_stats(self):
        self.flush_io()
        return self.device.stats

    def throughput_ceiling(self, sys: SystemSpec = SystemSpec()) -> float:
        """Aggregate tok/s ceiling across streams on the shared device."""
        self.flush_io()
        d = self.device.stats
        steps = max(sum(eng.pos for eng in self.streams), 1)
        t = max(d.dram_bytes_read / steps / sys.cxl_ddr_bw,
                d.link_bytes_out / steps / sys.cxl_link_bw, 1e-12)
        return min(1.0 / t, sys.cap_tok_s)


# ---------------------------------------------------------------------------
# Continuous batching — request arrival/departure over one shared device
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One serving request for the continuous-batching scheduler.

    ``arrival`` is measured in scheduler decode rounds (the clock a
    :func:`repro.core.synth.request_trace` produces); ``prompt`` is
    ``(batch, prompt_len)`` int32 token ids; ``seed`` feeds the same
    per-request sampling rng a solo :meth:`ServeEngine.generate` call
    would use, which is what makes the differential guarantee testable.
    """

    req_id: int
    arrival: float
    prompt: np.ndarray
    max_new_tokens: int
    greedy: bool = True
    seed: int = 0


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle record the scheduler keeps per request.

    Steps are scheduler clock ticks; ``t_*_s`` stamps are modeled seconds
    (compute quantum ⊔ tier-I/O time per tick).  ``tokens`` is filled at
    retirement with the ``(batch, max_new_tokens)`` generation.
    """

    req_id: int
    arrival: float
    kv_projected_bytes: int = 0
    kv_novel_bytes: int = -1    # admission charge after the shared-prefix
                                # discount (-1 until computed at admission)
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1
    t_arrive_s: float = -1.0
    t_admit_s: float = -1.0
    t_first_token_s: float = -1.0
    t_finish_s: float = -1.0
    prefill_tokens: int = 0
    tokens: Optional[np.ndarray] = None

    @property
    def finished(self) -> bool:
        return self.tokens is not None

    @property
    def kv_charged_bytes(self) -> int:
        """What admission actually charged against ``kv_capacity_bytes``:
        the novel-KV projection when prefix sharing discounted already-
        resident prompt windows, else the full projection.  Retirement
        returns exactly this amount."""
        return (self.kv_novel_bytes if self.kv_novel_bytes >= 0
                else self.kv_projected_bytes)

    @property
    def queue_delay_s(self) -> float:
        """Arrival → admission wait (slot or KV-capacity contention)."""
        return self.t_admit_s - self.t_arrive_s

    @property
    def latency_s(self) -> float:
        """Arrival → last generated token, in modeled seconds."""
        return self.t_finish_s - self.t_arrive_s

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival → the tick that produced the
        first generated token (queue wait + prefill + first round)."""
        return self.t_first_token_s - self.t_arrive_s

    @property
    def tpot_s(self) -> float:
        """Time per output token: mean inter-token gap *after* the
        first token.  NaN for single-token requests — there is no
        inter-token gap to measure (the explicit empty-denominator
        value, tested)."""
        if self.tokens is None or self.tokens.shape[1] <= 1:
            return float("nan")
        return ((self.t_finish_s - self.t_first_token_s)
                / (self.tokens.shape[1] - 1))


@functools.lru_cache(maxsize=32)
def _kv_bytes_per_token_b1(cfg: ArchConfig) -> int:
    """Batch-1 paged-KV bytes one committed token contributes, from the
    cache spec (``jax.eval_shape`` — no allocation).  Bounded: one entry
    per architecture, never per batch size."""
    spec = jax.eval_shape(lambda: init_cache(cfg, 1, 8))
    layers = spec.get("layers", {})
    total = 0
    for kind in ("k", "v", "c_kv"):
        if kind in layers:
            shape = layers[kind].shape          # (L, 1, S, ...channels)
            per_token = int(np.prod(shape[3:])) if len(shape) > 3 else 1
            total += int(shape[0]) * per_token * 2
    return total


def _kv_bytes_per_token(cfg: ArchConfig, batch: int) -> int:
    """Paged-KV bytes one committed token contributes at ``batch``.

    The per-token increment is exactly linear in batch (every KV leaf is
    ``(L, B, S, channels…)``), so only the batch-1 slope is traced and
    cached — a long-running server that sees many batch sizes re-traces
    nothing and the cache stays bounded by the number of architectures.
    """
    return _kv_bytes_per_token_b1(cfg) * batch


def projected_kv_bytes(cfg: ArchConfig, batch: int, total_tokens: int,
                       page_tokens: int,
                       per_token: Optional[int] = None) -> int:
    """Logical BF16 bytes of paged KV a ``total_tokens`` sequence commits.

    Admission control needs the footprint BEFORE running the model, so
    this derives it from the cache spec: every KV leaf
    (``k``/``v``/``c_kv``) contributes ``n_layers * batch *
    paged_tokens * per_token_channels * 2`` bytes, where
    ``paged_tokens`` counts only completed page windows (partial tails
    never reach the pool).  SSM/hybrid caches have no paged KV and
    project to zero.  ``per_token`` short-circuits the cache-spec lookup
    with an already-known per-token increment (the scheduler's cached
    slope) — one formula either way, so the two paths cannot drift.
    """
    paged = (total_tokens // page_tokens) * page_tokens
    if paged <= 0:
        return 0
    if per_token is None:
        per_token = _kv_bytes_per_token(cfg, batch)
    return paged * per_token


class _ActiveSeq:
    """One admitted request: its engine, sampling rng and progress."""

    __slots__ = ("req", "record", "engine", "rng", "logits", "out", "done")

    def __init__(self, req: ServeRequest, record: RequestRecord,
                 engine: ServeEngine, rng: np.random.Generator,
                 logits: np.ndarray):
        self.req = req
        self.record = record
        self.engine = engine
        self.rng = rng
        self.logits = logits
        self.out: List[np.ndarray] = []
        self.done = False


@dataclasses.dataclass
class SchedulerReport:
    """End-of-run roll-up: per-request records + modeled aggregates.

    ``peak_active`` is the largest concurrently admitted batch the run
    reached — the quantity the capacity-model sweep compares across
    `logical` and `physical` admission.  ``reclaimed_bytes`` totals the
    physical bytes precision-elastic reclamation freed (0 with the
    ladder disabled).  Every percentile/mean property returns an
    explicit value on an empty denominator (NaN) instead of raising —
    zero finished requests is a legal report state, tested.
    """

    records: List[RequestRecord]
    steps: int
    model_time_s: float
    decode_tokens: int
    prefill_tokens: int
    peak_active: int = 0
    capacity_model: str = "logical"
    kv_ratio_estimate: float = 1.0
    reclaimed_bytes: int = 0
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None
    # Fleet view: how many tier devices served the run, and how skewed
    # the per-device traffic ended up (max/mean moved bytes; 1.0 for a
    # single device or a perfectly balanced fleet).
    n_devices: int = 1
    fleet_skew: float = 1.0

    @property
    def slo_attainment(self) -> float:
        """Fraction of finished requests meeting BOTH configured SLOs
        (TTFT and TPOT, modeled seconds).  An unset target is vacuously
        met; a single-token request has no inter-token gap (``tpot_s``
        is NaN), so only its TTFT can miss — NaN never counts as a
        violation.  NaN when no SLO is configured or nothing finished.
        """
        if self.slo_ttft_s is None and self.slo_tpot_s is None:
            return float("nan")
        done = [r for r in self.records if r.finished]
        if not done:
            return float("nan")
        ok = 0
        for r in done:
            if (self.slo_ttft_s is not None
                    and not r.ttft_s <= self.slo_ttft_s):
                continue
            if (self.slo_tpot_s is not None and np.isfinite(r.tpot_s)
                    and not r.tpot_s <= self.slo_tpot_s):
                continue
            ok += 1
        return ok / len(done)

    @property
    def tok_s(self) -> float:
        """Decode throughput over the modeled run (generated tokens only)."""
        return self.decode_tokens / max(self.model_time_s, 1e-12)

    def latency_percentile(self, q: float) -> float:
        lats = [r.latency_s for r in self.records if r.finished]
        return float(np.percentile(lats, q)) if lats else float("nan")

    @property
    def p50_latency_s(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99_latency_s(self) -> float:
        return self.latency_percentile(99)

    @property
    def mean_queue_delay_s(self) -> float:
        qs = [r.queue_delay_s for r in self.records if r.finished]
        return float(np.mean(qs)) if qs else float("nan")

    def ttft_percentile(self, q: float) -> float:
        ts = [r.ttft_s for r in self.records if r.finished]
        return float(np.percentile(ts, q)) if ts else float("nan")

    @property
    def p50_ttft_s(self) -> float:
        return self.ttft_percentile(50)

    @property
    def p99_ttft_s(self) -> float:
        return self.ttft_percentile(99)

    @property
    def mean_tpot_s(self) -> float:
        """Mean time-per-output-token across finished multi-token
        requests (single-token requests have no inter-token gap and are
        excluded; NaN when none qualify)."""
        ts = [r.tpot_s for r in self.records
              if r.finished and np.isfinite(r.tpot_s)]
        return float(np.mean(ts)) if ts else float("nan")


class ServeScheduler:
    """Continuous-batching request scheduler over one shared tier device.

    Requests arrive on a synthetic trace (``arrival`` in decode rounds —
    see :func:`repro.core.synth.request_trace`), wait FIFO for a batch
    slot, run prefill-then-decode as a full :class:`ServeEngine` under a
    per-request key namespace (``r{id}.``), and retire at the commit
    boundary that produced their last token — :meth:`ServeEngine.retire`
    frees HBM pages and deletes the request's tier namespace
    (:meth:`TierStore.delete_prefix`), so the freed slot and KV capacity
    admit the next queued request.  All active engines share ONE device
    queue: their spill readback tickets coalesce into cross-request slab
    decodes and the busy clock prices cross-request pipe contention, just
    like :class:`MultiStreamEngine`.

    Admission is KV-capacity-aware: with ``kv_capacity_bytes`` set, a
    request joins only when the committed KV projection of every active
    request plus its own fits; the queue does NOT bypass a blocked
    head-of-line request (strict FIFO).  A request too large for the
    whole capacity is still admitted when the batch is empty, so the
    queue cannot deadlock.  The per-request projection is the cached
    per-token increment (one ``jax.eval_shape`` trace per (cfg, batch))
    times the request's completed page windows — admission checks are
    pure arithmetic.

    Two capacity models (``capacity_model``):

    * `logical` — the projection is compared against capacity as raw
      BF16 bytes (the conservative pre-ledger behavior, and the only
      sound model for a device whose stored footprint equals its
      logical footprint).
    * `physical` — the projection is divided by a feedback estimate of
      the device's compression ratio before the comparison.  The
      estimator seeds at 1.0 (no stored data: admit exactly like
      `logical`), reads the device-observed running ratio from the
      residency ledger (``TierStore.resident_bytes`` /
      ``compression_ratio``) and corrects itself against ledger deltas
      at every commit boundary.  A trace device storing KV at >2x
      therefore admits a strictly larger concurrent batch than a word
      device at the same ``kv_capacity_bytes`` — the paper's
      compression ratio acting as the serving control signal rather
      than a reporting statistic.

    Precision-elastic reclamation: with a ``degrade_ladder`` configured
    (and the `physical` model), a blocked head-of-line request triggers
    :meth:`KVPagePool.reclaim` across the active requests' pools before
    admission stalls — cold stored pages shed mantissa planes in place,
    the ledger shrinks, the ratio estimate rises, and the admission
    check is retried.  With the ladder disabled (the default) stored
    bytes are never touched and per-request tokens stay bit-identical
    to solo runs.

    Shared-prefix KV reuse (``prefix_share=True``): every engine's pool
    is wired to one :class:`PrefixShareIndex`, so identical completed
    prompt-prefix pages are stored once under the content-addressed
    ``shared.`` namespace (refcounted in the residency ledger, freed when
    the last referer retires) and the spill write is elided for every
    request after the first — and admission charges each request only its
    *novel* projection (frozen into ``RequestRecord.kv_novel_bytes`` so
    retirement refunds exactly what was charged).  At high prefix overlap this
    multiplies the admissible concurrent batch and cuts TTFT twice over:
    less queue wait and fewer spill bytes per tick.  Sharing preserves
    the differential guarantee — a reused page stores exactly the bytes
    the request's own write would have stored.

    The differential guarantee extends to dynamic membership: per-key
    program order on the shared queue means each request's decoded tokens
    are bit-identical to running it solo through
    ``ServeEngine.generate(prompt, n, greedy, seed)`` at the same
    ``max_seq`` — joins, leaves and capacity stalls change receipts'
    latency (queue delay), never data.

    Modeled time: every scheduler tick costs
    ``max(1/cap_tok_s, tier I/O time of the tick)`` seconds — one batched
    decode round at the compute ceiling, or the tick's DRAM/link transfer
    time when the tier is the bottleneck (the regime Figs. 12-14 study).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_batch: int = 4,
        device_kind: Union[str, TierStore] = "trace",
        policy=None,
        batch: int = 1,
        page_tokens: int = 16,
        hbm_kv_budget: int = 1 << 12,
        max_seq: Optional[int] = None,
        kv_capacity_bytes: Optional[int] = None,
        capacity_model: str = "logical",
        degrade_ladder: Optional[Sequence] = None,
        async_io: bool = True,
        sys: SystemSpec = SystemSpec(),
        sanitize: Optional[bool] = None,
        prefix_share: bool = False,
        slo_ttft_s: Optional[float] = None,
        slo_tpot_s: Optional[float] = None,
        shards: Optional[int] = None,
        placement: Optional[str] = None,
        pnm_topk: Optional[int] = None,
        importance: str = "recency",
    ):
        from .paging import PAPER_POLICY as _paper

        if capacity_model not in ("logical", "physical"):
            raise ValueError(f"unknown capacity model {capacity_model!r}")
        if degrade_ladder and capacity_model != "physical":
            # Reclamation frees *stored* bytes; logical admission compares
            # raw projections that never shrink, so a ladder could only
            # destroy precision without ever unblocking anything.  Refuse
            # loudly rather than silently ignoring the flag.
            raise ValueError(
                "degrade_ladder requires capacity_model='physical'"
            )
        self.cfg = cfg
        self.params = params
        # The fleet routing layer: shards > 1 builds a ShardedTierStore,
        # and because every engine replica this scheduler starts keys its
        # pages under its own `r{id}.` namespace, the placement policy
        # spreads the replicas' traffic across the device fleet (hash-
        # stripe: per-page; namespace: whole replicas pinned per shard).
        # shards=None defers to the TRACE_SHARDS env var (make_device).
        self.device = (make_device(device_kind, shards=shards,
                                   placement=placement, sanitize=sanitize)
                       if isinstance(device_kind, str) else device_kind)
        self.max_batch = max_batch
        self.policy = _paper if policy is None else policy
        self.batch = batch
        self.page_tokens = page_tokens
        self.hbm_kv_budget = hbm_kv_budget
        self.kv_capacity_bytes = kv_capacity_bytes
        self.capacity_model = capacity_model
        self.degrade_ladder = tuple(degrade_ladder or ())
        self.async_io = async_io
        self.sys = sys
        # SLO targets (modeled seconds) carried into every report; the
        # scheduler itself never gates on them — attainment is a
        # reporting statistic, not an admission signal
        self.slo_ttft_s = slo_ttft_s
        self.slo_tpot_s = slo_tpot_s
        # PNM read mode + importance signal, threaded into every engine
        # this scheduler starts.
        self.pnm_topk = pnm_topk
        self.importance = importance
        # Shared-prefix KV reuse: one content-addressed index across every
        # engine this scheduler starts.  Identical prompt-prefix pages are
        # stored once (refcounted), and admission charges each request only
        # its NOVEL projection (see _novel_bytes).
        self.prefix_index = (PrefixShareIndex(self.device)
                             if prefix_share else None)
        self._max_seq = max_seq
        self.pending: List[ServeRequest] = []
        self.active: List[Optional[_ActiveSeq]] = [None] * max_batch
        self.records: Dict[int, RequestRecord] = {}
        self.clock = 0                  # scheduler ticks (decode rounds)
        self.model_time_s = 0.0
        self.kv_committed_bytes = 0     # projections of active requests
        self.peak_active = 0            # largest admitted batch reached
        self.reclaimed_bytes = 0        # ladder-freed physical bytes
        # Ratio-aware admission feedback: seeds neutral (admit like the
        # logical model), tracks the device-observed running compression
        # ratio from the residency ledger, corrected at every commit
        # boundary (see _update_ratio).
        self.kv_ratio_estimate = 1.0
        self._ratio_seeded = False
        self._ledger_mark = (0, 0)      # (raw, physical) at last correction
        self._kv_per_token: Optional[int] = None   # cached projection slope
        self._first_this_tick: List[RequestRecord] = []
        self._next_id = 0
        self._io_mark = self._io_snapshot()

    @property
    def max_seq(self) -> Optional[int]:
        """Largest sequence budget any submitted request has needed so far
        (grown by :meth:`submit`; ``None`` until the first request)."""
        return self._max_seq

    # -- request intake ------------------------------------------------------
    def submit(self, requests: Sequence[Union[ServeRequest, dict]]):
        """Add requests (``ServeRequest`` or ``request_trace`` dicts) to
        the arrival queue.  Ids are assigned to dict entries; the queue is
        kept sorted by (arrival, id)."""
        for r in requests:
            if isinstance(r, dict):
                r = ServeRequest(req_id=self._next_id, **r)
            self._next_id = max(self._next_id, r.req_id + 1)
            if r.max_new_tokens < 1:
                raise ValueError("requests must generate at least one token")
            if r.prompt.shape[0] != self.batch:
                raise ValueError(
                    f"prompt batch {r.prompt.shape[0]} != scheduler batch "
                    f"{self.batch}"
                )
            if r.req_id in self.records:
                raise ValueError(f"duplicate req_id {r.req_id}")
            total = r.prompt.shape[-1] + r.max_new_tokens
            need = total + self.page_tokens
            if self._max_seq is None or self._max_seq < need:
                self._max_seq = max(self._max_seq or 0, need)
            # The projection is the cached per-token increment times the
            # request's completed page windows — the eval_shape trace
            # runs once per scheduler, not once per admission check.
            if self._kv_per_token is None:
                self._kv_per_token = _kv_bytes_per_token(self.cfg, self.batch)
            self.records[r.req_id] = RequestRecord(
                req_id=r.req_id, arrival=r.arrival,
                kv_projected_bytes=projected_kv_bytes(
                    self.cfg, self.batch, total, self.page_tokens,
                    per_token=self._kv_per_token),
            )
            self.pending.append(r)
        self.pending.sort(key=lambda r: (r.arrival, r.req_id))

    # -- one scheduler tick --------------------------------------------------
    def step(self) -> bool:
        """One commit-boundary round: admit arrivals into free slots, run
        one decode step for every active sequence, advance the modeled
        clock, retire finished sequences.  Returns True while work (queued
        or active) remains; an idle tick (nothing arrived yet) still
        advances both clocks.

        Finished sequences' engine teardown (readback drain + namespace
        delete) runs BEFORE the tick's time advance, so retirement I/O is
        priced into the same tick — including the run's final tick, which
        has no later tick to absorb it."""
        self._admit()
        self.peak_active = max(self.peak_active, self.n_active)
        self._decode_round()
        for seq in self.active:
            if seq is not None and seq.done:
                seq.engine.retire()
        self._advance_time()
        # First-token stamps land after the tick's time advance: TTFT
        # includes the round that actually produced the token.
        for rec in self._first_this_tick:
            rec.first_token_step = self.clock
            rec.t_first_token_s = self.model_time_s
        self._first_this_tick.clear()
        self._update_ratio()
        self._retire()
        self.clock += 1
        return bool(self.pending or any(s is not None for s in self.active))

    def run(self, requests: Optional[Sequence] = None,
            max_steps: int = 1_000_000) -> SchedulerReport:
        """Drive :meth:`step` until every submitted request has retired."""
        if requests:
            self.submit(requests)
        while self.step():
            max_steps -= 1
            if max_steps <= 0:
                raise RuntimeError("scheduler failed to drain")
        return self.report()

    def report(self) -> SchedulerReport:
        done = [self.records[k] for k in sorted(self.records)
                if self.records[k].finished]
        return SchedulerReport(
            records=done,
            steps=self.clock,
            model_time_s=self.model_time_s,
            decode_tokens=sum(r.tokens.size for r in done),
            prefill_tokens=sum(r.prefill_tokens for r in done),
            peak_active=self.peak_active,
            capacity_model=self.capacity_model,
            kv_ratio_estimate=self.kv_ratio_estimate,
            reclaimed_bytes=self.reclaimed_bytes,
            slo_ttft_s=self.slo_ttft_s,
            slo_tpot_s=self.slo_tpot_s,
            n_devices=len(self._device_stat_list()),
            fleet_skew=getattr(self.device, "fleet_skew", lambda: 1.0)(),
        )

    # -- internals -----------------------------------------------------------
    def _device_stat_list(self):
        """Per-device stats: each entry is one device's own pipes.  A
        single TierStore is a one-entry fleet; a sharded device exposes
        ``per_device_stats`` and the tick's I/O time becomes the slowest
        shard's (the straggler), not the fleet total over one pipe."""
        per = getattr(self.device, "per_device_stats", None)
        return per() if per is not None else [self.device.stats]

    def _io_snapshot(self):
        return [(d.dram_bytes_read + d.dram_bytes_written,
                 d.link_bytes_in + d.link_bytes_out)
                for d in self._device_stat_list()]

    def _projected_physical(self, logical_bytes: int) -> int:
        """Map a logical-KV projection to the bytes the device is
        expected to store for it under the current ratio estimate."""
        if self.capacity_model == "logical":
            return logical_bytes
        return int(np.ceil(logical_bytes
                           / max(self.kv_ratio_estimate, 1e-6)))

    def _novel_bytes(self, req: ServeRequest, rec: RequestRecord) -> int:
        """The admission charge for one request: its full KV projection
        minus the leading prompt windows whose shared pages are already
        stored on the device (``PrefixShareIndex.resident_chain``).

        Computed at each admission attempt — the index changes as other
        requests prefill and retire — and frozen into the record at
        admission so retirement returns exactly what was charged.  It is
        a projection like everything else admission uses: a referenced
        shared page stays alive while this request runs (the pool
        acquires it at spill), but a page counted here could free between
        this check and this request's own spill, in which case the pool
        simply writes it again — same estimate-then-correct contract as
        the ratio feedback.
        """
        if self.prefix_index is None:
            return rec.kv_projected_bytes
        hashes = prefix_chain_hashes(req.prompt, self.page_tokens)
        hit_windows = self.prefix_index.resident_chain(hashes)
        shared = hit_windows * self.page_tokens * (self._kv_per_token or 0)
        return max(rec.kv_projected_bytes - shared, 0)

    def _kv_fits(self, rec: RequestRecord) -> bool:
        if self.kv_capacity_bytes is None:
            return True
        if not any(s is not None for s in self.active):
            return True                  # empty-batch escape (no deadlock)
        need = self.kv_committed_bytes + rec.kv_charged_bytes
        return self._projected_physical(need) <= self.kv_capacity_bytes

    def _update_ratio(self):
        """Correct the admission ratio estimate against the residency
        ledger — called at every commit boundary (scheduler tick).

        Prefers the delta since the last correction (fresh commits are
        the best predictor of the next request's storage behavior);
        falls back to the absolute stored ratio when the tick freed
        bytes (retirement, reclamation) or committed nothing."""
        raw = self.device.stats.raw_bytes_stored
        phys = self.device.resident_bytes()
        d_raw = raw - self._ledger_mark[0]
        d_phys = phys - self._ledger_mark[1]
        self._ledger_mark = (raw, phys)
        if d_raw > 0 and d_phys > 0:
            obs = d_raw / d_phys
        elif raw > 0 and phys > 0:
            obs = raw / phys
        else:
            return                       # device empty: keep the estimate
        if not self._ratio_seeded:
            # first stored bytes: adopt the observed ratio outright (the
            # neutral 1.0 was a placeholder, not a measurement)
            self.kv_ratio_estimate = obs
            self._ratio_seeded = True
        else:
            self.kv_ratio_estimate += 0.5 * (obs - self.kv_ratio_estimate)

    def _reclaim_for(self, rec: RequestRecord) -> bool:
        """Blocked-admission pressure valve: shed cold stored planes
        across the active requests' pools until the head-of-line
        request's projection fits, then re-check.  Returns True when the
        reclamation unblocked admission.

        The deficit is denominated in *projected* physical bytes while
        reclaim frees *stored* bytes, so one pass is not guaranteed to
        unblock — the fit re-check only moves through the corrected
        ratio estimate.  Sustained pressure therefore keeps degrading
        cold pages, bounded by ladder exhaustion (``reclaim`` returns 0
        once every cold page sits at the last rung, and the admission
        stalls exactly like the ladderless scheduler).  That
        precision-for-capacity trade is the documented contract of
        enabling a ladder."""
        if not self.degrade_ladder or self.capacity_model != "physical":
            return False
        need = self.kv_committed_bytes + rec.kv_charged_bytes
        deficit = self._projected_physical(need) - self.kv_capacity_bytes
        freed = 0
        for seq in self.active:
            if seq is None or freed >= deficit:
                continue
            freed += seq.engine.pool.reclaim(deficit - freed,
                                             self.degrade_ladder)
        if freed == 0:
            return False
        self.reclaimed_bytes += freed
        self._update_ratio()             # the ledger just shrank
        return self._kv_fits(rec)

    def _admit(self):
        # Stamp every request the trace has delivered by now: queueing
        # delay starts at arrival, not at admission.
        for r in self.pending:
            if r.arrival > self.clock:
                break
            rec = self.records[r.req_id]
            if rec.t_arrive_s < 0:
                rec.t_arrive_s = self.model_time_s
        free = [i for i, s in enumerate(self.active) if s is None]
        while free and self.pending and self.pending[0].arrival <= self.clock:
            req = self.pending[0]
            rec = self.records[req.req_id]
            rec.kv_novel_bytes = self._novel_bytes(req, rec)
            if not self._kv_fits(rec) and not self._reclaim_for(rec):
                break                    # strict FIFO: wait for retirements
            self.pending.pop(0)
            self.kv_committed_bytes += rec.kv_charged_bytes
            self.active[free.pop(0)] = self._start(req, rec)

    def _start(self, req: ServeRequest, rec: RequestRecord) -> _ActiveSeq:
        eng = ServeEngine(
            self.cfg, self.params, max_seq=self._max_seq, batch=self.batch,
            page_tokens=self.page_tokens, hbm_kv_budget=self.hbm_kv_budget,
            device_kind=self.device, policy=self.policy,
            key_prefix=f"r{req.req_id}.", async_io=self.async_io,
            prefix_index=self.prefix_index,
            pnm_topk=self.pnm_topk, importance=self.importance,
        )
        rec.admit_step = self.clock
        rec.t_admit_s = self.model_time_s
        rec.prefill_tokens = int(req.prompt.size)
        logits = eng.prefill(req.prompt)
        return _ActiveSeq(req, rec, eng,
                          np.random.default_rng(req.seed), logits)

    def _decode_round(self):
        for seq in self.active:
            if seq is None:
                continue
            nxt = _sample_next(seq.logits, seq.rng, seq.req.greedy)
            seq.out.append(nxt)
            if len(seq.out) == 1:
                self._first_this_tick.append(seq.record)
            if len(seq.out) < seq.req.max_new_tokens:
                seq.logits = seq.engine.decode(nxt.reshape(-1, 1))
            else:
                seq.done = True

    def _advance_time(self):
        """One tick costs the compute ceiling or the tick's tier I/O,
        whichever dominates.  Each device moves its own tick delta over
        its OWN DDR/link pipes concurrently, so the tick's I/O time is
        the slowest device's — a balanced fleet divides the I/O wall by
        ``n`` while one hot shard drags every request with it."""
        snap = self._io_snapshot()
        io_s = 0.0
        for (dram, link), (m_dram, m_link) in zip(snap, self._io_mark):
            io_s = max(io_s,
                       (dram - m_dram) / self.sys.cxl_ddr_bw,
                       (link - m_link) / self.sys.cxl_link_bw)
        self._io_mark = snap
        self.model_time_s += max(1.0 / self.sys.cap_tok_s, io_s)

    def _retire(self):
        """Record + free finished sequences (their engines were already
        torn down in :meth:`step`, before the tick's time advance)."""
        for i, seq in enumerate(self.active):
            if seq is None or not seq.done:
                continue
            rec = seq.record
            rec.tokens = np.stack(seq.out, axis=1)
            rec.finish_step = self.clock
            rec.t_finish_s = self.model_time_s
            self.kv_committed_bytes -= rec.kv_charged_bytes
            self.active[i] = None

    # -- introspection -------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.active)

    def device_stats(self):
        return self.device.stats
