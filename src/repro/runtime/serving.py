"""Batched serving engine with TRACE-tiered KV offload.

End-to-end path (paper Fig. 1/6 mapped onto a TPU host):

  prefill  — jit'd full-prompt forward fills a jnp KV cache; completed
             pages (window of ``page_tokens``) are committed to the
             ``KVPagePool`` as BF16 token-major streams (the CXL.mem write
             stream of the paper).
  decode   — jit'd single-token step reads the *reconstructed* KV
             (HBM-resident pages exact; spilled pages served by the tier
             device at their policy precision) and appends new tokens.
  accounting — every step tallies bytes on HBM / CXL link / device DRAM
             from the pool's device stats; ``throughput_model()`` converts
             them to a tok/s ceiling with the paper's first-order model.

This engine is intentionally *functional* about the device: KV numerics
flow through the actual bit-plane + codec + precision pipeline, so serving
quality under a policy is measurable, not assumed.

I/O overlap (``async_io``, default on): spill readback goes through the
tier's queued front-end — tickets are issued at the commit boundary and
drained at the *next* one, so they are in flight across the jitted decode
step in between and their receipts carry overlap-adjusted latency instead
of serialized sync latency.  Tier reads are byte-identical either way
(the async queue preserves per-key program order), and under a lossless
policy generation is bit-identical to ``async_io=False`` (tested).  Under
a *lossy* policy the one-boundary deferral is visible: the decode steps
between issue and drain still attend over the pristine HBM values, so
tokens can differ from the serialized engine (freshly spilled pages serve
one extra boundary at full precision — the overlap hides, never adds,
degradation).  Total traffic is identical in all modes.

Multi-stream serving: :class:`MultiStreamEngine` runs N independent
sequences whose page pools share ONE tier device queue (per-stream key
namespaces).  In round-robin steady state every stream's boundary-issued
tickets accumulate in the shared window and the first stream to reach
its next commit boundary drains them as one coalesced cross-stream flush
group (see :meth:`KVPagePool.drain_reads`) — the many-stream sharing the
ROADMAP calls for.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.system_model import SystemSpec
from ..core.tier import Ticket, TierStore, make_device
from ..models import decode_step, forward, init_cache
from .paging import KVPagePool, PagePolicy, PAPER_POLICY, _Page

# One jitted step per distinct (frozen, hashable) ArchConfig, shared by
# every engine — N streams of the same model trace and compile once, not
# N times.
_jit_step = jax.jit(decode_step, static_argnums=0)


def _sample_next(logits: np.ndarray, rng: np.random.Generator,
                 greedy: bool) -> np.ndarray:
    """Next-token ids from last-position logits (one sampling path for
    single- and multi-stream generation)."""
    if greedy:
        return logits.argmax(-1).astype(np.int32)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.array([rng.choice(p.shape[-1], p=row) for row in p], np.int32)


@dataclasses.dataclass
class ServeStats:
    tokens_generated: int = 0
    prefill_tokens: int = 0
    hbm_page_bytes: int = 0
    tier_dram_read: int = 0
    tier_dram_stored: int = 0
    tier_link_out: int = 0
    spilled_pages: int = 0
    kv_logical_bytes: int = 0
    tier_io_service_s: float = 0.0      # serialized service time of all I/O
    tier_io_queue_delay_s: float = 0.0  # queueing on the shared DDR/link pipes

    @property
    def kv_compression_ratio(self) -> float:
        return self.kv_logical_bytes / max(
            self.tier_dram_stored + self.hbm_page_bytes, 1
        )


class ServeEngine:
    """Single-host serving of one model with paged, tiered KV."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_seq: int = 512,
        batch: int = 1,
        page_tokens: int = 64,
        hbm_kv_budget: int = 1 << 22,
        device_kind: Union[str, TierStore] = "trace",
        policy: PagePolicy = PAPER_POLICY,
        key_prefix: str = "",
        async_io: bool = True,
    ):
        assert not cfg.is_encoder_only, "serving needs a decoder"
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.page_tokens = page_tokens
        self.async_io = async_io
        self.pool = KVPagePool(
            device_kind, page_tokens, hbm_kv_budget, policy,
            key_prefix=key_prefix,
        )
        self.cache = init_cache(cfg, batch, max_seq)
        self.pos = 0
        self._inflight: List[Tuple[_Page, Ticket]] = []
        self._decode = lambda p, b, c: _jit_step(cfg, p, b, c)
        self._prefill = self._decode

    # -- helpers ---------------------------------------------------------------
    def _commit_pages(self, lo: int, hi: int):
        """Push completed KV windows [lo, hi) into the page pool."""
        # Tickets issued at the previous boundary were in flight across the
        # decode step that just ran — apply their data before committing.
        self.flush_io()
        layers = self.cache.get("layers", {})
        kv_keys = [k for k in ("k", "v", "c_kv") if k in layers]
        if not kv_keys:
            return  # SSM/hybrid: constant-size state, nothing paged
        # Gather every completed window across layers and kinds into one
        # batched admission: the spill this triggers goes to the device as
        # one write batch → one vectorized encode slab, instead of a
        # per-page pack+codec pipeline.
        batch_pages = []
        for start in range(lo - lo % self.page_tokens, hi, self.page_tokens):
            if start + self.page_tokens > hi:
                break
            for kind in kv_keys:
                buf = np.asarray(layers[kind])  # (L, B, S, ...) bf16
                n_layers = buf.shape[0]
                for layer in range(n_layers):
                    page = buf[layer, :, start : start + self.page_tokens]
                    tok = page.reshape(self.page_tokens * self.batch, -1)
                    u16 = np.ascontiguousarray(tok).view(np.uint16)
                    # recency as default importance; attention-mass updates
                    # arrive via pool.update_importance
                    batch_pages.append(
                        (layer, kind, start, u16, float(start))
                    )
        if batch_pages:
            self.pool.append_pages(batch_pages)
        self._issue_readback()

    def _issue_readback(self):
        """Start spill readback for this boundary's evictions.

        Sync mode reads and applies immediately (the pre-async behavior).
        Async mode only issues tickets: they ride the device's in-flight
        window across the next jitted decode step and are drained/applied
        by :meth:`flush_io` at the next commit boundary — decode and tier
        fetch overlap instead of serializing.
        """
        events, self.pool.spill_events = self.pool.spill_events, []
        if not events:
            return
        if self.async_io:
            self._inflight.extend(
                zip(events, self.pool.read_pages_async(events))
            )
        else:
            self._apply_readback(events, self.pool.read_pages(events))

    def flush_io(self):
        """Drain in-flight readback tickets and fold them into the cache."""
        if not self._inflight:
            return
        inflight, self._inflight = self._inflight, []
        pages = [p for p, _ in inflight]
        data = self.pool.drain_reads([t for _, t in inflight])
        self._apply_readback(pages, data)

    def _apply_readback(self, pages: Sequence[_Page],
                        data: Sequence[np.ndarray]):
        """Replace spilled pages' jnp-cache content with the tier-served
        values at their policy precision, so generation quality actually
        reflects the device pipeline (and DRAM reads are tallied).  All
        spilled pages of one boundary reach the device as a single request
        batch (vectorized plane decode on the device side)."""
        import ml_dtypes

        layers = dict(self.cache["layers"])
        touched = False
        for page, u16 in zip(pages, data):
            buf = np.asarray(layers[page.kind])
            target = buf[page.layer][:, page.start : page.start + self.page_tokens]
            vals = u16.view(ml_dtypes.bfloat16).reshape(target.shape)
            buf = buf.copy()
            buf[page.layer][:, page.start : page.start + self.page_tokens] = vals
            layers[page.kind] = buf
            touched = True
        if touched:
            self.cache = dict(self.cache)
            self.cache["layers"] = {
                k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
                for k, v in layers.items()
            }

    # -- API ---------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (batch, prompt_len) → last-token logits."""
        B, S = tokens.shape
        assert B == self.batch
        batch = {
            "tokens": jnp.asarray(tokens),
            "cache_pos": jnp.int32(self.pos),
        }
        logits, self.cache = self._prefill(self.params, batch, self.cache)
        old = self.pos
        self.pos += S
        self._commit_pages(old, self.pos)
        return np.asarray(logits[:, -1])

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (batch, 1) current token → next-token logits."""
        batch = {
            "tokens": jnp.asarray(tokens.reshape(self.batch, 1)),
            "cache_pos": jnp.int32(self.pos),
        }
        logits, self.cache = self._decode(self.params, batch, self.cache)
        old = self.pos
        self.pos += 1
        self._commit_pages(old, self.pos)
        return np.asarray(logits[:, -1])

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        logits = self.prefill(prompt)
        out = []
        for _ in range(n_tokens):
            nxt = _sample_next(logits, rng, greedy)
            out.append(nxt)
            logits = self.decode(nxt.reshape(-1, 1))
        return np.stack(out, axis=1)

    # -- KV readback through the tier (quality measurement path) ---------------
    def kv_through_tier(self, layer: int, kind: str = "k") -> np.ndarray:
        """Token-major KV for (layer, kind) as the host would see it after a
        round-trip through the tier at the current policy."""
        self.flush_io()
        return self.pool.read_layer(layer, kind)

    def layer_traffic(self):
        """Per-layer tier traffic, attributed from the pool's receipts."""
        self.flush_io()
        return self.pool.traffic_by_layer()

    def stats(self) -> ServeStats:
        self.flush_io()
        d = self.pool.stats()
        return ServeStats(
            tokens_generated=self.pos,
            hbm_page_bytes=self.pool.hbm_bytes,
            tier_dram_read=d.dram_bytes_read,
            tier_dram_stored=d.dram_bytes_stored,
            tier_link_out=d.link_bytes_out,
            spilled_pages=self.pool.spilled_pages,
            kv_logical_bytes=d.raw_bytes_stored + self.pool.hbm_bytes,
            tier_io_service_s=self.pool.io_service_s,
            tier_io_queue_delay_s=self.pool.io_queue_delay_s,
        )

    def throughput_ceiling(self, sys: SystemSpec = SystemSpec()) -> float:
        """tok/s ceiling implied by current per-step tier traffic."""
        d = self.pool.stats()
        steps = max(self.pos, 1)
        ddr_per_step = d.dram_bytes_read / steps
        link_per_step = d.link_bytes_out / steps
        t = max(ddr_per_step / sys.cxl_ddr_bw,
                link_per_step / sys.cxl_link_bw, 1e-12)
        return min(1.0 / t, sys.cap_tok_s)


class MultiStreamEngine:
    """N independent sequences sharing one tier device queue.

    Each stream is a full :class:`ServeEngine` (own jnp cache, own page
    pool, own HBM budget) but all pools write/read through a single
    :class:`TierStore`, namespaced by a per-stream key prefix.  Decode
    proceeds round-robin one token at a time: each round's readback
    tickets accumulate in the shared in-flight window, and the first
    stream whose commit boundary finds its tickets still queued drains
    the whole window — the device coalesces reads *across* streams into
    one vectorized slab decode, and receipts price the queueing on the
    shared DDR + link pipes.  The async queue preserves per-key program
    order, so stream results are bit-identical to running each stream
    alone.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        n_streams: int,
        *,
        device_kind: Union[str, TierStore] = "trace",
        async_io: bool = True,
        **engine_kw,
    ):
        self.device = (make_device(device_kind)
                       if isinstance(device_kind, str) else device_kind)
        self.streams = [
            ServeEngine(cfg, params, device_kind=self.device,
                        key_prefix=f"s{i}.", async_io=async_io, **engine_kw)
            for i in range(n_streams)
        ]

    def generate(self, prompts: Sequence[np.ndarray], n_tokens: int,
                 greedy: bool = True, seed: int = 0) -> List[np.ndarray]:
        """Round-robin generation; ``prompts[i]`` is stream *i*'s (batch,
        prompt_len) tokens.  Returns per-stream (batch, n_tokens) arrays."""
        assert len(prompts) == len(self.streams)
        rngs = [np.random.default_rng(seed + i) for i in range(len(prompts))]
        logits = [eng.prefill(p) for eng, p in zip(self.streams, prompts)]
        outs: List[List[np.ndarray]] = [[] for _ in self.streams]
        for _ in range(n_tokens):
            for i, eng in enumerate(self.streams):
                nxt = _sample_next(logits[i], rngs[i], greedy)
                outs[i].append(nxt)
                logits[i] = eng.decode(nxt.reshape(-1, 1))
        return [np.stack(o, axis=1) for o in outs]

    def flush_io(self):
        for eng in self.streams:
            eng.flush_io()

    def stats(self) -> List[ServeStats]:
        """Per-stream stats (shared-device aggregates are identical)."""
        return [eng.stats() for eng in self.streams]

    def device_stats(self):
        self.flush_io()
        return self.device.stats

    def throughput_ceiling(self, sys: SystemSpec = SystemSpec()) -> float:
        """Aggregate tok/s ceiling across streams on the shared device."""
        self.flush_io()
        d = self.device.stats
        steps = max(sum(eng.pos for eng in self.streams), 1)
        t = max(d.dram_bytes_read / steps / sys.cxl_ddr_bw,
                d.link_bytes_out / steps / sys.cxl_link_bw, 1e-12)
        return min(1.0 / t, sys.cap_tok_s)
