"""Batched serving engine with TRACE-tiered KV offload.

End-to-end path (paper Fig. 1/6 mapped onto a TPU host):

  prefill  — jit'd full-prompt forward fills a jnp KV cache; completed
             pages (window of ``page_tokens``) are committed to the
             ``KVPagePool`` as BF16 token-major streams (the CXL.mem write
             stream of the paper).
  decode   — jit'd single-token step reads the *reconstructed* KV
             (HBM-resident pages exact; spilled pages served by the tier
             device at their policy precision) and appends new tokens.
  accounting — every step tallies bytes on HBM / CXL link / device DRAM
             from the pool's device stats; ``throughput_model()`` converts
             them to a tok/s ceiling with the paper's first-order model.

This engine is intentionally *functional* about the device: KV numerics
flow through the actual bit-plane + codec + precision pipeline, so serving
quality under a policy is measurable, not assumed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.system_model import SystemSpec
from ..models import decode_step, forward, init_cache
from .paging import KVPagePool, PagePolicy, PAPER_POLICY


@dataclasses.dataclass
class ServeStats:
    tokens_generated: int = 0
    prefill_tokens: int = 0
    hbm_page_bytes: int = 0
    tier_dram_read: int = 0
    tier_dram_stored: int = 0
    tier_link_out: int = 0
    spilled_pages: int = 0
    kv_logical_bytes: int = 0

    @property
    def kv_compression_ratio(self) -> float:
        return self.kv_logical_bytes / max(
            self.tier_dram_stored + self.hbm_page_bytes, 1
        )


class ServeEngine:
    """Single-host serving of one model with paged, tiered KV."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_seq: int = 512,
        batch: int = 1,
        page_tokens: int = 64,
        hbm_kv_budget: int = 1 << 22,
        device_kind: str = "trace",
        policy: PagePolicy = PAPER_POLICY,
    ):
        assert not cfg.is_encoder_only, "serving needs a decoder"
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.page_tokens = page_tokens
        self.pool = KVPagePool(
            device_kind, page_tokens, hbm_kv_budget, policy
        )
        self.cache = init_cache(cfg, batch, max_seq)
        self.pos = 0
        self._decode = jax.jit(
            lambda p, b, c: decode_step(cfg, p, b, c)
        )
        self._prefill = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))

    # -- helpers ---------------------------------------------------------------
    def _commit_pages(self, lo: int, hi: int):
        """Push completed KV windows [lo, hi) into the page pool."""
        layers = self.cache.get("layers", {})
        kv_keys = [k for k in ("k", "v", "c_kv") if k in layers]
        if not kv_keys:
            return  # SSM/hybrid: constant-size state, nothing paged
        for start in range(lo - lo % self.page_tokens, hi, self.page_tokens):
            if start + self.page_tokens > hi:
                break
            for kind in kv_keys:
                buf = np.asarray(layers[kind])  # (L, B, S, ...) bf16
                n_layers = buf.shape[0]
                for layer in range(n_layers):
                    page = buf[layer, :, start : start + self.page_tokens]
                    tok = page.reshape(self.page_tokens * self.batch, -1)
                    u16 = np.ascontiguousarray(tok).view(np.uint16)
                    # recency as default importance; attention-mass updates
                    # arrive via pool.update_importance
                    self.pool.append_page(
                        layer, kind, start, u16, importance=float(start)
                    )
        self._apply_spill_readback()

    def _apply_spill_readback(self):
        """Replace spilled pages' jnp-cache content with the tier-served
        values at their policy precision, so generation quality actually
        reflects the device pipeline (and DRAM reads are tallied).  All
        spilled pages of one commit go to the device as a single request
        batch (vectorized plane decode on the device side)."""
        import ml_dtypes

        events, self.pool.spill_events = self.pool.spill_events, []
        if not events:
            return
        layers = dict(self.cache["layers"])
        touched = False
        for page, u16 in zip(events, self.pool.read_pages(events)):
            buf = np.asarray(layers[page.kind])
            target = buf[page.layer][:, page.start : page.start + self.page_tokens]
            vals = u16.view(ml_dtypes.bfloat16).reshape(target.shape)
            buf = buf.copy()
            buf[page.layer][:, page.start : page.start + self.page_tokens] = vals
            layers[page.kind] = buf
            touched = True
        if touched:
            self.cache = dict(self.cache)
            self.cache["layers"] = {
                k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
                for k, v in layers.items()
            }

    # -- API ---------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (batch, prompt_len) → last-token logits."""
        B, S = tokens.shape
        assert B == self.batch
        batch = {
            "tokens": jnp.asarray(tokens),
            "cache_pos": jnp.int32(self.pos),
        }
        logits, self.cache = self._prefill(self.params, batch, self.cache)
        old = self.pos
        self.pos += S
        self._commit_pages(old, self.pos)
        return np.asarray(logits[:, -1])

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (batch, 1) current token → next-token logits."""
        batch = {
            "tokens": jnp.asarray(tokens.reshape(self.batch, 1)),
            "cache_pos": jnp.int32(self.pos),
        }
        logits, self.cache = self._decode(self.params, batch, self.cache)
        old = self.pos
        self.pos += 1
        self._commit_pages(old, self.pos)
        return np.asarray(logits[:, -1])

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        logits = self.prefill(prompt)
        out = []
        for _ in range(n_tokens):
            if greedy:
                nxt = logits.argmax(-1).astype(np.int32)
            else:
                p = np.exp(logits - logits.max(-1, keepdims=True))
                p /= p.sum(-1, keepdims=True)
                nxt = np.array(
                    [rng.choice(p.shape[-1], p=row) for row in p], np.int32
                )
            out.append(nxt)
            logits = self.decode(nxt.reshape(-1, 1))
        return np.stack(out, axis=1)

    # -- KV readback through the tier (quality measurement path) ---------------
    def kv_through_tier(self, layer: int, kind: str = "k") -> np.ndarray:
        """Token-major KV for (layer, kind) as the host would see it after a
        round-trip through the tier at the current policy."""
        return self.pool.read_layer(layer, kind)

    def layer_traffic(self):
        """Per-layer tier traffic, attributed from the pool's receipts."""
        return self.pool.traffic_by_layer()

    def stats(self) -> ServeStats:
        d = self.pool.stats()
        return ServeStats(
            tokens_generated=self.pos,
            hbm_page_bytes=self.pool.hbm_bytes,
            tier_dram_read=d.dram_bytes_read,
            tier_dram_stored=d.dram_bytes_stored,
            tier_link_out=d.link_bytes_out,
            spilled_pages=self.pool.spilled_pages,
            kv_logical_bytes=d.raw_bytes_stored + self.pool.hbm_bytes,
        )

    def throughput_ceiling(self, sys: SystemSpec = SystemSpec()) -> float:
        """tok/s ceiling implied by current per-step tier traffic."""
        d = self.pool.stats()
        steps = max(self.pos, 1)
        ddr_per_step = d.dram_bytes_read / steps
        link_per_step = d.link_bytes_out / steps
        t = max(ddr_per_step / sys.cxl_ddr_bw,
                link_per_step / sys.cxl_link_bw, 1e-12)
        return min(1.0 / t, sys.cap_tok_s)
