from .paging import KVPagePool, PagePolicy, PAPER_POLICY
from .serving import ServeEngine, ServeStats
from .weights import WeightStore

__all__ = ["KVPagePool", "PagePolicy", "PAPER_POLICY", "ServeEngine",
           "ServeStats", "WeightStore"]
