from .paging import KVPagePool, PagePolicy, PAPER_POLICY
from .serving import MultiStreamEngine, ServeEngine, ServeStats
from .weights import WeightStore

__all__ = ["KVPagePool", "PagePolicy", "PAPER_POLICY", "MultiStreamEngine",
           "ServeEngine", "ServeStats", "WeightStore"]
