from .paging import (
    DEFAULT_DEGRADE_LADDER, KVPagePool, LOSSLESS_POLICY, PagePolicy,
    PAPER_POLICY,
)
from .serving import (
    MultiStreamEngine, RequestRecord, SchedulerReport, ServeEngine,
    ServeRequest, ServeScheduler, ServeStats, projected_kv_bytes,
)
from .weights import WeightStore

__all__ = ["DEFAULT_DEGRADE_LADDER", "KVPagePool", "LOSSLESS_POLICY",
           "PagePolicy", "PAPER_POLICY", "MultiStreamEngine",
           "RequestRecord", "SchedulerReport", "ServeEngine", "ServeRequest",
           "ServeScheduler", "ServeStats", "WeightStore",
           "projected_kv_bytes"]
