"""Weight offload through the TRACE tier with elastic per-unit precision.

The paper's second traffic stream (§IV-D): weights are re-read every
decode step; when they spill past HBM, the tier serves them — and a
TRACE device can serve each *unit* (expert / attention head / MLP
neuron) at its runtime-assigned precision view via plane-aligned fetch
(Granularity I/II), while word devices always move full containers.

``WeightStore`` keeps the per-step accounting honest the same way the
KV pool does: weights written once (bit-plane compressed on TRACE),
``fetch`` returns the reconstructed tensor at the requested view and
tallies device-DRAM/link bytes, so a serving loop can measure the
traffic ratio between importance policies — the Fig. 18-21 experiment
with real bytes instead of the structural model.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..core.precision import FULL, MAN0, MAN2, MAN4, PrecisionView
from ..core.tier import ReadReq, TierStore, WriteReq, make_device

# Precision tiers by unit importance rank-fraction (Fig. 17-style mix).
DEFAULT_TIERS = ((0.4, FULL), (0.3, MAN4), (0.2, MAN2), (0.1, MAN0))


@dataclasses.dataclass
class UnitMeta:
    name: str
    shape: tuple
    importance: float


class WeightStore:
    """Unit-granular weight storage on a tier device.

    Units are tensors the runtime fetches independently (an expert's FFN
    matrices, one head's projections, ...).  Importance drives the view.
    """

    def __init__(self, device: TierStore | str = "trace",
                 tiers=DEFAULT_TIERS):
        self.device = make_device(device) if isinstance(device, str) else device
        self.tiers = tiers
        self._units: Dict[str, UnitMeta] = {}

    # -- write once ------------------------------------------------------------
    def put(self, name: str, w: np.ndarray, importance: float = 1.0):
        self.put_many({name: (w, importance)})

    def put_many(self, units: Dict[str, tuple]):
        """Load a batch of units — ``name -> (array, importance)`` — in one
        submit.

        The model-load path is write-heavy by construction (every unit
        streams through the tier exactly once); batching the WriteReqs
        lets the device encode the whole load as a few vectorized slab
        passes instead of a per-unit pack+codec pipeline.
        """
        import ml_dtypes

        reqs, metas = [], []
        for name, (w, importance) in units.items():
            u16 = np.ascontiguousarray(
                w, dtype=ml_dtypes.bfloat16).view(np.uint16)
            reqs.append(WriteReq(name, u16, tag=name))
            metas.append(UnitMeta(name, np.shape(w), importance))
        if reqs:
            self.device.submit(reqs)
            # register only after the store accepted the batch, so a
            # failed submit cannot leave metadata for absent units
            for meta in metas:
                self._units[meta.name] = meta

    def set_importance(self, scores: Dict[str, float]):
        for k, v in scores.items():
            if k in self._units:
                self._units[k].importance = v

    # -- view assignment --------------------------------------------------------
    def view_for(self, name: str) -> PrecisionView:
        ranked = sorted(self._units.values(), key=lambda u: -u.importance)
        idx = next(i for i, u in enumerate(ranked) if u.name == name)
        frac = (idx + 0.5) / max(len(ranked), 1)
        acc = 0.0
        for width, view in self.tiers:
            acc += width
            if frac <= acc:
                return view
        return self.tiers[-1][1]

    # -- read per step ------------------------------------------------------------
    def fetch(self, name: str, view: PrecisionView | None = None) -> np.ndarray:
        import ml_dtypes

        view = view or self.view_for(name)
        rec, = self.device.submit([ReadReq(name, view=view, tag=name)])
        return rec.data.view(ml_dtypes.bfloat16).reshape(self._units[name].shape)

    def fetch_all(self) -> Dict[str, np.ndarray]:
        """One batched submit for every unit at its policy view — the
        per-decode-step weight stream as a single request batch."""
        import ml_dtypes

        reqs = [ReadReq(n, view=self.view_for(n), tag=n) for n in self._units]
        recs = self.device.submit(reqs)
        return {
            n: r.data.view(ml_dtypes.bfloat16).reshape(self._units[n].shape)
            for n, r in zip(self._units, recs)
        }

    # -- accounting ----------------------------------------------------------------
    @property
    def stats(self):
        return self.device.stats

    def avg_bits(self) -> float:
        views = [self.view_for(n) for n in self._units]
        return float(np.mean([v.bits for v in views])) if views else 16.0
