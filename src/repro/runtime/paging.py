"""Paged KV management over an HBM + CXL(TRACE) tier (paper §II-C, Table II).

KV is managed as fixed-size pages (a window of tokens for all channels of
one layer's K or V).  Pages live in HBM while the hot budget lasts; the
long tail spills to the offload tier (a ``core.tier`` :class:`TierStore`
— Plain, GComp or TRACE).  Page *importance* is long-tailed, so spilled
pages are assigned precision tiers, which a plane-aligned layout serves
with plane-aligned fetch (Mechanism II) — word layouts must always move
full containers (paper Issue 2).

The pool speaks only the TierStore request protocol: spills are
``WriteReq`` submissions, reads are batched ``ReadReq`` submissions (one
``submit`` per layer gather / spill-readback), and every receipt is folded
into per-page traffic counters so attribution is per-page / per-layer
rather than one global stats blob.

The shipped policy mirrors Table II's best row:
    top pages   → BF16 (full, lossless)
    next tier   → ~FP8  (man4 view + guard round: 1+8+4 visible bits)
    cold tail   → ~FP4  (man0 view + guard round: sign+exp only)
KV views keep the full (delta) exponent planes — they are the cheapest,
most compressible planes — and scale mantissa planes only (precision.py).

Physical-footprint accounting + precision-elastic reclamation: eviction
and spill already move *physical* bytes (HBM pages are raw BF16; spilled
pages occupy their post-compression footprint on the device, tracked by
the tier's residency ledger).  ``physical_kv_bytes`` reports the pool's
live physical footprint (HBM + device ledger), and :meth:`reclaim` frees
device bytes *without dropping tokens*: it walks cold spilled pages —
least-recent commit boundary first — applying the next rung of a
configurable degradation ladder of ``PrecisionView`` s via
``TierStore.truncate_planes`` (paper §III-C's in-place plane shedding),
until the requested bytes are reclaimed or the ladder is exhausted.
Word-layout devices cannot shed planes; reclaim then reports 0.

Shared-prefix KV reuse: pools wired to one :class:`PrefixShareIndex`
store identical completed *prompt-prefix* pages once, under a
content-addressed ``shared.`` namespace keyed by a chained token-window
hash (:func:`prefix_chain_hashes`).  The first pool to spill a window
writes it; later pools acquire a refcounted ledger reference instead of
writing (copy-on-write: windows past the token divergence point hash
differently and stay private).  A shared page frees when its last
referer retires, and degrades only while singly-referenced.
"""

from __future__ import annotations

import dataclasses
import hashlib
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.precision import FULL, MAN0, MAN2, MAN4, PrecisionView
from ..core.tier import (
    GatherReq, KV, ReadReq, Receipt, Ticket, TierStore, WriteReq, make_device,
)


@dataclasses.dataclass(frozen=True)
class PagePolicy:
    """Importance-ranked precision assignment for *spilled* pages."""

    tiers: tuple = ((5, FULL), (3, MAN4), (2, MAN0))   # (count, view) in rank order
    tail_view: PrecisionView = MAN0                     # beyond listed tiers

    def view_for_rank(self, rank: int) -> PrecisionView:
        acc = 0
        for count, view in self.tiers:
            acc += count
            if rank < acc:
                return view
        return self.tail_view

    def avg_bits(self, n_pages: int) -> float:
        if n_pages == 0:
            return 16.0
        return float(
            np.mean([self.view_for_rank(r).bits for r in range(n_pages)])
        )


PAPER_POLICY = PagePolicy()           # Table II: 5×BF16 / 3×FP8 / 2×FP4
LOSSLESS_POLICY = PagePolicy(tiers=((1 << 30, FULL),), tail_view=FULL)


def prefix_chain_hashes(tokens: np.ndarray, page_tokens: int) -> List[str]:
    """Chained content hashes of the leading full token windows.

    ``hashes[w]`` digests tokens ``[0, (w+1)*page_tokens)`` (all batch
    rows), so it names the *entire* prefix up to the end of window ``w``
    — exactly what the KV values of that window are a causal function
    of.  Two requests get equal ``hashes[w]`` iff their prompts agree on
    every token through that window, which is the copy-on-write
    divergence rule: windows past the first differing token chain to
    different digests and stay private.  Only full windows hash; a
    partial tail (or a window containing generated tokens) never
    shares.
    """
    h = hashlib.sha1(str(page_tokens).encode())
    out: List[str] = []
    arr = np.ascontiguousarray(tokens)
    for w in range(arr.shape[-1] // page_tokens):
        h.update(arr[..., w * page_tokens:(w + 1) * page_tokens].tobytes())
        out.append(h.hexdigest()[:16])
    return out


def shared_page_key(share_hash: str, layer: int, kind: str) -> str:
    """Content-addressed device key of one shared page: the chain hash
    names the token prefix, layer/kind select the tensor — every request
    whose prompt contains that prefix computes the same key."""
    return f"shared.{share_hash}.L{layer}.{kind}"


class PrefixShareIndex:
    """Content-addressed index of shared prefix pages on one device.

    Maps a prefix chain hash (see :func:`prefix_chain_hashes`) to the
    ``shared.`` device keys holding that window's KV pages.  Pools
    sharing a device (one :class:`ServeScheduler`'s engines) consult it
    at spill time: the first pool to spill a window writes the page and
    registers it; every later pool with an identical prompt prefix
    *acquires* a reference (``TierStore.acquire``) instead of writing —
    one stored copy, refcounted in the residency ledger, freed when the
    last referer retires.  All pools must serve one model: the hash
    names tokens, and identical tokens only imply identical KV under
    identical params.
    """

    def __init__(self, device: TierStore):
        self.device = device
        # chain hash → {(layer, kind): key}; only live (stored) pages
        self._nodes: Dict[str, Dict[Tuple[int, str], str]] = {}
        self._owner: Dict[str, Tuple[str, Tuple[int, str]]] = {}

    def acquire(self, share_hash: str, layer: int, kind: str) -> Optional[str]:
        """Take a reference on the stored copy of (hash, layer, kind),
        or return None when no pool has stored it yet."""
        key = self._nodes.get(share_hash, {}).get((layer, kind))
        if key is None:
            return None
        self.device.acquire(key)
        return key

    def register(self, share_hash: str, layer: int, kind: str, key: str):
        """Record a freshly written shared page (writer holds the first
        reference via its commit)."""
        self._nodes.setdefault(share_hash, {})[(layer, kind)] = key
        self._owner[key] = (share_hash, (layer, kind))

    def invalidate(self, key: str):
        """Drop a page from the index without releasing it — called
        before a sole-referer page is degraded in place, so no future
        request acquires (and decodes) the truncated copy."""
        owner = self._owner.pop(key, None)
        if owner is None:
            return
        share_hash, slot = owner
        node = self._nodes.get(share_hash)
        if node is not None:
            node.pop(slot, None)
            if not node:
                self._nodes.pop(share_hash, None)

    def release(self, key: str) -> int:
        """Drop one reference; unindex the page when the last retires.
        Returns the remaining reference count."""
        left = self.device.release(key)
        if left == 0:
            self.invalidate(key)
        return left

    def resident_chain(self, hashes: Sequence[str]) -> int:
        """How many *leading* windows of this hash chain have live shared
        pages — the scheduler's novel-KV admission discount."""
        n = 0
        for h in hashes:
            if not self._nodes.get(h):
                break
            n += 1
        return n

# Default precision-elastic degradation ladder: each reclaim rung sheds
# further mantissa planes of cold stored pages in place (Table II's
# BF16 → ~FP8 → ~FP4 progression, applied as a *storage* knob).
DEFAULT_DEGRADE_LADDER = (MAN4, MAN2, MAN0)


@dataclasses.dataclass
class _Page:
    key: str                  # stream id on the device
    layer: int
    kind: str                 # "k" | "v"
    start: int                # first token index
    n_tokens: int
    importance: float = 0.0
    resident: Optional[np.ndarray] = None   # HBM copy (token-major u16) or None
    commit_seq: int = 0       # commit boundary that admitted this page (LRU)
    degrade_level: int = -1   # last degradation-ladder rung applied
    share_hash: Optional[str] = None  # prefix chain hash (shareable window)
    shared_ref: bool = False  # this pool holds a ledger ref on a shared key
    gather_view: Optional[PrecisionView] = None  # frozen PNM winner view


@dataclasses.dataclass
class PageTraffic:
    """Per-page roll-up of the receipts this pool has seen."""

    dram_bytes_read: int = 0
    dram_bytes_written: int = 0
    link_bytes_in: int = 0
    link_bytes_out: int = 0
    index_bytes: int = 0
    device_compute_s: float = 0.0
    requests: int = 0

    def add(self, r: Receipt):
        """Fold one receipt in (field names shared with Receipt)."""
        for f in dataclasses.fields(self):
            if f.name != "requests":
                setattr(self, f.name, getattr(self, f.name) + getattr(r, f.name))
        self.requests += 1

    def merge(self, other: "PageTraffic"):
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


class KVPagePool:
    """Per-sequence paged KV with HBM budget + tier spill.

    Host arrays are BF16-as-uint16, token-major ``(tokens, channels)`` —
    exactly the stream a real host would store through CXL.mem.
    """

    # Importance-feedback bookkeeping: scores submitted for keys this
    # pool does not track (see update_importance).
    unknown_importance_keys: int = 0

    def __init__(
        self,
        device: TierStore | str = "trace",
        page_tokens: int = 64,
        hbm_budget_bytes: int = 1 << 30,
        policy: PagePolicy = PAPER_POLICY,
        key_prefix: str = "",
        degrade_ladder: Sequence[PrecisionView] = (),
        sanitize: Optional[bool] = None,
        prefix_index: Optional[PrefixShareIndex] = None,
        strict_importance: bool = False,
    ):
        self.device = (make_device(device, sanitize=sanitize)
                       if isinstance(device, str) else device)
        self.page_tokens = page_tokens
        self.hbm_budget = hbm_budget_bytes
        self.policy = policy
        self.key_prefix = key_prefix        # stream namespace on a shared device
        self.degrade_ladder = tuple(degrade_ladder)
        if prefix_index is not None and prefix_index.device is not self.device:
            raise ValueError(
                "prefix_index must be built on this pool's device — shared "
                "pages are acquired from the device the index registers "
                "them on"
            )
        self.prefix_index = prefix_index
        # Importance-score hygiene (see update_importance): unknown keys
        # are counted (and warned about once); strict mode raises.
        self.strict_importance = strict_importance
        self.unknown_importance_keys = 0
        self._warned_unknown_importance = False
        self._pages: List[_Page] = []
        self._commit_clock = 0              # commit boundaries seen (page LRU)
        self._hbm_used = 0
        self.spill_events: List[_Page] = []   # drained by the serving engine
        self.page_traffic: Dict[str, PageTraffic] = {}
        # key → [Ticket, view-at-issue, Receipt | None]; the receipt slot
        # memoizes exactly-once accounting (see _settle_prefetch)
        self._prefetched: Dict[str, list] = {}
        # I/O latency roll-up from this pool's receipts (simulated seconds).
        self.io_service_s = 0.0       # serialized service time
        self.io_queue_delay_s = 0.0   # time spent queued behind other I/O
        # One page per KV window: the device commits each page's stream in
        # a single transform window.
        self.device.kv_window = page_tokens

    def _account(self, receipts: Sequence[Receipt]):
        for r in receipts:
            self.page_traffic.setdefault(r.key, PageTraffic()).add(r)
            self.io_service_s += r.service_s
            self.io_queue_delay_s += r.queue_delay_s

    def traffic_by_layer(self) -> Dict[int, PageTraffic]:
        """Aggregate per-page traffic up to layers (key format L{n}.*)."""
        out: Dict[int, PageTraffic] = {}
        for p in self._pages:
            t = self.page_traffic.get(p.key)
            if t is not None:
                out.setdefault(p.layer, PageTraffic()).merge(t)
        return out

    # -- write path -----------------------------------------------------------
    def append_page(self, layer: int, kind: str, start: int,
                    tokens_u16: np.ndarray, importance: float = 0.0):
        """Commit one full page (token-major (n, C) uint16)."""
        self.append_pages([(layer, kind, start, tokens_u16, importance)])

    def append_pages(self, pages: Sequence[tuple]):
        """Commit a batch of pages — ``(layer, kind, start, tokens_u16,
        importance)`` each, with an optional sixth ``share_hash`` element
        (see :func:`prefix_chain_hashes`) — with ONE eviction pass at the
        end.

        A commit boundary admits every layer's K and V windows at once;
        batching them turns the resulting spill into one write batch, which
        the device encodes as a single vectorized slab (pack + codec a few
        passes for the whole group) instead of per-page pipelines.

        Share-tagged pages take the content-addressed ``shared.`` key
        instead of this pool's private namespace; residency and eviction
        behave exactly as for private pages (so solo-run differentials
        hold), but the spill write is elided when an identical page is
        already stored — the pool acquires a ledger reference instead.
        """
        self._commit_clock += 1
        for entry in pages:
            layer, kind, start, tokens_u16, importance = entry[:5]
            share_hash = entry[5] if len(entry) > 5 else None
            if share_hash is not None and self.prefix_index is not None:
                key = shared_page_key(share_hash, layer, kind)
            else:
                share_hash = None
                key = f"{self.key_prefix}L{layer}.{kind}.{start}"
            page = _Page(key, layer, kind, start, tokens_u16.shape[0],
                         importance=importance,
                         commit_seq=self._commit_clock,
                         share_hash=share_hash)
            # Always admit to HBM first, then evict the least-important
            # pages (possibly this one) — importance, not arrival order,
            # decides residency (paper §II-C: importance is long-tailed).
            page.resident = tokens_u16.copy()
            self._hbm_used += tokens_u16.size * 2
            self._pages.append(page)
        self._rebalance()

    def _rebalance(self):
        """Evict the least-important resident pages when over budget."""
        if self._hbm_used <= self.hbm_budget:
            return
        resident = sorted(
            (p for p in self._pages if p.resident is not None),
            key=lambda p: p.importance,
        )
        writes = []
        fresh_shared: List[_Page] = []
        for p in resident:
            if self._hbm_used <= self.hbm_budget:
                break
            tok = p.resident
            self._hbm_used -= tok.size * 2
            p.resident = None
            self.spill_events.append(p)
            if p.share_hash is not None and self.prefix_index is not None:
                if self.prefix_index.acquire(
                        p.share_hash, p.layer, p.kind) is not None:
                    # Identical page already stored by another referer:
                    # take a ledger reference, skip the spill write.
                    p.shared_ref = True
                    continue
                fresh_shared.append(p)
            writes.append(WriteReq(p.key, tok, kind=KV, flush=True, tag=p.key))
        if writes:
            # Post through the async front-end: spill writes commit eagerly
            # either way, but submit_async leaves queued readback/prefetch
            # tickets in flight instead of forcing them to drain.
            self._account([t.wait() for t in self.device.submit_async(writes)])
        for p in fresh_shared:
            # First writer of this prefix window: the commit's initial
            # reference is this pool's claim; index it for later arrivals.
            self.prefix_index.register(p.share_hash, p.layer, p.kind, p.key)
            p.shared_ref = True

    def update_importance(self, scores: Dict[str, float],
                          strict: Optional[bool] = None):
        """Re-rank pages by externally measured importance (attention
        mass from the serving engine, see ``ServeEngine``'s
        ``importance="attention"`` mode), then rebalance residency.

        Scores for keys this pool does not track (retired pages, typo'd
        namespaces) used to be dropped silently, quietly skewing
        reclamation; they are now counted in
        ``unknown_importance_keys`` and warned about once per pool.
        Strict mode (the ``strict`` argument, defaulting to the pool's
        ``strict_importance`` flag) raises ``KeyError`` instead, so
        stale-key bugs surface at the call site."""
        unknown = [k for k in scores
                   if k not in {p.key for p in self._pages}]
        if unknown:
            self.unknown_importance_keys += len(unknown)
            if self.strict_importance if strict is None else strict:
                raise KeyError(
                    f"importance scores for {len(unknown)} unknown page "
                    f"key(s), e.g. {sorted(unknown)[:3]}"
                )
            if not self._warned_unknown_importance:
                self._warned_unknown_importance = True
                warnings.warn(
                    f"update_importance dropped scores for {len(unknown)} "
                    f"unknown page key(s) (e.g. {sorted(unknown)[:3]}); "
                    "see KVPagePool.unknown_importance_keys",
                    stacklevel=2,
                )
        for p in self._pages:
            if p.key in scores:
                p.importance = scores[p.key]
        self._rebalance()

    # -- read path --------------------------------------------------------------
    def _spill_ranks(self, pages=None) -> Dict[str, int]:
        spilled = sorted(
            (p for p in (pages if pages is not None else self._pages)
             if p.resident is None),
            key=lambda p: -p.importance,
        )
        return {p.key: i for i, p in enumerate(spilled)}

    def read_page(self, page: _Page) -> np.ndarray:
        """One spilled page through the tier at its current policy view."""
        return self.read_pages([page])[0]

    def _page_reqs(self, pages: Sequence[_Page]) -> List[ReadReq]:
        rank = self._spill_ranks()
        return [
            ReadReq(p.key, kind=KV, view=self.policy.view_for_rank(rank[p.key]),
                    tag=p.key)
            for p in pages
        ]

    def read_pages(self, pages: Sequence[_Page]) -> List[np.ndarray]:
        """Batched tier read of spilled pages (one submit for the batch)."""
        receipts = self.device.submit(self._page_reqs(pages))
        self._account(receipts)
        return [r.data for r in receipts]

    def read_pages_async(self, pages: Sequence[_Page]) -> List[Ticket]:
        """Issue spill-readback tickets for ``pages`` without waiting.

        The reads join the device's in-flight window (coalescing with any
        other stream's queued reads) and execute when the window fills or
        :meth:`drain_reads` forces completion — the serving engine calls
        that at the next commit boundary, after the jitted decode step the
        tickets overlapped with.  Views are fixed at issue time from the
        current spill ranks, so a later drain reads the same bytes a sync
        read here would have.
        """
        return self.device.submit_async(self._page_reqs(pages))

    # -- PNM read path: device-side top-k gather -------------------------------
    def _gather_req(self, pages: Sequence[_Page], digest: np.ndarray,
                    k: int) -> GatherReq:
        """Build one :class:`GatherReq` over ``pages``.

        Each candidate's full-precision winner view is FROZEN at its
        first gather — the policy view at the spill ranks of that moment,
        exactly the view the classic readback (:meth:`read_pages`) would
        have issued for the page at its spill boundary.  Later rank
        drift therefore never changes the bytes a winner ships, which is
        what keeps ``k >= len(candidates)`` bit-identical to the full
        readback path across sync/async submission and shard counts."""
        rank = None
        for p in pages:
            if p.gather_view is None:
                if rank is None:
                    rank = self._spill_ranks()
                p.gather_view = self.policy.view_for_rank(rank[p.key])
        return GatherReq(
            keys=tuple(p.key for p in pages),
            digest=np.asarray(digest, dtype=np.float32),
            k=int(k),
            kind=KV,
            views=tuple(p.gather_view for p in pages),
            tag=pages[0].key,
        )

    def gather_topk(self, digest: np.ndarray, k: int,
                    pages: Optional[Sequence[_Page]] = None,
                    ) -> Tuple[List[_Page], List[np.ndarray]]:
        """Device-side top-k over spilled pages — the PNM replacement for
        full spill readback.  ONE ``GatherReq`` scores every candidate on
        the reduced ``score_view`` plane subset against ``digest`` and
        ships full (frozen-view) precision for only the ``k`` winners, so
        link bytes are O(k · page) + one score-plane pass instead of
        O(candidates · page).  Returns ``(winner_pages, data)`` in score
        order; ``k >= len(candidates)`` returns every candidate's exact
        :meth:`read_pages` bytes (tested differential)."""
        cands = [p for p in (pages if pages is not None else self._pages)
                 if p.resident is None]
        if not cands:
            return [], []
        rec = self.device.submit([self._gather_req(cands, digest, k)])[0]
        self._account([rec])
        by_key = {p.key: p for p in cands}
        return [by_key[kk] for kk in rec.gather.keys], rec.gather.data

    def gather_topk_async(self, digest: np.ndarray, k: int,
                          pages: Optional[Sequence[_Page]] = None,
                          ) -> Tuple[List[_Page], Optional[Ticket]]:
        """Issue :meth:`gather_topk` through the async front-end: the
        gather rides the device's in-flight window across the next decode
        step.  Returns ``(candidates, ticket)`` for :meth:`drain_gather`
        (``([], None)`` when nothing is spilled)."""
        cands = [p for p in (pages if pages is not None else self._pages)
                 if p.resident is None]
        if not cands:
            return [], None
        ticket = self.device.submit_async(
            [self._gather_req(cands, digest, k)])[0]
        return cands, ticket

    def drain_gather(self, cands: Sequence[_Page], ticket: Optional[Ticket],
                     ) -> Tuple[List[_Page], List[np.ndarray]]:
        """Wait one gather ticket, fold its receipt into pool traffic,
        and map the winners back to pages → ``(winner_pages, data)``."""
        if ticket is None:
            return [], []
        rec = ticket.wait()
        self._account([rec])
        by_key = {p.key: p for p in cands}
        return [by_key[kk] for kk in rec.gather.keys], rec.gather.data

    def drain_reads(self, tickets: Sequence[Ticket]) -> List[np.ndarray]:
        """Wait on readback tickets, folding receipts into pool traffic.

        If any waited ticket is still queued, this drains the device's
        WHOLE in-flight window, not just these tickets' queue prefix: when
        several streams share one device, the first stream to reach its
        commit boundary flushes every stream's queued reads as one
        coalesced group (cross-stream slab decode, shared-pipe queue-delay
        pricing).  Pools whose tickets were completed by someone else's
        drain just collect receipts without touching the queue — so they
        never prematurely flush tickets issued after theirs.
        """
        if not tickets:
            return []
        if any(not t.done for t in tickets):
            receipts = self.device.drain(tickets)
        else:
            receipts = [t.wait() for t in tickets]
        self._account(receipts)
        return [r.data for r in receipts]

    def prefetch_layer(self, layer: int, kind: str) -> int:
        """Issue async read tickets for (layer, kind)'s spilled pages so a
        following :meth:`read_layer` is served from the in-flight window.
        Returns the number of tickets issued (0 if everything is resident
        or already in flight)."""
        subset = [p for p in self._pages
                  if p.layer == layer and p.kind == kind]
        pages = [p for p in subset
                 if p.resident is None and p.key not in self._prefetched]
        if not pages:
            return 0
        # Rank within the (layer, kind) subset — the same basis read_layer
        # will use — so the issued views match and the prefetch is consumed
        # rather than discarded and re-read.
        rank = self._spill_ranks(subset)
        views = {p.key: self.policy.view_for_rank(rank[p.key]) for p in pages}
        reqs = [ReadReq(p.key, kind=KV, view=views[p.key], tag=p.key)
                for p in pages]
        for p, t in zip(pages, self.device.submit_async(reqs)):
            # entry: [ticket, view_at_issue, receipt-once-accounted]
            self._prefetched[p.key] = [t, views[p.key], None]
        return len(pages)

    def _settle_prefetch(self, entry) -> Receipt:
        """Wait a prefetch ticket, folding its receipt into the pool's
        accounting exactly once (idempotent across settle/consume)."""
        if entry[2] is None:
            entry[2] = entry[0].wait()
            self._account([entry[2]])
        return entry[2]

    def settle_prefetched(self):
        """Account every prefetch ticket the device has already executed.

        A prefetch can be flushed by unrelated traffic (window overflow,
        another stream's sync read) before its ``read_layer`` arrives; its
        bytes are then in ``device.stats`` but not yet in this pool's
        receipts.  Settling keeps the receipts-sum == device-stats
        conservation invariant without forcing pending tickets to execute
        (a still-queued prefetch is counted on neither side).  The settled
        data stays available for a later :meth:`read_layer`.
        """
        for entry in self._prefetched.values():
            if entry[0].done and entry[2] is None:
                self._settle_prefetch(entry)

    def read_layer(self, layer: int, kind: str) -> np.ndarray:
        """Gather all pages of (layer, kind) in token order, applying the
        precision policy to spilled pages (ranked by importance).  Spilled
        pages come from matching prefetch tickets when available; the rest
        go to the device as one request batch."""
        pages = sorted(
            (p for p in self._pages if p.layer == layer and p.kind == kind),
            key=lambda p: p.start,
        )
        rank = self._spill_ranks(pages)
        served: Dict[str, np.ndarray] = {}
        reqs = []
        for p in pages:
            if p.resident is not None:
                continue
            view = self.policy.view_for_rank(rank[p.key])
            pf = self._prefetched.pop(p.key, None)
            if pf is not None:
                rec = self._settle_prefetch(pf)
                if pf[1] == view:
                    served[p.key] = rec.data
                    continue
                # rank drifted since prefetch: traffic stays accounted,
                # data is re-read at the now-correct view
            reqs.append(ReadReq(p.key, kind=KV, view=view, tag=p.key))
        rs = self.device.submit(reqs) if reqs else []
        self._account(rs)
        served.update({r.key: r.data for r in rs})
        out = [p.resident if p.resident is not None else served[p.key]
               for p in pages]
        return np.concatenate(out, axis=0) if out else np.empty((0, 0), np.uint16)

    # -- precision-elastic reclamation -----------------------------------------
    def reclaim(self, target_bytes: int,
                ladder: Optional[Sequence[PrecisionView]] = None) -> int:
        """Reclaim up to ``target_bytes`` of *physical* device bytes by
        shedding mantissa planes of cold spilled pages in place.

        Walks spilled pages coldest-first — least-recent commit boundary,
        then least important — applying one ladder rung per pass
        (``TierStore.truncate_planes``): every cold page degrades one
        step before any page degrades two, so sustained pressure spreads
        precision loss instead of destroying a single page.  A page's
        ladder position is remembered across calls.  Shedding planes is
        lossy and irreversible, so the ladder is strictly opt-in: the
        pool's ``degrade_ladder`` defaults to empty and an explicit
        ladder (e.g. ``DEFAULT_DEGRADE_LADDER``) must be configured for
        reclaim to touch anything.  Returns the bytes actually
        reclaimed (0 when the ladder is empty, nothing is spilled,
        everything is already at the last rung, or the device's layout
        cannot shed planes — word layouts).  HBM-resident pages are
        untouched: they occupy HBM, not device capacity, and keep their
        exact values.

        Shared pages never degrade in place: truncating a co-owned page
        would change what every other referer decodes, and even a
        sole-referer page keeps its content-addressed key — a later
        request re-writing that "fresh" window would append to the
        degraded stream.  The ladder walks private pages only; shared
        pages free whole at the last referer's retirement.  Any prefetch
        ticket issued against a page before its truncation is settled
        and discarded: its data predates the degrade, and serving it
        would break the degraded-decode differential.
        """
        ladder = (self.degrade_ladder if ladder is None else tuple(ladder))
        if target_bytes <= 0 or not ladder:
            return 0
        cold = sorted(
            (p for p in self._pages if p.resident is None),
            key=lambda p: (p.commit_seq, p.importance, p.start),
        )
        freed = 0
        for level, view in enumerate(ladder):
            for page in cold:
                if freed >= target_bytes:
                    return freed
                if page.degrade_level >= level:
                    continue
                if page.shared_ref:
                    continue
                try:
                    freed += self.device.truncate_planes([page.key], view)
                except NotImplementedError:
                    return freed        # word layout: nothing to shed
                page.degrade_level = level
                # truncate_planes drained the queue, so a prefetch issued
                # earlier has executed against the PRE-truncation planes;
                # account it, then drop it so read_layer re-reads the
                # degraded state instead of serving stale full precision.
                pf = self._prefetched.pop(page.key, None)
                if pf is not None:
                    self._settle_prefetch(pf)
        return freed

    # -- teardown ---------------------------------------------------------------
    def release(self) -> int:
        """Retire this pool: free every page and tear down its namespace.

        Outstanding prefetch tickets are settled first (their receipts
        fold into this pool's accounting exactly once), then every key the
        pool ever wrote is deleted from the device in one
        :meth:`TierStore.delete_prefix` call — blocks, staged partial
        windows and index entries — so the stored capacity returns to the
        device for the next admitted request.  HBM-resident pages are
        dropped and ``hbm_bytes`` goes to zero.  Returns the number of
        device keys freed.

        The pool's traffic receipts (``page_traffic``, ``io_service_s``)
        survive release — a retired request's accounting is still part of
        the serving record.  Pools sharing a device must use distinct
        prefixes (the scheduler namespaces per request: ``r{id}.``); with
        an EMPTY ``key_prefix`` only this pool's own page keys are
        deleted, never the rest of a shared device.

        Shared pages release their ledger reference instead: the stored
        copy survives as long as any other request still refers to it,
        and frees with the last retirement.
        """
        for entry in self._prefetched.values():
            self._settle_prefetch(entry)
        self._prefetched.clear()
        freed = 0
        for p in self._pages:
            if p.shared_ref:
                self.prefix_index.release(p.key)
                p.shared_ref = False
                freed += 1
        if self.key_prefix:
            freed += self.device.delete_prefix(self.key_prefix)
        else:
            keys = {p.key for p in self._pages if not p.share_hash}
            for k in keys:
                self.device.delete(k)
            freed += len(keys)
        self._pages.clear()
        self.spill_events.clear()
        self._hbm_used = 0
        return freed

    # -- accounting ---------------------------------------------------------------
    @property
    def hbm_bytes(self) -> int:
        return self._hbm_used

    @property
    def device_resident_bytes(self) -> int:
        """Physical bytes this pool's private namespace occupies on the
        device right now (stored payload + index, from the residency
        ledger).  Shared pages live under the device-wide ``shared.``
        namespace and are reported by :attr:`shared_resident_bytes`."""
        return self.device.resident_bytes(self.key_prefix)

    @property
    def shared_resident_bytes(self) -> int:
        """Physical bytes of the shared pages this pool holds references
        on.  Summed per *key*, so two pools referencing one stored copy
        each report its full size — use the device-wide
        ``resident_bytes("shared.")`` for the deduplicated total."""
        return sum(self.device.resident_bytes(p.key)
                   for p in self._pages if p.shared_ref)

    @property
    def physical_kv_bytes(self) -> int:
        """Live physical KV footprint: raw HBM residents + the device
        namespace's post-compression ledger bytes — the quantity a
        physical capacity model admits against, as opposed to the
        logical ``projected_kv_bytes`` projection."""
        return self._hbm_used + self.device_resident_bytes

    @property
    def spilled_pages(self) -> int:
        return sum(1 for p in self._pages if p.resident is None)

    def iter_pages(self) -> Tuple[_Page, ...]:
        """All committed pages in commit order — the public view engines
        and benchmarks rank/gather over.  The returned handles are the
        same objects ``read_pages`` / ``gather_topk`` accept."""
        return tuple(self._pages)

    def stats(self):
        self.settle_prefetched()
        return self.device.stats
