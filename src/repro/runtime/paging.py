"""Paged KV management over an HBM + CXL(TRACE) tier (paper §II-C, Table II).

KV is managed as fixed-size pages (a window of tokens for all channels of
one layer's K or V).  Pages live in HBM while the hot budget lasts; the
long tail spills to the offload tier (a ``core.tier`` :class:`TierStore`
— Plain, GComp or TRACE).  Page *importance* is long-tailed, so spilled
pages are assigned precision tiers, which a plane-aligned layout serves
with plane-aligned fetch (Mechanism II) — word layouts must always move
full containers (paper Issue 2).

The pool speaks only the TierStore request protocol: spills are
``WriteReq`` submissions, reads are batched ``ReadReq`` submissions (one
``submit`` per layer gather / spill-readback), and every receipt is folded
into per-page traffic counters so attribution is per-page / per-layer
rather than one global stats blob.

The shipped policy mirrors Table II's best row:
    top pages   → BF16 (full, lossless)
    next tier   → ~FP8  (man4 view + guard round: 1+8+4 visible bits)
    cold tail   → ~FP4  (man0 view + guard round: sign+exp only)
KV views keep the full (delta) exponent planes — they are the cheapest,
most compressible planes — and scale mantissa planes only (precision.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.precision import FULL, MAN0, MAN4, PrecisionView
from ..core.tier import KV, ReadReq, Receipt, TierStore, WriteReq, make_device


@dataclasses.dataclass(frozen=True)
class PagePolicy:
    """Importance-ranked precision assignment for *spilled* pages."""

    tiers: tuple = ((5, FULL), (3, MAN4), (2, MAN0))   # (count, view) in rank order
    tail_view: PrecisionView = MAN0                     # beyond listed tiers

    def view_for_rank(self, rank: int) -> PrecisionView:
        acc = 0
        for count, view in self.tiers:
            acc += count
            if rank < acc:
                return view
        return self.tail_view

    def avg_bits(self, n_pages: int) -> float:
        if n_pages == 0:
            return 16.0
        return float(
            np.mean([self.view_for_rank(r).bits for r in range(n_pages)])
        )


PAPER_POLICY = PagePolicy()           # Table II: 5×BF16 / 3×FP8 / 2×FP4
LOSSLESS_POLICY = PagePolicy(tiers=((1 << 30, FULL),), tail_view=FULL)


@dataclasses.dataclass
class _Page:
    key: str                  # stream id on the device
    layer: int
    kind: str                 # "k" | "v"
    start: int                # first token index
    n_tokens: int
    importance: float = 0.0
    resident: Optional[np.ndarray] = None   # HBM copy (token-major u16) or None


@dataclasses.dataclass
class PageTraffic:
    """Per-page roll-up of the receipts this pool has seen."""

    dram_bytes_read: int = 0
    dram_bytes_written: int = 0
    link_bytes_in: int = 0
    link_bytes_out: int = 0
    index_bytes: int = 0
    requests: int = 0

    def add(self, r: Receipt):
        """Fold one receipt in (field names shared with Receipt)."""
        for f in dataclasses.fields(self):
            if f.name != "requests":
                setattr(self, f.name, getattr(self, f.name) + getattr(r, f.name))
        self.requests += 1

    def merge(self, other: "PageTraffic"):
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


class KVPagePool:
    """Per-sequence paged KV with HBM budget + tier spill.

    Host arrays are BF16-as-uint16, token-major ``(tokens, channels)`` —
    exactly the stream a real host would store through CXL.mem.
    """

    def __init__(
        self,
        device: TierStore | str = "trace",
        page_tokens: int = 64,
        hbm_budget_bytes: int = 1 << 30,
        policy: PagePolicy = PAPER_POLICY,
    ):
        self.device = make_device(device) if isinstance(device, str) else device
        self.page_tokens = page_tokens
        self.hbm_budget = hbm_budget_bytes
        self.policy = policy
        self._pages: List[_Page] = []
        self._hbm_used = 0
        self.spill_events: List[_Page] = []   # drained by the serving engine
        self.page_traffic: Dict[str, PageTraffic] = {}
        # One page per KV window: the device commits each page's stream in
        # a single transform window.
        self.device.kv_window = page_tokens

    def _account(self, receipts: Sequence[Receipt]):
        for r in receipts:
            self.page_traffic.setdefault(r.key, PageTraffic()).add(r)

    def traffic_by_layer(self) -> Dict[int, PageTraffic]:
        """Aggregate per-page traffic up to layers (key format L{n}.*)."""
        out: Dict[int, PageTraffic] = {}
        for p in self._pages:
            t = self.page_traffic.get(p.key)
            if t is not None:
                out.setdefault(p.layer, PageTraffic()).merge(t)
        return out

    # -- write path -----------------------------------------------------------
    def append_page(self, layer: int, kind: str, start: int,
                    tokens_u16: np.ndarray, importance: float = 0.0):
        """Commit one full page (token-major (n, C) uint16)."""
        key = f"L{layer}.{kind}.{start}"
        page = _Page(key, layer, kind, start, tokens_u16.shape[0],
                     importance=importance)
        # Always admit to HBM first, then evict the least-important pages
        # (possibly this one) — importance, not arrival order, decides
        # residency (paper §II-C: importance is long-tailed).
        page.resident = tokens_u16.copy()
        self._hbm_used += tokens_u16.size * 2
        self._pages.append(page)
        self._rebalance()

    def _rebalance(self):
        """Evict the least-important resident pages when over budget."""
        if self._hbm_used <= self.hbm_budget:
            return
        resident = sorted(
            (p for p in self._pages if p.resident is not None),
            key=lambda p: p.importance,
        )
        writes = []
        for p in resident:
            if self._hbm_used <= self.hbm_budget:
                break
            tok = p.resident
            self._hbm_used -= tok.size * 2
            writes.append(WriteReq(p.key, tok, kind=KV, flush=True, tag=p.key))
            p.resident = None
            self.spill_events.append(p)
        if writes:
            self._account(self.device.submit(writes))

    def update_importance(self, scores: Dict[str, float]):
        for p in self._pages:
            if p.key in scores:
                p.importance = scores[p.key]
        self._rebalance()

    # -- read path --------------------------------------------------------------
    def _spill_ranks(self, pages=None) -> Dict[str, int]:
        spilled = sorted(
            (p for p in (pages if pages is not None else self._pages)
             if p.resident is None),
            key=lambda p: -p.importance,
        )
        return {p.key: i for i, p in enumerate(spilled)}

    def read_page(self, page: _Page) -> np.ndarray:
        """One spilled page through the tier at its current policy view."""
        return self.read_pages([page])[0]

    def read_pages(self, pages: Sequence[_Page]) -> List[np.ndarray]:
        """Batched tier read of spilled pages (one submit for the batch)."""
        rank = self._spill_ranks()
        reqs = [
            ReadReq(p.key, kind=KV, view=self.policy.view_for_rank(rank[p.key]),
                    tag=p.key)
            for p in pages
        ]
        receipts = self.device.submit(reqs)
        self._account(receipts)
        return [r.data for r in receipts]

    def read_layer(self, layer: int, kind: str) -> np.ndarray:
        """Gather all pages of (layer, kind) in token order, applying the
        precision policy to spilled pages (ranked by importance).  All
        spilled pages go to the device as one request batch."""
        pages = sorted(
            (p for p in self._pages if p.layer == layer and p.kind == kind),
            key=lambda p: p.start,
        )
        rank = self._spill_ranks(pages)
        reqs = [
            ReadReq(p.key, kind=KV, view=self.policy.view_for_rank(rank[p.key]),
                    tag=p.key)
            for p in pages if p.resident is None
        ]
        rs = self.device.submit(reqs)
        self._account(rs)
        served = {r.key: r.data for r in rs}
        out = [p.resident if p.resident is not None else served[p.key]
               for p in pages]
        return np.concatenate(out, axis=0) if out else np.empty((0, 0), np.uint16)

    # -- accounting ---------------------------------------------------------------
    @property
    def hbm_bytes(self) -> int:
        return self._hbm_used

    @property
    def spilled_pages(self) -> int:
        return sum(1 for p in self._pages if p.resident is None)

    def stats(self):
        return self.device.stats
