"""Architecture config system.

One frozen dataclass describes every supported family (dense GQA / MoE /
MLA / SSM / hybrid / encoder-only / modality-backbone).  Configs are pure
data — models/ builds the parameter tree and step functions from them, and
launch/ derives sharding and input specs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp: str = "swiglu"          # swiglu | squared_relu | gelu
    causal: bool = True          # False → encoder-only (no decode step)
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    rope_theta: float = 1e4

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert FFN width (0 → use d_ff)
    moe_every: int = 1           # MoE layer cadence (1 = every layer)
    first_dense: int = 0         # leading dense layers (deepseek style)

    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    v_head_dim: int = 0

    # SSM
    ssm: str = ""                # "" | mamba1 | mamba2
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    ssm_heads: int = 0           # mamba2 multihead (0 → derived)

    # hybrid (zamba2): one shared attention+MLP block applied every k blocks
    attn_every: int = 0
    window: int = 0              # sliding-window size for long-context attn

    # modality frontend stub: inputs are precomputed embeddings
    frontend: str = ""           # "" | vision | audio

    # MoE capacity (training dispatch); decode uses the exact dense path
    capacity_factor: float = 1.25

    # numerics / training
    remat: bool = True
    optimizer_dtype: str = "float32"   # moment dtype; "bfloat16" for 100B+
    # decode KV-cache storage dtype — "float8_e4m3fn" halves HBM bytes for
    # the KV-bound decode cells (the on-chip analogue of the paper's
    # elastic precision fetch; values upcast to bf16 inside attention)
    kv_dtype: str = "bfloat16"
    # §Perf lever: FSDP the params' d_model dim over the data axis (True)
    # or replicate params across data (False — pure TP/EP).  FSDP's weight
    # -grad backward all-gathers GLOBAL activations per layer under SPMD;
    # for models whose TP-sharded params fit HBM, turning it off trades
    # param memory for a large collective-volume cut.
    fsdp: bool = True
    # §Perf lever (decode): "batch_dp" shards the request batch over data
    # (weights FSDP-gathered per step); "replicated" replicates the tiny
    # decode batch and keeps weights 2D-stationary (params/256 per chip,
    # psum on activations) — the right layout for 100B+ decode.
    decode_layout: str = "batch_dp"
    # §Perf lever: shard the embedding TABLE's vocab dim over the model
    # axis (True) or leave vocab unsharded and FSDP only the d_model dim
    # (False).  vocab-sharded tables force an all-gather feeding the token
    # gather, which XLA schedules cross-pod on the 2×16×16 mesh.
    embed_vocab_shard: bool = True

    # --- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state or sliding-window hybrid."""
        return self.family in ("ssm", "hybrid")

    @property
    def uses_tokens(self) -> bool:
        return self.frontend == ""

    def param_count(self) -> float:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.ssm == "mamba1":
            di, N = self.d_inner, self.ssm_state
            dt_rank = max(d // 16, 1)
            per = (d * 2 * di + di * self.d_conv + di * (dt_rank + 2 * N)
                   + dt_rank * di + di * N + di + di * d)
            return emb + L * per
        att = 0.0
        if self.mla:
            r, hd = self.kv_lora_rank, self.hd
            vh = self.v_head_dim or hd
            att = (d * self.n_heads * (hd + self.qk_rope_dim)
                   + d * (r + self.qk_rope_dim)
                   + r * self.n_heads * (hd + vh)
                   + self.n_heads * vh * d)
        elif self.n_heads:
            att = (d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd
                   + self.n_heads * self.hd * d)
        mlp_mult = 3 if self.mlp == "swiglu" else 2
        f = self.d_ff
        dense_mlp = mlp_mult * d * f
        per_layer = att + dense_mlp
        total = emb + L * per_layer
        if self.n_experts:
            fe = self.moe_d_ff or f
            n_moe = max((L - self.first_dense) // max(self.moe_every, 1), 0)
            moe = n_moe * (self.n_experts + self.n_shared_experts) * mlp_mult * d * fe
            total = emb + self.first_dense * per_layer + n_moe * att + moe
            if self.family == "hybrid":
                pass
        if self.family == "hybrid":
            # mamba2 backbone + one shared attention block
            di, N = self.d_inner, self.ssm_state
            per_m = d * 2 * di + di * self.d_conv + di * N + di + di * d
            shared = att + dense_mlp
            total = emb + L * per_m + shared
        return float(total)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        fe = self.moe_d_ff or self.d_ff
        mlp_mult = 3 if self.mlp == "swiglu" else 2
        n_moe = max((self.n_layers - self.first_dense) // max(self.moe_every, 1), 0)
        routed_all = n_moe * self.n_experts * mlp_mult * self.d_model * fe
        routed_active = n_moe * self.top_k * mlp_mult * self.d_model * fe
        return full - routed_all + routed_active  # shared experts stay in

    def kv_bytes_per_token(self, elem_bytes: int = 2) -> float:
        if self.ssm == "mamba1":
            return 0.0  # constant-size state, not per-token
        if self.mla:
            return self.n_layers * (self.kv_lora_rank + self.qk_rope_dim) * elem_bytes
        n_attn = self.n_layers
        if self.attn_every:
            n_attn = self.n_layers // self.attn_every
        return n_attn * 2 * self.n_kv_heads * self.hd * elem_bytes


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned shapes pool)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ArchConfig) -> list[ShapeConfig]:
    """Shape cells that are well-defined for this architecture.

    * encoder-only archs have no decode step → skip decode shapes;
    * ``long_500k`` needs sub-quadratic attention → SSM / hybrid only
      (skips recorded in DESIGN.md §Arch-applicability).
    """
    out = [TRAIN_4K, PREFILL_32K]
    if not cfg.is_encoder_only:
        out.append(DECODE_32K)
        if cfg.supports_long_context:
            out.append(LONG_500K)
    return out


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2 if not cfg.attn_every else cfg.attn_every),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        kv_lora_rank=64 if cfg.mla else 0,
        qk_rope_dim=16 if cfg.mla else 64,
        v_head_dim=32 if cfg.mla else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.ssm == "mamba2" else 0,
        first_dense=min(cfg.first_dense, 1),
        capacity_factor=4.0,   # smoke: capacity can never drop → decode==forward
        window=min(cfg.window, 64) if cfg.window else 0,
        remat=False,
    )
