"""HuBERT X-Large — encoder-only audio transformer (wav2vec2-style
backbone; CNN feature extractor is a stub: input_specs provides frame
embeddings).  No decode step.  [arXiv:2106.07447; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    norm="layernorm",
    mlp="gelu",
    frontend="audio",
)
