"""DeepSeek-V2-Lite 16B — MLA attention (kv_lora=512) + fine-grained MoE.

Assigned spec says "MoE 64e top-6" in the shape line and "2 shared + 160
routed" in the note; we follow the primary 64-routed spec (the HF config's
160-expert variant is noted in DESIGN.md §Arch-applicability).
[arXiv:2405.04434; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,            # dense first layer FFN
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense=1,
    mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    v_head_dim=128,
    mlp="swiglu",
)
