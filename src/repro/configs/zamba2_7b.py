"""Zamba2 7B — hybrid: Mamba2 backbone with one SHARED attention+MLP block
applied every 6 mamba blocks.  Sliding-window attention enables long_500k.
[arXiv:2411.15242; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm="mamba2",
    ssm_state=64,
    ssm_heads=32,
    attn_every=6,
    window=4096,
    mlp="swiglu",
)
