"""Assigned architecture registry: ``--arch <id>`` resolves here."""

from .base import (
    ArchConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    applicable_shapes,
    smoke_config,
)
from .llava_next_34b import CONFIG as llava_next_34b
from .stablelm_12b import CONFIG as stablelm_12b
from .qwen1_5_32b import CONFIG as qwen1_5_32b
from .qwen2_0_5b import CONFIG as qwen2_0_5b
from .nemotron_4_340b import CONFIG as nemotron_4_340b
from .zamba2_7b import CONFIG as zamba2_7b
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .grok_1_314b import CONFIG as grok_1_314b
from .deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from .hubert_xlarge import CONFIG as hubert_xlarge

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        llava_next_34b,
        stablelm_12b,
        qwen1_5_32b,
        qwen2_0_5b,
        nemotron_4_340b,
        zamba2_7b,
        falcon_mamba_7b,
        grok_1_314b,
        deepseek_v2_lite_16b,
        hubert_xlarge,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
