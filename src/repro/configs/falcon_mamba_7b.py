"""Falcon-Mamba 7B — pure Mamba1 SSM, attention-free (d_ff=0).
[arXiv:2410.05355; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm="mamba1",
    ssm_state=16,
)
