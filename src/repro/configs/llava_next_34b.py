"""LLaVA-NeXT 34B — VLM backbone (anyres tiling frontend is a stub;
input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    mlp="swiglu",
    frontend="vision",
)
