"""Commodity lossless codecs used by the device model (paper §III-B).

TRACE deliberately reuses *generic* codecs — the gain comes from changing
the codec input (plane streams instead of mixed-field words), not from a
bespoke compressor.  We model the paper's two codecs:

* ``lz4`` — a from-scratch LZ4 *block format* encoder/decoder (the offline
  environment has no lz4 binding).  Greedy hash-chain matching, standard
  end-of-block rules, byte-exact round-trip; this stands in for the 32-lane
  streaming LZ4 engine of the controller (paper §IV-E).
* ``zstd`` — the real Zstandard via the ``zstandard`` package.

Both are exposed through a tiny registry with block-level *bypass*: when a
block is incompressible the device stores it raw and marks the index entry
(paper §III-D "Bypass and correctness invariants").  The bypass decision is
two-stage: a cheap sampled entropy pre-screen routes near-certainly
incompressible blocks to raw storage *before* paying for compression (the
controller's line-rate engines do the same to avoid stalling on
high-entropy planes), and blocks that do run the codec fall back to raw
when the payload fails :data:`BYPASS_THRESHOLD`.

The write path is batched: :func:`compress_batch` compresses a flush
group's blocks in one pass — for LZ4 the 4-byte words and their hashes are
precomputed over the whole concatenated slab in vectorized numpy (the
per-block emit loop then just walks precomputed arrays), and for zstd the
group goes through the library's multi-frame API when available.  Payloads
are byte-identical to per-block :func:`compress_block` calls by
construction (per-block hash tables, per-block emit), which the encode
differential tests assert.

Contract: every compressed payload round-trips byte-exactly
(``decompress_block(compress_block(x)) == x``); batch entry points are
byte-identical per block to their scalar counterparts; a ``RAW`` flag
always means the stored payload IS the input bytes.  Callers (the layout
strategies in ``core.tier``) rely on all three.
"""

from __future__ import annotations

import os
import warnings
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

try:
    import zstandard as _zstd

    _ZSTD_C = _zstd.ZstdCompressor(level=3)
    _ZSTD_D = _zstd.ZstdDecompressor()
except (ImportError, AttributeError):  # pragma: no cover - optional dep
    _zstd = None

HAVE_ZSTD = _zstd is not None

_HASH_LOG = 13
_HASH_SIZE = 1 << _HASH_LOG
_MIN_MATCH = 4
_MFLIMIT = 12          # match must not start within last 12 bytes
_LAST_LITERALS = 5     # last 5 bytes are always literals


class CorruptPayloadError(ValueError):
    """A compressed payload failed structural validation during decode.

    Raised by :func:`lz4_decompress` for truncated frames, match offsets
    pointing before the produced-length frontier, zero offsets, and
    outputs exceeding the caller's bound — instead of surfacing a raw
    ``IndexError`` or silently wrapping a bad copy.  Subclasses
    ``ValueError`` so existing callers that guard broadly keep working.
    """


# ---------------------------------------------------------------------------
# LZ4 block format
# ---------------------------------------------------------------------------

def _lz4_hash(seq_u32: int) -> int:
    return (seq_u32 * 2654435761) >> (32 - _HASH_LOG) & (_HASH_SIZE - 1)


def _lz4_words_hashes(buf: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorised 4-byte little-endian words + hashes for every position.

    ``buf`` may be a whole encode slab: per-block hash/emit loops only ever
    touch positions whose 4-byte window lies inside their own block, so the
    precompute can be shared across a batch (see :func:`lz4_compress_batch`).
    """
    w = (
        buf[:-3].astype(np.uint32)
        | (buf[1:-2].astype(np.uint32) << 8)
        | (buf[2:-1].astype(np.uint32) << 16)
        | (buf[3:].astype(np.uint32) << 24)
    )
    hashes = ((w * np.uint32(2654435761)) >> np.uint32(32 - _HASH_LOG)).astype(
        np.int64
    )
    return w, hashes


_MATCH_CAP = 64        # vectorized-LCP sweep bound for offsets > 1; NOT an
                       # output cap — selected matches that reach it are
                       # extended to the true LCP by galloping (offset-1
                       # byte runs extend uncapped via the run table), so
                       # emitted matches equal the scalar scan's exactly
_RUN_STRIDE = 4        # interior byte-run positions keep a candidate only
                       # every _RUN_STRIDE bytes: candidates stay ~N/4 on
                       # zero-heavy planes while a match ending mid-run
                       # re-anchors within at most 3 literal bytes


def _emit_seq(out: bytearray, data: bytes, lit_start: int, lit_end: int,
              mlen: int, dist: int):
    """Append one LZ4 sequence (token, literal run, optional match) —
    the general path with 255-extension chains; ``mlen == 0`` emits the
    end-of-block literal-only sequence."""
    append = out.append
    lit_len = lit_end - lit_start
    tok_lit = min(lit_len, 15)
    tok_match = min(mlen - _MIN_MATCH, 15) if mlen else 0
    append((tok_lit << 4) | tok_match)
    rest = lit_len - 15
    while rest >= 0:
        append(min(rest, 255))
        if rest < 255:
            break
        rest -= 255
    out += data[lit_start:lit_end]
    if mlen:
        append(dist & 0xFF)
        append(dist >> 8)
        rest = mlen - _MIN_MATCH - 15
        while rest >= 0:
            append(min(rest, 255))
            if rest < 255:
                break
            rest -= 255


def _lz4_emit(data: bytes, events, out: bytearray):
    """Serialize match ``events`` over ``data`` in LZ4 block format.

    ``events`` is an ascending list of ``(pos, dist, mlen)``; everything
    between events is literals, and the block ends in a literal-only
    sequence (the standard end-of-block rule).
    """
    n = len(data)
    anchor = 0
    for pos, dist, mlen in events:
        _emit_seq(out, data, anchor, pos, mlen, dist)
        anchor = pos + mlen
    _emit_seq(out, data, anchor, n, 0, 0)


def _lz4_events_scalar(data: bytes) -> list:
    """Reference match scan for one block — sequential python.

    The algorithm (shared bit-for-bit with the vectorized batch scan):
    every position feeds a last-occurrence hash table; a position ``i``
    outside any selected match starts a match when its table candidate
    has the same 4-byte word within the 64 KiB window; matches extend by
    LCP, bounded by the end-of-block literal rules.  Offset-1
    candidates are honoured only at a run's FIRST interior position
    (``data[i-2] != data[i-1]``): one uncapped match covers the whole
    run, and skipping the interior keeps the batch scan's candidate set
    proportional to runs, not bytes.
    """
    n = len(data)
    events: list = []
    if n < _MFLIMIT + 1:
        return events
    w_np, h_np = _lz4_words_hashes(np.frombuffer(data, dtype=np.uint8))
    w, hashes = w_np.tolist(), h_np.tolist()
    table = [-1] * _HASH_SIZE
    limit = n - _MFLIMIT
    cur_end = 0
    for i in range(n - 3):
        h = hashes[i]
        cand = table[h]
        table[h] = i
        if (i >= limit or i < cur_end or cand < 0
                or i - cand > 0xFFFF or w[cand] != w[i]):
            continue
        dist = i - cand
        if (dist == 1 and i >= 2 and data[i - 2] == data[i - 1]
                and i % _RUN_STRIDE):
            continue          # run interior: covered by run-first / stride
        max_len = n - _LAST_LITERALS - i
        mlen = _MIN_MATCH
        while (mlen + 32 <= max_len
               and data[cand + mlen : cand + mlen + 32]
               == data[i + mlen : i + mlen + 32]):
            mlen += 32
        while mlen < max_len and data[cand + mlen] == data[i + mlen]:
            mlen += 1
        events.append((i, dist, mlen))
        cur_end = i + mlen
    return events


def lz4_compress(data: bytes) -> bytes:
    """LZ4 block-format compression (pure python + numpy hashing)."""
    if len(data) == 0:
        return b"\x00"
    out = bytearray()
    _lz4_emit(data, _lz4_events_scalar(data), out)
    return bytes(out)


def _lz4_compress_slab(buf: np.ndarray, chunks: Sequence[bytes]) -> List[bytes]:
    """Vectorized match scan + fused greedy emit over a concatenated slab.

    One word/hash pass, one previous-occurrence argsort, one run-boundary
    scan and one (capped) LCP sweep serve every block of the batch; only
    the final greedy selection walks SELECTED matches in python, emitting
    each sequence as it is chosen (no event materialization).  Per block
    the output is byte-identical to :func:`lz4_compress`: the
    previous-occurrence keys are namespaced by block id, so candidates can
    never cross a block boundary, exactly like the per-block hash table.
    """
    N = int(buf.size)
    B = len(chunks)
    def _all_literals() -> List[bytes]:
        outs = []
        for data in chunks:
            blk = bytearray()
            _lz4_emit(data, [], blk)
            outs.append(bytes(blk) if data else b"\x00")
        return outs
    if N < _MIN_MATCH:
        return _all_literals()
    w, h = _lz4_words_hashes(buf)
    sizes_a = np.asarray([len(c) for c in chunks], dtype=np.int64)
    ends = np.cumsum(sizes_a)
    starts_a = ends - sizes_a
    # positions whose 4-byte word lies inside their own block: everything
    # except the (up to) 3 positions before each block boundary
    mask = np.ones(N - 3, dtype=bool)
    cols = (ends[:, None] - np.arange(3, 0, -1)[None, :]).ravel()
    cols = cols[(cols >= np.repeat(starts_a, 3)) & (cols >= 0)
                & (cols < N - 3)]
    mask[cols] = False
    wvalid = np.flatnonzero(mask)
    if wvalid.size == 0:
        return _all_literals()
    # previous same-hash occurrence within the block = last-occurrence
    # hash table, computed for all positions at once (int32 keys sort
    # measurably faster and hold block_id * 8192 + hash comfortably)
    blk_w = np.searchsorted(ends, wvalid, side="right")
    keys = (blk_w * np.int64(_HASH_SIZE) + h[wvalid]).astype(
        np.int32 if B * _HASH_SIZE < (1 << 31) else np.int64
    )
    order = np.argsort(keys, kind="stable")      # stable: ascending pos
    sp = wvalid[order]
    same = keys[order][1:] == keys[order][:-1]
    prev = np.full(N - 3, -1, dtype=np.int64)
    prev[sp[1:][same]] = sp[:-1][same]

    g = np.flatnonzero(prev >= 0)
    cand = prev[g]
    blk_g = np.searchsorted(ends, g, side="right")
    local_g = g - starts_a[blk_g]
    nb_g = sizes_a[blk_g]
    ok = ((local_g < nb_g - _MFLIMIT)
          & (g - cand <= 0xFFFF)
          & (w[g] == w[cand]))
    # interior of a byte run: covered by the run-first candidate's
    # uncapped match (same rule as the scalar scan) — dropping all but
    # every _RUN_STRIDE-th keeps the candidate set ~N/4 instead of N on
    # zero-heavy planes, while matches that end mid-run re-anchor within
    # at most _RUN_STRIDE-1 literal bytes
    ok &= ~((g - cand == 1) & (local_g >= 2) & (buf[g - 2] == buf[g - 1])
            & (local_g % _RUN_STRIDE != 0))
    keep = np.flatnonzero(ok)
    g, cand, blk_g, local_g, nb_g = (
        g[keep], cand[keep], blk_g[keep], local_g[keep], nb_g[keep])
    if g.size == 0:
        return _all_literals()
    dist = g - cand
    max_len = nb_g - _LAST_LITERALS - local_g
    mlen = np.full(g.size, _MIN_MATCH, dtype=np.int64)

    run = dist == 1
    if run.any():
        # offset-1 = byte run: LCP is the run length, read off the
        # run-boundary table instead of byte-compare loops
        bnd = np.flatnonzero(buf[1:] != buf[:-1])    # last index of each run
        if bnd.size:
            idx = np.searchsorted(bnd, g[run] - 1, side="left")
            rend = np.where(idx < bnd.size,
                            bnd[np.minimum(idx, bnd.size - 1)], N - 1)
        else:
            rend = np.full(int(run.sum()), N - 1, dtype=np.int64)
        mlen[run] = np.minimum(rend - g[run] + 1, max_len[run])
    gen = np.flatnonzero(~run)
    if gen.size:
        # LCP sweep, word-stride: compare 4 bytes per pass via the word
        # array (w[x] = bytes x..x+3, in-block by the cap bound); a failed
        # word resolves its 0-3 leading equal bytes exactly, survivors
        # that run out of word room finish in the byte phase below.
        cap = np.minimum(max_len[gen], _MATCH_CAP)
        gg, cc = g[gen], cand[gen]
        ml = np.full(gen.size, _MIN_MATCH, dtype=np.int64)
        k = _MIN_MATCH
        alive = np.arange(gen.size)
        partial: List[np.ndarray] = []       # ran out of word room at ml=k
        while True:
            word_ok = cap[alive] >= k + 4
            if not word_ok.all():
                partial.append(alive[~word_ok])
                alive = alive[word_ok]
            if alive.size == 0:
                break
            eqw = w[gg[alive] + k] == w[cc[alive] + k]
            fail = alive[~eqw]
            if fail.size:
                b0 = (buf[gg[fail] + k] == buf[cc[fail] + k]).astype(np.int64)
                b1 = b0 & (buf[gg[fail] + k + 1] == buf[cc[fail] + k + 1])
                b2 = b1 & (buf[gg[fail] + k + 2] == buf[cc[fail] + k + 2])
                ml[fail] = k + b0 + b1 + b2
            alive = alive[eqw]
            k += 4
            ml[alive] = k
        # byte phase: at most 3 bytes of per-element room left
        arr = np.concatenate(partial) if partial else alive
        while arr.size:
            arr = arr[cap[arr] > ml[arr]]
            if arr.size == 0:
                break
            eq = buf[gg[arr] + ml[arr]] == buf[cc[arr] + ml[arr]]
            arr = arr[eq]
            ml[arr] += 1
        mlen[gen] = ml

    # Greedy left-to-right selection fused with emit.  bisect skips the
    # candidates a selected match covers in O(log) instead of walking
    # them, so this loop runs once per EMITTED match, not once per
    # candidate; dist/mlen are only materialized for matches that are
    # actually selected, and each sequence is serialized as it is chosen.
    # Selected matches whose sweep hit _MATCH_CAP gallop out to the true
    # LCP here — selected matches never overlap, so total galloping work
    # is bounded by the slab size.
    pos_l = local_g.tolist()
    b_lo = np.searchsorted(blk_g, np.arange(B), side="left").tolist()
    b_hi = np.searchsorted(blk_g, np.arange(B), side="right").tolist()
    dist_i = dist.item
    mlen_i = mlen.item
    sizes_l = sizes_a.tolist()
    outs: List[bytes] = []
    for blk in range(B):
        data = chunks[blk]
        n = sizes_l[blk]
        if n == 0:
            outs.append(b"\x00")
            continue
        i, hi = b_lo[blk], b_hi[blk]
        out = bytearray()
        append = out.append
        anchor = 0
        while i < hi:
            p = pos_l[i]
            m = mlen_i(i)
            d = dist_i(i)
            if m == _MATCH_CAP and d != 1:
                c = p - d
                max_len = n - _LAST_LITERALS - p
                while (m + 32 <= max_len
                       and data[c + m : c + m + 32]
                       == data[p + m : p + m + 32]):
                    m += 32
                while m < max_len and data[c + m] == data[p + m]:
                    m += 1
            lit = p - anchor
            if lit < 15 and m < 19:
                # fast path: single token byte, no extension chains
                append((lit << 4) | (m - _MIN_MATCH))
                out += data[anchor:p]
                append(d & 0xFF)
                append(d >> 8)
            else:
                _emit_seq(out, data, anchor, p, m, d)
            anchor = p + m
            i = bisect_left(pos_l, anchor, i + 1, hi)
        lit = n - anchor
        if lit < 15:
            append(lit << 4)
            out += data[anchor:]
        else:
            _emit_seq(out, data, anchor, n, 0, 0)
        outs.append(bytes(out))
    return outs


_KERNEL_LZ4 = None       # lazy: module, or False when kernels are unavailable


def _lz4_kernel():
    """The ``kernels.lz4`` match engine, or ``None`` (missing runtime).

    The kernel and this module each carry the LZ4 policy constants; a
    drift would silently break kernel-vs-oracle byte identity, so it is
    asserted once at first dispatch.
    """
    global _KERNEL_LZ4
    if _KERNEL_LZ4 is None:
        try:
            from ..kernels import lz4 as _k

            if (_k.HASH_LOG, _k.MIN_MATCH, _k.MFLIMIT, _k.LAST_LITERALS,
                    _k.RUN_STRIDE) != (_HASH_LOG, _MIN_MATCH, _MFLIMIT,
                                       _LAST_LITERALS, _RUN_STRIDE):
                raise RuntimeError(
                    "kernels.lz4 match-policy constants diverged from "
                    "core.codec's scalar reference")
            _KERNEL_LZ4 = _k
        except ImportError:  # pragma: no cover - stripped install
            _KERNEL_LZ4 = False
    return _KERNEL_LZ4 or None


def _scalar_lz4_forced() -> bool:
    """``TRACE_SCALAR_LZ4=1`` pins the PR 3 fused slab encoder — the
    parity oracle the kernel path is differential-tested against."""
    return os.environ.get("TRACE_SCALAR_LZ4", "") not in ("", "0")


def lz4_emit_events(buf: np.ndarray, starts: np.ndarray, ends: np.ndarray,
                    pos: np.ndarray, dist: np.ndarray,
                    mlen: np.ndarray) -> List[bytes]:
    """Serialize a match event tensor to LZ4 block payloads — vectorized.

    The event tensor is the kernel/emit interface: three int64 arrays
    sorted by global ``pos`` (match start), ``dist`` (backwards offset,
    1..65535) and ``mlen`` (true LCP length ≥ 4), one row per selected
    match across ALL streams of the slab; stream membership is implied
    by position.  Gaps between events are literal runs; each stream ends
    in a literal-only closer sequence (the standard end-of-block rule).

    Instead of walking sequences in python, the serializer builds a
    per-sequence table (token value, extension-chain lengths, literal
    source/length, output offset via one cumsum) and scatters token
    bytes, 255-extension chains, literals and offset words with ragged
    numpy fills — O(output bytes) C-speed work, no per-match python.
    Byte-identical to :func:`_lz4_emit` over each stream's events.
    """
    S = int(starts.size)
    E = int(pos.size)
    sizes = ends - starts
    sid_e = np.searchsorted(ends, pos, side="right")
    ec = np.bincount(sid_e, minlength=S) if E else np.zeros(S, np.int64)
    ecum = np.concatenate(([0], np.cumsum(ec)))
    mend = pos + mlen
    # literal anchors: stream start for a stream's first event, previous
    # match end for the rest; closer rows start at the last match end
    first = np.ones(E, dtype=bool)
    first[1:] = sid_e[1:] != sid_e[:-1]
    anchor = np.empty(E, dtype=np.int64)
    anchor[first] = starts[sid_e[first]]
    anchor[~first] = mend[:-1][~first[1:]]
    fin_anchor = starts.copy()
    nz = ec > 0
    fin_anchor[nz] = mend[ecum[1:][nz] - 1]

    # sequence table: per stream, ec rows then one literal-only closer
    row_start = np.concatenate(([0], np.cumsum(ec + 1)))
    R = int(row_start[-1])
    ev_rows = row_start[sid_e] + (np.arange(E) - ecum[sid_e])
    fin_rows = row_start[:-1] + ec
    lit = np.empty(R, dtype=np.int64)
    lsrc = np.empty(R, dtype=np.int64)
    mr = np.zeros(R, dtype=np.int64)
    dr = np.zeros(R, dtype=np.int64)
    lit[ev_rows] = pos - anchor
    lsrc[ev_rows] = anchor
    mr[ev_rows] = mlen
    dr[ev_rows] = dist
    lit[fin_rows] = ends - fin_anchor
    lsrc[fin_rows] = fin_anchor

    has_m = mr > 0
    lit_ext = np.where(lit >= 15, (lit - 15) // 255 + 1, 0)
    mx = np.where(has_m, mr - _MIN_MATCH, 0)
    m_ext = np.where(mx >= 15, (mx - 15) // 255 + 1, 0)
    row_len = 1 + lit_ext + lit + 2 * has_m + m_ext
    out_off = np.concatenate(([0], np.cumsum(row_len)))
    out = np.empty(int(out_off[-1]), dtype=np.uint8)

    tok = (np.minimum(lit, 15) << 4) | np.minimum(mx, 15)
    out[out_off[:-1]] = tok.astype(np.uint8)

    def _ext_chain(rows: np.ndarray, base: np.ndarray, val: np.ndarray,
                   cnt: np.ndarray) -> None:
        # 255-extension chain: cnt bytes of 255...255, rem — rem < 255 by
        # construction of cnt = (val - 15) // 255 + 1
        tot = int(cnt.sum())
        gend = np.cumsum(cnt)
        within = np.arange(tot) - np.repeat(gend - cnt, cnt)
        vals = np.full(tot, 255, dtype=np.uint8)
        vals[gend - 1] = (val - 15 - 255 * (cnt - 1)).astype(np.uint8)
        out[np.repeat(base, cnt) + within] = vals

    er = np.flatnonzero(lit_ext > 0)
    if er.size:
        _ext_chain(er, out_off[:-1][er] + 1, lit[er], lit_ext[er])
    lstart = out_off[:-1] + 1 + lit_ext
    lr = np.flatnonzero(lit > 0)
    if lr.size:
        # int32 ragged indices: the literal copy touches ~every output
        # byte, so halving index-array traffic is a measurable win
        cnt = lit[lr]
        within = np.arange(int(cnt.sum()), dtype=np.int32)
        within -= np.repeat((np.cumsum(cnt) - cnt).astype(np.int32), cnt)
        dsti = np.repeat(lstart[lr].astype(np.int32), cnt)
        dsti += within
        srci = np.repeat(lsrc[lr].astype(np.int32), cnt)
        srci += within
        out[dsti] = buf[srci]
    mstart = lstart + lit
    mrows = np.flatnonzero(has_m)
    if mrows.size:
        out[mstart[mrows]] = (dr[mrows] & 0xFF).astype(np.uint8)
        out[mstart[mrows] + 1] = (dr[mrows] >> 8).astype(np.uint8)
    xr = np.flatnonzero(m_ext > 0)
    if xr.size:
        _ext_chain(xr, mstart[xr] + 2, mx[xr], m_ext[xr])

    blob = out.tobytes()
    so = out_off[row_start]
    return [blob[so[s]: so[s + 1]] for s in range(S)]


def _lz4_slab_streams(slab, buf: np.ndarray, starts: np.ndarray,
                      ends: np.ndarray,
                      force: Optional[str] = None) -> List[bytes]:
    """LZ4-compress the addressed streams of a slab, kernel path first.

    ``slab`` may be a device array (handed straight to the match kernel —
    no host round trip); ``buf`` is its host uint8 view for the emit.
    Falls back to the PR 3 fused slab encoder when the kernel package is
    unavailable or ``TRACE_SCALAR_LZ4=1`` pins the oracle.
    """
    kern = None if _scalar_lz4_forced() else _lz4_kernel()
    if kern is None:
        chunks = [buf[s:e].tobytes() for s, e in zip(starts, ends)]
        return _lz4_compress_slab(
            np.frombuffer(b"".join(chunks), dtype=np.uint8), chunks)
    starts = np.asarray(starts, dtype=np.int64).ravel()
    ends = np.asarray(ends, dtype=np.int64).ravel()
    gapped = bool(starts.size) and (
        int(starts[0]) != 0 or int(ends[-1]) != buf.size
        or bool((starts[1:] != ends[:-1]).any()))
    if gapped and isinstance(slab, np.ndarray):
        # bypassed streams leave gaps in the slab, and the match
        # kernel's prep passes scale with every slab byte — compact a
        # host slab down to the covered ranges (device slabs stay put:
        # a copy there would cost the round trip this path avoids)
        sizes = ends - starts
        buf = np.concatenate([buf[a:b] for a, b in zip(starts, ends)])
        ends = np.cumsum(sizes)
        starts = ends - sizes
        slab = buf
    pos, dist, mlen = kern.match_events_slab(slab, starts, ends, force=force)
    return lz4_emit_events(buf, starts, ends, pos, dist, mlen)


def lz4_compress_batch(chunks: Sequence[bytes],
                       force: Optional[str] = None) -> List[bytes]:
    """Compress a batch of blocks in a few vectorized passes.

    Byte-identical to mapping :func:`lz4_compress` over ``chunks`` (the
    differential encode tests assert this).  The match scan runs as one
    array program over the concatenated slab (``kernels.lz4`` — pallas on
    accelerator backends, vectorized numpy elsewhere) and the token emit
    is one ragged scatter (:func:`lz4_emit_events`); ``TRACE_SCALAR_LZ4=1``
    pins the previous fused slab encoder as a parity oracle.
    """
    if not chunks:
        return []
    sizes = np.asarray([len(c) for c in chunks], dtype=np.int64)
    ends = np.cumsum(sizes)
    starts = ends - sizes
    buf = np.frombuffer(b"".join(chunks), dtype=np.uint8)
    return _lz4_slab_streams(buf, buf, starts, ends, force=force)


def lz4_decompress(comp: bytes, max_out: int | None = None) -> bytes:
    """Decode an LZ4 block payload, validating structure as it goes.

    Corrupt frames — truncated extension chains or literal runs, a match
    offset of zero or pointing before the produced-length frontier, or
    output exceeding ``max_out`` — raise :class:`CorruptPayloadError`
    rather than an ``IndexError`` or a silently-wrapped copy.
    """
    out = bytearray()
    i, n = 0, len(comp)
    while i < n:
        token = comp[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                if i >= n:
                    raise CorruptPayloadError(
                        "lz4: truncated literal-length extension at byte "
                        f"{i} of {n}")
                b = comp[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if i + lit_len > n:
            raise CorruptPayloadError(
                f"lz4: literal run of {lit_len} overruns frame "
                f"({n - i} bytes left)")
        out.extend(comp[i : i + lit_len])
        i += lit_len
        if max_out is not None and len(out) > max_out:
            # checked before the last-sequence break: a tail literal run
            # must not overshoot the caller's bound either
            raise CorruptPayloadError(
                f"lz4: decompressed size {len(out)} exceeds bound {max_out}")
        if i >= n:
            break  # last sequence has no match part
        if i + 2 > n:
            raise CorruptPayloadError(
                f"lz4: truncated match offset at byte {i} of {n}")
        offset = comp[i] | (comp[i + 1] << 8)
        i += 2
        if offset == 0 or offset > len(out):
            raise CorruptPayloadError(
                f"lz4: match offset {offset} outside produced frontier "
                f"({len(out)} bytes)")
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                if i >= n:
                    raise CorruptPayloadError(
                        "lz4: truncated match-length extension at byte "
                        f"{i} of {n}")
                b = comp[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        start = len(out) - offset
        if offset >= mlen:
            # disjoint source range — one slice copy
            out += out[start : start + mlen]
        else:
            # overlapping match = repeating pattern of period `offset`
            # (offset 1 is a byte run — the common case on zero-heavy
            # planes); replicate at C speed instead of a python loop
            pattern = bytes(out[start:])
            out += (pattern * (mlen // offset + 1))[:mlen]
        if max_out is not None and len(out) > max_out:
            raise CorruptPayloadError(
                f"lz4: decompressed size {len(out)} exceeds bound {max_out}")
    return bytes(out)


# ---------------------------------------------------------------------------
# zstd wrappers
# ---------------------------------------------------------------------------

def zstd_compress(data: bytes) -> bytes:
    if _zstd is None:  # pragma: no cover
        raise RuntimeError("zstandard not available")
    return _ZSTD_C.compress(data)


def zstd_compress_batch(chunks: Sequence[bytes]) -> List[bytes]:
    """Multi-frame zstd: one library call for a whole flush group.

    ``multi_compress_to_buffer`` produces the same independent frames as
    per-chunk :func:`zstd_compress` calls (same compressor parameters),
    amortizing python→C transitions; falls back to the per-chunk loop on
    older ``zstandard`` builds.
    """
    if _zstd is None:  # pragma: no cover
        raise RuntimeError("zstandard not available")
    if chunks and hasattr(_ZSTD_C, "multi_compress_to_buffer"):
        try:
            res = _ZSTD_C.multi_compress_to_buffer(list(chunks))
            return [res[i].tobytes() for i in range(len(res))]
        # tracecheck: allow-broad-except(multi_compress raises build-specific types; falls back to the byte-identical per-chunk loop)
        except Exception:  # pragma: no cover - library/build specific
            pass
    return [_ZSTD_C.compress(c) for c in chunks]


def zstd_decompress(data: bytes, max_out: int | None = None) -> bytes:
    if _zstd is None:  # pragma: no cover
        raise RuntimeError("zstandard not available")
    return _ZSTD_D.decompress(data, max_output_size=max_out or 0)


# ---------------------------------------------------------------------------
# Registry + block API with bypass
# ---------------------------------------------------------------------------

CODECS: Dict[str, Tuple[Callable[[bytes], bytes], Callable[..., bytes]]] = {
    "lz4": (lz4_compress, lz4_decompress),
    "none": (lambda b: b, lambda b, max_out=None: b),
}
if HAVE_ZSTD:
    CODECS["zstd"] = (zstd_compress, zstd_decompress)

_warned_fallback = False


def resolve_codec(name: str) -> str:
    """Map a requested codec to an available one.

    ``zstd`` silently degrades to ``lz4`` (with a one-time warning) when the
    ``zstandard`` package is missing, so device models stay usable in minimal
    environments; tests that depend on zstd-specific ratios should check
    :data:`HAVE_ZSTD` and skip instead.
    """
    global _warned_fallback
    if name in CODECS:
        return name
    if name == "zstd":
        if not _warned_fallback:
            warnings.warn(
                "zstandard is not installed; falling back to the built-in "
                "lz4 codec for all 'zstd' devices",
                RuntimeWarning,
                stacklevel=2,
            )
            _warned_fallback = True
        return "lz4"
    raise KeyError(f"unknown codec {name!r}; registered: {sorted(CODECS)}")

RAW, COMPRESSED = 0, 1

# Bypass rule (paper §III-D): a compressed payload is stored only when
# len(comp) < BYPASS_THRESHOLD * len(raw); otherwise the block is stored
# raw and the index entry is flagged.  1.0 = "store compressed iff it is
# strictly smaller" — the conservative setting that can never expand a
# block.  Devices that want headroom for decompression latency can lower
# it (e.g. 0.9 requires a 10% gain before paying the codec on reads).
BYPASS_THRESHOLD = 1.0

# Entropy pre-screen: blocks whose sampled byte distribution is this close
# to uniform (bits/byte, Miller-Madow bias-corrected) AND show no repeated
# 4-byte word among the sampled positions are routed to bypass WITHOUT
# running the codec.  Calibrated so uniform-random payloads ≥ 128 B (e.g.
# mantissa/sign plane streams of well-scaled bf16 tensors, H ≈ 7.6-8.0)
# bypass, while everything LZ4/zstd actually shrinks — periodic patterns,
# text, exponent planes — stays well below (H ≤ 6.8 or duplicate words).
BYPASS_ENTROPY_BITS = 7.5
_PRESCREEN_MIN_LEN = 128     # below this, codec overhead is negligible
_PRESCREEN_BYTES = 1024      # max bytes sampled for the histogram
_PRESCREEN_WORDS = 64        # 4-byte words sampled for the repeat check


def _prescreen_group(rows: np.ndarray) -> np.ndarray:
    """Vectorized pre-screen over a ``(R, n)`` uint8 matrix of same-length
    blocks → boolean bypass decision per row.

    Single source of truth: the scalar :func:`prescreen_bypass` wraps this
    with ``R = 1``, so the scalar and batched encoders cannot diverge on a
    threshold-boundary rounding difference.
    """
    R, n = rows.shape
    sample = rows[:, :: max(1, n // _PRESCREEN_BYTES)][:, :_PRESCREEN_BYTES]
    S = sample.shape[1]
    # per-row histograms via one offset bincount
    offs = (np.arange(R, dtype=np.int64) * 256)[:, None]
    counts = np.bincount(
        (sample.astype(np.int64) + offs).ravel(), minlength=256 * R
    ).reshape(R, 256)
    p = counts / S
    with np.errstate(divide="ignore", invalid="ignore"):
        plogp = np.where(counts > 0, p * np.log2(np.where(counts > 0, p, 1.0)),
                         0.0)
    # Miller-Madow correction removes the small-sample bias that would
    # otherwise make uniform data look ~0.3-0.7 bits "compressible".
    entropy = -plogp.sum(axis=1) \
        + ((counts > 0).sum(axis=1) - 1) / (2 * S * np.log(2))
    out = entropy >= BYPASS_ENTROPY_BITS
    if out.any():
        # Long-range repeats hide from a histogram: sample 4-byte words on
        # an even stride; any duplicate means LZ matches are likely —
        # compress instead of bypassing.
        k = min(_PRESCREEN_WORDS, n // 4)
        pos = np.arange(k, dtype=np.int64) * ((n - 4) // max(k - 1, 1))
        words = (
            rows[:, pos].astype(np.uint32)
            | (rows[:, pos + 1].astype(np.uint32) << 8)
            | (rows[:, pos + 2].astype(np.uint32) << 16)
            | (rows[:, pos + 3].astype(np.uint32) << 24)
        )
        sw = np.sort(words, axis=1)
        out &= ~(sw[:, 1:] == sw[:, :-1]).any(axis=1)
    return out


def prescreen_bypass(data: bytes) -> bool:
    """True when ``data`` is near-certainly incompressible (sampled test).

    Deterministic (stride sampling, no RNG) so scalar and batched encoders
    agree block-for-block.  False negatives only cost a wasted compression
    attempt; false positives would change stored bytes, so both statistics
    are thresholded conservatively.
    """
    if len(data) < _PRESCREEN_MIN_LEN:
        return False
    return bool(_prescreen_group(
        np.frombuffer(data, dtype=np.uint8).reshape(1, -1))[0])


def _prescreen_batch(chunks: Sequence[bytes]) -> List[bool]:
    """Per-block bypass decisions for a batch — identical to mapping
    :func:`prescreen_bypass`, but same-length blocks (the common case: a
    plane stream per 4 KB block) share one vectorized pass."""
    res = [False] * len(chunks)
    by_len: Dict[int, List[int]] = {}
    for i, ch in enumerate(chunks):
        if len(ch) >= _PRESCREEN_MIN_LEN:
            by_len.setdefault(len(ch), []).append(i)
    for n, idxs in by_len.items():
        rows = np.frombuffer(
            b"".join(chunks[i] for i in idxs), dtype=np.uint8
        ).reshape(len(idxs), n)
        for i, ok in zip(idxs, _prescreen_group(rows)):
            res[i] = bool(ok)
    return res


def _prescreen_slab(buf: np.ndarray, starts: np.ndarray,
                    ends: np.ndarray) -> np.ndarray:
    """:func:`_prescreen_batch` over slab-addressed streams — same-length
    streams gather into one ``(R, n)`` matrix, no bytes materialized."""
    sizes = ends - starts
    res = np.zeros(starts.size, dtype=bool)
    for n in np.unique(sizes):
        if n < _PRESCREEN_MIN_LEN:
            continue
        idxs = np.flatnonzero(sizes == n)
        rows = buf[starts[idxs][:, None] + np.arange(int(n))[None, :]]
        res[idxs] = _prescreen_group(rows)
    return res


def compress_block(data: bytes, codec: str) -> tuple[bytes, int]:
    """Compress one block; fall back to raw storage when incompressible.

    Returns ``(payload, flag)`` with flag ∈ {RAW, COMPRESSED}.  The bypass
    decision (pre-screen + :data:`BYPASS_THRESHOLD`) is shared with
    :func:`compress_batch`, so the two are byte-identical per block.
    """
    if prescreen_bypass(data):
        return data, RAW
    c, _ = CODECS[resolve_codec(codec)]
    comp = c(data)
    if len(comp) >= BYPASS_THRESHOLD * len(data):
        return data, RAW
    return comp, COMPRESSED


def compress_batch(chunks: Sequence[bytes],
                   codec: str) -> Tuple[List[bytes], List[int]]:
    """Compress a flush group of blocks in a few vectorized passes.

    Semantically ``zip(*[compress_block(c, codec) for c in chunks])`` —
    byte-identical payloads and flags — but the pre-screen routes
    incompressible blocks out before compression, and the surviving blocks
    share one precompute (LZ4 slab words/hashes, zstd multi-frame call)
    instead of paying per-block numpy/library overhead.
    """
    name = resolve_codec(codec)
    payloads: List[bytes] = [b""] * len(chunks)
    flags: List[int] = [RAW] * len(chunks)
    todo: List[int] = []
    for i, skip in enumerate(_prescreen_batch(chunks)):
        if skip:
            payloads[i] = chunks[i]
        else:
            todo.append(i)
    if todo:
        if name == "lz4":
            comps = lz4_compress_batch([chunks[i] for i in todo])
        elif name == "zstd":
            comps = zstd_compress_batch([chunks[i] for i in todo])
        else:
            c, _ = CODECS[name]
            comps = [c(chunks[i]) for i in todo]
        for i, comp in zip(todo, comps):
            if len(comp) >= BYPASS_THRESHOLD * len(chunks[i]):
                payloads[i] = chunks[i]
            else:
                payloads[i], flags[i] = comp, COMPRESSED
    return payloads, flags


def compress_slab(slab, starts: Sequence[int], ends: Sequence[int],
                  codec: str,
                  force: Optional[str] = None) -> Tuple[List[bytes], List[int]]:
    """:func:`compress_batch` over streams addressed INSIDE a flat slab.

    ``slab`` is a flat uint8 buffer — numpy, or a device array straight
    from ``pack_planes_slab`` (the match kernel then consumes it without
    a device→host→device round trip; only the emit reads a host view).
    ``starts[i]:ends[i]`` bounds stream ``i``.  Byte-identical payloads
    and flags to ``compress_batch([slab[s:e] ...], codec)``, but raw /
    bypassed payloads are sliced from the slab and LZ4 streams go to the
    kernel as (start, end) bounds — no per-stream bytes are materialized
    before the bypass decision.
    """
    name = resolve_codec(codec)
    starts = np.asarray(starts, dtype=np.int64).ravel()
    ends = np.asarray(ends, dtype=np.int64).ravel()
    S = int(starts.size)
    buf = np.asarray(slab, dtype=np.uint8).ravel()
    payloads: List[bytes] = [b""] * S
    flags: List[int] = [RAW] * S
    todo: List[int] = []
    for i, skip in enumerate(_prescreen_slab(buf, starts, ends)):
        if skip:
            payloads[i] = buf[starts[i]: ends[i]].tobytes()
        else:
            todo.append(i)
    if todo:
        tsel = np.asarray(todo, dtype=np.int64)
        if name == "lz4":
            comps = _lz4_slab_streams(slab, buf, starts[tsel], ends[tsel],
                                      force=force)
        elif name == "zstd":
            comps = zstd_compress_batch(
                [buf[starts[i]: ends[i]].tobytes() for i in todo])
        else:
            c, _ = CODECS[name]
            comps = [c(buf[starts[i]: ends[i]].tobytes()) for i in todo]
        for i, comp in zip(todo, comps):
            n = int(ends[i] - starts[i])
            if len(comp) >= BYPASS_THRESHOLD * n:
                payloads[i] = buf[starts[i]: ends[i]].tobytes()
            else:
                payloads[i], flags[i] = comp, COMPRESSED
    return payloads, flags


def decompress_block(payload: bytes, flag: int, codec: str, orig_len: int) -> bytes:
    if flag == RAW:
        return payload
    _, d = CODECS[resolve_codec(codec)]
    out = d(payload, max_out=orig_len)
    return out


def decompress_batch(payloads: Sequence[bytes], flags: Sequence[int],
                     codec: str, orig_lens: Sequence[int]) -> List[bytes]:
    """Inverse of :func:`compress_batch`: one codec resolve for the group."""
    _, d = CODECS[resolve_codec(codec)]
    return [
        pay if fl == RAW else d(pay, max_out=n)
        for pay, fl, n in zip(payloads, flags, orig_lens)
    ]


def ratio(orig: int, comp: int) -> float:
    """Compression ratio S_orig / S_comp (≥ 1 is a gain)."""
    return orig / max(comp, 1)
