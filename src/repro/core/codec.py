"""Commodity lossless codecs used by the device model (paper §III-B).

TRACE deliberately reuses *generic* codecs — the gain comes from changing
the codec input (plane streams instead of mixed-field words), not from a
bespoke compressor.  We model the paper's two codecs:

* ``lz4`` — a from-scratch LZ4 *block format* encoder/decoder (the offline
  environment has no lz4 binding).  Greedy hash-chain matching, standard
  end-of-block rules, byte-exact round-trip; this stands in for the 32-lane
  streaming LZ4 engine of the controller (paper §IV-E).
* ``zstd`` — the real Zstandard via the ``zstandard`` package.

Both are exposed through a tiny registry with block-level *bypass*: when a
block is incompressible the device stores it raw and marks the index entry
(paper §III-D "Bypass and correctness invariants").
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, Tuple

import numpy as np

try:
    import zstandard as _zstd

    _ZSTD_C = _zstd.ZstdCompressor(level=3)
    _ZSTD_D = _zstd.ZstdDecompressor()
except Exception:  # pragma: no cover
    _zstd = None

HAVE_ZSTD = _zstd is not None

_HASH_LOG = 13
_HASH_SIZE = 1 << _HASH_LOG
_MIN_MATCH = 4
_MFLIMIT = 12          # match must not start within last 12 bytes
_LAST_LITERALS = 5     # last 5 bytes are always literals


# ---------------------------------------------------------------------------
# LZ4 block format
# ---------------------------------------------------------------------------

def _lz4_hash(seq_u32: int) -> int:
    return (seq_u32 * 2654435761) >> (32 - _HASH_LOG) & (_HASH_SIZE - 1)


def lz4_compress(data: bytes) -> bytes:
    """Greedy LZ4 block-format compression (pure python + numpy hashing)."""
    n = len(data)
    if n == 0:
        return b"\x00"
    buf = np.frombuffer(data, dtype=np.uint8)
    out = bytearray()
    if n >= _MIN_MATCH:
        # vectorised 4-byte little-endian words + hashes for every position
        w = (
            buf[:-3].astype(np.uint32)
            | (buf[1:-2].astype(np.uint32) << 8)
            | (buf[2:-1].astype(np.uint32) << 16)
            | (buf[3:].astype(np.uint32) << 24)
        )
        hashes = ((w * np.uint32(2654435761)) >> np.uint32(32 - _HASH_LOG)).astype(
            np.int64
        )
    table = np.full(_HASH_SIZE, -1, dtype=np.int64)

    def emit(lit_start: int, lit_end: int, match_len: int, offset: int):
        lit_len = lit_end - lit_start
        tok_lit = min(lit_len, 15)
        tok_match = min(match_len - _MIN_MATCH, 15) if match_len else 0
        out.append((tok_lit << 4) | tok_match)
        rest = lit_len - 15
        while rest >= 0:
            out.append(min(rest, 255))
            if rest < 255:
                break
            rest -= 255
        out.extend(data[lit_start:lit_end])
        if match_len:
            out.append(offset & 0xFF)
            out.append(offset >> 8)
            rest = match_len - _MIN_MATCH - 15
            while rest >= 0:
                out.append(min(rest, 255))
                if rest < 255:
                    break
                rest -= 255

    i = 0
    anchor = 0
    limit = n - _MFLIMIT
    while i < limit:
        h = hashes[i]
        cand = table[h]
        table[h] = i
        if cand >= 0 and i - cand <= 0xFFFF and w[cand] == w[i]:
            # extend match forward
            mlen = _MIN_MATCH
            max_len = n - _LAST_LITERALS - i
            while mlen < max_len and data[cand + mlen] == data[i + mlen]:
                mlen += 1
            emit(anchor, i, mlen, i - cand)
            # insert a couple of positions inside the match to help later refs
            step_end = min(i + mlen, limit)
            for j in range(i + 1, min(i + 3, step_end)):
                table[hashes[j]] = j
            i += mlen
            anchor = i
        else:
            i += 1
    # final literals
    emit(anchor, n, 0, 0)
    return bytes(out)


def lz4_decompress(comp: bytes, max_out: int | None = None) -> bytes:
    out = bytearray()
    i, n = 0, len(comp)
    while i < n:
        token = comp[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                b = comp[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        out.extend(comp[i : i + lit_len])
        i += lit_len
        if i >= n:
            break  # last sequence has no match part
        offset = comp[i] | (comp[i + 1] << 8)
        i += 2
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                b = comp[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        start = len(out) - offset
        if offset >= mlen:
            # disjoint source range — one slice copy
            out += out[start : start + mlen]
        else:
            # overlapping match = repeating pattern of period `offset`
            # (offset 1 is a byte run — the common case on zero-heavy
            # planes); replicate at C speed instead of a python loop
            pattern = bytes(out[start:])
            out += (pattern * (mlen // offset + 1))[:mlen]
        if max_out is not None and len(out) > max_out:
            raise ValueError("decompressed size exceeds bound")
    return bytes(out)


# ---------------------------------------------------------------------------
# zstd wrappers
# ---------------------------------------------------------------------------

def zstd_compress(data: bytes) -> bytes:
    if _zstd is None:  # pragma: no cover
        raise RuntimeError("zstandard not available")
    return _ZSTD_C.compress(data)


def zstd_decompress(data: bytes, max_out: int | None = None) -> bytes:
    if _zstd is None:  # pragma: no cover
        raise RuntimeError("zstandard not available")
    return _ZSTD_D.decompress(data, max_output_size=max_out or 0)


# ---------------------------------------------------------------------------
# Registry + block API with bypass
# ---------------------------------------------------------------------------

CODECS: Dict[str, Tuple[Callable[[bytes], bytes], Callable[..., bytes]]] = {
    "lz4": (lz4_compress, lz4_decompress),
    "none": (lambda b: b, lambda b, max_out=None: b),
}
if HAVE_ZSTD:
    CODECS["zstd"] = (zstd_compress, zstd_decompress)

_warned_fallback = False


def resolve_codec(name: str) -> str:
    """Map a requested codec to an available one.

    ``zstd`` silently degrades to ``lz4`` (with a one-time warning) when the
    ``zstandard`` package is missing, so device models stay usable in minimal
    environments; tests that depend on zstd-specific ratios should check
    :data:`HAVE_ZSTD` and skip instead.
    """
    global _warned_fallback
    if name in CODECS:
        return name
    if name == "zstd":
        if not _warned_fallback:
            warnings.warn(
                "zstandard is not installed; falling back to the built-in "
                "lz4 codec for all 'zstd' devices",
                RuntimeWarning,
                stacklevel=2,
            )
            _warned_fallback = True
        return "lz4"
    raise KeyError(f"unknown codec {name!r}; registered: {sorted(CODECS)}")

RAW, COMPRESSED = 0, 1


def compress_block(data: bytes, codec: str) -> tuple[bytes, int]:
    """Compress one block; fall back to raw storage when incompressible.

    Returns ``(payload, flag)`` with flag ∈ {RAW, COMPRESSED}.
    """
    c, _ = CODECS[resolve_codec(codec)]
    comp = c(data)
    if len(comp) >= len(data):
        return data, RAW
    return comp, COMPRESSED


def decompress_block(payload: bytes, flag: int, codec: str, orig_len: int) -> bytes:
    if flag == RAW:
        return payload
    _, d = CODECS[resolve_codec(codec)]
    out = d(payload, max_out=orig_len)
    return out


def ratio(orig: int, comp: int) -> float:
    """Compression ratio S_orig / S_comp (≥ 1 is a gain)."""
    return orig / max(comp, 1)
