"""Controller pipeline timing + PPA model (paper §III-D / §IV-E).

Cycle-level stage model of the four-stage controller pipeline (Fig. 11),
calibrated so the published operating points are reproduced exactly:

  Table V   load-to-use: Plain 71, GComp 84, TRACE 89 cycles @ 2 GHz
  Fig. 22   stage split: F/M/S + tRCD/tCL/Burst, codec overlapped
  Fig. 23   TRACE latency vs compression ratio: 89 @ 1.5x → 85 @ 3.0x,
            bypass (incompressible) 76 cycles

The DRAM window (tRCD + tCL + burst) and the variable burst/codec-exposed
term are explicit; the codec datapath itself streams and overlaps with the
DRAM access window, so only its non-overlapped tail is exposed
(`v(r) = VAR_A / r + VAR_C` fitted to the two published points).

Area/power are reported from the paper's ASAP7 synthesis (Table V) — this
container cannot run synthesis; constants are data, clearly labelled.
"""

from __future__ import annotations

import dataclasses

CLOCK_GHZ = 2.0

# -- per-design stage cycles (Fig. 22) --------------------------------------
STAGES = {
    # design:        F   M   S   tRCD tCL
    "plain": dict(front=3, meta=2, sched=8, trcd=18, tcl=22, burst=18),
    "gcomp": dict(front=3, meta=4, sched=9, trcd=18, tcl=22),
    "trace": dict(front=5, meta=2, sched=10, trcd=18, tcl=22),
}

# variable (burst + exposed-codec tail) term v(r) = A / r + C, fitted to
# Fig. 23: v(1.5) = 32, v(3.0) = 28  →  A = 12, C = 24  (TRACE)
# GComp single published point (84 total at the same ~1.5x corpus ratio):
# fixed = 56 → v(1.5) = 28 → keep same A, C = 20.
_VAR = {"trace": (12.0, 24.0), "gcomp": (12.0, 20.0)}

BYPASS_BURST = 19          # raw planes, codec skipped (Fig. 23: total 76)
INDEX_MISS_BURST = 2       # one 64 B index entry


def load_to_use_cycles(
    design: str,
    comp_ratio: float = 1.5,
    meta_hit: bool = True,
    bypass: bool = False,
) -> float:
    """Device-local load-to-use service time in cycles."""
    s = STAGES[design]
    fixed = s["front"] + s["meta"] + s["sched"] + s["trcd"] + s["tcl"]
    if design == "plain":
        total = fixed + s["burst"]
    elif bypass:
        total = fixed + BYPASS_BURST
    else:
        a, c = _VAR[design]
        total = fixed + a / max(comp_ratio, 1.0) + c
    if not meta_hit:
        # one extra DRAM access window to fetch the index entry (§IV-E);
        # data planes are not re-read.
        total += s["trcd"] + s["tcl"] + INDEX_MISS_BURST
    return total


def load_to_use_ns(design: str, **kw) -> float:
    return load_to_use_cycles(design, **kw) / CLOCK_GHZ


# -- PPA (paper Table V; ASAP7 7 nm @ 2 GHz, 0.7 V) --------------------------
@dataclasses.dataclass(frozen=True)
class PPA:
    area_mm2: float
    power_w: float
    breakdown: dict


PPA_TABLE = {
    "plain": PPA(3.91, 9.0, dict(phy=3.50, metadata=0.21, scheduler=0.02, other=0.18)),
    "gcomp": PPA(
        6.66,
        21.4,
        dict(phy=3.50, codec=1.92, codec_sram=0.62, metadata=0.42, scheduler=0.02, other=0.18),
    ),
    "trace": PPA(
        7.14,
        22.4,
        dict(
            phy=3.50, codec=1.92, codec_sram=0.62, metadata=0.83,
            scheduler=0.03, transpose_recon=0.06, other=0.18,
        ),
    ),
}


def staging_sram_bytes(n_tokens: int, channels: int, elem_bytes: int = 2,
                       overhead: int = 64, n_streams: int = 1) -> int:
    """KV staging-buffer sizing, Eq. 4: S_buf = n·C·b + S_ovhd."""
    return n_streams * (n_tokens * channels * elem_bytes + overhead)
