"""TRACE core: bit-plane substrate, KV transform, elastic precision,
codecs, device models, and the paper's analytic system models."""

from . import bitplane, codec, controller, dram_model, kv_transform, precision
from . import sharding, system_model, tier
from .precision import PrecisionView, FULL, MAN4, MAN2, MAN0, VIEWS
from .sharding import PLACEMENTS, FleetStats, ShardedTierStore
from .tier import (
    GCompDevice,
    PlainDevice,
    ReadReq,
    Receipt,
    Ticket,
    TierStore,
    TraceDevice,
    WriteReq,
    make_device,
)

__all__ = [
    "bitplane", "codec", "controller", "dram_model", "kv_transform",
    "precision", "sharding", "system_model", "tier",
    "PrecisionView", "FULL", "MAN4", "MAN2", "MAN0", "VIEWS",
    "PlainDevice", "GCompDevice", "TraceDevice", "TierStore", "make_device",
    "WriteReq", "ReadReq", "Receipt", "Ticket",
    "PLACEMENTS", "FleetStats", "ShardedTierStore",
]
