"""KV-specific transform — Mechanism I (paper §III-B, Fig. 8).

The host writes KV token-major; adjacent addresses hold *different*
channels, whose scales differ, so the byte stream is high-entropy.  TRACE
buffers a window of ``n`` tokens, transposes to channel-major groups
``G_j = {k_{t,j}}`` (Eq. 3), then de-correlates each group by replacing the
exponent field with a small delta against a per-channel base exponent
``beta_j`` (Eq. 5) before bit-plane packing.

Losslessness.  The paper's delta can be negative; we make the transform
unconditionally invertible by computing the delta mod 256 and *zigzag*
encoding it around zero (small |delta| → small code → zero runs in the
high-order delta planes, which is exactly what the codec exploits).
``beta_j`` is the modal exponent of the channel group, stored as
constant-size per-stream metadata (paper §III-D "Metadata management").

The transformed element keeps the BF16 container layout:
    bit 15   sign            (unchanged)
    bits14..7 zigzag(exp - beta_j)
    bits 6..0 mantissa        (unchanged)
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from .bitplane import (
    EXP_BITS,
    EXP_LO,
    MAN_BITS,
    pack_planes,
    unpack_planes,
)

_EXP_MASK = np.uint16(((1 << EXP_BITS) - 1) << EXP_LO)
_REST_MASK = np.uint16(~(((1 << EXP_BITS) - 1) << EXP_LO) & 0xFFFF)


@dataclasses.dataclass
class KVBlockMeta:
    """Constant-size per-block state needed to invert the transform."""

    beta: np.ndarray      # (C,) uint8 — per-channel base exponent
    n_tokens: int
    n_channels: int

    @property
    def nbytes(self) -> int:
        return self.beta.size + 8  # betas + window header


# -- exponent delta (zigzag, mod-256 → always invertible) -------------------

def _zigzag_u8(d: np.ndarray) -> np.ndarray:
    """Map signed int8-range deltas to small unsigned codes: 0,-1,1,-2,… →
    0,1,2,3,…  Input is the mod-256 difference as uint8."""
    s = d.astype(np.int16)
    s = np.where(s >= 128, s - 256, s)  # interpret as signed
    z = np.where(s >= 0, 2 * s, -2 * s - 1)
    return z.astype(np.uint8)


def _unzigzag_u8(z: np.ndarray) -> np.ndarray:
    z = z.astype(np.int16)
    s = np.where(z % 2 == 0, z // 2, -(z + 1) // 2)
    return (s % 256).astype(np.uint8)


def _modal_exponent(exp: np.ndarray) -> np.ndarray:
    """Per-row modal value of (C, n) uint8 exponents."""
    C = exp.shape[0]
    out = np.empty(C, dtype=np.uint8)
    for j in range(C):
        out[j] = np.bincount(exp[j], minlength=256).argmax()
    return out


# -- forward / inverse transform on a (n_tokens, C) block --------------------

def kv_forward(block_u16: np.ndarray) -> tuple[np.ndarray, KVBlockMeta]:
    """Token-major (n, C) uint16 → channel-major transformed flat uint16.

    Returns the transformed element stream (flattened channel-major, i.e.
    all tokens of channel 0, then channel 1, …) and the per-block metadata.
    """
    n, C = block_u16.shape
    cm = np.ascontiguousarray(block_u16.T)          # (C, n) channel-major
    exp = ((cm & _EXP_MASK) >> EXP_LO).astype(np.uint8)
    beta = _modal_exponent(exp)
    delta = (exp.astype(np.int16) - beta[:, None].astype(np.int16)) % 256
    z = _zigzag_u8(delta.astype(np.uint8))
    out = (cm & _REST_MASK) | (z.astype(np.uint16) << EXP_LO)
    return out.ravel(), KVBlockMeta(beta=beta, n_tokens=n, n_channels=C)


def kv_inverse(stream_u16: np.ndarray, meta: KVBlockMeta) -> np.ndarray:
    """Invert :func:`kv_forward` → token-major (n, C) uint16."""
    C, n = meta.n_channels, meta.n_tokens
    cm = stream_u16.reshape(C, n)
    z = ((cm & _EXP_MASK) >> EXP_LO).astype(np.uint8)
    delta = _unzigzag_u8(z)
    exp = (delta.astype(np.int16) + meta.beta[:, None].astype(np.int16)) % 256
    out = (cm & _REST_MASK) | (exp.astype(np.uint16) << EXP_LO)
    return np.ascontiguousarray(out.T)


def kv_forward_batch(windows: np.ndarray) -> tuple[np.ndarray, list]:
    """Vectorized :func:`kv_forward` over same-shape windows.

    ``windows``: ``(B, n, C)`` uint16 token-major.  Returns ``((B, n*C)``
    transformed channel-major streams, ``B`` metas)`` — identical per
    window to the scalar transform (the modal exponent is the same
    bincount-argmax, just computed for all ``B*C`` channel groups in one
    offset-bincount pass).  The write-side mirror of
    :func:`kv_inverse_batch`: a flush group's windows transform in two
    numpy passes instead of one python call per window.
    """
    B, n, C = windows.shape
    cm = np.ascontiguousarray(windows.transpose(0, 2, 1))   # (B, C, n)
    exp = ((cm & _EXP_MASK) >> EXP_LO).astype(np.uint8)
    offs = (np.arange(B * C, dtype=np.int64) * 256)[:, None]
    counts = np.bincount(
        (exp.reshape(B * C, n).astype(np.int64) + offs).ravel(),
        minlength=256 * B * C,
    ).reshape(B * C, 256)
    beta = counts.argmax(axis=1).astype(np.uint8).reshape(B, C)
    delta = (exp.astype(np.int16) - beta[:, :, None].astype(np.int16)) % 256
    z = _zigzag_u8(delta.astype(np.uint8))
    out = (cm & _REST_MASK) | (z.astype(np.uint16) << EXP_LO)
    metas = [KVBlockMeta(beta=beta[b].copy(), n_tokens=n, n_channels=C)
             for b in range(B)]
    return out.reshape(B, n * C), metas


def kv_inverse_batch(streams: np.ndarray, metas: list) -> np.ndarray:
    """Vectorized :func:`kv_inverse` over same-shape windows.

    ``streams``: ``(B, n*C)`` uint16, one transformed window per row;
    ``metas``: B :class:`KVBlockMeta` with identical ``(n_tokens,
    n_channels)``.  Returns token-major ``(B, n, C)`` uint16.  Batched
    device reads use this to invert a whole request batch in two numpy
    passes instead of one python call per 4 KB block.
    """
    C, n = metas[0].n_channels, metas[0].n_tokens
    cm = streams.reshape(len(metas), C, n)
    beta = np.stack([m.beta for m in metas])          # (B, C)
    z = ((cm & _EXP_MASK) >> EXP_LO).astype(np.uint8)
    delta = _unzigzag_u8(z)
    exp = (delta.astype(np.int16) + beta[:, :, None].astype(np.int16)) % 256
    out = (cm & _REST_MASK) | (exp.astype(np.uint16) << EXP_LO)
    return np.ascontiguousarray(out.transpose(0, 2, 1))


def kv_pack(block_u16: np.ndarray) -> tuple[np.ndarray, KVBlockMeta]:
    """Full Mechanism-I chain: transform then bit-plane pack (Fig. 8)."""
    stream, meta = kv_forward(block_u16)
    return pack_planes(stream), meta


def kv_unpack(planes: np.ndarray, meta: KVBlockMeta) -> np.ndarray:
    stream = unpack_planes(planes, meta.n_tokens * meta.n_channels)
    return kv_inverse(stream, meta)


# -- jnp forward (oracle for the Pallas kernel; beta supplied externally) ----

def kv_forward_jnp(block_u16: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """(n, C) uint16 + (C,) uint8 beta → (C, n) transformed uint16 (jnp)."""
    cm = block_u16.T.astype(jnp.uint16)
    exp = ((cm & jnp.uint16(0x7F80)) >> 7).astype(jnp.int16)
    d = (exp - beta[:, None].astype(jnp.int16)) % 256
    s = jnp.where(d >= 128, d - 256, d)
    z = jnp.where(s >= 0, 2 * s, -2 * s - 1).astype(jnp.uint16)
    return (cm & jnp.uint16(0x807F)) | (z << jnp.uint16(7))
