"""Trace-driven decoding-throughput model (paper §IV-B, Figs. 12-14).

First-order bandwidth accounting: per-step traffic is decomposed into
weight reads + KV reads/writes; each tier (HBM, CXL link, CXL device DDR)
converts bytes-per-step into a tok/s ceiling and decode rate is the
bottleneck, additionally capped by a GPU compute ceiling.  The model
isolates how *bytes-per-token* changes (compression, plane-aligned elastic
fetch) move the ceilings — it does not model queueing.

Calibration (documented in DESIGN.md §Model-calibration): the paper gives
the structure but not every constant; the free parameters below were
reverse-engineered so the published anchors are reproduced:

  * Fig. 12 all-designs plateau 68.99 tok/s → compute/HBM cap `cap_tok_s`.
  * CXL-GComp ≈ CXL-Plain once KV-bound → the inline KV-path codec is LZ4,
    whose ratio on token-major KV is ~1.0 (Table I: LZ4 KV = 0.0%).
  * Plain = 16.28/8.21/5.49 tok/s at 128/196/256k → kv concurrency
    ``batch≈4`` with ``f_rd≈0.8`` and hot-KV budget = HBM − weights.
  * TRACE returning to the 68.99 cap at 128k is NOT reachable with the
    lossless ratio (1.8×) alone; it additionally requires elastic
    precision on spilled KV pages (`elastic_spill_bits`≈6, i.e. the
    Table II mixed BF16/FP8/FP4 page policy) — consistent with the
    paper's title: compression AND precision scaling.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """LLM shape terms that generate traffic."""

    name: str
    weight_bytes: float          # total stored weight footprint
    active_weight_bytes: float   # weight bytes *read per step* (MoE: active)
    kv_bytes_per_token: float    # layers * 2 * kv_heads * head_dim * elem
    batch: int = 4               # concurrent sequences (KV scales, weights amortise)


# GPT-OSS-120B (model card arXiv:2508.10925): 36 layers, d_model 2880,
# 64 q / 8 kv heads, head_dim 64, 128 experts top-4, ~5.1B active params.
def gpt_oss_120b(fmt: str = "mxfp4", batch: int = 4) -> ModelSpec:
    n_total, n_active = 116.8e9, 5.1e9
    bpw = {"mxfp4": 0.514, "bf16": 2.0}[fmt]    # ~60 GB / ~240 GB stored
    kv = 36 * 2 * 8 * 64 * 2.0                  # KV kept in BF16
    return ModelSpec(
        f"gpt-oss-120b-{fmt}", n_total * bpw, n_active * bpw, kv, batch
    )


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """Paper §IV-B: single GPU + one CXL Type-3 device."""

    hbm_bytes: float = 76e9          # usable HBM
    hbm_bw: float = 4.2e12           # HBM3E-class
    cxl_link_bw: float = 512e9       # per direction
    cxl_ddr_bw: float = 256e9        # device-side DDR
    f_rd: float = 0.8                # fraction of spilled context read/step
    cap_tok_s: float = 68.99         # GPU compute ceiling (Fig. 12 plateau)


@dataclasses.dataclass(frozen=True)
class DesignRatios:
    """Average compressed-size ratios of the device inline codec (LZ4 —
    the latency-sensitive path, paper §III-B) on 4 KB blocks."""

    weight: float = 1.0              # S_orig / S_comp for stored weights
    kv: float = 1.0

    @classmethod
    def for_design(cls, design: str, weight_fmt: str = "bf16") -> "DesignRatios":
        # Paper-measured LZ4 corpus ratios; benchmarks can override with
        # ratios measured by this repo's own pipeline (core.tier).
        table = {
            "plain": dict(bf16=(1.00, 1.00), mxfp4=(1.00, 1.00)),
            "gcomp": dict(bf16=(1.10, 1.02), mxfp4=(1.01, 1.02)),
            "trace": dict(bf16=(1.25, 1.80), mxfp4=(1.02, 1.80)),
        }
        w, kv = table[design][weight_fmt]
        return cls(weight=w, kv=kv)


@dataclasses.dataclass
class Breakdown:
    tok_s: float
    bottleneck: str
    hbm_bytes: float
    link_bytes: float
    ddr_bytes: float
    kv_spill_frac: float
    w_spill_frac: float


def throughput(
    model: ModelSpec,
    ctx: int,
    design: str,
    sys: SystemSpec = SystemSpec(),
    alpha: float | None = None,
    ratios: DesignRatios | None = None,
    weight_fmt: str | None = None,
    elastic_spill_bits: float | None = 6.0,
) -> Breakdown:
    """Per-stream decode tok/s at context length ``ctx`` for one design.

    ``elastic_spill_bits``: average bits/element at which TRACE serves
    *spilled* KV pages via plane-aligned fetch (None disables elasticity →
    lossless-only TRACE).  Ignored for plain/gcomp (word devices cannot
    fetch sub-container precision — paper Issue 2).
    """
    fmt = weight_fmt or ("mxfp4" if "mxfp4" in model.name else "bf16")
    r = ratios or DesignRatios.for_design(design, fmt)

    # --- capacity split (Eq. 9) ---------------------------------------------
    if alpha is None:
        h_w = min(model.weight_bytes, sys.hbm_bytes)     # weight-priority
    else:
        h_w = alpha * sys.hbm_bytes
    w_resident = min(model.weight_bytes, h_w)
    w_spill_frac = 1.0 - w_resident / model.weight_bytes
    h_kv = max(sys.hbm_bytes - w_resident, 0.0)

    kv_total = model.kv_bytes_per_token * ctx * model.batch
    kv_resident_frac = min(1.0, h_kv / kv_total) if kv_total > 0 else 1.0
    kv_spill_frac = 1.0 - kv_resident_frac

    # --- per-step traffic ----------------------------------------------------
    w_read = model.active_weight_bytes                    # one sweep per step
    kv_read_hot = sys.f_rd * kv_total * kv_resident_frac
    kv_read_spill = sys.f_rd * kv_total * kv_spill_frac
    kv_write = model.kv_bytes_per_token * model.batch

    hbm_bytes = w_read * (1 - w_spill_frac) + kv_read_hot + kv_write

    # Elastic precision on spilled pages: bytes scale with fetched planes
    # on BOTH the device DDR and the link (plane-aligned fetch, §III-C).
    elastic = 1.0
    if design == "trace" and elastic_spill_bits is not None:
        elastic = 16.0 / elastic_spill_bits

    link_bytes = w_read * w_spill_frac + kv_read_spill / elastic
    ddr_bytes = (
        w_read * w_spill_frac / r.weight
        + kv_read_spill / (r.kv * elastic)
        + kv_write * kv_spill_frac / r.kv
    )

    # --- ceilings ------------------------------------------------------------
    times = {
        "hbm": hbm_bytes / sys.hbm_bw,
        "cxl-link": link_bytes / sys.cxl_link_bw,
        "cxl-ddr": ddr_bytes / sys.cxl_ddr_bw,
    }
    bottleneck = max(times, key=times.get)
    step_time = max(max(times.values()), 1e-12)
    tok_s = min(1.0 / step_time, sys.cap_tok_s)
    if tok_s == sys.cap_tok_s:
        bottleneck = "compute-cap"
    return Breakdown(
        tok_s, bottleneck, hbm_bytes, link_bytes, ddr_bytes,
        kv_spill_frac, w_spill_frac,
    )


def sweep_context(model, ctxs, designs=("plain", "gcomp", "trace"), **kw):
    return {
        d: [throughput(model, c, d, **kw).tok_s for c in ctxs] for d in designs
    }


def sweep_alpha(model, ctx, alphas, designs=("plain", "gcomp", "trace"), **kw):
    return {
        d: [throughput(model, ctx, d, alpha=a, **kw).tok_s for a in alphas]
        for d in designs
    }


# Published anchor points used by the calibration benchmark (Fig. 12-14).
PAPER_ANCHORS_FIG12 = {  # (ctx → tok/s), GPT-OSS-120B-MXFP4, weights fit
    "plain": {65536: 68.99, 131072: 16.28, 196608: 8.21, 262144: 5.49},
    "trace": {65536: 68.99, 131072: 68.99, 196608: 32.03, 262144: 16.28},
}
PAPER_ANCHORS_FIG13 = {  # GPT-OSS-120B BF16, alpha=0.8, 4k / 128k
    "plain": {4096: 33.61, 131072: 10.97},
    "gcomp": {4096: 36.97, 131072: 11.30},
    "trace": {4096: 42.02, 131072: 40.29},
}
