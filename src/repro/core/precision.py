"""Elastic precision access — plane selection, guard rounding, reconstruction.

Paper §III-C.  A *precision view* of a BF16 tensor is defined by the number
of exponent/mantissa planes retained ``(r_e, r_m)`` plus guard planes
``(d_e, d_m)`` used for on-device round-to-nearest.  The controller always
fetches the sign plane and the MOST significant ``r_e + d_e`` exponent and
``r_m + d_m`` mantissa planes (Eq. 6); it never inspects element values.

Reconstruction (the ``R`` operator of Eq. 7):
  * guard bits drive round-to-nearest-even at the mantissa cut point; the
    carry may propagate into the exponent (exactly standard FP rounding,
    because the (exp, mantissa) concatenation is monotone in magnitude);
  * dropped LSB planes are zero-padded to restore a full 16-bit container;
  * Inf/NaN patterns (exponent all-ones) are preserved verbatim.

NOTE on exponent truncation: Eq. 6 permits ``r_e < 8`` (dropping low-order
exponent planes).  That quantizes the exponent to multiples of ``2^(8-r_e)``
which is numerically aggressive; the shipped views keep the full exponent
(``r_e = 8``) and scale the mantissa, matching how the paper's evaluation
sweeps bits/weight.  ``r_e < 8`` remains supported for completeness.

For KV blocks that went through the cross-token transform (kv_transform.py)
the exponent planes hold *zigzagged deltas*; views on KV therefore always
fetch all 8 exponent planes (they are the cheapest, most compressible
planes) and scale only mantissa planes.  See KVPolicy in runtime/paging.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .bitplane import (
    BF16_BITS,
    EXP_BITS,
    EXP_HI,
    MAN_BITS,
    MAN_HI,
    SIGN_BIT,
)


@dataclasses.dataclass(frozen=True)
class PrecisionView:
    """A reduced-precision alias of a BF16 tensor (paper Fig. 9).

    ``r_e``/``r_m``: exponent / mantissa planes returned to the host.
    ``d_e``/``d_m``: guard planes fetched beyond the cut for rounding.
    """

    r_e: int = EXP_BITS
    r_m: int = MAN_BITS
    d_e: int = 0
    d_m: int = 0
    name: str = ""

    def __post_init__(self):
        if not (0 <= self.r_e <= EXP_BITS):
            raise ValueError(f"r_e={self.r_e} out of range")
        if not (0 <= self.r_m <= MAN_BITS):
            raise ValueError(f"r_m={self.r_m} out of range")
        if self.r_e + self.d_e > EXP_BITS or self.r_m + self.d_m > MAN_BITS:
            raise ValueError("guard planes exceed field width")

    # -- plane sets ---------------------------------------------------------
    @property
    def bits(self) -> int:
        """Host-visible effective bits (1 + r_e + r_m)."""
        return 1 + self.r_e + self.r_m

    def kept_planes(self) -> Tuple[int, ...]:
        """Planes whose bits survive into the host-visible value (Eq. 6)."""
        exp = tuple(range(EXP_HI, EXP_HI - self.r_e, -1))
        man = tuple(range(MAN_HI, MAN_HI - self.r_m, -1))
        return (SIGN_BIT,) + exp + man

    def fetched_planes(self) -> Tuple[int, ...]:
        """Planes physically read from DRAM (kept + guard)."""
        exp = tuple(range(EXP_HI, EXP_HI - self.r_e - self.d_e, -1))
        man = tuple(range(MAN_HI, MAN_HI - self.r_m - self.d_m, -1))
        return (SIGN_BIT,) + exp + man

    def plane_mask(self) -> int:
        """Bitmask over plane indices (bit i set = plane i fetched)."""
        m = 0
        for p in self.fetched_planes():
            m |= 1 << p
        return m

    @property
    def is_full(self) -> bool:
        return self.r_e == EXP_BITS and self.r_m == MAN_BITS


# Canonical views exposed by the driver as address aliases (paper Fig. 9).
FULL = PrecisionView(name="bf16")                                  # 16 bits
BF16 = FULL
MAN4 = PrecisionView(r_m=4, d_m=1, name="man4")                    # 13 bits
MAN2 = PrecisionView(r_m=2, d_m=1, name="man2")                    # 11 bits
MAN0 = PrecisionView(r_m=0, d_m=1, name="man0")                    # 9 bits
VIEWS = {v.name: v for v in (FULL, MAN4, MAN2, MAN0)}

# PNM scoring view (GatherReq.score_view default): sign + the full
# exponent — the delta-transformed, most compressible planes — with NO
# mantissa planes at all, not even a rounding guard.  Magnitudes come
# back quantized to signed powers of two, which is plenty for top-k
# *ranking*, and the score pass skips every incompressible mantissa
# plane.  Not in VIEWS: it is a ranking alias, not a storage precision a
# degrade ladder should ever truncate to.
SCORE = PrecisionView(r_m=0, d_m=0, name="score")                  # 9 bits


# ---------------------------------------------------------------------------
# Reconstruction (R operator) on uint16 bit patterns
# ---------------------------------------------------------------------------

_EXP_ALL_ONES = np.uint16(((1 << EXP_BITS) - 1) << (MAN_HI + 1))


def _field_keep_mask(view: PrecisionView) -> int:
    """uint16 mask of bits kept in the host-visible value."""
    m = 1 << SIGN_BIT
    for p in view.kept_planes():
        m |= 1 << p
    return m


def reconstruct_u16(fetched_u16: np.ndarray, view: PrecisionView) -> np.ndarray:
    """Apply guard-plane round-to-nearest-even + zero padding.

    ``fetched_u16`` holds the bit patterns assembled from the *fetched*
    planes (missing planes already zero).  Returns host-visible uint16
    patterns containing only kept planes.
    """
    x = fetched_u16.astype(np.uint16)
    if view.is_full:
        return x

    keep = np.uint16(_field_keep_mask(view))
    # Mantissa cut position (bit index of lowest kept mantissa bit).
    cut = MAN_HI - view.r_m + 1

    if view.d_m == 0 or view.r_e != EXP_BITS:
        # No usable guard bits (or exponent itself truncated): plain truncate.
        return x & keep

    # Round-to-nearest-even at `cut` over the magnitude bits (exp|mantissa).
    sign = x & np.uint16(1 << SIGN_BIT)
    mag = x & np.uint16((1 << SIGN_BIT) - 1)
    is_special = (x & _EXP_ALL_ONES) == _EXP_ALL_ONES  # Inf/NaN: keep as-is

    half = np.uint16(1 << (cut - 1))
    guard_mask = np.uint16((1 << cut) - 1)
    guard = mag & guard_mask
    lsb = (mag >> np.uint16(cut)) & np.uint16(1)
    round_up = (guard > half) | ((guard == half) & (lsb == 1))
    mag_r = (mag & ~guard_mask) + (round_up.astype(np.uint16) << np.uint16(cut))
    # Carry into exponent is the correct FP rounding; saturate at Inf pattern.
    mag_r = np.minimum(mag_r, _EXP_ALL_ONES)

    # Specials: keep pattern as-is (masked); a NaN whose payload lives only
    # in dropped planes must stay NaN — force the top kept mantissa bit.
    special_out = x & keep
    if view.r_m > 0:
        man_mask = np.uint16(((1 << MAN_BITS) - 1))
        nan_lost = is_special & ((x & man_mask) != 0) & ((special_out & man_mask) == 0)
        special_out = np.where(
            nan_lost, special_out | np.uint16(1 << MAN_HI), special_out
        )
    out = np.where(is_special, special_out, sign | mag_r)
    return (out & keep).astype(np.uint16)


def assemble_from_planes(planes: np.ndarray, n_elems: int, view: PrecisionView) -> np.ndarray:
    """Assemble uint16 patterns from a full plane stack, honouring the view.

    Device model convenience: select ``view.fetched_planes()`` from
    ``planes`` (shape (16, m//8)), zero the rest, unpack, then reconstruct.
    """
    from .bitplane import unpack_planes

    sel = np.zeros_like(planes)
    for p in view.fetched_planes():
        sel[p] = planes[p]
    u16 = unpack_planes(sel, n_elems)
    return reconstruct_u16(u16, view)


def truncate_reference(u16: np.ndarray, view: PrecisionView) -> np.ndarray:
    """Oracle: mask to the fetched planes, then apply guard rounding.

    The device never reads below-guard planes, so rounding decisions are
    made on the fetched bits only.  Must equal assemble_from_planes.
    """
    fetch_mask = np.uint16(0)
    for p in view.fetched_planes():
        fetch_mask |= np.uint16(1 << p)
    return reconstruct_u16(u16 & fetch_mask, view)


def view_dram_bytes(n_elems: int, view: PrecisionView) -> int:
    """Uncompressed DRAM bytes touched to serve this view (plane-aligned)."""
    from .bitplane import plane_bytes

    return len(view.fetched_planes()) * plane_bytes(n_elems)
