"""Bit-plane disaggregation — the physical substrate of TRACE (paper §III-A).

A block of ``m`` values, each ``B`` bits wide, is stored as the *transpose*
of its logical bit-matrix: ``B`` bit-planes, each a packed bitstream of
``m`` bits (Eq. 1-2 of the paper).  Plane ``i`` collects bit position ``i``
(0 = LSB) of every element.  The transform is a pure permutation of bits,
hence exactly lossless for any payload including NaN/Inf/subnormals.

Two implementations live here:

* numpy (``pack_planes`` / ``unpack_planes``) — the device-side model used
  by the memory-tier simulator and the codecs.  Planes are returned as a
  ``(B, m//8) uint8`` array so each plane is a contiguous byte stream, the
  exact representation handed to the inline codec.
* jax (``pack_planes_jnp`` / ``unpack_planes_jnp``) — reference used by the
  Pallas kernels' oracles and by the elastic-precision serving path.

BF16 field layout (bit position, 0 = LSB):
    sign      = bit 15
    exponent  = bits 14..7   (8 bits)
    mantissa  = bits 6..0    (7 bits)
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Field layout constants (BF16 container; INT8/FP8 use the low bits).
# ---------------------------------------------------------------------------
BF16_BITS = 16
SIGN_BIT = 15
EXP_HI, EXP_LO = 14, 7          # inclusive bit range of the exponent field
MAN_HI, MAN_LO = 6, 0           # inclusive bit range of the mantissa field
EXP_BITS = EXP_HI - EXP_LO + 1  # 8
MAN_BITS = MAN_HI - MAN_LO + 1  # 7

# Default device block: 2048 BF16 elements = 4 KiB, aligned to DRAM rows
# (paper §III-A "Line-rate implementation").
BLOCK_ELEMS = 2048
BLOCK_BYTES = BLOCK_ELEMS * 2


def bf16_to_u16(x: np.ndarray) -> np.ndarray:
    """View a bfloat16/uint16 array as uint16 bit patterns."""
    if x.dtype == np.uint16:
        return x
    # np has no bfloat16; callers hand us ml_dtypes bfloat16 or jnp arrays.
    return np.asarray(x).view(np.uint16)


def u16_to_bf16(u: np.ndarray):
    import ml_dtypes  # ships with jax

    return u.astype(np.uint16).view(ml_dtypes.bfloat16)


# ---------------------------------------------------------------------------
# numpy pack / unpack
# ---------------------------------------------------------------------------

def pack_planes(u16: np.ndarray, bits: int = BF16_BITS) -> np.ndarray:
    """Disaggregate ``u16`` (flat uint16, length multiple of 8) into packed
    bit-planes.

    Returns ``planes``: uint8 array of shape ``(bits, len(u16) // 8)``;
    ``planes[i]`` is the packed stream of bit ``i`` (0 = LSB) across all
    elements, MSB-first within each byte (np.packbits default), so that
    elements 0..7 land in byte 0.
    """
    u16 = np.ascontiguousarray(u16, dtype=np.uint16).ravel()
    if u16.size % 8:
        raise ValueError(f"block length {u16.size} not a multiple of 8")
    # (bits, m) bit matrix: row i = bit i of every element.
    shifts = np.arange(bits, dtype=np.uint16)[:, None]
    bitmat = (u16[None, :] >> shifts) & np.uint16(1)
    return np.packbits(bitmat.astype(np.uint8), axis=1)


def unpack_planes(planes: np.ndarray, n_elems: int, bits: int = BF16_BITS) -> np.ndarray:
    """Inverse of :func:`pack_planes` → flat uint16 of length ``n_elems``."""
    bitmat = np.unpackbits(planes, axis=1, count=n_elems).astype(np.uint16)
    shifts = np.arange(bits, dtype=np.uint16)[:, None]
    return np.bitwise_or.reduce(bitmat << shifts, axis=0)


def plane_bytes(n_elems: int) -> int:
    """Bytes per plane for a block of ``n_elems`` elements."""
    return (n_elems + 7) // 8


def unpack_planes_subset(rows: np.ndarray, plane_idx, n_elems: int) -> np.ndarray:
    """Unpack only the planes in ``plane_idx`` (absent planes read as zero).

    ``rows`` is a ``(len(plane_idx), nbytes) uint8`` matrix whose i-th row is
    the packed stream of plane ``plane_idx[i]``.  Batched reads use this to
    skip the all-zero rows a full 16-plane unpack would grind through when a
    precision view fetches only a subset of planes.  Accumulates plane by
    plane so temporaries stay one-plane-sized (cache-resident even for
    multi-megabyte batches).
    """
    out = np.zeros(n_elems, dtype=np.uint16)
    for i, p in enumerate(plane_idx):
        bits = np.unpackbits(rows[i], count=n_elems)
        out |= bits.astype(np.uint16) << np.uint16(p)
    return out


# ---------------------------------------------------------------------------
# jnp pack / unpack (oracle for the Pallas kernels; also used in serving)
# ---------------------------------------------------------------------------

def pack_planes_jnp(u16: jnp.ndarray, bits: int = BF16_BITS) -> jnp.ndarray:
    """jnp version of :func:`pack_planes`.

    Input (m,) uint16 → output (bits, m // 8) uint8, identical bytes to the
    numpy path.
    """
    m = u16.shape[-1]
    shifts = jnp.arange(bits, dtype=jnp.uint16)[:, None]
    bitmat = ((u16.astype(jnp.uint16)[None, :] >> shifts) & jnp.uint16(1)).astype(jnp.uint8)
    # pack MSB-first groups of 8: weights 128..1
    grouped = bitmat.reshape(bits, m // 8, 8)
    weights = (jnp.uint8(1) << jnp.arange(7, -1, -1, dtype=jnp.uint8))
    return jnp.sum(grouped * weights[None, None, :], axis=-1, dtype=jnp.uint8)


def unpack_planes_jnp(planes: jnp.ndarray, n_elems: int, bits: int = BF16_BITS) -> jnp.ndarray:
    nbytes = planes.shape[-1]
    shifts_in = jnp.arange(7, -1, -1, dtype=jnp.uint8)  # MSB-first
    bitmat = ((planes[:, :, None] >> shifts_in[None, None, :]) & jnp.uint8(1))
    bitmat = bitmat.reshape(bits, nbytes * 8)[:, :n_elems].astype(jnp.uint16)
    shifts = jnp.arange(bits, dtype=jnp.uint16)[:, None]
    return jnp.sum(bitmat << shifts, axis=0).astype(jnp.uint16)


# ---------------------------------------------------------------------------
# Block helpers
# ---------------------------------------------------------------------------

def iter_blocks(u16: np.ndarray, block_elems: int = BLOCK_ELEMS):
    """Yield fixed-size blocks of a flat uint16 tensor, zero-padding the tail.

    Yields ``(block, valid)`` where ``valid`` is the number of real elements.
    """
    u16 = u16.ravel()
    n = u16.size
    for start in range(0, n, block_elems):
        chunk = u16[start : start + block_elems]
        valid = chunk.size
        if valid < block_elems:
            chunk = np.pad(chunk, (0, block_elems - valid))
        yield chunk, valid
