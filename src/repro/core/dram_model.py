"""Device-side DRAM access model for plane-aligned fetch (paper §IV-D).

DRAMSim3 is not available offline; this is a first-order structural model
of the same experiment:

    energy = bytes_moved * E_BYTE  +  row_activations * E_ACT

with bytes and activations derived from the physical layout.  The paper's
published per-weight energies (Fig. 21) scale ~linearly with the
bits/weight target for BOTH designs, i.e. CXL-Plain also stores quantised
units natively packed; the TRACE gain is dominated by *row-buffer
locality*:

* word fetch (CXL-Plain): units with heterogeneous precision are
  interleaved in the word-major address space, so the per-bank schedule
  hops between rows — low row-hit rate on mixed-precision sweeps.
* plane fetch (TRACE): every plane is a contiguous stripe across units;
  the plane-aware scheduler (paper Fig. 11) streams each stripe — high
  row-hit rate, but *small* units (MLP neurons, 900 B/plane) leave gaps in
  each stripe when only a subset of units needs a given plane, costing
  extra activations.  This is exactly why the paper's per-neuron savings
  (19-34 %) trail the per-head savings (30-41 %).

Compression is disabled here, matching §IV-D ("compare word-fetch vs
plane-fetch on the same uncompressed storage").
"""

from __future__ import annotations

import dataclasses

import numpy as np

ROW_BYTES = 8192          # row buffer per rank (10x4 DDR5 devices/channel)
BURST_BYTES = 64          # BL16 x4 rank access granularity

# Energy coefficients (pJ), calibrated against the paper's published
# per-weight anchors (Fig. 21): plain at 8.0 bits ≈ 238.9 pJ/w with ~40%
# of that in activate/precharge (word-major mixed-precision sweeps hit the
# row buffer only ~50%), trace at 8.0 bits ≈ 141.2 pJ/w (plane streams hit
# ~98%).  E_ACT is per *rank* activation cycle (10x4 DDR5 devices fire
# together), hence the nJ scale.
E_BYTE_PJ = 140.0         # read/IO energy per byte moved
E_ACT_PJ = 12000.0        # activate+precharge energy per rank row cycle

# Row-hit rates by layout (structural, see module docstring).
ROW_HIT_PLANE_STREAM = 0.98   # contiguous plane stripe, large units
ROW_HIT_WORD_MIXED = 0.50     # word-major mixed-precision sweep


@dataclasses.dataclass(frozen=True)
class UnitSpec:
    """A precision-controlled weight unit (expert / head / neuron)."""

    weights: int            # elements per unit
    name: str = "unit"


HEAD = UnitSpec(int(3.7e6), "attention-head")     # OPT-30B per-head chunk
NEURON = UnitSpec(7200, "mlp-neuron")             # OPT-30B per-neuron chunk
EXPERT = UnitSpec(int(176e6), "expert")           # Mixtral 8x7B FFN expert


def mixture_for_target(target_bits: float,
                       levels=(1, 2, 4, 8, 16)) -> dict[int, float]:
    """Maximum-entropy mixture over precision levels with the given mean.

    Runtime importance is long-tailed and per-unit diverse (paper §II-C,
    Fig. 17's precision distributions); an exponential-family mixture
    p_i ∝ exp(lam*b_i) with E[b] = target captures that diversity at every
    target instead of collapsing to a single level.
    """
    b = np.array(sorted(levels), dtype=float)
    target_bits = float(np.clip(target_bits, b[0], b[-1]))
    lo_, hi_ = -5.0, 5.0
    for _ in range(80):  # bisection on lam
        lam = 0.5 * (lo_ + hi_)
        p = np.exp(lam * b)
        p /= p.sum()
        if (p * b).sum() < target_bits:
            lo_ = lam
        else:
            hi_ = lam
    return {int(bi): float(pi) for bi, pi in zip(b, p) if pi > 1e-9}


def _mix_diversity(mix: dict[int, float] | None) -> float:
    """Simpson diversity 1-Σp² of the precision mixture — how mixed the
    per-unit precisions are.  Word-major row-hit rate degrades with it:
    a uniform-precision sweep streams rows; a diverse mixture hops."""
    if not mix:
        return 1.0
    import numpy as _np

    p = _np.array(list(mix.values()))
    return float(1.0 - _np.sum(p * p))


def _traffic(unit: UnitSpec, bits: float, design: str,
             presence: dict[int, float] | None = None,
             mix: dict[int, float] | None = None) -> tuple[float, float]:
    """(bytes, activations) to fetch one unit at ``bits`` precision.

    ``presence``: plane index → fraction of units fetching that plane;
    controls stripe-gap activations for plane fetch on small units.
    """
    nbytes = max(unit.weights * bits / 8.0, BURST_BYTES)
    bursts = nbytes / BURST_BYTES
    if design == "plain":
        # native packed containers, word-major; row-hit rate falls with
        # mixture diversity (quantized bases admit fewer tiers → less
        # diverse mixes → plain recovers locality → TRACE savings taper,
        # paper Fig. 18)
        div = _mix_diversity(mix)
        hit = ROW_HIT_PLANE_STREAM - (
            ROW_HIT_PLANE_STREAM - ROW_HIT_WORD_MIXED
        ) * div / 0.75
        hit = min(max(hit, ROW_HIT_WORD_MIXED), ROW_HIT_PLANE_STREAM)
        acts = bursts * (1.0 - hit)
    else:
        n_planes = int(np.ceil(bits))
        stripe = max(unit.weights / 8.0, BURST_BYTES)   # bytes/plane/unit
        # contiguous stream within a stripe...
        acts = n_planes * (stripe / ROW_BYTES)
        # ...plus a stripe-gap activation whenever the previous unit did
        # not fetch this plane (prob 1 - presence) and the stripe chunk is
        # smaller than a row (fine-grained units, e.g. MLP neurons).
        if stripe < ROW_BYTES and presence:
            for i in range(1, n_planes + 1):
                acts += 1.0 - presence.get(i, 0.0)
        acts += bursts * (1.0 - ROW_HIT_PLANE_STREAM)
    return nbytes, max(acts, 1.0)


def _plane_presence(mix: dict[int, float]) -> dict[int, float]:
    """plane index (1-based) → fraction of units that fetch it."""
    out = {}
    for i in range(1, 17):
        out[i] = sum(f for b, f in mix.items() if b >= i)
    return out


def energy_per_weight_pj(
    unit: UnitSpec,
    target_bits: float,
    design: str,
    e_byte: float = E_BYTE_PJ,
    e_act: float = E_ACT_PJ,
    levels=(1, 2, 4, 8, 16),
) -> float:
    """Average DRAM access energy per weight at an avg-bits/weight target.

    ``levels``: precision tiers the base format admits — (2,4,8,16) for
    BF16 bases, (2,4,8) for FP8, (2,4) for INT4.  Narrower level sets
    leave fewer planes to skip, which tapers TRACE's savings exactly as
    the paper observes for quantized bases.
    """
    rd, act = energy_split_per_weight_pj(
        unit, target_bits, design, e_byte, e_act, levels
    )
    return rd + act


def energy_split_per_weight_pj(unit, target_bits, design,
                               e_byte=E_BYTE_PJ, e_act=E_ACT_PJ,
                               levels=(1, 2, 4, 8, 16)):
    """(read_pj, activation_pj) split — paper Fig. 21 stacked bars."""
    mix = mixture_for_target(target_bits, levels)
    presence = _plane_presence(mix)
    rd = act = 0.0
    for bits, frac in mix.items():
        nbytes, acts = _traffic(unit, bits, design, presence, mix)
        rd += frac * nbytes * e_byte / unit.weights
        act += frac * acts * e_act / unit.weights
    return rd, act


def model_load_energy_j(
    units: int, unit_spec: UnitSpec, target_bits: float, design: str, **kw
) -> float:
    """Total DRAM energy for one full model load (Fig. 20)."""
    return units * unit_spec.weights * energy_per_weight_pj(
        unit_spec, target_bits, design, **kw
    ) * 1e-12


def load_latency_s(
    units: int, unit_spec: UnitSpec, target_bits: float, design: str,
    ddr_bw: float = 256e9,
) -> float:
    """Device-side DRAM service time for the weight reads (Fig. 19 analog):
    stream time + *exposed* activation stalls.  Bank-level parallelism
    hides most of tRCD+tRP; the exposed penalty per activation is a small
    effective constant (calibrated so savings track the paper's 25-30 %
    latency reductions, which follow the byte savings)."""
    mix = mixture_for_target(target_bits)
    presence = _plane_presence(mix)
    exposed_act = 0.2e-9
    t = 0.0
    for bits, frac in mix.items():
        nbytes, acts = _traffic(unit_spec, bits, design, presence, mix)
        t += frac * units * (nbytes / ddr_bw + acts * exposed_act)
    return t
