"""Multi-device tier sharding: N :class:`TierStore` instances behind the
single-device protocol.

One ``TierStore`` models one CXL controller; a production rack has many.
:class:`ShardedTierStore` fans a request batch out across ``n`` inner
stores — each with its own :class:`LinkModel` pipes and busy clock — and
reassembles per-request receipts in order, so every consumer that speaks
``WriteReq``/``ReadReq`` → ``submit``/``submit_async`` → ``Receipt``
(`KVPagePool`, `ServeEngine`, `ServeScheduler`) works unchanged.

Routing is a pluggable :class:`Placement` policy (the ``PLACEMENTS``
registry, mirroring ``LAYOUTS``/``DEVICE_KINDS``):

* ``hash-stripe`` (default) — every key hashes to one home shard, so one
  request's KV pages stripe across the fleet and cold capacity scales
  with ``n``.
* ``namespace`` — keys route by their first ``.``-segment, pinning each
  engine replica's whole namespace (``r7.*``) to one device: per-request
  device affinity instead of per-page striping.
* ``replicate-weights`` — hash-stripe for KV, but ``TENSOR``-kind writes
  replicate to every shard and tensor reads fan out to the least-busy
  replica (smallest :attr:`TierStore.busy_backlog_s`).

Two invariants placement must never break, and the differential suite
holds it to:

1. **Key locality** — a key's whole append stream lives on exactly one
   home shard (replicas are full copies), so bytes read back are
   byte-identical to a single-device run, sync or async.
2. **Pinned ``shared.`` pages** — content-addressed prefix pages route
   by their ``shared.<hash>`` head, so every layer/kind page of one
   prefix window colocates and ``acquire``/``release`` refcounts stay
   device-local (no cross-shard reference bookkeeping).

Receipts carry the serving shard's ``device_id``; per-device
``DeviceStats`` stay first-class (``per_device_stats``) and aggregate
into the :class:`FleetStats` view (``.stats``), so skew and stragglers
are measurable (``fleet_skew``).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .precision import FULL, PrecisionView
from .tier import (
    DEVICE_KINDS,
    DeviceStats,
    GatherReq,
    GatherResult,
    KV,
    LinkModel,
    ReadReq,
    Receipt,
    Request,
    TENSOR,
    Ticket,
    TierStore,
    WriteReq,
    _ns_match,
)

SHARED_NS = "shared."


def _stable_hash(token: str) -> int:
    """Process-stable key hash (crc32) — placement must not depend on
    ``PYTHONHASHSEED``, or two pools sharing a fleet would disagree on
    which shard owns a ``shared.`` page."""
    return zlib.crc32(token.encode("utf-8"))


def shard_route_token(key: str) -> Optional[str]:
    """The pinned routing token for namespace-pinned keys, else None.

    ``shared.<hash>.L3.k`` routes by ``shared.<hash>`` so all of one
    content hash's layer/kind pages land on the same shard and its
    refcounts stay device-local.
    """
    if key.startswith(SHARED_NS):
        parts = key.split(".", 2)
        if len(parts) > 1:
            return parts[0] + "." + parts[1]
    return None


class Placement:
    """Where keys live in the fleet.  Subclasses pick the routing token
    (and optionally replicate writes); the token → shard map is a stable
    hash so every pool sharing the fleet agrees."""

    name = ""

    def __init__(self, n: int):
        self.n = n

    def _token(self, key: str) -> str:
        return key

    def owner(self, key: str) -> int:
        """The home shard of ``key`` — stable for the key's lifetime."""
        token = shard_route_token(key)
        if token is None:
            token = self._token(key)
        return _stable_hash(token) % self.n

    def replicates(self, req: WriteReq) -> bool:
        """True when this write lands a full copy on every shard."""
        return False


class HashStripePlacement(Placement):
    """Stripe every key by a stable full-key hash (cold-KV default)."""

    name = "hash-stripe"


class NamespacePlacement(Placement):
    """Pin each top-level namespace (``r7.*``) to one shard: engine
    replicas get whole-device affinity instead of per-page striping."""

    name = "namespace"

    def _token(self, key: str) -> str:
        return key.split(".", 1)[0]


class ReplicateWeightsPlacement(HashStripePlacement):
    """Hash-stripe KV, replicate hot weights: ``TENSOR``-kind writes land
    on every shard and tensor reads fan out to the least-busy replica."""

    name = "replicate-weights"

    def replicates(self, req: WriteReq) -> bool:
        return req.kind == TENSOR


PLACEMENTS: Dict[str, type] = {
    p.name: p for p in (
        HashStripePlacement, NamespacePlacement, ReplicateWeightsPlacement,
    )
}


def _fleet_sum(field: str):
    return property(
        lambda self: sum(getattr(s.stats, field) for s in self._shards))


class FleetStats:
    """Live fleet-wide aggregate over per-shard :class:`DeviceStats`.

    Every ``DeviceStats`` field reads as the sum across shards at access
    time, so consumers that poll ``device.stats`` (`ServeScheduler`'s IO
    snapshot, the pools' ratio estimator) see fleet totals without a
    sync point; ``reset_traffic`` fans out to every shard.  Note the
    receipts-sum identity holds per shard, not at the fleet view, under
    ``replicate-weights``: a replicated write returns ONE receipt but
    lands bytes on every shard (each shard's own ledger and sanitizer
    still balance).
    """

    def __init__(self, shards: Sequence[TierStore]):
        self._shards = list(shards)

    def reset_traffic(self):
        for s in self._shards:
            s.stats.reset_traffic()

    @property
    def bypass_rate(self) -> float:
        return self.codec_bypass / max(self.codec_blocks, 1)

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes_stored / max(self.dram_bytes_stored, 1)


for _field in dataclasses.fields(DeviceStats):
    setattr(FleetStats, _field.name, _fleet_sum(_field.name))


class _MergedGatherTicket:
    """Ticket over one fleet-spanning :class:`GatherReq`.

    Wraps the per-shard sub-gather tickets; :meth:`wait` waits every
    shard's local top-k and merges them into ONE receipt through the
    same host-side merge the sync path uses (memoized — repeat waits
    return the identical receipt, matching :class:`Ticket` semantics).
    """

    __slots__ = ("request", "_store", "_inner", "_per_pos", "_receipt",
                 "_error")

    def __init__(self, store: "ShardedTierStore", request: GatherReq,
                 inner: Sequence[Ticket],
                 per_pos: Sequence[Sequence[int]]):
        self.request = request
        self._store = store
        self._inner = list(inner)
        self._per_pos = per_pos
        self._receipt: Optional[Receipt] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        if self._receipt is not None or self._error is not None:
            return True
        return all(t.done for t in self._inner)

    def wait(self) -> Receipt:
        if self._error is not None:
            raise self._error
        if self._receipt is None:
            try:
                recs = [t.wait() for t in self._inner]
            except BaseException as e:
                self._error = e
                raise
            self._receipt = self._store._merge_gather(
                self.request, recs, self._per_pos)
        return self._receipt

    def __repr__(self):
        state = ("done" if self._receipt is not None
                 else "failed" if self._error is not None else "pending")
        return f"_MergedGatherTicket({self.request.key!r}, {state})"


class ShardedTierStore:
    """N inner tier devices behind the single-device request protocol.

    Construction mirrors :func:`make_device`: pass ``kind`` for a named
    device per shard, ``layout`` for a bare :class:`TierStore` per shard,
    or ``shard_factory`` (an ``i -> TierStore`` callable that must set
    ``device_id=i``) for full control — e.g. heterogeneous fleets with a
    deliberately slow straggler.  ``link_models`` overrides the pipe
    model per shard.  All remaining keyword args forward to every inner
    device.
    """

    name = "sharded"

    def __init__(self, n: int, kind: Optional[str] = None,
                 layout: Optional[str] = None,
                 placement: Union[str, Placement, None] = "hash-stripe",
                 link_models: Optional[Sequence[LinkModel]] = None,
                 sanitize: Optional[bool] = None,
                 shard_factory: Optional[Callable[[int], TierStore]] = None,
                 **device_kw):
        if n < 1:
            raise ValueError(f"need at least one shard, got {n}")
        if link_models is not None and len(link_models) != n:
            raise ValueError(
                f"link_models has {len(link_models)} entries for {n} shards")
        if placement is None:
            placement = "hash-stripe"
        self.placement = (PLACEMENTS[placement](n)
                          if isinstance(placement, str) else placement)
        shards: List[TierStore] = []
        for i in range(n):
            if shard_factory is not None:
                dev = shard_factory(i)
                if dev.device_id != i:
                    raise ValueError(
                        f"shard_factory({i}) built device_id="
                        f"{dev.device_id}; receipts could not attribute "
                        f"traffic — construct with device_id=i")
            else:
                kw = dict(device_kw)
                if link_models is not None:
                    kw["link_model"] = link_models[i]
                if sanitize is not None:
                    kw["sanitize"] = sanitize
                kw["device_id"] = i
                if kind is not None:
                    dev = DEVICE_KINDS[kind](**kw)
                else:
                    dev = TierStore(layout=layout or "word", **kw)
            shards.append(dev)
        self.shards = shards
        self.n_shards = n
        self.stats = FleetStats(shards)
        self.sanitize = shards[0].sanitize
        # Keys written under a replicating policy: reads of these may fan
        # out to any shard, deletes must retire every copy.
        self._replicated: set = set()

    # -- routing -------------------------------------------------------------
    def owner(self, key: str) -> int:
        """Home shard index of ``key`` under the active placement."""
        return self.placement.owner(key)

    def _read_shard(self, key: str) -> int:
        if key in self._replicated:
            return min(range(self.n_shards),
                       key=lambda i: (self.shards[i].busy_backlog_s, i))
        return self.placement.owner(key)

    def _partition(self, requests: Sequence[Request]
                   ) -> Tuple[List[List[Request]], List[List[Optional[int]]]]:
        """Split a batch into per-shard sub-batches (relative order kept).

        ``slots[s][j]`` is the batch index the j-th request of shard s
        answers, or None for a replica copy whose receipt is dropped
        (its traffic still lands in that shard's stats).
        """
        per: List[List[Request]] = [[] for _ in range(self.n_shards)]
        slots: List[List[Optional[int]]] = [[] for _ in range(self.n_shards)]
        for idx, req in enumerate(requests):
            if isinstance(req, WriteReq):
                home = self.placement.owner(req.key)
                if self.placement.replicates(req):
                    self._replicated.add(req.key)
                    targets = range(self.n_shards)
                else:
                    targets = (home,)
                for s in targets:
                    per[s].append(req)
                    slots[s].append(idx if s == home else None)
            else:
                key = getattr(req, "key", "")
                s = self._read_shard(key)
                per[s].append(req)
                slots[s].append(idx)
        return per, slots

    # -- fleet scatter-gather (PNM top-k) -------------------------------------
    def _split_gather(self, req: GatherReq
                      ) -> Tuple[List[Optional[GatherReq]],
                                 List[List[int]]]:
        """One sub-GatherReq per shard holding candidates (keys keep
        their relative — therefore global tie-break — order).  Each
        shard ranks its local candidates at ``k' = min(k, local count)``:
        any global winner is in the top-k of its own shard, so the
        global top-k is always a subset of the union of local winners.

        Returns ``(subs, per_pos)`` where ``per_pos[s][j]`` is the
        global candidate position of shard ``s``'s j-th key.
        """
        per_keys: List[List[str]] = [[] for _ in range(self.n_shards)]
        per_pos: List[List[int]] = [[] for _ in range(self.n_shards)]
        for pos, key in enumerate(req.keys):
            s = self._read_shard(key)
            per_keys[s].append(key)
            per_pos[s].append(pos)
        if not req.keys:
            # degenerate zero-candidate gather: run it (k=0, empty
            # winner set) on the default-routed shard so the caller
            # still gets one well-formed receipt
            subs = [None] * self.n_shards
            subs[self._read_shard(req.key)] = GatherReq(
                keys=(), digest=req.digest, k=0, kind=req.kind,
                views=None if req.views is None else (),
                score_view=req.score_view, tag=req.tag,
            )
            return subs, per_pos
        subs: List[Optional[GatherReq]] = []
        for s in range(self.n_shards):
            if not per_keys[s]:
                subs.append(None)
                continue
            views = (tuple(req.views[p] for p in per_pos[s])
                     if req.views is not None else None)
            subs.append(GatherReq(
                keys=tuple(per_keys[s]), digest=req.digest,
                k=min(req.k, len(per_keys[s])), kind=req.kind,
                views=views, score_view=req.score_view, tag=req.tag,
            ))
        return subs, per_pos

    def _merge_gather(self, req: GatherReq, shard_recs: Sequence[Receipt],
                      per_pos: Sequence[Sequence[int]]) -> Receipt:
        """Fold per-shard local top-k receipts into one fleet receipt.

        Scores reassemble into the request's global candidate order and
        the global top-k re-selects with the same deterministic
        tie-break the single-device kernel uses (local per-shard order
        preserves global order, so ties resolve identically at any
        shard count).  Byte/compute fields sum, latency is the slowest
        shard (scatter-gather completes when the last shard answers);
        the per-shard receipts stay applied to their own device stats,
        so the fleet's per-shard receipts-sum identity is untouched.
        """
        from ..kernels.pnm_score import topk_select

        occupied = [pos for pos in per_pos if pos]
        scores = np.full(len(req.keys), -np.inf, dtype=np.float32)
        data_by_pos: Dict[int, np.ndarray] = {}
        dev_by_pos: Dict[int, int] = {}
        ri = iter(shard_recs)
        recs = [next(ri) if pos else None for pos in per_pos]
        for pos, rec in zip(per_pos, recs):
            if not pos:
                continue
            scores[list(pos)] = rec.gather.scores
            for idx, arr in zip(rec.gather.indices, rec.gather.data):
                data_by_pos[pos[idx]] = arr
                dev_by_pos[pos[idx]] = rec.device_id
        winner_ix = topk_select(scores, req.k)
        live = [r for r in recs if r is not None]
        return Receipt(
            key=req.key, op="gather", kind=req.kind, tag=req.tag,
            blocks=sum(r.blocks for r in live),
            dram_bytes_read=sum(r.dram_bytes_read for r in live),
            dram_bytes_written=sum(r.dram_bytes_written for r in live),
            dram_bytes_stored=sum(r.dram_bytes_stored for r in live),
            raw_bytes_stored=sum(r.raw_bytes_stored for r in live),
            link_bytes_in=sum(r.link_bytes_in for r in live),
            link_bytes_out=sum(r.link_bytes_out for r in live),
            index_bytes=sum(r.index_bytes for r in live),
            index_hits=sum(r.index_hits for r in live),
            index_misses=sum(r.index_misses for r in live),
            codec_blocks=sum(r.codec_blocks for r in live),
            codec_bypass=sum(r.codec_bypass for r in live),
            latency_s=max(r.latency_s for r in live),
            queue_delay_s=max(r.queue_delay_s for r in live),
            service_s=max(r.service_s for r in live),
            device_compute_s=sum(r.device_compute_s for r in live),
            device_id=live[0].device_id,
            gather=GatherResult(
                keys=[req.keys[i] for i in winner_ix],
                indices=list(winner_ix), scores=scores,
                data=[data_by_pos[i] for i in winner_ix],
            ),
        )

    def _plan_gathers(self, requests: Sequence[Request]):
        """Split a mixed batch into (rest, rest indices, gather plans);
        pre-validates every shard's combined sub-batch so a malformed
        fleet batch — gathers included — rejects before ANY shard
        commits."""
        gathers = [(i, r) for i, r in enumerate(requests)
                   if isinstance(r, GatherReq)]
        rest_ix = [i for i, r in enumerate(requests)
                   if not isinstance(r, GatherReq)]
        rest = [requests[i] for i in rest_ix]
        per, slots = self._partition(rest)
        plans = [(i, self._split_gather(r)) for i, r in gathers]
        for s, shard in enumerate(self.shards):
            sub = list(per[s])
            for _, (subs, _pp) in plans:
                if subs[s] is not None:
                    sub.append(subs[s])
            if sub:
                shard.validate(sub)
        return rest_ix, per, slots, plans

    # -- batched entry points ------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> List[Receipt]:
        """Execute a batch across the fleet; one receipt per request, in
        order, each stamped with the ``device_id`` that served it.
        Every shard's sub-batch pre-flights :meth:`TierStore.validate`
        first, so a malformed batch rejects before ANY shard commits —
        the same atomicity one device gives.

        :class:`GatherReq` requests scatter-gather: candidates split by
        home shard, each shard scores and returns its local top-k, and
        the host merges the candidate sets into the global top-k (one
        receipt, scores in global candidate order).  Like the
        single-device path, writes and plain reads execute first, then
        gathers in listed order.
        """
        rest_ix, per, slots, plans = self._plan_gathers(requests)
        receipts: List[Optional[Receipt]] = [None] * len(requests)
        for shard, sub, sl in zip(self.shards, per, slots):
            if not sub:
                continue
            for i, rec in zip(sl, shard.submit(sub)):
                if i is not None:
                    receipts[rest_ix[i]] = rec
        for i, (subs, per_pos) in plans:
            live = [(s, sub) for s, sub in enumerate(subs)
                    if sub is not None]
            if len(live) == 1:
                # single-shard gather: the inner receipt IS the answer
                # (receipt-identical to a bare store)
                s, sub = live[0]
                receipts[i] = self.shards[s].submit([sub])[0]
            else:
                recs = [self.shards[s].submit([sub])[0] for s, sub in live]
                receipts[i] = self._merge_gather(requests[i], recs, per_pos)
        return receipts  # type: ignore[return-value]

    def submit_async(self, requests: Sequence[Request]) -> List[Ticket]:
        """Enqueue a batch across the fleet; one ticket per request, in
        order.  Tickets are the inner shards' own (they know their
        store), so ``Ticket.wait`` flushes exactly the owning shard's
        queue prefix.  Replica-copy write tickets are born complete and
        dropped — their receipts are accounted on their shard.  A
        fleet-spanning gather returns a merged ticket whose ``wait``
        waits every shard's local top-k and merges, byte-identical to
        the sync scatter-gather."""
        rest_ix, per, slots, plans = self._plan_gathers(requests)
        tickets: List[Optional[Ticket]] = [None] * len(requests)
        for shard, sub, sl in zip(self.shards, per, slots):
            if not sub:
                continue
            for i, t in zip(sl, shard.submit_async(sub)):
                if i is not None:
                    tickets[rest_ix[i]] = t
                else:
                    # replica-copy write: born complete on its shard —
                    # collect the receipt now, it has no caller-facing slot
                    t.wait()
        for i, (subs, per_pos) in plans:
            live = [(s, sub) for s, sub in enumerate(subs)
                    if sub is not None]
            if len(live) == 1:
                s, sub = live[0]
                tickets[i] = self.shards[s].submit_async([sub])[0]
            else:
                inner = [self.shards[s].submit_async([sub])[0]
                         for s, sub in live]
                tickets[i] = _MergedGatherTicket(self, requests[i], inner,
                                                 per_pos)
        return tickets  # type: ignore[return-value]

    @property
    def pending(self) -> int:
        """Queued (not yet executed) reads across every shard's window."""
        return sum(s.pending for s in self.shards)

    def drain(self, tickets: Optional[Sequence[Ticket]] = None
              ) -> List[Receipt]:
        """Flush every shard's queue; with ``tickets``, return exactly
        those receipts in order (single-device :meth:`TierStore.drain`
        semantics, fleet-wide)."""
        if tickets is None:
            out: List[Receipt] = []
            for shard in self.shards:
                out.extend(shard.drain())
            return out
        for shard in self.shards:
            shard.drain()
        return [t.wait() for t in tickets]

    def quiesce(self):
        """Idle the host until every shard's pipes drain."""
        for shard in self.shards:
            shard.quiesce()

    # -- single-device attribute surface -------------------------------------
    @property
    def kv_window(self) -> int:
        return self.shards[0].kv_window

    @kv_window.setter
    def kv_window(self, tokens: int):
        for shard in self.shards:
            shard.kv_window = tokens

    @property
    def layout(self):
        return self.shards[0].layout

    @property
    def link_model(self) -> LinkModel:
        return self.shards[0].link_model

    @property
    def window(self) -> int:
        return self.shards[0].window

    @property
    def busy_backlog_s(self) -> float:
        """The fleet straggler: the largest per-shard pipe backlog."""
        return max(s.busy_backlog_s for s in self.shards)

    # -- per-key introspection (routed to the home shard) ---------------------
    def n_blocks(self, key: str) -> int:
        return self.shards[self.owner(key)].n_blocks(key)

    def footprint(self, key: str) -> int:
        return self.shards[self.owner(key)].footprint(key)

    def logical_bytes(self, key: str) -> int:
        return self.shards[self.owner(key)].logical_bytes(key)

    # -- fleet residency ledger ----------------------------------------------
    def resident_bytes(self, prefix: str = "") -> int:
        """Physical bytes the namespace occupies across the whole fleet.
        Replicated weights count once per copy — that is real DRAM."""
        return sum(s.resident_bytes(prefix) for s in self.shards)

    def compression_ratio(self, prefix: str = "") -> float:
        raw = phys = 0.0
        for s in self.shards:
            p = s.resident_bytes(prefix)
            if p > 0:
                raw += s.compression_ratio(prefix) * p
                phys += p
        return raw / phys if phys > 0 else 1.0

    def truncate_planes(self, keys: Sequence[str],
                        view: PrecisionView) -> int:
        """In-place plane truncation, routed to each key's home shard
        (every copy, for replicated keys).  Refcounts pre-check across
        the fleet first so a co-owned page rejects before any shard
        sheds bytes."""
        if not self.layout.plane_aligned:
            raise NotImplementedError(
                f"layout {self.layout.name!r} stores word-major "
                "containers; in-place plane truncation needs a "
                "plane-aligned layout"
            )
        for key in keys:
            refs = self.refcount(key)
            if refs > 1:
                raise ValueError(
                    f"cannot truncate {key!r}: {refs} references "
                    "hold this shared page"
                )
        grouped: Dict[int, List[str]] = {}
        for key in keys:
            targets = (range(self.n_shards) if key in self._replicated
                       else (self.owner(key),))
            for s in targets:
                grouped.setdefault(s, []).append(key)
        reclaimed = 0
        for s, sub in grouped.items():
            reclaimed += self.shards[s].truncate_planes(sub, view)
        return reclaimed

    # -- refcounted shared pages (device-local on the home shard) -------------
    def refcount(self, key: str) -> int:
        return self.shards[self.owner(key)].refcount(key)

    def acquire(self, key: str) -> int:
        return self.shards[self.owner(key)].acquire(key)

    def release(self, key: str) -> int:
        return self.shards[self.owner(key)].release(key)

    def delete(self, key: str):
        if key in self._replicated:
            for shard in self.shards:
                shard.delete(key)
            self._replicated.discard(key)
        else:
            self.shards[self.owner(key)].delete(key)

    def delete_prefix(self, prefix: str) -> int:
        """Release one namespace fleet-wide.  Under hash-stripe a
        namespace spans shards, so the delete fans out and the key count
        sums; a pinned ``shared.<hash>`` namespace lives on one shard
        only, so co-owned refcounts decrement exactly once, there."""
        released = 0
        for shard in self.shards:
            released += shard.delete_prefix(prefix)
        self._replicated = {k for k in self._replicated
                            if not _ns_match(k, prefix)}
        return released

    # -- fleet view -----------------------------------------------------------
    def per_device_stats(self) -> List[DeviceStats]:
        """Each shard's own :class:`DeviceStats`, indexed by device_id."""
        return [s.stats for s in self.shards]

    def fleet_skew(self) -> float:
        """Load imbalance: max over mean of per-shard moved bytes (DRAM +
        link traffic).  1.0 is a perfectly balanced fleet; large values
        flag stragglers/hot shards.  1.0 when nothing moved yet."""
        moved = [s.stats.dram_bytes_read + s.stats.dram_bytes_written
                 + s.stats.link_bytes_in + s.stats.link_bytes_out
                 for s in self.shards]
        total = sum(moved)
        if total <= 0:
            return 1.0
        return max(moved) * self.n_shards / total

    # -- legacy shims (deprecated; forward to submit) ------------------------
    def write_tensor(self, name: str, u16: np.ndarray):
        self.submit([WriteReq(name, u16, kind=TENSOR)])

    def read_tensor(self, name: str, view: PrecisionView = FULL) -> np.ndarray:
        return self.submit([ReadReq(name, kind=TENSOR, view=view)])[0].data

    def write_kv(self, stream: str, tokens_u16: np.ndarray):
        self.submit([WriteReq(stream, tokens_u16, kind=KV, flush=False)])

    def read_kv(self, stream: str, view: PrecisionView = FULL) -> np.ndarray:
        return self.submit([ReadReq(stream, kind=KV, view=view)])[0].data

    def flush_kv(self, stream: str):
        self.shards[self.owner(stream)].flush_kv(stream)
