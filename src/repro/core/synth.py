"""Synthetic tensor generators with LLM-like statistics.

No pretrained checkpoints or datasets are available offline, so the
compression benchmarks run on synthetic tensors calibrated to the
structural properties the paper measures (Fig. 2):

* KV cache: per-channel AR(1) time series — values evolve smoothly along
  the *token* axis within a channel, while *channels* carry heterogeneous
  scales (log-normal spread) plus a sparse set of outlier channels.  This
  reproduces the "smooth along channel-major, jagged along token-major"
  structure that Mechanism I exploits.
* Weights: Gaussian with per-row scale variation (as after standard init /
  trained norms), optionally quantised to FP8/INT4-style grids to model
  Table IV's quantised bases.
* A second KV source runs an actual forward pass of a (random-init) model
  from this repo — see tests/benchmarks — to confirm results don't hinge
  on the AR(1) synthesiser.
* Serving traces: :func:`poisson_arrivals` / :func:`bursty_arrivals` /
  :func:`request_trace` generate the many-user request arrival processes
  the continuous-batching scheduler consumes (offered load measured in
  requests per scheduler decode round).
"""

from __future__ import annotations

import numpy as np
import ml_dtypes


def kv_cache(
    n_tokens: int,
    n_channels: int,
    smooth: float = 0.98,
    scale_spread: float = 1.0,
    outlier_frac: float = 0.02,
    mean_snr: float = 2.0,
    seed: int = 0,
) -> np.ndarray:
    """Token-major (n_tokens, n_channels) BF16 KV block (as uint16).

    ``mean_snr``: per-channel bias magnitude relative to the fluctuation —
    real K/V channels are NOT zero-mean (Fig. 2's smooth channel surfaces
    are offset bands); the bias keeps a channel's exponent stable across
    tokens, which is precisely what Mechanism I's exponent delta exploits.
    """
    rng = np.random.default_rng(seed)
    scales = np.exp(rng.normal(0.0, scale_spread, size=n_channels))
    n_out = max(1, int(outlier_frac * n_channels))
    scales[rng.choice(n_channels, n_out, replace=False)] *= 30.0
    mu = rng.normal(0.0, mean_snr, size=n_channels) * scales
    x = np.empty((n_tokens, n_channels), dtype=np.float64)
    x[0] = rng.normal(0, 1, n_channels)
    noise = rng.normal(0, 1, size=(n_tokens, n_channels))
    for t in range(1, n_tokens):
        x[t] = smooth * x[t - 1] + np.sqrt(1 - smooth**2) * noise[t]
    x = x * scales[None, :] + mu[None, :]
    return x.astype(ml_dtypes.bfloat16).view(np.uint16)


def weights(
    n: int,
    fmt: str = "bf16",
    row: int = 4096,
    scale_spread: float = 0.3,
    seed: int = 0,
) -> np.ndarray:
    """Flat weight tensor as uint16 BF16 containers.

    ``fmt``: 'bf16' | 'fp8' | 'int4' — quantised formats are stored on the
    value grid of the target format but kept in BF16 containers, matching
    how the device sees an already-quantised checkpoint re-expanded, OR
    packed natively via :func:`pack_quantized`.
    """
    rng = np.random.default_rng(seed)
    rows = max(1, n // row)
    # Trained-weight scale: sigma ~ 1/sqrt(fan_in) ~ 0.02 keeps block
    # exponents clustered AWAY from power-of-two carry boundaries, which
    # is what makes real checkpoints' high-order exponent planes nearly
    # constant (paper Fig. 16).  sigma ~ 1.0 would straddle the 127→128
    # exponent carry and decorrelate every exponent bit.
    w = rng.normal(0, 0.02, size=(rows, min(n, row)))
    w *= np.exp(rng.normal(0, scale_spread, size=(rows, 1)))
    w = w.ravel()[:n].astype(np.float32)
    if fmt == "fp8":
        w = w.astype(ml_dtypes.float8_e4m3).astype(np.float32)
    elif fmt == "int4":
        s = np.abs(w).max() / 7.0
        w = np.clip(np.round(w / s), -8, 7) * s
    return w.astype(ml_dtypes.bfloat16).view(np.uint16)


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """``n`` request arrival times under a Poisson process.

    ``rate`` is the offered load in requests per scheduler decode round
    (the :class:`~repro.runtime.serving.ServeScheduler` clock unit);
    inter-arrival gaps are i.i.d. exponential with mean ``1/rate``.
    Returns a sorted float array of arrival times starting near 0.
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def bursty_arrivals(n: int, rate: float, burst: int = 4,
                    seed: int = 0) -> np.ndarray:
    """``n`` arrival times in Poisson-spaced bursts of ``burst`` requests.

    Bursts arrive as a Poisson process at ``rate / burst`` so the mean
    offered load matches :func:`poisson_arrivals` at the same ``rate``,
    but requests land in simultaneous clumps — the flash-crowd pattern
    that stresses KV-capacity-aware admission (every member of a burst
    contends for the same pool + tier headroom at once).
    """
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    n_bursts = -(-n // burst)
    starts = poisson_arrivals(n_bursts, rate / burst, seed=seed)
    return np.repeat(starts, burst)[:n]


def request_trace(
    n_requests: int,
    vocab: int,
    rate: float = 0.25,
    kind: str = "poisson",
    prompt_len: int = 32,
    new_tokens: int = 8,
    batch: int = 1,
    burst: int = 4,
    seed: int = 0,
    share_prefix_len: int = 0,
) -> list:
    """Synthetic serving trace: one dict per request, sorted by arrival.

    Each entry carries ``arrival`` (float, scheduler decode rounds),
    ``prompt`` (``(batch, prompt_len)`` int32 token ids), ``max_new_tokens``
    and a per-request ``seed`` — exactly the fields
    :class:`~repro.runtime.serving.ServeRequest` takes, without this
    module importing the runtime.  ``kind`` selects the arrival process
    (``"poisson"`` or ``"bursty"``).

    ``share_prefix_len > 0`` models a common system prompt: the first
    ``share_prefix_len`` tokens are drawn once and repeated verbatim in
    every request's prompt (the tail stays per-request random) — the
    workload shape shared-prefix KV reuse multiplies capacity on.
    """
    if kind == "poisson":
        arrivals = poisson_arrivals(n_requests, rate, seed=seed)
    elif kind == "bursty":
        arrivals = bursty_arrivals(n_requests, rate, burst=burst, seed=seed)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")
    if not 0 <= share_prefix_len <= prompt_len:
        raise ValueError(
            f"share_prefix_len={share_prefix_len} must be within "
            f"[0, prompt_len={prompt_len}]")
    rng = np.random.default_rng(seed + 1)
    head = rng.integers(0, vocab, (batch, share_prefix_len)).astype(np.int32)
    return [
        {
            "arrival": float(t),
            "prompt": np.concatenate(
                [head, rng.integers(
                    0, vocab, (batch, prompt_len - share_prefix_len)
                ).astype(np.int32)], axis=1),
            "max_new_tokens": new_tokens,
            "seed": seed + 1000 + i,
        }
        for i, t in enumerate(arrivals)
    ]


def quantized_bits(u16_bf16: np.ndarray, fmt: str) -> np.ndarray:
    """Native bitstreams for quantised formats (for Table IV 'total savings').

    fp8 → uint8 codes; int4 → two nibbles packed per byte.
    """
    f = u16_bf16.view(ml_dtypes.bfloat16).astype(np.float32)
    if fmt == "fp8":
        return f.astype(ml_dtypes.float8_e4m3).view(np.uint8)
    if fmt == "int4":
        s = np.abs(f).max() / 7.0 or 1.0
        q = (np.clip(np.round(f / s), -8, 7).astype(np.int8) + 8).astype(np.uint8)
        if q.size % 2:
            q = np.pad(q, (0, 1))
        return (q[0::2] << 4 | q[1::2]).astype(np.uint8)
    raise ValueError(fmt)
