"""Request-batched CXL Type-3 tier store — Plain / GComp / TRACE (Table III).

The paper's central claim is that the *device-internal representation*
(word-major vs channel-major bit-plane) is swappable behind an unmodified
CXL.mem interface.  This module makes that boundary explicit:

* hosts speak **typed requests** — :class:`WriteReq` / :class:`ReadReq`
  descriptors that name a key, a payload kind (``tensor`` or ``kv``
  stream), a precision view and an optional block range;
* the device answers with **per-request receipts** — :class:`Receipt`
  carries the DRAM / link / index traffic and a first-order latency
  estimate for exactly that request, so traffic attribution is per-page /
  per-layer instead of one global counter blob (``DeviceStats`` remains as
  the running aggregate of all receipts);
* the internal representation is a **layout strategy** —
  :class:`WordLayout` (raw words), :class:`WordLayout` + codec (GComp's
  inline 4 KB block compression) or :class:`BitplaneLayout` (TRACE's
  bit-plane substrate, optionally with the cross-token KV transform of
  Fig. 8) — composed with the codec registry.  ``PlainDevice`` /
  ``GCompDevice`` / ``TraceDevice`` are thin :class:`TierStore`
  configurations kept for compatibility.

Batched submission is also a performance feature, in BOTH directions.  A
read batch's blocks are grouped by fetched plane-set and decoded in
vectorized numpy passes — one plane-unpack and one reconstruction call
per group, not per 4 KB block (see ``BitplaneLayout.decode_batch``).  A
write batch (sync or async posting group) stages every pending block into
one encode slab and encodes it in a few vectorized passes — one batched
KV transform, one plane pack (pallas kernel on accelerator backends) and
ONE ``codec.compress_batch`` over every (plane, block) stream — instead
of O(blocks x planes) python-level calls (see ``Layout.encode_batch`` /
``TierStore._post_writes``).  The per-block pipeline survives as
``Layout.encode_batch_scalar`` (``TierStore(batched_encode=False)``),
byte-identical by the encode differential tests.

Accounting conventions (per read):
  * ``dram_bytes``  — bytes the device DRAM actually serves (compressed
    planes for TRACE, compressed 4 KB blocks for GComp, raw words for
    Plain).  Plane-aligned fetch physically skips unfetched planes.
  * ``link_bytes``  — host-visible payload returned over CXL.mem (the
    reconstructed view; controller-side decompression per Fig. 11).
  * ``index_bytes`` — metadata traffic (64 B/entry on an index-cache miss).

Legacy shims (``write_tensor`` / ``read_tensor`` / ``write_kv`` /
``read_kv`` / ``flush_kv``) forward to :meth:`TierStore.submit` and are
kept so existing call sites keep working; new code should submit request
batches directly.

Namespaces: multi-stream consumers prefix their keys with a per-stream /
per-request namespace (``s0.``, ``r17.``).  :meth:`TierStore.delete_prefix`
retires a whole namespace in one call — blocks, staged KV windows and
index-cache entries — returning its stored capacity to ``stats``; this is
how the continuous-batching scheduler frees a finished request's pages
for queued admissions.

Asynchronous submission (the queued front-end):

``submit_async(requests) -> list[Ticket]`` enqueues a batch without
executing the reads.  Writes are *posted* — they commit immediately, in
listed order, exactly as :meth:`TierStore.submit` would — while reads
enter a bounded in-flight window (``window`` requests).  The scheduler
executes queued reads as coalesced groups: when the window fills, when a
:meth:`Ticket.wait` / :meth:`TierStore.drain` forces completion, or when
a hazard demands it.  A group decodes through the same vectorized
batched-read path as a sync batch, so reads from *different*
``submit_async`` calls coalesce into one slab decode.

Ordering semantics (these make async execution byte-identical to sync):

* within one ``submit_async`` call, writes post before reads enqueue —
  the same writes-drain-first rule as a single ``submit`` batch;
* across calls, program order is preserved per key: posting a write to a
  key with queued reads first flushes the queue (write-after-read
  fence), and ``submit`` / ``delete`` flush the queue before touching
  device state, so a late sync caller never observes stale ordering;
* queued reads execute in submission order (groups are queue prefixes),
  so index-cache hit/miss accounting is identical to the sync path.

Receipts from queued reads additionally carry ``queue_delay_s`` (time
spent behind earlier requests of the same flush group on the shared
DDR + link pipes) and an overlap-adjusted ``latency_s`` from
:class:`LinkModel.schedule` — the fixed request overhead is paid once
per group and transfers pipeline, which is what makes a drained batch
faster than the sum of serialized sync requests (the paper's decode /
fetch overlap at 128k context).  ``service_s`` keeps the serialized
service time for comparison.

Latency pricing carries ACROSS groups through a device-global busy
clock: posted writes and window-overflow flushes advance per-pipe busy
frontiers without advancing host time, so later groups queue behind
their residual occupancy (write-heavy many-stream receipts price
cross-boundary contention); a wait (sync read, ``drain``,
``Ticket.wait``) advances host time to delivery, and
:meth:`TierStore.quiesce` idles the host until the pipes drain.  The
clock only shapes ``queue_delay_s``/``latency_s`` — byte accounting, and
therefore the receipts-sum == ``DeviceStats`` invariant, is untouched.

Residency ledger (the physical-capacity control signal):

The store keeps a live per-key ledger of *stored* bytes — compressed
payload planes plus the 64 B/block index entry — updated at every block
commit (``_encode_commit`` → :meth:`TierStore._commit`), decremented by
:meth:`TierStore.delete` / :meth:`TierStore.delete_prefix` and by
in-place plane truncation.  :meth:`TierStore.resident_bytes` sums any
key-prefix namespace (a request's ``r{id}.`` keys, or the whole device
with an empty prefix) and :meth:`TierStore.compression_ratio` reports
the namespace's logical/physical ratio.  The invariant — the ledger
equals the sum of stored payload+index bytes at all times, under any
interleaving of writes, deletes and truncations — is property-tested.
This is what lets admission control reason about the *physical* KV
footprint instead of the logical projection (a trace device stores KV
at >2x compression, so it can admit a correspondingly larger batch).

Precision-elastic reclamation: plane-aligned layouts additionally
support :meth:`TierStore.truncate_planes` — dropping the low-order
mantissa planes of already-stored blocks *in place* (paper §III-C: the
bit-plane substrate makes precision a storage knob, not just a fetch
knob).  Truncation reclaims the dropped planes' payload bytes (returned
to the caller and reconciled against the ledger), records the surviving
:class:`PrecisionView` on each block, and later reads are served at the
intersection of the requested and stored views — bit-identical to
``reconstruct_u16`` applied at that view.  Word layouts store opaque
compressed containers and report truncation unsupported.
"""

from __future__ import annotations

import dataclasses
import os
from typing import (
    Collection, Dict, List, Optional, Sequence, Set, Tuple, Union,
)

import numpy as np

from . import codec as codecs
from .bitplane import (
    BF16_BITS,
    BLOCK_ELEMS,
    iter_blocks,
    pack_planes,
    unpack_planes_subset,
)
from .kv_transform import (
    KVBlockMeta, kv_forward, kv_forward_batch, kv_inverse_batch,
)
from .precision import EXP_BITS, PrecisionView, FULL, SCORE, reconstruct_u16

INDEX_ENTRY_BYTES = 64  # paper §III-D: one compact entry per 4 KB block

# Request payload kinds.
TENSOR = "tensor"
KV = "kv"


# ---------------------------------------------------------------------------
# Typed requests + receipts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WriteReq:
    """Host→device write descriptor.

    ``kind=TENSOR``: ``data`` is any-shape uint16; stored block-by-block.
    ``kind=KV``: ``data`` is token-major ``(t, C)`` uint16 rows appended to
    the stream ``key``; full windows are committed as they fill and
    ``flush=True`` commits any partial window at the end of the request.
    """

    key: str
    data: np.ndarray
    kind: str = TENSOR
    flush: bool = True
    tag: str = ""


@dataclasses.dataclass(frozen=True)
class ReadReq:
    """Device→host read descriptor.

    ``view`` selects the precision alias (plane-aligned fetch on bit-plane
    layouts; word layouts always move full containers and reconstruct
    host-side).  ``block_range=(lo, hi)`` restricts the read to that slice
    of the key's block list; ranged tensor reads return flat uint16.
    """

    key: str
    kind: str = TENSOR
    view: PrecisionView = FULL
    block_range: Optional[Tuple[int, int]] = None
    tag: str = ""


@dataclasses.dataclass(frozen=True)
class GatherReq:
    """Device-side top-k gather descriptor (the PNM read mode).

    The host names the candidate ``keys`` (spilled KV pages resident on
    the device), a flat ``(channels,)`` float32 query ``digest`` and a
    winner count ``k``.  The device scores every candidate ON the device
    — a plane-subset decode at ``score_view`` (sign + the compressible
    exponent planes ONLY by default, so scoring DRAM traffic is a small
    fraction of a full fetch) feeding the ``kernels.pnm_score`` kernel —
    and returns full-precision data for only the top-k pages, so link
    bytes drop from O(candidates) to O(k · page) + 4 B/candidate of
    shipped scores.  ``views`` optionally pins a per-key winner fetch
    view (position-aligned with ``keys``; ``FULL`` when omitted), which
    is what makes a gather at ``k >= len(keys)`` byte-identical to
    individual :class:`ReadReq` submissions at the same views.

    Ties on equal scores break by candidate list position (stable,
    host-chosen order), so winner selection is deterministic across
    sync/async submission and shard counts.
    """

    keys: Tuple[str, ...]
    digest: np.ndarray
    k: int
    kind: str = KV
    views: Optional[Tuple[PrecisionView, ...]] = None
    score_view: PrecisionView = SCORE
    tag: str = ""

    @property
    def key(self) -> str:
        """First candidate key — routing/repr convenience so a gather
        slots into code paths that label requests by ``request.key``."""
        return self.keys[0] if self.keys else ""


Request = Union[WriteReq, ReadReq, GatherReq]


def _req_keys(req: Request) -> frozenset:
    """Every device key one request touches (hazard-fence granularity)."""
    if isinstance(req, GatherReq):
        return frozenset(req.keys)
    return frozenset((req.key,))


@dataclasses.dataclass
class GatherResult:
    """Winner set of one executed :class:`GatherReq`.

    ``scores`` covers EVERY candidate (in the request's ``keys`` order,
    float32) — the host ledger can fold the full ranking into page
    importances, not just the winners.  ``keys`` / ``indices`` /
    ``data`` are the winners in descending-score order (ties by
    candidate position), ``data`` holding exactly the bytes a plain
    read of that key at its winner view would have returned.
    """

    keys: List[str]
    indices: List[int]
    scores: np.ndarray
    data: List[np.ndarray]


@dataclasses.dataclass
class Receipt:
    """Per-request traffic + latency accounting (and data, for reads).

    Field names mirror :class:`DeviceStats`; summing any field across the
    receipts of a session reproduces the corresponding aggregate delta
    exactly — this is tested.
    """

    key: str
    op: str                       # "write" | "read"
    kind: str = TENSOR
    tag: str = ""
    blocks: int = 0
    dram_bytes_read: int = 0
    dram_bytes_written: int = 0
    dram_bytes_stored: int = 0    # capacity delta (writes)
    raw_bytes_stored: int = 0     # logical (uncompressed) delta (writes)
    link_bytes_in: int = 0
    link_bytes_out: int = 0
    index_bytes: int = 0
    index_hits: int = 0
    index_misses: int = 0
    codec_blocks: int = 0         # payload streams that hit the bypass rule
    codec_bypass: int = 0         # ... of which were stored raw (§III-D)
    latency_s: float = 0.0        # delivery time: queue_delay_s + service
    queue_delay_s: float = 0.0    # wait behind earlier in-flight requests
    service_s: float = 0.0        # serialized service time (sync latency)
    device_compute_s: float = 0.0  # device-side PNM scoring time (gathers)
    device_id: int = 0            # which device in a fleet served this
    data: Optional[np.ndarray] = None
    gather: Optional[GatherResult] = None   # winner set (gather ops only)

    @property
    def dram_bytes(self) -> int:
        return self.dram_bytes_read + self.dram_bytes_written

    @property
    def link_bytes(self) -> int:
        return self.link_bytes_in + self.link_bytes_out


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """First-order service-time model for a receipt (paper §IV-B numbers).

    ``base_s`` is the fixed per-request overhead.  The named device
    configurations derive it from the calibrated controller pipeline via
    :meth:`for_design` (Table V load-to-use: Plain 71 / GComp 84 / TRACE
    89 cycles @ 2 GHz), so receipt latency reflects the per-design
    front-end + metadata + scheduling + DRAM-window cost; passing an
    explicit ``base_s`` (or a whole ``link_model``) overrides the anchor
    with a constant — which is what latency-shape tests do.
    """

    ddr_bw: float = 256e9         # device-side DDR
    link_bw: float = 512e9        # CXL.mem per direction
    base_s: float = 1e-6          # fixed request overhead
    pnm_ops_s: float = 2e12       # near-memory scoring throughput (elem/s)

    @classmethod
    def for_design(cls, design: str, comp_ratio: float = 1.5,
                   **kw) -> "LinkModel":
        """A link model whose fixed overhead is the calibrated
        load-to-use pipeline latency of ``design`` (controller.py
        anchors, Fig. 22/23) at the given compression ratio."""
        from .controller import load_to_use_ns

        return cls(base_s=load_to_use_ns(design, comp_ratio=comp_ratio)
                   * 1e-9, **kw)

    def latency(self, dram_bytes: int, link_bytes: int) -> float:
        return self.base_s + max(dram_bytes / self.ddr_bw,
                                 link_bytes / self.link_bw)

    def device_compute(self, elems: int) -> float:
        """Time the device's near-memory unit spends scoring ``elems``
        candidate elements for one gather (a third resource next to the
        DDR and link pipes; it extends delivery, never byte traffic)."""
        return elems / self.pnm_ops_s

    def schedule(
        self, traffic: Sequence[Tuple[int, int]],
        ddr_backlog_s: float = 0.0, link_backlog_s: float = 0.0,
    ) -> List[Tuple[float, float]]:
        """Completion model for one in-flight group sharing DDR + link.

        ``traffic`` is ordered ``(dram_bytes, link_bytes)`` per request.
        Request *i* is delivered once both pipes have moved its cumulative
        bytes; the fixed request overhead is paid once per group.  Returns
        ``(queue_delay_s, latency_s)`` per request, where ``latency_s`` is
        the delivery time measured from group issue and ``queue_delay_s``
        is that minus the request's own serialized service time — i.e. the
        wait behind earlier requests on the occupied pipes.

        ``ddr_backlog_s`` / ``link_backlog_s`` carry residual pipe
        occupancy from EARLIER groups the host did not wait for (posted
        writes, window-overflow flushes): this group's requests queue
        behind that backlog, which is how many-stream receipts price
        cross-group contention (the device-global busy clock kept by
        :class:`TierStore`).
        """
        out: List[Tuple[float, float]] = []
        cum_dram = cum_link = 0
        for dram, link in traffic:
            service = self.latency(dram, link)
            cum_dram += dram
            cum_link += link
            done = self.base_s + max(ddr_backlog_s + cum_dram / self.ddr_bw,
                                     link_backlog_s + cum_link / self.link_bw)
            out.append((max(done - service, 0.0), done))
        return out


@dataclasses.dataclass
class DeviceStats:
    """Running aggregate of every receipt the store has issued."""

    dram_bytes_stored: int = 0      # capacity footprint (compressed)
    dram_bytes_read: int = 0
    dram_bytes_written: int = 0
    link_bytes_out: int = 0
    link_bytes_in: int = 0
    index_bytes: int = 0
    index_hits: int = 0
    index_misses: int = 0
    blocks: int = 0
    raw_bytes_stored: int = 0       # logical (uncompressed) footprint
    codec_blocks: int = 0           # payload streams offered to the codec
    codec_bypass: int = 0           # ... stored raw (bypass, paper §III-D)
    device_compute_s: float = 0.0   # near-memory scoring time (PNM gathers)

    @property
    def bypass_rate(self) -> float:
        """Fraction of codec payload streams stored raw (bypass rate)."""
        return self.codec_bypass / max(self.codec_blocks, 1)

    def reset_traffic(self):
        self.dram_bytes_read = 0
        self.dram_bytes_written = 0
        self.link_bytes_out = 0
        self.link_bytes_in = 0
        self.index_bytes = 0
        self.index_hits = self.index_misses = 0
        self.device_compute_s = 0.0

    def apply(self, r: Receipt):
        self.dram_bytes_read += r.dram_bytes_read
        self.dram_bytes_written += r.dram_bytes_written
        self.dram_bytes_stored += r.dram_bytes_stored
        self.raw_bytes_stored += r.raw_bytes_stored
        self.link_bytes_in += r.link_bytes_in
        self.link_bytes_out += r.link_bytes_out
        self.index_bytes += r.index_bytes
        self.index_hits += r.index_hits
        self.index_misses += r.index_misses
        self.blocks += r.blocks
        self.codec_blocks += r.codec_blocks
        self.codec_bypass += r.codec_bypass
        self.device_compute_s += r.device_compute_s

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes_stored / max(self.dram_bytes_stored, 1)


def _ns_match(key: str, prefix: str) -> bool:
    """Namespace-delimited prefix match for ledger/delete queries.

    Key namespaces are ``.``-delimited (``r1.L0.k.0``, ``shared.<hash>.…``),
    so a query for ``"r1"`` must never claim ``r10.``'s keys: an empty
    prefix matches everything, an exact key matches itself, and otherwise
    the prefix is extended to the next ``.`` boundary before matching.
    """
    if not prefix:
        return True
    if key == prefix:
        return True
    if not prefix.endswith("."):
        prefix += "."
    return key.startswith(prefix)


# ---------------------------------------------------------------------------
# Runtime invariant sanitizer (TRACE_SANITIZE=1 / TierStore(sanitize=True))
# ---------------------------------------------------------------------------

class SanitizerViolation(AssertionError):
    """A live accounting invariant broke under sanitize mode.

    Carries the violated invariant's name, the key (or key prefix) it
    was detected on, and the expected/actual values — the runtime
    counterpart of the ``tools/tracecheck`` static rules.
    """

    def __init__(self, invariant: str, key: str = "", expected=None,
                 actual=None, detail: str = ""):
        self.invariant = invariant
        self.key = key
        self.expected = expected
        self.actual = actual
        self.detail = detail
        msg = (f"[{invariant}] key={key!r} expected={expected!r} "
               f"actual={actual!r}")
        if detail:
            msg += f" — {detail}"
        super().__init__(msg)


class _MirroredStats(DeviceStats):
    """DeviceStats wired to the sanitizer's shadow aggregate so a
    caller's ``stats.reset_traffic()`` resets both sides in lockstep
    (direct field pokes still desync and trip the sanitizer — that is
    the point)."""

    def __init__(self, mirror: DeviceStats):
        super().__init__()
        self._mirror = mirror

    def reset_traffic(self):
        super().reset_traffic()
        self._mirror.reset_traffic()


class _Sanitizer:
    """Always-on invariant checks for one :class:`TierStore`.

    Enabled by ``TierStore(sanitize=True)`` or ``TRACE_SANITIZE=1``;
    zero overhead when off (the store holds ``None``).  Validated at
    every commit boundary (write post, read group, KV flush) and on the
    retirement paths (``delete`` / ``delete_prefix`` /
    ``truncate_planes``):

    * ``ledger-stored-equality`` — each residency-ledger row equals its
      key's stored payload/index/raw bytes and block count, and the
      ledger totals equal the stats capacity fields;
    * ``receipt-conservation`` — ``stats`` equals a shadow aggregate
      rebuilt from every receipt through the sanctioned helpers
      (receipts-sum == ``DeviceStats``);
    * ``busy-clock-monotonic`` — host time and the per-pipe busy
      frontiers never move backwards;
    * ``inflight-window-bound`` — queued reads never exceed ``window``;
    * ``retire-cleanup`` — a delete leaves no orphaned blocks, ledger
      rows, staging buffers, shapes, channel metadata or index-cache
      entries behind;
    * ``refcount-conservation`` — each ledger row's reference count
      equals a shadow count rebuilt from every commit / ``acquire`` /
      ``release`` (shared pages are freed exactly when the last
      reference retires, never earlier or later).
    """

    __slots__ = ("store", "shadow", "refs", "_now", "_ddr", "_link")

    _LEDGER_FIELDS = ("payload_bytes", "index_bytes", "raw_bytes", "blocks")
    _CAPACITY_FIELDS = ("dram_bytes_stored", "raw_bytes_stored", "blocks")

    def __init__(self, store: "TierStore"):
        self.store = store
        self.shadow = DeviceStats()
        self.refs: Dict[str, int] = {}
        self._now = self._ddr = self._link = 0.0

    def boundary(self, touched: Optional[Set[str]] = None):
        """Full commit-boundary validation (per-key checks limited to
        ``touched`` keys; aggregates always checked)."""
        self.check_clock()
        self.check_window()
        self.check_ledger(touched)
        self.check_conservation()

    def check_clock(self):
        s = self.store
        for attr, last in (("_now_s", self._now), ("_ddr_free_s", self._ddr),
                           ("_link_free_s", self._link)):
            cur = getattr(s, attr)
            if cur < last - 1e-12:
                raise SanitizerViolation(
                    "busy-clock-monotonic", key=attr,
                    expected=f">= {last!r}", actual=cur,
                    detail="busy-clock frontier moved backwards",
                )
        self._now, self._ddr, self._link = (s._now_s, s._ddr_free_s,
                                            s._link_free_s)

    def check_window(self):
        s = self.store
        if len(s._queue) > s.window:
            raise SanitizerViolation(
                "inflight-window-bound", expected=f"<= {s.window}",
                actual=len(s._queue),
                detail="queued reads exceed the in-flight window",
            )

    def check_ledger(self, touched: Optional[Set[str]] = None):
        s = self.store
        if set(s._ledger) != set(s._tensors):
            only_l = sorted(set(s._ledger) - set(s._tensors))
            only_t = sorted(set(s._tensors) - set(s._ledger))
            raise SanitizerViolation(
                "ledger-stored-equality", key=(only_l + only_t)[0],
                expected="ledger keys == stored keys",
                actual=f"ledger-only={only_l[:3]} stored-only={only_t[:3]}",
            )
        keys = (s._ledger if touched is None
                else [k for k in touched if k in s._ledger])
        for key in keys:
            entry = s._ledger[key]
            blocks = s._tensors[key]
            want = (sum(b.stored_bytes for b in blocks),
                    len(blocks) * INDEX_ENTRY_BYTES,
                    sum(b.valid_elems for b in blocks) * 2, len(blocks))
            got = tuple(getattr(entry, f) for f in self._LEDGER_FIELDS)
            if want != got:
                raise SanitizerViolation(
                    "ledger-stored-equality", key=key,
                    expected=dict(zip(self._LEDGER_FIELDS, want)),
                    actual=dict(zip(self._LEDGER_FIELDS, got)),
                    detail="residency ledger row != stored bytes",
                )
            want_refs = self.refs.get(key, 1)
            if entry.refs != want_refs or entry.refs < 1:
                raise SanitizerViolation(
                    "refcount-conservation", key=key,
                    expected=want_refs, actual=entry.refs,
                    detail="ledger refcount drifted from the "
                           "acquire/release shadow",
                )
        totals = (sum(e.payload_bytes for e in s._ledger.values()),
                  sum(e.raw_bytes for e in s._ledger.values()),
                  sum(e.blocks for e in s._ledger.values()))
        stat = tuple(getattr(s.stats, f) for f in self._CAPACITY_FIELDS)
        if totals != stat:
            raise SanitizerViolation(
                "ledger-stored-equality",
                expected=dict(zip(self._CAPACITY_FIELDS, totals)),
                actual=dict(zip(self._CAPACITY_FIELDS, stat)),
                detail="ledger totals != stats capacity fields",
            )

    def check_conservation(self):
        for f in dataclasses.fields(DeviceStats):
            want = getattr(self.shadow, f.name)
            got = getattr(self.store.stats, f.name)
            if want != got:
                raise SanitizerViolation(
                    "receipt-conservation", key=f.name, expected=want,
                    actual=got,
                    detail="stats field drifted from the receipts-sum "
                           "shadow (mutated outside the sanctioned "
                           "helpers?)",
                )

    def check_retired(self, prefix: Optional[str] = None,
                      key: Optional[str] = None,
                      survivors: Collection[str] = ()):
        """``survivors``: keys a namespace delete legitimately left behind
        because other references still hold them (refcount > 0)."""
        s = self.store

        def gone(k: str) -> bool:
            if k in survivors:
                return False
            return k == key if key is not None else _ns_match(k, prefix)

        stores = (("stored blocks", s._tensors), ("ledger", s._ledger),
                  ("shapes", s._shapes), ("kv staging", s._kv_staging),
                  ("kv channels", s._kv_channels))
        target = key if key is not None else prefix
        for what, d in stores:
            left = sorted(k for k in d if gone(k))
            if left:
                raise SanitizerViolation(
                    "retire-cleanup", key=target,
                    expected="no surviving entries",
                    actual=f"{what}: {left[:3]}",
                    detail="delete left orphaned keys behind",
                )
        left = sorted({k[0] for k in s._index._lru if gone(k[0])})
        if left:
            raise SanitizerViolation(
                "retire-cleanup", key=target,
                expected="no surviving entries",
                actual=f"index cache: {left[:3]}",
                detail="delete left orphaned index-cache entries behind",
            )


@dataclasses.dataclass
class _Block:
    """One 4 KB logical block in device DRAM."""

    payloads: List[bytes]            # per-plane (bit-plane) or single (word)
    flags: List[int]                 # codec.RAW / codec.COMPRESSED
    valid_elems: int                 # host-visible elements
    padded_elems: int                # elements the payloads encode (≥ valid)
    kv_meta: Optional[KVBlockMeta] = None
    view: Optional[PrecisionView] = None   # surviving view after truncation

    @property
    def stored_bytes(self) -> int:
        return sum(len(p) for p in self.payloads)


@dataclasses.dataclass
class ResidencyEntry:
    """One key's row in the physical-footprint residency ledger.

    ``refs`` counts outstanding references to the key.  Private pages
    stay at 1 for their whole life; content-addressed ``shared.`` pages
    gain a reference per :meth:`TierStore.acquire` and lose one per
    :meth:`TierStore.release` — the stored bytes are counted once here
    regardless of how many referers hold the page, and are freed exactly
    when the count reaches zero.
    """

    payload_bytes: int = 0      # stored (post-compression) plane payloads
    index_bytes: int = 0        # 64 B per committed block (metadata)
    raw_bytes: int = 0          # logical (uncompressed) footprint
    blocks: int = 0
    refs: int = 1               # outstanding references (shared pages > 1)

    @property
    def physical_bytes(self) -> int:
        return self.payload_bytes + self.index_bytes


class _EncodeSlab:
    """Per-posting-group staging area for deferred batched encoding.

    Write staging appends each pending block here (with its key, receipt
    and optional KV meta, kept in parallel lists); the group is then
    packed+compressed in one ``Layout.encode_batch`` pass and committed in
    staging order — the write-side mirror of the read side's shared decode
    slab.  KV windows are staged UNtransformed (``kv_windows`` slot): the
    exponent-delta transform is independent per window, so it too defers
    and runs as a batched pass at encode time (``kv_forward_batch``).
    """

    __slots__ = ("keys", "recs", "chunks", "valids", "metas", "kv_windows")

    def __init__(self):
        self.keys: List[str] = []
        self.recs: List[Receipt] = []
        self.chunks: List[Optional[np.ndarray]] = []
        self.valids: List[int] = []
        self.metas: List[Optional[KVBlockMeta]] = []
        self.kv_windows: List[Optional[np.ndarray]] = []

    def add(self, key: str, rec: Receipt, chunk: Optional[np.ndarray],
            valid: int, meta: Optional[KVBlockMeta] = None,
            kv_window: Optional[np.ndarray] = None):
        self.keys.append(key)
        self.recs.append(rec)
        self.chunks.append(chunk)
        self.valids.append(valid)
        self.metas.append(meta)
        self.kv_windows.append(kv_window)

    def clear(self):
        self.keys.clear()
        self.recs.clear()
        self.chunks.clear()
        self.valids.clear()
        self.metas.clear()
        self.kv_windows.clear()


class _IndexCache:
    """On-chip plane-index cache (paper Fig. 11, metadata management)."""

    def __init__(self, capacity_entries: int = 4096):
        self.capacity = capacity_entries
        self._lru: Dict[tuple, None] = {}

    def access(self, key: tuple) -> bool:
        hit = key in self._lru
        if hit:
            self._lru.pop(key)
        self._lru[key] = None
        if len(self._lru) > self.capacity:
            self._lru.pop(next(iter(self._lru)))
        return hit

    def evict_stream(self, stream: str):
        """Drop every cached entry of one stream key (entries are
        ``(stream, block_index)`` tuples) — deleting a key must not leave
        dangling index entries that a later same-named key would "hit"."""
        for k in [k for k in self._lru if k[0] == stream]:
            self._lru.pop(k)

    def evict_prefix(self, prefix: str):
        """Drop every cached entry whose stream key is in ``prefix``'s
        namespace (one LRU pass for a whole-namespace delete)."""
        for k in [k for k in self._lru if _ns_match(k[0], prefix)]:
            self._lru.pop(k)


# ---------------------------------------------------------------------------
# Layout strategies — the device-internal representation
# ---------------------------------------------------------------------------

class Layout:
    """Encodes 4 KB blocks to payloads and decodes request batches back.

    ``plane_aligned`` declares whether a reduced :class:`PrecisionView`
    physically cuts DRAM traffic (TRACE Mechanism II); word layouts always
    move full containers and reconstruct host-side (paper Issue 2).
    ``kv_transform`` enables the cross-token exponent-delta transform on KV
    windows (TRACE Mechanism I).  ``uses_codec`` marks layouts whose
    payloads go through the inline codec (drives bypass-rate accounting).

    Encoding is batched two ways: :meth:`encode_batch` is the production
    path — a whole flush group in a few vectorized passes (one plane pack,
    one ``compress_batch`` over every payload stream) — while
    :meth:`encode_batch_scalar` is the O(blocks x planes) per-block
    reference the device originally shipped with.  Both must produce
    byte-identical payloads and flags; the encode differential tests hold
    them to that.
    """

    name = "layout"
    plane_aligned = False
    kv_transform = False
    uses_codec = False

    def encode_batch(self, chunks: Sequence[np.ndarray],
                     codec: str) -> List[Tuple[List[bytes], List[int]]]:
        """Vectorized batch encode: one entry ``(payloads, flags)`` per chunk."""
        raise NotImplementedError

    def encode_batch_scalar(self, chunks: Sequence[np.ndarray],
                            codec: str) -> List[Tuple[List[bytes], List[int]]]:
        """Per-block reference encode (parity oracle + benchmark baseline)."""
        raise NotImplementedError

    def fetched_payloads(self, block: _Block, view: PrecisionView) -> Sequence[int]:
        """Payload indices a read with ``view`` physically touches."""
        raise NotImplementedError

    def decode_batch(self, blocks: Sequence[_Block], view: PrecisionView,
                     codec: str) -> List[np.ndarray]:
        """Per-block host-visible uint16 (valid-trimmed, reconstructed)."""
        raise NotImplementedError


class WordLayout(Layout):
    """Word-major containers; optional generic inline block compression."""

    plane_aligned = False
    kv_transform = False

    def __init__(self, compress: bool):
        self.compress = compress
        self.uses_codec = compress
        self.name = "word-comp" if compress else "word"

    def encode_batch(self, chunks, codec):
        raws = [chunk.tobytes() for chunk in chunks]
        if self.compress:
            payloads, flags = codecs.compress_batch(raws, codec)
            return [([pay], [fl]) for pay, fl in zip(payloads, flags)]
        return [([raw], [codecs.RAW]) for raw in raws]

    def encode_batch_scalar(self, chunks, codec):
        out = []
        for chunk in chunks:
            raw = chunk.tobytes()
            if self.compress:
                out.append(codecs.compress_block(raw, codec))
            else:
                out.append((raw, codecs.RAW))
        return [([pay], [fl]) for pay, fl in out]

    def fetched_payloads(self, block, view):
        return (0,)

    def decode_batch(self, blocks, view, codec):
        if not blocks:
            return []
        raws = codecs.decompress_batch(
            [b.payloads[0] for b in blocks], [b.flags[0] for b in blocks],
            codec, [b.padded_elems * 2 for b in blocks],
        )
        outs = [np.frombuffer(raw, dtype=np.uint16)[: b.valid_elems]
                for raw, b in zip(raws, blocks)]
        if view.is_full:
            return [np.asarray(o) for o in outs]
        # Host-side precision conversion: one vectorized pass over the batch.
        flat = reconstruct_u16(np.concatenate(outs), view)
        return _split_like(flat, outs)


def _pack_slab(flat_u16: np.ndarray) -> np.ndarray:
    """Pack a flat uint16 slab to (16, n//8) planes — pallas kernel when an
    accelerator backend is up, numpy otherwise (lazy one-time dispatch)."""
    global _PACK_SLAB
    if _PACK_SLAB is None:
        try:
            from ..kernels.bitplane import pack_planes_slab
            _PACK_SLAB = pack_planes_slab
        except ImportError:  # pragma: no cover - kernels unavailable
            _PACK_SLAB = lambda flat: pack_planes(flat)
    return _PACK_SLAB(flat_u16)


_PACK_SLAB = None


class BitplaneLayout(Layout):
    """TRACE bit-plane substrate; plane-aligned fetch, vectorized batches."""

    plane_aligned = True
    uses_codec = True

    # Max elements packed+compressed per encode pass: same cache-residency
    # tradeoff as SLAB_ELEMS on the decode side, but encode temporaries
    # (the (16, n) bit matrix) are larger, so groups split on block
    # boundaries past this budget.
    ENCODE_SLAB_ELEMS = 128 * 1024

    def __init__(self, kv_transform: bool = True):
        self.kv_transform = kv_transform
        self.name = "bitplane-kv" if kv_transform else "bitplane"

    @staticmethod
    def _check_sizes(chunks) -> List[int]:
        sizes = [c.size for c in chunks]
        for n in sizes:
            if n % 8:
                raise ValueError(f"block length {n} not a multiple of 8")
        return sizes

    def encode_batch(self, chunks, codec):
        if not chunks:
            return []
        sizes = self._check_sizes(chunks)
        if len(chunks) > 1 and sum(sizes) > self.ENCODE_SLAB_ELEMS:
            out, cur, cur_n = [], [], 0
            for c in chunks:
                if cur and cur_n + c.size > self.ENCODE_SLAB_ELEMS:
                    out.extend(self._encode_slab(cur, codec))
                    cur, cur_n = [], 0
                cur.append(c)
                cur_n += c.size
            out.extend(self._encode_slab(cur, codec))
            return out
        return self._encode_slab(chunks, codec)

    def _encode_slab(self, chunks, codec):
        """One pack + ONE compress_slab for every (plane, block) stream.

        Blocks are padded to a byte multiple, so their plane streams
        concatenate cleanly: packing the concatenation and slicing per
        block is byte-identical to packing each block alone.  The packed
        plane matrix is handed to the codec as a flat slab with (start,
        end) stream bounds — no per-stream bytes are materialized, and
        on accelerator backends the match kernel consumes the packed
        planes without a device→host→device round trip.
        """
        sizes = [c.size for c in chunks]
        planes = _pack_slab(np.concatenate(chunks) if len(chunks) > 1
                            else chunks[0].ravel())
        offs = np.cumsum([0] + [n // 8 for n in sizes])
        nblk = len(chunks)
        n8 = planes.shape[1]
        base = np.arange(BF16_BITS, dtype=np.int64)[:, None] * n8
        payloads, flags = codecs.compress_slab(
            planes.reshape(-1),
            (base + offs[None, :-1]).ravel(),
            (base + offs[None, 1:]).ravel(),
            codec,
        )
        return [
            ([payloads[p * nblk + i] for p in range(BF16_BITS)],
             [flags[p * nblk + i] for p in range(BF16_BITS)])
            for i in range(nblk)
        ]

    def encode_batch_scalar(self, chunks, codec):
        # The original write pipeline: per-block plane pack, per-plane
        # compress_block — O(blocks x planes) python-level calls.
        out = []
        self._check_sizes(chunks)
        for chunk in chunks:
            planes = pack_planes(chunk.ravel())
            payloads, flags = [], []
            for p in range(BF16_BITS):
                pay, fl = codecs.compress_block(planes[p].tobytes(), codec)
                payloads.append(pay)
                flags.append(fl)
            out.append((payloads, flags))
        return out

    def fetched_payloads(self, block, view):
        return view.fetched_planes()

    # Max elements decoded per vectorized pass: big enough to amortize the
    # per-call numpy overhead across many 4 KB blocks, small enough that
    # plane/bit temporaries stay cache-resident (the win over per-block
    # decode evaporates once working sets spill to DRAM).
    SLAB_ELEMS = 64 * 1024

    def decode_batch(self, blocks, view, codec):
        if len(blocks) > 1:
            # split into cache-sized slabs on block boundaries
            slabs, cur, cur_elems = [], [], 0
            for b in blocks:
                if cur and cur_elems + b.padded_elems > self.SLAB_ELEMS:
                    slabs.append(cur)
                    cur, cur_elems = [], 0
                cur.append(b)
                cur_elems += b.padded_elems
            slabs.append(cur)
            if len(slabs) > 1:
                out = []
                for s in slabs:
                    out.extend(self.decode_batch(s, view, codec))
                return out
        if not blocks:
            return []
        plane_set = view.fetched_planes()
        nbytes = [b.padded_elems // 8 for b in blocks]
        total = sum(nbytes)
        # Per plane: join the batch's decompressed byte streams, then one
        # subset-unpack for the whole slab (unfetched planes read as zero).
        rows = np.stack([
            np.frombuffer(
                b"".join(codecs.decompress_batch(
                    [b.payloads[p] for b in blocks],
                    [b.flags[p] for b in blocks], codec, nbytes,
                )),
                dtype=np.uint8,
            )
            for p in plane_set
        ])
        flat = unpack_planes_subset(rows, plane_set, total * 8)
        segs: List[Optional[np.ndarray]] = []
        off = 0
        kv_groups: Dict[tuple, List[int]] = {}
        for bi, b in enumerate(blocks):
            seg = flat[off * 8 : off * 8 + b.valid_elems]
            off += nbytes[bi]
            if b.kv_meta is not None:
                m = b.kv_meta
                kv_groups.setdefault((m.n_tokens, m.n_channels), []).append(bi)
                seg = seg[: m.n_tokens * m.n_channels]
            segs.append(seg)
        # Invert the exponent-delta FIRST: guard-bit rounding may carry from
        # mantissa into the exponent, which is only meaningful in the
        # real-exponent domain (not the zigzag-delta domain).  Same-shape
        # windows invert as one vectorized pass.
        for (_, _), idxs in kv_groups.items():
            metas = [blocks[i].kv_meta for i in idxs]
            inv = kv_inverse_batch(np.stack([segs[i] for i in idxs]), metas)
            for i, tok in zip(idxs, inv):
                segs[i] = tok
        if view.is_full:
            return segs
        flat = reconstruct_u16(np.concatenate([s.ravel() for s in segs]), view)
        return [r.reshape(s.shape) for r, s in zip(_split_like(flat, segs), segs)]


def _intersect_views(a: PrecisionView, b: PrecisionView) -> PrecisionView:
    """The widest view whose fetched planes are a subset of both ``a``'s
    and ``b``'s.  Kept planes are the narrower cut; guard planes are
    whatever of the narrower fetch frontier remains beyond it.  This is
    how a read against a truncated block is served: the host gets
    exactly the planes that still physically exist, reconstructed with
    the same guard-rounding rule as a plane-aligned fetch at that view.
    """
    if a == b:
        return a
    r_e = min(a.r_e, b.r_e)
    d_e = min(a.r_e + a.d_e, b.r_e + b.d_e) - r_e
    r_m = min(a.r_m, b.r_m)
    d_m = min(a.r_m + a.d_m, b.r_m + b.d_m) - r_m
    for v in (a, b):
        if (v.r_e, v.d_e, v.r_m, v.d_m) == (r_e, d_e, r_m, d_m):
            return v
    return PrecisionView(r_e=r_e, r_m=r_m, d_e=d_e, d_m=d_m,
                         name=f"cut{1 + r_e + r_m}")


def _split_like(flat: np.ndarray, segs: Sequence[np.ndarray]) -> List[np.ndarray]:
    out, off = [], 0
    for s in segs:
        out.append(flat[off : off + s.size])
        off += s.size
    return out


LAYOUTS = {
    "word": lambda: WordLayout(compress=False),
    "word-comp": lambda: WordLayout(compress=True),
    "bitplane": lambda: BitplaneLayout(kv_transform=False),
    "bitplane-kv": lambda: BitplaneLayout(kv_transform=True),
}


# ---------------------------------------------------------------------------
# Async submission — tickets over a bounded in-flight window
# ---------------------------------------------------------------------------

class Ticket:
    """Handle to one request submitted through :meth:`TierStore.submit_async`.

    Posted writes complete at submission, so their tickets are born done.
    Read tickets complete when the scheduler flushes their in-flight group
    (window overflow, hazard fence, :meth:`wait` or :meth:`drain`).
    ``wait()`` is idempotent: it forces execution of the queue prefix up to
    this ticket on first call and returns the same :class:`Receipt` (or
    re-raises the same error) on every call.
    """

    __slots__ = ("request", "_store", "_receipt", "_error")

    def __init__(self, store: "TierStore", request: Request):
        self._store = store
        self.request = request
        self._receipt: Optional[Receipt] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._receipt is not None or self._error is not None

    def _complete(self, receipt: Receipt):
        self._receipt = receipt

    def _fail(self, error: BaseException):
        self._error = error

    def wait(self) -> Receipt:
        if not self.done:
            self._store._flush_through(self)
        if self._error is not None:
            raise self._error
        assert self._receipt is not None
        return self._receipt

    def __repr__(self):
        state = ("done" if self._receipt is not None
                 else "failed" if self._error is not None else "pending")
        return f"Ticket({self.request.key!r}, {state})"


# ---------------------------------------------------------------------------
# TierStore — the host↔device boundary
# ---------------------------------------------------------------------------

class TierStore:
    """A tier device: a :class:`Layout` + codec behind a batched request API.

    ``submit`` is the only real entry point; the legacy tensor/KV methods
    are shims over it.  All traffic lands in per-request receipts, which
    also roll up into ``self.stats``.
    """

    name = "tier"

    def __init__(self, layout: Union[Layout, str] = "word",
                 codec: str = "lz4", block_elems: int = BLOCK_ELEMS,
                 index_cache_entries: int = 4096, kv_window: int = 64,
                 link_model: LinkModel = LinkModel(), window: int = 64,
                 batched_encode: bool = True,
                 sanitize: Optional[bool] = None, device_id: int = 0):
        self.layout = LAYOUTS[layout]() if isinstance(layout, str) else layout
        self.codec = codecs.resolve_codec(codec)
        self.block_elems = block_elems
        self.kv_window = kv_window
        self.link_model = link_model
        self.device_id = device_id           # fleet position (receipts carry it)
        self.window = window                 # max queued (in-flight) reads
        self.batched_encode = batched_encode  # False: scalar reference path
        # Runtime invariant sanitizer: explicit flag wins, else the
        # TRACE_SANITIZE env var ("" / "0" = off).  See _Sanitizer.
        if sanitize is None:
            sanitize = os.environ.get("TRACE_SANITIZE", "").strip() \
                not in ("", "0")
        self.sanitize = bool(sanitize)
        self._san = _Sanitizer(self) if self.sanitize else None
        self.stats = (_MirroredStats(self._san.shadow) if self._san
                      else DeviceStats())
        # Physical-footprint residency ledger: one entry per stored key,
        # equal to that key's stored payload+index bytes at all times.
        self._ledger: Dict[str, ResidencyEntry] = {}
        self._tensors: Dict[str, List[_Block]] = {}
        self._shapes: Dict[str, tuple] = {}
        self._kv_staging: Dict[str, list] = {}   # stream → [token rows]
        self._kv_channels: Dict[str, int] = {}
        self._index = _IndexCache(index_cache_entries)
        self._queue: List[Ticket] = []       # pending read tickets, FIFO
        # Device-global busy clock: host-time `now` plus per-pipe busy
        # frontiers.  Posted writes and window-overflow flushes advance the
        # frontiers without advancing `now`, so LATER groups queue behind
        # their residual occupancy (cross-group contention pricing); waits
        # (sync reads, drain, Ticket.wait) advance `now` to delivery.
        self._now_s = 0.0
        self._ddr_free_s = 0.0
        self._link_free_s = 0.0

    # -- validation (shared by submit / submit_async) -------------------------
    def _validate(self, requests: Sequence[Request]):
        """Reject a malformed batch BEFORE mutating any device state, so a
        bad request cannot leave committed blocks unaccounted.  Reads may
        target any key written anywhere in the same batch: writes drain
        before reads regardless of listed order."""
        written = {req.key for req in requests if isinstance(req, WriteReq)}
        for req in requests:
            if isinstance(req, WriteReq):
                if req.kind not in (TENSOR, KV):
                    raise ValueError(f"unknown request kind {req.kind!r}")
            elif isinstance(req, ReadReq):
                if (req.kind == KV and self.layout.kv_transform
                        and req.view.r_e != EXP_BITS):
                    raise ValueError(
                        "KV views must keep the full (delta) exponent"
                    )
                if (req.key not in self._tensors
                        and not self._kv_staging.get(req.key)
                        and req.key not in written):
                    raise KeyError(req.key)
            elif isinstance(req, GatherReq):
                if req.kind not in (TENSOR, KV):
                    raise ValueError(f"unknown request kind {req.kind!r}")
                if req.k < 0:
                    raise ValueError(f"gather k must be >= 0, got {req.k}")
                digest = np.asarray(req.digest)
                if digest.ndim != 1 or digest.size == 0:
                    raise ValueError(
                        "gather digest must be a flat (channels,) vector"
                    )
                if (req.views is not None
                        and len(req.views) != len(req.keys)):
                    raise ValueError(
                        f"gather views ({len(req.views)}) must align "
                        f"with keys ({len(req.keys)})"
                    )
                kv_exp = req.kind == KV and self.layout.kv_transform
                for view in (req.score_view,) + tuple(req.views or ()):
                    if kv_exp and view.r_e != EXP_BITS:
                        raise ValueError(
                            "KV views must keep the full (delta) exponent"
                        )
                for key in req.keys:
                    if (key not in self._tensors
                            and not self._kv_staging.get(key)
                            and key not in written):
                        raise KeyError(key)
                    c = self._kv_channels.get(key)
                    if c is not None and c != digest.size:
                        raise ValueError(
                            f"gather digest has {digest.size} channels "
                            f"but {key!r} stores {c}"
                        )
            else:
                raise TypeError(f"not a tier request: {req!r}")

    def validate(self, requests: Sequence[Request]):
        """Public batch validation — same checks :meth:`submit` runs before
        touching device state.  A sharding front-end pre-flights every
        shard's sub-batch through this so a malformed fleet batch rejects
        before ANY shard commits (single-device atomicity, fleet-wide)."""
        self._validate(requests)

    # -- sanctioned accounting helpers (lint rule R3) -------------------------
    def _apply_receipt(self, rec: Receipt):
        """Fold one receipt into the running aggregate — the only
        sanctioned path for receipt-driven stats mutation (and the
        point where the sanitizer's shadow aggregate stays in step)."""
        rec.device_id = self.device_id
        self.stats.apply(rec)
        if self._san is not None:
            self._san.shadow.apply(rec)

    def _adjust_stored(self, payload: int = 0, raw: int = 0,
                       blocks: int = 0):
        """Capacity delta outside a receipt (deletes, in-place plane
        truncation) — the only sanctioned path for direct capacity
        mutation."""
        self.stats.dram_bytes_stored += payload
        self.stats.raw_bytes_stored += raw
        self.stats.blocks += blocks
        if self._san is not None:
            sh = self._san.shadow
            sh.dram_bytes_stored += payload
            sh.raw_bytes_stored += raw
            sh.blocks += blocks

    def _sanitize_boundary(self, touched: Optional[Set[str]] = None):
        if self._san is not None:
            self._san.boundary(touched)

    # -- batched entry point -------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> List[Receipt]:
        """Execute a request batch; one receipt per request, in order.

        Reads across the batch are decoded together (grouped by precision
        view) so plane unpacking and reconstruction run as a few vectorized
        numpy passes instead of one per 4 KB block.  Any queued async reads
        drain first, so sync callers always observe program order.
        """
        self._validate(requests)
        if self._queue:
            self._flush_queue(len(self._queue), wait=True)
        receipts: List[Receipt] = [None] * len(requests)  # type: ignore
        # Writes execute in order first so reads in the same batch observe
        # them (single-queue device semantics); the batch's writes encode
        # as ONE slab (see _post_writes).
        write_ix = [i for i, r in enumerate(requests)
                    if isinstance(r, WriteReq)]
        written = set(write_ix)
        read_ix = [i for i in range(len(requests)) if i not in written]
        if write_ix:
            for i, r in zip(write_ix,
                            self._post_writes([requests[i] for i in write_ix])):
                receipts[i] = r
        if read_ix:
            recs = self._do_reads([requests[i] for i in read_ix])
            # sync reads are one group on the shared pipes; the host blocks
            # on their data, so delivery advances the busy clock
            self._schedule_group(
                recs, [(r.dram_bytes_read, r.link_bytes_out) for r in recs],
                wait=True,
            )
            for i, r in zip(read_ix, recs):
                receipts[i] = r
        return receipts

    # -- async entry point ---------------------------------------------------
    def submit_async(self, requests: Sequence[Request]) -> List[Ticket]:
        """Enqueue a request batch; one :class:`Ticket` per request, in order.

        Writes are posted — committed immediately, in listed order — so
        their tickets are born complete.  Reads enter the bounded in-flight
        window and execute later as coalesced groups (window overflow,
        ``wait``/``drain``, or a write-after-read fence on their key).  As
        in :meth:`submit`, the call's writes post before its reads enqueue,
        which makes ``submit_async`` + :meth:`drain` receipt- and
        byte-identical to one sync ``submit`` of the same batch.
        """
        self._validate(requests)
        writes = [r for r in requests if isinstance(r, WriteReq)]
        # Write-after-read fence: posting a write over queued reads of the
        # same key would let those reads observe data from their future.
        # Flush the queue first (groups are prefixes, so order holds).
        if writes:
            hot = frozenset(w.key for w in writes)
            if any(hot & _req_keys(t.request) for t in self._queue):
                self._flush_queue(len(self._queue), wait=False)
        tickets: Dict[int, Ticket] = {}
        if writes:
            # posted writes accumulate into one encode slab, mirroring how
            # queued reads share one decode slab
            write_ix = [i for i, r in enumerate(requests)
                        if isinstance(r, WriteReq)]
            for i, rec in zip(write_ix, self._post_writes(writes)):
                t = Ticket(self, requests[i])
                t._complete(rec)
                tickets[i] = t
        for i, req in enumerate(requests):
            if i not in tickets:
                if len(self._queue) >= self.window:
                    self._flush_queue(len(self._queue), wait=False)
                t = Ticket(self, req)
                self._queue.append(t)
                tickets[i] = t
        if self._san is not None:
            self._san.check_window()
            self._san.check_clock()
        return [tickets[i] for i in range(len(requests))]

    @property
    def pending(self) -> int:
        """Queued (not yet executed) read requests in the in-flight window."""
        return len(self._queue)

    def drain(self, tickets: Optional[Sequence[Ticket]] = None) -> List[Receipt]:
        """Execute everything still queued and return receipts in order.

        With ``tickets``, returns exactly those tickets' receipts (waiting
        on each); otherwise returns the receipts of the reads that were
        pending at call time.  Re-raises the first failed ticket's error.
        """
        waiting = list(tickets) if tickets is not None else list(self._queue)
        if self._queue:
            self._flush_queue(len(self._queue), wait=True)
        return [t.wait() for t in waiting]

    def _flush_through(self, ticket: Ticket):
        """Execute the queue prefix up to and including ``ticket``."""
        try:
            n = self._queue.index(ticket) + 1
        except ValueError:
            return                       # completed (or failed) elsewhere
        self._flush_queue(n, wait=True)

    def _flush_queue(self, n: int, wait: bool = True):
        """Execute the first ``n`` queued reads as one coalesced group.

        The group goes through the same vectorized batched-read path as a
        sync batch; receipts then get queue-delay / overlap-adjusted
        latency from :meth:`LinkModel.schedule`, including any residual
        pipe backlog from earlier groups (the busy clock).  ``wait`` marks
        flushes the host blocks on (Ticket.wait / drain / sync submit) —
        those advance host time to the group's delivery; window-overflow
        and fence flushes do not, so their occupancy carries forward.  On
        failure every ticket of the group records the error (stats for
        whatever committed stay applied by ``_do_reads``) and the error
        propagates.
        """
        group, self._queue = self._queue[:n], self._queue[n:]
        if not group:
            return
        try:
            recs = self._do_reads([t.request for t in group])
        except BaseException as e:
            for t in group:
                t._fail(e)
            raise
        self._schedule_group(
            recs, [(r.dram_bytes_read, r.link_bytes_out) for r in recs],
            wait=wait,
        )
        for t, r in zip(group, recs):
            t._complete(r)

    # -- busy clock ----------------------------------------------------------
    def _schedule_group(self, recs: List[Receipt],
                        traffic: List[Tuple[int, int]], wait: bool):
        """Price one request group on the shared pipes and advance the
        device-global busy clock.  Receipts get ``queue_delay_s`` /
        ``latency_s`` measured from group issue (= host `now`), INCLUDING
        residual DDR/link occupancy left by earlier groups the host never
        waited for — receipts-sum == DeviceStats is untouched (bytes only).
        """
        if not recs:
            return
        now = self._now_s
        ddr_b = max(self._ddr_free_s - now, 0.0)
        link_b = max(self._link_free_s - now, 0.0)
        times = self.link_model.schedule(traffic, ddr_backlog_s=ddr_b,
                                         link_backlog_s=link_b)
        # Device compute (PNM scoring) is a third resource next to the
        # DDR/link pipes: it extends that request's delivery time but
        # occupies neither pipe, so later groups don't queue behind it.
        for rec, (delay, done) in zip(recs, times):
            rec.queue_delay_s = delay
            rec.latency_s = done + rec.device_compute_s
        lm = self.link_model
        self._ddr_free_s = now + lm.base_s + ddr_b \
            + sum(t[0] for t in traffic) / lm.ddr_bw
        self._link_free_s = now + lm.base_s + link_b \
            + sum(t[1] for t in traffic) / lm.link_bw
        if wait:
            # host blocked until the last delivery; pipes are drained past
            # this point, so backlogs collapse to zero for the next group
            self._now_s = now + max(r.latency_s for r in recs)

    @property
    def busy_backlog_s(self) -> float:
        """Residual pipe occupancy beyond host `now` (seconds) — how far
        this device's DDR/link frontiers run ahead of the host clock.
        Zero on an idle device.  The sanctioned readout fleet placement
        uses to fan replicated reads out to the least-busy replica."""
        return max(self._ddr_free_s - self._now_s,
                   self._link_free_s - self._now_s, 0.0)

    def quiesce(self):
        """Idle the host until both device pipes drain.

        Advances host time past every posted write / unwaited flush group,
        so the next request group starts on idle pipes (zero backlog).
        Queued-but-unexecuted reads are NOT forced — use :meth:`drain`.
        """
        self._now_s = max(self._now_s, self._ddr_free_s, self._link_free_s)
        if self._san is not None:
            self._san.check_clock()

    # -- write path ----------------------------------------------------------
    def _post_write(self, req: WriteReq) -> Receipt:
        """Post one write (single-request convenience over _post_writes)."""
        return self._post_writes([req])[0]

    def _post_writes(self, reqs: Sequence[WriteReq]) -> List[Receipt]:
        """Post a batch of writes as ONE encode slab — the single posting
        path shared by ``submit`` and ``submit_async``, so the sync/async
        receipt-identity invariant cannot drift.

        Staging walks the requests in listed order, turning tensors into
        fixed-size blocks and KV rows into transformed windows, but defers
        pack + codec: every staged block lands in one slab that the layout
        encodes in a few vectorized passes (``encode_batch``), mirroring
        how queued reads share one decode slab.  Block commit order — and
        therefore payload bytes, receipts and index entries — is identical
        to encoding each request alone; the differential tests hold the
        batched and scalar pipelines to byte-identity.

        Writes are *posted* (CXL.mem semantics): they occupy the pipes but
        the host does not wait, so their receipts carry schedule latency
        while the busy-clock frontier advances past host `now`.
        """
        recs = [Receipt(key=r.key, op="write", kind=r.kind, tag=r.tag)
                for r in reqs]
        slab = _EncodeSlab()
        try:
            for req, rec in zip(reqs, recs):
                self._stage_write(req, rec, slab)
        finally:
            try:
                # even on a staging failure, everything staged so far must
                # commit — sync semantics committed prior requests' blocks
                self._encode_commit(slab)
            finally:
                lm = self.link_model
                for rec in recs:
                    rec.service_s = lm.latency(rec.dram_bytes_written,
                                               rec.link_bytes_in)
                self._schedule_group(
                    recs,
                    [(r.dram_bytes_written, r.link_bytes_in) for r in recs],
                    wait=False,
                )
                for rec in recs:
                    # whatever was committed stays counted
                    self._apply_receipt(rec)
                self._sanitize_boundary({r.key for r in reqs})
        return recs

    def _stage_write(self, req: WriteReq, rec: Receipt, slab: "_EncodeSlab"):
        data = np.ascontiguousarray(req.data, dtype=np.uint16)
        rec.link_bytes_in += data.size * 2
        if req.kind == TENSOR:
            self._shapes[req.key] = data.shape
            for chunk, valid in iter_blocks(data, self.block_elems):
                slab.add(req.key, rec, chunk, valid)
        else:  # KV (kinds validated in submit)
            rows = data[None, :] if data.ndim == 1 else data
            self._kv_channels[req.key] = rows.shape[-1]
            if not self.layout.kv_transform:
                # Word devices store the token-major stream verbatim in
                # 4 KB blocks — no staging window, no transform.
                for chunk, valid in iter_blocks(rows, self.block_elems):
                    slab.add(req.key, rec, chunk, valid)
            else:
                buf = self._kv_staging.setdefault(req.key, [])
                flat = rows.reshape(-1, rows.shape[-1])
                nrows, i = flat.shape[0], 0
                while i < nrows:
                    if not buf and nrows - i >= self.kv_window:
                        # whole window in one request (page spill, prefill
                        # flush): stage the contiguous slice directly, no
                        # row buffering
                        slab.add(req.key, rec, None,
                                 self.kv_window * flat.shape[1],
                                 kv_window=np.ascontiguousarray(
                                     flat[i : i + self.kv_window]))
                        i += self.kv_window
                        continue
                    take = min(self.kv_window - len(buf), nrows - i)
                    buf.extend(flat[i : i + take])
                    i += take
                    if len(buf) >= self.kv_window:
                        self._stage_kv_window(rec, req.key, slab)
                if req.flush and buf:
                    self._stage_kv_window(rec, req.key, slab)

    def _stage_kv_window(self, rec: Receipt, stream: str,
                         slab: "_EncodeSlab"):
        """Claim the staged window now (ordering is per-stream), but defer
        the exponent-delta transform: it is independent per window, so it
        joins the posting group's batched passes at encode time."""
        buf = self._kv_staging[stream]
        window = np.stack(buf, axis=0)
        buf.clear()  # in place — _stage_write holds a reference to this list
        slab.add(stream, rec, None, window.size, kv_window=window)

    def _encode_commit(self, slab: "_EncodeSlab"):
        """Run the posting group's deferred passes — KV transform, plane
        pack, codec — and commit blocks in staging order."""
        if not slab.chunks:
            return
        self._transform_kv_windows(slab)
        enc = (self.layout.encode_batch if self.batched_encode
               else self.layout.encode_batch_scalar)
        encoded = enc(slab.chunks, self.codec)
        for (payloads, flags), key, rec, chunk, valid, meta in zip(
                encoded, slab.keys, slab.recs, slab.chunks, slab.valids,
                slab.metas):
            self._commit(rec, key,
                         _Block(payloads, flags, valid, chunk.size,
                                kv_meta=meta))
        slab.clear()

    def _transform_kv_windows(self, slab: "_EncodeSlab"):
        """Resolve deferred KV windows into transformed chunks + metas.

        Batched mode groups same-shape windows through one
        ``kv_forward_batch`` (vectorized modal-exponent + zigzag); the
        scalar reference transforms per window — identical outputs, the
        parity tests compare them.
        """
        pend = [i for i, w in enumerate(slab.kv_windows) if w is not None]
        if not pend:
            return

        def _pad(t: np.ndarray) -> np.ndarray:
            return (np.pad(t, (0, 8 - t.size % 8)) if t.size % 8 else t)

        if not self.batched_encode:
            for i in pend:
                transformed, meta = kv_forward(slab.kv_windows[i])
                slab.chunks[i] = _pad(transformed)
                slab.metas[i] = meta
                slab.kv_windows[i] = None
            return
        groups: Dict[tuple, List[int]] = {}
        for i in pend:
            groups.setdefault(slab.kv_windows[i].shape, []).append(i)
        for shape, idxs in groups.items():
            streams, metas = kv_forward_batch(
                np.stack([slab.kv_windows[i] for i in idxs])
            )
            for i, row, meta in zip(idxs, streams, metas):
                slab.chunks[i] = _pad(row)
                slab.metas[i] = meta
                slab.kv_windows[i] = None

    def _commit(self, rec: Receipt, key: str, block: _Block):
        self._tensors.setdefault(key, []).append(block)
        entry = self._ledger.setdefault(key, ResidencyEntry())
        if self._san is not None:
            self._san.refs.setdefault(key, 1)
        entry.payload_bytes += block.stored_bytes
        entry.index_bytes += INDEX_ENTRY_BYTES
        entry.raw_bytes += block.valid_elems * 2
        entry.blocks += 1
        rec.blocks += 1
        rec.dram_bytes_stored += block.stored_bytes
        rec.dram_bytes_written += block.stored_bytes
        rec.raw_bytes_stored += block.valid_elems * 2
        if self.layout.uses_codec:
            rec.codec_blocks += len(block.flags)
            rec.codec_bypass += sum(
                1 for f in block.flags if f == codecs.RAW)

    def _commit_kv_window(self, rec: Receipt, stream: str):
        """Immediate (non-deferred) window commit for the read path's
        implicit flush and the legacy ``flush_kv`` shim."""
        slab = _EncodeSlab()
        self._stage_kv_window(rec, stream, slab)
        self._encode_commit(slab)

    # -- read path -----------------------------------------------------------
    def _do_reads(self, reqs: Sequence[Request]) -> List[Receipt]:
        # Gather every requested block, tally per-request DRAM/index traffic,
        # then decode per view-group in vectorized passes.  Receipts are
        # applied to the aggregate in a finally so an exception mid-batch
        # cannot desync stats from already-flushed staging windows.
        #
        # Plain reads decode as ONE batched group first; GatherReqs (the
        # PNM top-k path) then execute in listed order — a gather's index
        # accounting depends on its own score-then-winner fetch sequence,
        # so it cannot fold into the shared decode slab.
        recs = [Receipt(key=r.key,
                        op="gather" if isinstance(r, GatherReq) else "read",
                        kind=r.kind, tag=r.tag)
                for r in reqs]
        try:
            read_ix = [i for i, r in enumerate(reqs)
                       if not isinstance(r, GatherReq)]
            if read_ix:
                self._gather_and_decode([reqs[i] for i in read_ix],
                                        [recs[i] for i in read_ix])
            for i, req in enumerate(reqs):
                if isinstance(req, GatherReq):
                    self._do_gather(req, recs[i])
            return recs
        finally:
            for rec in recs:
                self._apply_receipt(rec)
            touched: Set[str] = set()
            for r in reqs:
                touched |= _req_keys(r)
            self._sanitize_boundary(touched)

    def _do_gather(self, req: GatherReq, rec: Receipt):
        """Execute one PNM gather: score every candidate device-side on
        the ``score_view`` plane subset, then decode full precision for
        the top-k winners only.

        Accounting: the scoring pass reads only the score view's planes
        from DRAM (plus index touches) and ships 4 B/candidate of scores
        over the link; winners are then fetched exactly like plain reads
        at their per-key views, so a gather with ``k >= len(keys)``
        returns byte-identical data to individual :class:`ReadReq`
        submissions.  ``device_compute_s`` prices the scoring kernel at
        :meth:`LinkModel.device_compute` over the scored elements.
        """
        from ..kernels.pnm_score import page_scores_u16, topk_select

        if req.kind == KV:
            for key in req.keys:
                if self._kv_staging.get(key):
                    # implicit flush, accounted to this gather
                    self._commit_kv_window(rec, key)

        def _fetch(keys: Sequence[str], views: Sequence[PrecisionView]
                   ) -> List[np.ndarray]:
            """Plane-aligned fetch + decode of whole keys, tallied into
            ``rec`` — the same per-block walk as ``_gather_and_decode``."""
            per_key_blocks: List[List[_Block]] = []
            per_key_views: List[List[PrecisionView]] = []
            for key, view in zip(keys, views):
                blocks = self._tensors.get(key, [])
                eff = [view if b.view is None
                       else _intersect_views(view, b.view) for b in blocks]
                for i, (b, v) in enumerate(zip(blocks, eff)):
                    self._touch_index(rec, key, i)
                    for p in self.layout.fetched_payloads(b, v):
                        rec.dram_bytes_read += len(b.payloads[p])
                per_key_blocks.append(list(blocks))
                per_key_views.append(eff)
            groups: Dict[PrecisionView, List[_Block]] = {}
            for eff, blocks in zip(per_key_views, per_key_blocks):
                for v, b in zip(eff, blocks):
                    groups.setdefault(v, []).append(b)
            decoded = {
                v: iter(self.layout.decode_batch(blocks, v, self.codec))
                for v, blocks in groups.items()
            }
            out = []
            for key, eff in zip(keys, per_key_views):
                segs = [next(decoded[v]) for v in eff]
                out.append(self._assemble(
                    ReadReq(key, kind=req.kind, view=FULL), segs))
            return out

        # --- scoring pass: plane-subset decode feeds the PNM kernel ---
        score_views = [req.score_view] * len(req.keys)
        candidates = _fetch(req.keys, score_views)
        scores = page_scores_u16(candidates, np.asarray(req.digest,
                                                        dtype=np.float32))
        # scores ship to the host: 4 B (f32) per candidate
        rec.link_bytes_out += 4 * len(req.keys)
        rec.device_compute_s += self.link_model.device_compute(
            sum(int(c.size) for c in candidates))

        # --- winner pass: full-precision fetch for the top-k only ---
        winner_ix = topk_select(scores, req.k)
        winner_views = [req.views[i] if req.views is not None else FULL
                        for i in winner_ix]
        winner_keys = [req.keys[i] for i in winner_ix]
        data = _fetch(winner_keys, winner_views)
        if self.layout.plane_aligned:
            # effective per-block views may be truncation-clamped below
            # the request view; recompute the shipped bits per winner
            for key, view, arr in zip(winner_keys, winner_views, data):
                for b in self._tensors.get(key, []):
                    v = view if b.view is None else _intersect_views(view,
                                                                     b.view)
                    n = b.valid_elems
                    if b.kv_meta is not None:
                        n = b.kv_meta.n_tokens * b.kv_meta.n_channels
                    rec.link_bytes_out += n * v.bits // 8
        else:
            rec.link_bytes_out += sum(a.size for a in data) * BF16_BITS // 8
        rec.gather = GatherResult(keys=winner_keys, indices=list(winner_ix),
                                  scores=scores, data=data)
        rec.service_s = rec.latency_s = self.link_model.latency(
            rec.dram_bytes_read, rec.link_bytes_out
        ) + rec.device_compute_s

    def _gather_and_decode(self, reqs: Sequence[ReadReq],
                           recs: List[Receipt]) -> List[Receipt]:
        req_blocks: List[List[_Block]] = []
        req_views: List[List[PrecisionView]] = []
        for req, rec in zip(reqs, recs):
            if req.kind == KV and self._kv_staging.get(req.key):
                # implicit flush, accounted to this request
                self._commit_kv_window(rec, req.key)
            blocks = self._tensors.get(req.key, [])
            if req.block_range is not None:
                lo, hi = req.block_range
                blocks = blocks[lo:hi]
            # A truncated block clamps the request's view to the planes
            # that still exist (per block — blocks committed after the
            # truncation are full again).
            views = [req.view if b.view is None
                     else _intersect_views(req.view, b.view)
                     for b in blocks]
            for off, (b, view) in enumerate(zip(blocks, views)):
                base = (req.block_range[0] if req.block_range else 0) + off
                self._touch_index(rec, req.key, base)
                for p in self.layout.fetched_payloads(b, view):
                    rec.dram_bytes_read += len(b.payloads[p])
            req_blocks.append(list(blocks))
            req_views.append(views)

        # Group all blocks across requests by effective view (the view
        # fixes both the fetched plane set and the reconstruction),
        # decode each group once.
        groups: Dict[PrecisionView, List[_Block]] = {}
        for views, blocks in zip(req_views, req_blocks):
            for view, b in zip(views, blocks):
                groups.setdefault(view, []).append(b)
        decoded = {
            view: iter(self.layout.decode_batch(blocks, view, self.codec))
            for view, blocks in groups.items()
        }

        out: List[Receipt] = []
        for req, rec, blocks, views in zip(reqs, recs, req_blocks,
                                           req_views):
            # per-group iterators advance in encounter order, which is
            # exactly the order the group lists were built in
            segs = [next(decoded[view]) for view in views]
            rec.data = self._assemble(req, segs)
            # Word devices always move full 16-bit containers over the link
            # (paper Issue 2); plane-aligned layouts return the view's bits
            # (the effective, possibly truncation-clamped view per block).
            if self.layout.plane_aligned:
                rec.link_bytes_out += sum(
                    seg.size * view.bits for seg, view in zip(segs, views)
                ) // 8
            else:
                rec.link_bytes_out += rec.data.size * BF16_BITS // 8
            rec.service_s = rec.latency_s = self.link_model.latency(
                rec.dram_bytes_read, rec.link_bytes_out
            )
            out.append(rec)
        return out

    def _assemble(self, req: ReadReq, segs: List[np.ndarray]) -> np.ndarray:
        if not segs:
            return np.empty((0,), dtype=np.uint16)
        if req.kind == KV:
            if segs[0].ndim == 2:           # kv-transformed: (t, C) per window
                return np.concatenate(segs, axis=0)
            flat = np.concatenate(segs)
            C = self._kv_channels.get(req.key, flat.size)
            return flat.reshape(-1, C)
        flat = np.concatenate([s.ravel() for s in segs])
        shape = self._shapes.get(req.key)
        if (req.block_range is None and shape is not None
                and flat.size == int(np.prod(shape))):
            return flat.reshape(shape)
        # ranged reads / multi-write appends return the flat element stream
        return flat

    def _touch_index(self, rec: Receipt, key: str, i: int):
        if self._index.access((key, i)):
            rec.index_hits += 1
        else:
            rec.index_misses += 1
            rec.index_bytes += INDEX_ENTRY_BYTES
            rec.dram_bytes_read += INDEX_ENTRY_BYTES

    # -- introspection -------------------------------------------------------
    def n_blocks(self, key: str) -> int:
        return len(self._tensors.get(key, []))

    def footprint(self, key: str) -> int:
        return sum(b.stored_bytes for b in self._tensors[key])

    def logical_bytes(self, key: str) -> int:
        return sum(b.valid_elems for b in self._tensors[key]) * 2

    # -- residency ledger -----------------------------------------------------
    def resident_bytes(self, prefix: str = "") -> int:
        """Physical bytes this namespace occupies in device DRAM right
        now: stored payload planes plus the 64 B/block index entries.
        An empty prefix sums the whole device.  Equal to the sum of
        stored payload+index bytes at all times (the ledger invariant),
        which makes it the admission-control counterpart of the logical
        :meth:`logical_bytes` projection.  Matching is namespace-
        delimited: ``"r1"`` and ``"r1."`` both mean the ``r1.`` namespace
        (plus the exact key ``r1``) and never claim ``r10.``'s keys.
        Shared (refcounted) pages are counted once however many referers
        hold them."""
        if not prefix:
            return sum(e.physical_bytes for e in self._ledger.values())
        return sum(e.physical_bytes for k, e in self._ledger.items()
                   if _ns_match(k, prefix))

    def compression_ratio(self, prefix: str = "") -> float:
        """Observed logical/physical ratio of one namespace (1.0 when it
        holds nothing) — the feedback signal the ratio-aware admission
        estimator corrects against at every commit boundary.  Namespace-
        delimited like :meth:`resident_bytes`."""
        raw = phys = 0
        for k, e in self._ledger.items():
            if _ns_match(k, prefix):
                raw += e.raw_bytes
                phys += e.physical_bytes
        return raw / phys if phys > 0 else 1.0

    def truncate_planes(self, keys: Sequence[str],
                        view: PrecisionView) -> int:
        """Drop stored planes outside ``view``'s fetched set *in place*,
        reclaiming their payload bytes (paper §III-C: precision scaling
        as a storage knob).

        Each surviving block records the intersection of its previous
        view with ``view``; later reads are served at the intersection
        of the requested and stored views (bit-identical to
        ``reconstruct_u16`` at that view), and their DRAM traffic only
        touches surviving planes.  Staged (uncommitted) KV windows are
        unaffected — blocks committed after a truncation store full
        precision again.  Returns the reclaimed bytes, which reconcile
        exactly with the ledger delta.  Only plane-aligned layouts can
        shed planes of an already-stored block; word layouts store
        opaque compressed containers and raise ``NotImplementedError``.
        Unknown keys are ignored (a cold page may already be deleted).
        Keys with more than one outstanding reference are refused
        (``ValueError``): degrading a shared page would silently change
        what every other referer decodes, breaking their solo-run
        differential — callers must skip shared pages or wait for the
        refcount to drop to one.
        """
        if not self.layout.plane_aligned:
            raise NotImplementedError(
                f"layout {self.layout.name!r} stores word-major "
                "containers; in-place plane truncation needs a "
                "plane-aligned layout"
            )
        for key in keys:
            entry = self._ledger.get(key)
            if entry is not None and entry.refs > 1:
                raise ValueError(
                    f"cannot truncate {key!r}: {entry.refs} references "
                    "hold this shared page"
                )
        # In-flight reads were issued against the current plane mapping;
        # complete them before planes disappear (program order).
        if self._queue:
            self._flush_queue(len(self._queue), wait=True)
        keep = set(view.fetched_planes())
        reclaimed = 0
        for key in keys:
            blocks = self._tensors.get(key)
            if not blocks:
                continue
            freed = 0
            for b in blocks:
                if b.kv_meta is not None and view.r_e != EXP_BITS:
                    raise ValueError(
                        "KV views must keep the full (delta) exponent"
                    )
                for p in range(len(b.payloads)):
                    if p not in keep and b.payloads[p]:
                        freed += len(b.payloads[p])
                        b.payloads[p] = b""
                        b.flags[p] = codecs.RAW
                b.view = (view if b.view is None
                          else _intersect_views(b.view, view))
            if freed:
                self._ledger[key].payload_bytes -= freed
                self._adjust_stored(payload=-freed)
                reclaimed += freed
        self._sanitize_boundary(set(keys))
        return reclaimed

    def refcount(self, key: str) -> int:
        """Outstanding references to ``key`` (0 when not stored)."""
        entry = self._ledger.get(key)
        return entry.refs if entry is not None else 0

    def acquire(self, key: str) -> int:
        """Take one more reference on a stored key (shared-page reuse).

        The caller becomes a co-owner: the stored bytes stay counted once
        in the ledger, and the key survives any single referer's
        :meth:`release` / :meth:`delete` / :meth:`delete_prefix` until
        the last reference retires.  Raises ``KeyError`` for unknown keys
        and ``ValueError`` for truncated ones — a new referer must never
        decode data degraded below what a solo run would have stored.
        Returns the new reference count.
        """
        entry = self._ledger.get(key)
        if entry is None:
            raise KeyError(key)
        if any(b.view is not None for b in self._tensors.get(key, ())):
            raise ValueError(
                f"cannot acquire {key!r}: stored planes were truncated; "
                "a new referer would decode degraded data"
            )
        entry.refs += 1
        if self._san is not None:
            self._san.refs[key] = self._san.refs.get(key, 1) + 1
            self._san.boundary({key})
        return entry.refs

    def release(self, key: str) -> int:
        """Drop one reference; free the stored bytes at zero.

        Returns the remaining reference count.  Raises ``KeyError`` for
        unknown keys — a double release is an accounting bug, not a
        no-op.
        """
        entry = self._ledger.get(key)
        if entry is None:
            raise KeyError(key)
        if entry.refs > 1:
            entry.refs -= 1
            if self._san is not None:
                self._san.refs[key] = self._san.refs.get(key, 1) - 1
                self._san.boundary({key})
            return entry.refs
        # Last reference: in-flight reads were issued against the key's
        # current mapping; complete them before the mapping disappears.
        if self._queue:
            self._flush_queue(len(self._queue), wait=True)
        self._forget(key)
        if self._san is not None:
            self._san.boundary()
            self._san.check_retired(key=key)
        return 0

    def delete(self, key: str):
        entry = self._ledger.get(key)
        if entry is not None and entry.refs > 1:
            # Shared page: deleting means giving up this caller's claim,
            # never yanking bytes out from under the other referers.
            self.release(key)
            return
        # In-flight reads were issued against the key's current mapping;
        # complete them before the mapping disappears.
        if self._queue:
            self._flush_queue(len(self._queue), wait=True)
        self._forget(key)
        if self._san is not None:
            self._san.boundary()
            self._san.check_retired(key=key)

    def _forget(self, key: str, evict_index: bool = True):
        """Drop one key's blocks, staging, shape and index entries,
        returning the stored capacity to the device (queue already
        flushed by the caller).  ``evict_index=False`` lets a namespace
        delete purge the index cache in one pass instead of per key."""
        dropped = self._tensors.pop(key, [])
        if dropped:
            self._adjust_stored(
                payload=-sum(b.stored_bytes for b in dropped),
                raw=-sum(b.valid_elems for b in dropped) * 2,
                blocks=-len(dropped),
            )
        self._ledger.pop(key, None)
        self._shapes.pop(key, None)
        self._kv_staging.pop(key, None)
        self._kv_channels.pop(key, None)
        if self._san is not None:
            self._san.refs.pop(key, None)
        if evict_index:
            self._index.evict_stream(key)

    def delete_prefix(self, prefix: str) -> int:
        """Release every key in one namespace (``.``-delimited match, so
        ``"r1"`` never claims ``r10.``'s keys).

        This is the retirement path of the continuous-batching scheduler:
        a finished request's pages live under a per-request key prefix, and
        one call frees its blocks, staged windows, shapes, KV-channel
        metadata and index-cache entries, returning the stored capacity to
        ``stats`` so the pool can admit queued requests into the headroom.
        Keys other referers still hold (refcount > 1) drop one reference
        and keep their bytes — they free when the last referer retires.
        Queued reads (any stream's) are drained first, exactly like
        :meth:`delete` — per-key program order means the flush cannot
        change any surviving stream's bytes.  Returns the number of keys
        released.  An empty prefix clears the whole device (releasing,
        not force-freeing, shared keys).
        """
        if self._queue:
            self._flush_queue(len(self._queue), wait=True)
        keys = {k for k in self._tensors if _ns_match(k, prefix)}
        keys.update(k for k in self._kv_staging if _ns_match(k, prefix))
        keys.update(k for k in self._kv_channels if _ns_match(k, prefix))
        keys.update(k for k in self._shapes if _ns_match(k, prefix))
        survivors = set()
        for k in keys:
            entry = self._ledger.get(k)
            if entry is not None and entry.refs > 1:
                entry.refs -= 1
                if self._san is not None:
                    self._san.refs[k] = self._san.refs.get(k, 1) - 1
                survivors.add(k)
            else:
                self._forget(k, evict_index=False)
        if not survivors:
            self._index.evict_prefix(prefix)
        else:
            for k in keys - survivors:
                self._index.evict_stream(k)
        if self._san is not None:
            self._san.boundary()
            self._san.check_retired(prefix=prefix, survivors=survivors)
        return len(keys)

    # -- legacy shims (deprecated; forward to submit) ------------------------
    def write_tensor(self, name: str, u16: np.ndarray):
        self.submit([WriteReq(name, u16, kind=TENSOR)])

    def read_tensor(self, name: str, view: PrecisionView = FULL) -> np.ndarray:
        return self.submit([ReadReq(name, kind=TENSOR, view=view)])[0].data

    def write_kv(self, stream: str, tokens_u16: np.ndarray):
        # Matches the historical semantics: full windows commit eagerly,
        # partial tails stay staged until flush_kv / a KV read.
        self.submit([WriteReq(stream, tokens_u16, kind=KV, flush=False)])

    def read_kv(self, stream: str, view: PrecisionView = FULL) -> np.ndarray:
        return self.submit([ReadReq(stream, kind=KV, view=view)])[0].data

    def flush_kv(self, stream: str):
        if self._kv_staging.get(stream):
            # sync entry point: queued reads observe program order (they
            # would otherwise absorb this commit into their own receipts)
            if self._queue:
                self._flush_queue(len(self._queue), wait=True)
            rec = Receipt(key=stream, op="write", kind=KV)
            self._commit_kv_window(rec, stream)
            self._apply_receipt(rec)
            self._sanitize_boundary({stream})


# ---------------------------------------------------------------------------
# Named device configurations (paper Table III)
# ---------------------------------------------------------------------------

class PlainDevice(TierStore):
    """CXL-Plain: word-major, no compression, full-container fetch.

    The named designs default their ``link_model`` overhead to the
    calibrated controller pipeline (``LinkModel.for_design`` — Table V's
    71/84/89-cycle load-to-use anchors); pass ``link_model`` explicitly
    to override with a constant.
    """

    name = "plain"

    def __init__(self, codec: str = "lz4", **kw):
        kw.setdefault("link_model", LinkModel.for_design("plain"))
        super().__init__(layout=WordLayout(compress=False), codec=codec, **kw)


class GCompDevice(TierStore):
    """CXL-GComp: word-major + generic inline 4 KB block compression."""

    name = "gcomp"

    def __init__(self, codec: str = "lz4", **kw):
        kw.setdefault("link_model", LinkModel.for_design("gcomp"))
        super().__init__(layout=WordLayout(compress=True), codec=codec, **kw)


class TraceDevice(TierStore):
    """TRACE: bit-plane substrate + KV transform + plane-aligned fetch."""

    name = "trace"

    def __init__(self, codec: str = "lz4", **kw):
        kw.setdefault("link_model", LinkModel.for_design("trace"))
        super().__init__(layout=BitplaneLayout(kv_transform=True),
                         codec=codec, **kw)


# Compatibility alias: the old common base class.
BaseDevice = TierStore

DEVICE_KINDS = {"plain": PlainDevice, "gcomp": GCompDevice, "trace": TraceDevice}


def make_device(kind: str, shards: Optional[int] = None,
                placement: Optional[str] = None, **kw) -> TierStore:
    """Build a named device — or a fleet of them.

    ``shards`` > 1 returns a :class:`repro.core.sharding.ShardedTierStore`
    over ``shards`` inner devices of this kind (same protocol, so every
    consumer works unchanged).  ``shards=None`` defers to the
    ``TRACE_SHARDS`` env var (the sharded CI suite runs the whole fast
    suite at ``TRACE_SHARDS=4``); pass ``shards=1`` to pin a single
    device regardless — tests that assert single-queue latency shapes do.
    ``placement`` names a policy in ``repro.core.sharding.PLACEMENTS``
    (ignored for a single device).
    """
    if shards is None:
        raw = os.environ.get("TRACE_SHARDS", "").strip()
        shards = int(raw) if raw else 1
    if shards > 1:
        from .sharding import ShardedTierStore
        return ShardedTierStore(shards, kind=kind,
                                placement=placement or "hash-stripe", **kw)
    return DEVICE_KINDS[kind](**kw)
