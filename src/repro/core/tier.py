"""CXL Type-3 device models — Plain / GComp / TRACE (paper Table III).

These are functional + traffic models of the device-internal pipeline.
All three expose the same host-visible semantics (byte-exact tensors per
view); they differ only in the device-internal representation and hence in
the bytes stored in device DRAM and moved per access — exactly the paper's
correctness invariant (§III-D).

On TPU systems the "CXL tier" maps to host DRAM behind PCIe used for KV /
weight offload; the device model therefore doubles as the offload-tier
backend of the serving runtime (runtime/serving.py).

Accounting conventions (per read):
  * ``dram_bytes``  — bytes the device DRAM actually serves (compressed
    planes for TRACE, compressed 4 KB blocks for GComp, raw words for
    Plain).  Plane-aligned fetch physically skips unfetched planes.
  * ``link_bytes``  — host-visible payload returned over CXL.mem (the
    reconstructed view; controller-side decompression per Fig. 11).
  * ``index_bytes`` — metadata traffic (64 B/entry on an index-cache miss).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from . import codec as codecs
from .bitplane import (
    BF16_BITS,
    BLOCK_ELEMS,
    iter_blocks,
    pack_planes,
    plane_bytes,
    unpack_planes,
)
from .kv_transform import KVBlockMeta, kv_inverse, kv_forward
from .precision import EXP_BITS, MAN_BITS, PrecisionView, FULL, reconstruct_u16

INDEX_ENTRY_BYTES = 64  # paper §III-D: one compact entry per 4 KB block


@dataclasses.dataclass
class DeviceStats:
    dram_bytes_stored: int = 0      # capacity footprint (compressed)
    dram_bytes_read: int = 0
    dram_bytes_written: int = 0
    link_bytes_out: int = 0
    link_bytes_in: int = 0
    index_bytes: int = 0
    index_hits: int = 0
    index_misses: int = 0
    blocks: int = 0
    raw_bytes_stored: int = 0       # logical (uncompressed) footprint

    def reset_traffic(self):
        self.dram_bytes_read = 0
        self.dram_bytes_written = 0
        self.link_bytes_out = 0
        self.link_bytes_in = 0
        self.index_bytes = 0
        self.index_hits = self.index_misses = 0

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes_stored / max(self.dram_bytes_stored, 1)


@dataclasses.dataclass
class _Block:
    """One 4 KB logical block in device DRAM."""

    payloads: List[bytes]            # per-plane (TRACE) or single (word)
    flags: List[int]                 # codec.RAW / codec.COMPRESSED
    valid_elems: int
    kv_meta: Optional[KVBlockMeta] = None

    @property
    def stored_bytes(self) -> int:
        return sum(len(p) for p in self.payloads)


class _IndexCache:
    """On-chip plane-index cache (paper Fig. 11, metadata management)."""

    def __init__(self, capacity_entries: int = 4096):
        self.capacity = capacity_entries
        self._lru: Dict[tuple, None] = {}

    def access(self, key: tuple) -> bool:
        hit = key in self._lru
        if hit:
            self._lru.pop(key)
        self._lru[key] = None
        if len(self._lru) > self.capacity:
            self._lru.pop(next(iter(self._lru)))
        return hit


class BaseDevice:
    """Common store / stats plumbing."""

    name = "base"

    def __init__(self, codec: str = "lz4", block_elems: int = BLOCK_ELEMS,
                 index_cache_entries: int = 4096):
        self.codec = codec
        self.block_elems = block_elems
        self.stats = DeviceStats()
        self._tensors: Dict[str, List[_Block]] = {}
        self._shapes: Dict[str, tuple] = {}
        self._index = _IndexCache(index_cache_entries)

    # -- helpers -------------------------------------------------------------
    def _commit(self, name: str, block: _Block):
        self._tensors.setdefault(name, []).append(block)
        self.stats.blocks += 1
        self.stats.dram_bytes_stored += block.stored_bytes
        self.stats.dram_bytes_written += block.stored_bytes
        self.stats.raw_bytes_stored += block.valid_elems * 2

    def _touch_index(self, name: str, i: int):
        if self._index.access((name, i)):
            self.stats.index_hits += 1
        else:
            self.stats.index_misses += 1
            self.stats.index_bytes += INDEX_ENTRY_BYTES
            self.stats.dram_bytes_read += INDEX_ENTRY_BYTES

    def footprint(self, name: str) -> int:
        return sum(b.stored_bytes for b in self._tensors[name])

    def logical_bytes(self, name: str) -> int:
        return sum(b.valid_elems for b in self._tensors[name]) * 2

    def delete(self, name: str):
        for b in self._tensors.pop(name, []):
            self.stats.dram_bytes_stored -= b.stored_bytes
            self.stats.raw_bytes_stored -= b.valid_elems * 2
            self.stats.blocks -= 1
        self._shapes.pop(name, None)


class PlainDevice(BaseDevice):
    """CXL-Plain: word-major, no compression, full-container fetch."""

    name = "plain"

    def write_tensor(self, name: str, u16: np.ndarray):
        self._shapes[name] = u16.shape
        self.stats.link_bytes_in += u16.size * 2
        for chunk, valid in iter_blocks(u16, self.block_elems):
            self._commit(name, _Block([chunk.tobytes()], [codecs.RAW], valid))

    # KV arrives token-major; a word device stores it verbatim.
    write_kv = write_tensor

    def read_tensor(self, name: str, view: PrecisionView = FULL) -> np.ndarray:
        """Always moves full containers; precision conversion is host-side."""
        out = []
        for i, b in enumerate(self._tensors[name]):
            self._touch_index(name, i)
            self.stats.dram_bytes_read += len(b.payloads[0])
            u16 = np.frombuffer(b.payloads[0], dtype=np.uint16)[: b.valid_elems]
            out.append(u16)
        flat = np.concatenate(out)
        self.stats.link_bytes_out += flat.size * 2
        flat = reconstruct_u16(flat, view) if not view.is_full else flat
        return flat.reshape(self._shapes[name])

    read_kv = read_tensor


class GCompDevice(PlainDevice):
    """CXL-GComp: word-major + generic inline 4 KB block compression."""

    name = "gcomp"

    def write_tensor(self, name: str, u16: np.ndarray):
        self._shapes[name] = u16.shape
        self.stats.link_bytes_in += u16.size * 2
        for chunk, valid in iter_blocks(u16, self.block_elems):
            payload, flag = codecs.compress_block(chunk.tobytes(), self.codec)
            self._commit(name, _Block([payload], [flag], valid))

    write_kv = write_tensor

    def read_tensor(self, name: str, view: PrecisionView = FULL) -> np.ndarray:
        out = []
        for i, b in enumerate(self._tensors[name]):
            self._touch_index(name, i)
            self.stats.dram_bytes_read += len(b.payloads[0])
            raw = codecs.decompress_block(
                b.payloads[0], b.flags[0], self.codec, self.block_elems * 2
            )
            u16 = np.frombuffer(raw, dtype=np.uint16)[: b.valid_elems]
            out.append(u16)
        flat = np.concatenate(out)
        self.stats.link_bytes_out += flat.size * 2
        flat = reconstruct_u16(flat, view) if not view.is_full else flat
        return flat.reshape(self._shapes[name])

    read_kv = read_tensor


class TraceDevice(BaseDevice):
    """TRACE: bit-plane substrate + KV transform + plane-aligned fetch."""

    name = "trace"

    def __init__(self, codec: str = "lz4", block_elems: int = BLOCK_ELEMS,
                 index_cache_entries: int = 4096, kv_window: int = 64):
        super().__init__(codec, block_elems, index_cache_entries)
        self.kv_window = kv_window
        self._kv_staging: Dict[str, list] = {}   # stream → [token rows]
        self._kv_channels: Dict[str, int] = {}

    # -- weights: direct bit-plane encoding (paper §III-B) -------------------
    def write_tensor(self, name: str, u16: np.ndarray):
        self._shapes[name] = u16.shape
        self.stats.link_bytes_in += u16.size * 2
        for chunk, valid in iter_blocks(u16, self.block_elems):
            planes = pack_planes(chunk)
            payloads, flags = [], []
            for p in range(BF16_BITS):
                pay, fl = codecs.compress_block(planes[p].tobytes(), self.codec)
                payloads.append(pay)
                flags.append(fl)
            self._commit(name, _Block(payloads, flags, valid))

    # -- KV write path: staging buffer → transform → planes (Fig. 8) ---------
    def write_kv(self, stream: str, tokens_u16: np.ndarray):
        """Append token-major rows ``(t, C)`` to a KV stream."""
        if tokens_u16.ndim == 1:
            tokens_u16 = tokens_u16[None, :]
        C = tokens_u16.shape[1]
        self._kv_channels[stream] = C
        buf = self._kv_staging.setdefault(stream, [])
        self.stats.link_bytes_in += tokens_u16.size * 2
        for row in tokens_u16:
            buf.append(row)
            if len(buf) >= self.kv_window:
                self._commit_kv_window(stream)

    def flush_kv(self, stream: str):
        if self._kv_staging.get(stream):
            self._commit_kv_window(stream)

    def _commit_kv_window(self, stream: str):
        buf = self._kv_staging[stream]
        block = np.stack(buf, axis=0)
        buf.clear()  # in place — write_kv holds a reference to this list
        transformed, meta = kv_forward(block)
        # pad to byte multiple for plane packing
        n = transformed.size
        if n % 8:
            transformed = np.pad(transformed, (0, 8 - n % 8))
        planes = pack_planes(transformed)
        payloads, flags = [], []
        for p in range(BF16_BITS):
            pay, fl = codecs.compress_block(planes[p].tobytes(), self.codec)
            payloads.append(pay)
            flags.append(fl)
        blk = _Block(payloads, flags, n, kv_meta=meta)
        self._commit(stream, blk)

    # -- reads: plane-aligned fetch + reconstruction (Eq. 6-8) ---------------
    def _fetch_planes(self, name: str, i: int, b: _Block,
                      plane_set: tuple) -> np.ndarray:
        self._touch_index(name, i)
        nbytes = plane_bytes(((b.valid_elems + 7) // 8) * 8)
        planes = np.zeros((BF16_BITS, nbytes), dtype=np.uint8)
        for p in plane_set:
            self.stats.dram_bytes_read += len(b.payloads[p])
            raw = codecs.decompress_block(b.payloads[p], b.flags[p], self.codec, nbytes)
            planes[p] = np.frombuffer(raw, dtype=np.uint8)
        return planes

    def read_tensor(self, name: str, view: PrecisionView = FULL) -> np.ndarray:
        out = []
        for i, b in enumerate(self._tensors[name]):
            planes = self._fetch_planes(name, i, b, view.fetched_planes())
            u16 = unpack_planes(planes, b.valid_elems)
            out.append(reconstruct_u16(u16, view))
        flat = np.concatenate(out)
        self.stats.link_bytes_out += flat.size * view.bits // 8
        return flat.reshape(self._shapes.get(name, flat.shape))

    def read_kv(self, stream: str, view: PrecisionView = FULL) -> np.ndarray:
        """Return token-major KV.  Exponent planes hold zigzag deltas, so KV
        views always fetch all 8 exponent planes (they compress best) and
        scale mantissa planes only (see precision.py note)."""
        if view.r_e != EXP_BITS:
            raise ValueError("KV views must keep the full (delta) exponent")
        self.flush_kv(stream)
        rows = []
        for i, b in enumerate(self._tensors.get(stream, [])):
            planes = self._fetch_planes(stream, i, b, view.fetched_planes())
            stream_u16 = unpack_planes(planes, b.valid_elems)
            meta = b.kv_meta
            n_real = meta.n_tokens * meta.n_channels
            # Invert the exponent-delta FIRST: guard-bit rounding may carry
            # from mantissa into the exponent, which is only meaningful in
            # the real-exponent domain (not the zigzag-delta domain).
            token_major = kv_inverse(stream_u16[:n_real], meta)
            rows.append(reconstruct_u16(token_major, view))
        out = np.concatenate(rows, axis=0)
        self.stats.link_bytes_out += out.size * view.bits // 8
        return out


DEVICE_KINDS = {"plain": PlainDevice, "gcomp": GCompDevice, "trace": TraceDevice}


def make_device(kind: str, **kw) -> BaseDevice:
    return DEVICE_KINDS[kind](**kw)
