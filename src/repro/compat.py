"""Version shims for the installed jax.

The repo targets current jax but must import (and run its CPU tests) on
older releases: ``shard_map`` moved from ``jax.experimental`` to the top
level, and its replication-check kwarg was renamed ``check_rep`` →
``check_vma`` along the way.
"""

from __future__ import annotations

try:  # jax ≥ 0.6
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the replication-check kwarg of either era."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
