"""Fault-tolerant checkpointing: sharded-npz pytrees + atomic manifests.

Design goals (what a 1000-node deployment needs, scaled to this container):

* **Atomicity**: a checkpoint directory is written under a temp name and
  ``os.rename``'d into place; the ``manifest.json`` is the commit record.
  A crash mid-save never corrupts the latest restorable step.
* **Mesh-independence (elastic restart)**: arrays are saved *unsharded
  logical values* (gathered per leaf); restore re-applies whatever sharding
  the new mesh dictates.  Shardings are derived from logical axes at load
  time, never stored — so restoring 256→512 chips (or onto CPU) just works.
  For 100B+ states a production system would write per-shard files keyed by
  logical slices (same manifest schema; swap the serializer).
* **Async save**: ``save(..., blocking=False)`` snapshots device arrays to
  host (cheap) then serializes on a worker thread, keeping the train loop
  running — the standard overlap trick.
* **Retention**: keeps the newest ``keep`` checkpoints, always preserving
  the oldest fully-committed one.

The train state layout is ``{"params": ..., "opt": ..., "data_step": int,
"error_feedback": ...}``; the manager is agnostic (any pytree of arrays).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def save_pytree(tree, directory: str):
    """Serialize one pytree to ``directory`` (npz shards + treedef)."""
    os.makedirs(directory, exist_ok=True)
    arrays = {}
    meta = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jax.numpy.bfloat16:
            meta[key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr
    np.savez(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "dtypes.json"), "w") as f:
        json.dump(meta, f)


def restore_pytree(template, directory: str, shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for device placement on the *current* mesh."""
    import ml_dtypes

    with np.load(os.path.join(directory, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    with open(os.path.join(directory, "dtypes.json")) as f:
        meta = json.load(f)
    for k, d in meta.items():
        if d == "bfloat16":
            data[k] = data[k].view(ml_dtypes.bfloat16)

    keys = [k for k, _ in _flatten_with_paths(template)]
    missing = [k for k in keys if k not in data]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    leaves = [data[k] for k in keys]
    treedef = jax.tree_util.tree_structure(template)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings
        )
    return restored


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._worker: Optional[threading.Thread] = None

    # -- discovery -----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.root, name, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True, extra: dict | None = None):
        """Snapshot to host immediately; serialize (a)synchronously."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp_")
            try:
                save_pytree(host_tree, tmp)
                manifest = {"step": step, **(extra or {})}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                final = self._dir(step)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self._gc()

        self.wait()
        if blocking:
            work()
        else:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, template, step: Optional[int] = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        tree = restore_pytree(template, self._dir(step), shardings)
        with open(os.path.join(self._dir(step), "manifest.json")) as f:
            manifest = json.load(f)
        return tree, manifest
